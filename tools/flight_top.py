"""flight-top: a terminal view of a live Flight server or cluster head.

Scrapes the Flight-native telemetry actions — ``server-stats`` (JSON) and
``cluster-metrics`` / ``server-metrics`` (Arrow record batches) — and renders
the numbers an operator reaches for first: per-verb call counts and
p50/p95/p99 latency, error breakdowns by wire code, event-loop health
(queue-wait, dispatch latency, worker queue depth, backpressure stalls, fd
counts) and per-shard serving rates.

One-shot (print once and exit)::

    PYTHONPATH=src python tools/flight_top.py tcp://127.0.0.1:8815

Watch mode (redraw every N seconds; rates are deltas between scrapes)::

    PYTHONPATH=src python tools/flight_top.py tcp://127.0.0.1:8815 --watch 2

``--selftest`` spins an in-process TCP cluster, sends traced traffic, takes
two scrapes and renders them — the CI docs job runs this so the tool can
never rot apart from the scrape schema it reads.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.core.flight import (  # noqa: E402
    Action,
    FlightClient,
    batch_to_rows,
    decode_telemetry_batch,
)


def scrape(client: FlightClient) -> dict:
    """One snapshot: metrics rows (cluster-wide when the target is a head,
    single-server otherwise) + the head's own server-stats JSON."""
    try:
        body = client.do_action(Action("cluster-metrics"))[0].body
    except Exception:
        body = client.do_action(Action("server-metrics"))[0].body
    rows = batch_to_rows(decode_telemetry_batch(body))
    stats = json.loads(client.do_action("server-stats")[0].body)
    return {"t": time.time(), "rows": rows, "stats": stats}


def _ms(s: float) -> str:
    return f"{s * 1e3:8.2f}"


def _by(rows: list[dict], scope: str) -> list[dict]:
    return [r for r in rows if r["scope"] == scope]


def render(snap: dict, prev: dict | None = None) -> str:
    rows, stats = snap["rows"], snap["stats"]
    io = stats.get("io") or {}
    dt = (snap["t"] - prev["t"]) if prev else 0.0
    lines: list[str] = []
    epoch = next((r["epoch"] for r in rows if r.get("epoch", -1) >= 0), None)
    head = "flight-top"
    if epoch is not None:
        head += f"  epoch={epoch}"
    head += (f"  fds={io.get('open_fds', '?')}"
             f"  conns={io.get('open_connections', '?')}"
             f"  queue={io.get('worker_queue_depth', '?')}"
             f"  stall_s={io.get('stall_seconds', 0)}"
             f"  io_errors={io.get('handler_errors', 0)}")
    lines.append(head)

    lines.append("")
    lines.append(f"{'shard':>5} {'verb':<24} {'calls':>8} {'p50 ms':>8} "
                 f"{'p95 ms':>8} {'p99 ms':>8}")
    for r in sorted(_by(rows, "verb") + _by(rows, "exchange"),
                    key=lambda r: (r.get("shard", -1), r["name"])):
        sh = r.get("shard", -1)
        lines.append(f"{('head' if sh < 0 else sh):>5} {r['name']:<24} "
                     f"{r['count']:>8} {_ms(r['p50_s'])} {_ms(r['p95_s'])} "
                     f"{_ms(r['p99_s'])}")

    serve = _by(rows, "serve")
    if serve:
        lines.append("")
        lines.append(f"{'shard':>5} {'rows served':>12} {'rows/s':>10}")
        prev_serve = {(" ", r.get("shard", -1)): r["count"]
                      for r in _by(prev["rows"], "serve")} if prev else {}
        for r in sorted(serve, key=lambda r: r.get("shard", -1)):
            sh = r.get("shard", -1)
            rate = ""
            if prev and dt > 0:
                rate = f"{(r['count'] - prev_serve.get((' ', sh), 0)) / dt:10.0f}"
            lines.append(f"{('head' if sh < 0 else sh):>5} "
                         f"{r['count']:>12} {rate:>10}")

    errs = _by(rows, "errors")
    if errs:
        lines.append("")
        lines.append(f"{'shard':>5} {'verb:code':<32} {'count':>8}")
        for r in sorted(errs, key=lambda r: (r.get("shard", -1), r["name"])):
            sh = r.get("shard", -1)
            lines.append(f"{('head' if sh < 0 else sh):>5} {r['name']:<32} "
                         f"{r['count']:>8}")

    ios = _by(rows, "io")
    if ios:
        lines.append("")
        lines.append(f"{'shard':>5} {'event loop':<24} {'n':>8} {'p50':>10} "
                     f"{'p99':>10}")
        for r in sorted(ios, key=lambda r: (r.get("shard", -1), r["name"])):
            sh = r.get("shard", -1)
            if r["name"] == "worker_queue_depth":  # depth buckets, not seconds
                p50, p99 = f"{r['p50_s']:10.0f}", f"{r['p99_s']:10.0f}"
            else:
                p50 = f"{r['p50_s'] * 1e6:8.0f}us"
                p99 = f"{r['p99_s'] * 1e6:8.0f}us"
            lines.append(f"{('head' if sh < 0 else sh):>5} {r['name']:<24} "
                         f"{r['count']:>8} {p50} {p99}")
    return "\n".join(lines)


def selftest() -> int:
    """Spin a 2-shard cluster over TCP, run traced reads, render two scrapes."""
    import numpy as np

    from repro.core import RecordBatch
    from repro.core.flight import (FlightClusterClient, FlightClusterServer,
                                   Tracer)

    cluster = FlightClusterServer(num_shards=2)
    cluster.serve_tcp()
    try:
        cluster.add_dataset("t", [
            RecordBatch.from_numpy(
                {"k": np.arange(i * 100, (i + 1) * 100, dtype=np.int64)})
            for i in range(4)])
        uri = f"tcp://127.0.0.1:{cluster.port}"
        cli = FlightClusterClient(uri)
        tracer = Tracer()
        with tracer.trace("flight-top-selftest"):
            table, _ = cli.read("t")
        assert table.num_rows == 400
        head = FlightClient(uri)
        first = scrape(head)
        with tracer.trace("flight-top-selftest-2"):
            cli.read("t")
        second = scrape(head)
        out = render(second, prev=first)
        print(out)
        assert "DoGet" in out and "rows served" in out
        # both shards' DoGet rows must be present in the cluster scrape
        shards = {r.get("shard") for r in second["rows"]
                  if r["scope"] == "verb" and r["name"] == "DoGet"}
        assert {0, 1} <= shards, shards
        print("\nflight_top selftest: ok")
        return 0
    finally:
        cluster.shutdown()


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("uri", nargs="?", help="tcp://host:port of a server or head")
    ap.add_argument("--watch", type=float, default=0.0, metavar="SECONDS",
                    help="redraw every N seconds (0 = one-shot)")
    ap.add_argument("--selftest", action="store_true",
                    help="spin an in-process cluster, scrape it, exit")
    args = ap.parse_args()
    if args.selftest:
        return selftest()
    if not args.uri:
        ap.error("uri required (or --selftest)")
    client = FlightClient(args.uri)
    prev = None
    while True:
        snap = scrape(client)
        out = render(snap, prev=prev)
        if args.watch:
            print("\x1b[2J\x1b[H" + out, flush=True)
        else:
            print(out)
            return 0
        prev = snap
        time.sleep(args.watch)


if __name__ == "__main__":
    raise SystemExit(main())

"""Docs gate: the code in README/docs must run, not just read well.

Executes every fenced ``python`` block in README.md and docs/*.md — blocks
within one file share a namespace and run in order, so a quickstart can
build on earlier snippets — then smoke-runs the example scripts a reader
would try first.  Any exception (or a failing ``assert`` inside a snippet)
fails the build with the file and block number.

Fences tagged anything other than ``python`` (``bash``, ``text``, ``json``)
are ignored.  A block whose info string is ``python no-run`` is skipped —
use sparingly, for snippets that genuinely cannot run in CI.

Run from the repo root::

    python tools/check_docs.py
"""
from __future__ import annotations

import re
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
SRC = ROOT / "src"

DOC_FILES = [ROOT / "README.md", *sorted((ROOT / "docs").glob("*.md"))]
EXAMPLES = [
    ROOT / "examples" / "cluster_quickstart.py",
    ROOT / "examples" / "query_cluster.py",
    ROOT / "examples" / "microservice_pipeline.py",
]

_FENCE = re.compile(r"^```(\w+[^\n]*)\n(.*?)^```\s*$", re.MULTILINE | re.DOTALL)


def python_blocks(text: str) -> list[str]:
    out = []
    for m in _FENCE.finditer(text):
        info, body = m.group(1).strip(), m.group(2)
        if info == "python":
            out.append(body)
    return out


def run_doc(path: Path) -> int:
    blocks = python_blocks(path.read_text())
    if not blocks:
        print(f"  {path.relative_to(ROOT)}: no python blocks")
        return 0
    ns: dict = {"__name__": f"doc:{path.name}"}
    for i, block in enumerate(blocks, 1):
        try:
            exec(compile(block, f"{path.name}[block {i}]", "exec"), ns)
        except Exception as e:
            print(f"FAIL {path.relative_to(ROOT)} block {i}: "
                  f"{type(e).__name__}: {e}", file=sys.stderr)
            return 1
    print(f"  {path.relative_to(ROOT)}: {len(blocks)} block(s) ran clean")
    return 0


def run_example(path: Path) -> int:
    import os

    env = dict(os.environ)
    env["PYTHONPATH"] = f"{SRC}{os.pathsep}{env['PYTHONPATH']}" \
        if env.get("PYTHONPATH") else str(SRC)
    proc = subprocess.run([sys.executable, str(path)], env=env, cwd=ROOT,
                          capture_output=True, text=True, timeout=300)
    if proc.returncode != 0:
        print(f"FAIL {path.relative_to(ROOT)} (exit {proc.returncode}):\n"
              f"{proc.stderr[-2000:]}", file=sys.stderr)
        return 1
    print(f"  {path.relative_to(ROOT)}: ran clean")
    return 0


def main() -> int:
    if str(SRC) not in sys.path:
        sys.path.insert(0, str(SRC))
    rc = 0
    print("executing fenced python blocks:")
    for doc in DOC_FILES:
        rc |= run_doc(doc)
    print("smoke-running examples:")
    for ex in EXAMPLES:
        rc |= run_example(ex)
    if rc == 0:
        print("docs OK: every snippet and example runs")
    return rc


if __name__ == "__main__":
    raise SystemExit(main())

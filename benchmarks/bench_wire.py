"""Wire data plane: batch size × metadata codec × coalescing over TCP DoGet.

The paper's claim is that Flight reaches wire speed because serialization is
engineered away; the Analytical-DBMS formats study (PAPERS.md) shows
*metadata handling* dominates at small batch sizes.  This suite measures
that regime directly on the loopback TCP transport:

* ``seed``      — the pre-PR data plane: JSON batch metadata, one sendmsg
                  per frame, re-encode on every DoGet (cache off).
* ``binary``    — binary struct metadata alone (no coalescing, no cache).
* ``bin+cache`` — binary metadata + encode-once cache.
* ``full``      — binary metadata + cache + coalesced sendmsg: the shipped
                  default configuration.

Reported per config × batch size: seconds, MB/s and msgs/s (data frames per
second — the small-batch figure of merit).  ``full`` rows also carry
``speedup_msgs_vs_seed`` and ``encode_calls_timed`` (must stay 0: a cached
DoGet performs zero encode_batch calls).  ``run.py`` emits BENCH_wire.json;
``check_wire_regression.py`` gates CI on the normalized msgs/s.

Two caveats when reading the numbers:

* the ``seed`` config reproduces the pre-PR *send/encode* path only — the
  receive-side improvements (buffered header+meta reads, pooled bodies) are
  transparent connection properties shared by every config, so in-run
  ``seed`` is faster than the true pre-PR plane (measured on the prior
  commit: ~2.5k msgs/s at 1 KiB and ~750 MB/s at 1 MiB on this container,
  vs ~4k msgs/s / ~1.1 GB/s for in-run ``seed``).
* at ≥1 MiB batches every config degenerates to one sendmsg per frame
  (frames exceed the coalescing budget) and loopback memcpy dominates, so
  the configs are syscall-identical there and differences are scheduler
  noise; the interesting signal at 1 MiB is MB/s versus the previous
  commit's BENCH_wire.json, not config-vs-config.
"""
from __future__ import annotations

import json
import time

from repro.core.flight import FlightClient, FlightDescriptor, InMemoryFlightServer

from .common import Timing, records_batch

RECORD_BYTES = 32  # the paper's fixed-width record microbenchmark shape

CONFIGS = (
    # (label, wire_codec, coalesce, cache_encoded)
    ("seed", "json", False, False),
    ("binary", "binary", False, False),
    ("bin+cache", "binary", False, True),
    ("full", "binary", True, True),
)


def _best_of(fn, repeats: int = 3):
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _server_stats(client: FlightClient) -> dict:
    return json.loads(client.do_action("server-stats")[0].body)


def run(quick: bool = True) -> list[Timing]:
    out: list[Timing] = []
    # batch payload sizes; ≤4 KiB is the metadata/syscall-bound regime the
    # tentpole targets, 1 MiB checks the bulk path kept its throughput
    batch_bytes = (1 << 10, 4 << 10, 64 << 10, 1 << 20)
    for size in batch_bytes:
        rows = max(1, size // RECORD_BYTES)
        n_batches = 16 if size >= (1 << 20) else (64 if size >= (64 << 10) else 256)
        if not quick:
            n_batches *= 4
        batches = [records_batch(rows, seed=s) for s in range(n_batches)]
        nbytes = sum(b.nbytes() for b in batches)
        seed_msgs_s = None
        for label, codec, coalesce, cache in CONFIGS:
            srv = InMemoryFlightServer(
                batches_per_endpoint=0, wire_codec=codec, coalesce=coalesce,
                cache_encoded=cache,
            ).serve_tcp()
            try:
                srv.add_dataset("w", batches)
                client = FlightClient(f"tcp://127.0.0.1:{srv.port}")
                ticket = client.get_flight_info(
                    FlightDescriptor.for_path("w")).endpoints[0].ticket

                def fetch():
                    n = sum(1 for _ in client.do_get(ticket))
                    assert n == n_batches

                fetch()  # warm connections (and the encode cache when on)
                encode_before = _server_stats(client)["encode_calls"]
                secs = _best_of(fetch, repeats=2 if size >= (1 << 20) else 3)
                encode_timed = _server_stats(client)["encode_calls"] - encode_before
                msgs_s = n_batches / secs
                if label == "seed":
                    seed_msgs_s = msgs_s
                extra = {
                    "config": label, "codec": codec, "coalesce": coalesce,
                    "cache": cache, "batch_bytes": size, "n_batches": n_batches,
                    "msgs_per_s": round(msgs_s, 1),
                    "encode_calls_timed": encode_timed,
                }
                if seed_msgs_s and label != "seed":
                    extra["speedup_msgs_vs_seed"] = round(msgs_s / seed_msgs_s, 2)
                out.append(Timing(f"wire_doget_tcp_{label}_b{size}", secs, nbytes, extra=extra))
            finally:
                srv.shutdown()
    return out


if __name__ == "__main__":
    from .common import emit_bench_json

    timings = run()
    for t in timings:
        print(t.csv() + (f" {t.extra}" if t.extra else ""))
    print(f"# wrote {emit_bench_json('wire', timings)}")

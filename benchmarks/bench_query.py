"""Fig 8/9: query-to-client time — ODBC vs turbodbc vs Flight columnar,
plus typed-command pushdown vs full-scan+client-filter over loopback TCP.

Two experiments, both recorded to ``BENCH_query.json`` by run.py:

* **protocol sims** — NYC-taxi-like table (ints/floats + datetime strings,
  faithfully painful for row protocols), single select query, varying result
  set size.  Reproduces the paper's 20×/30× turbodbc/ODBC gaps.
* **pushdown vs full scan** — the same predicated+projected ``QueryPlan``
  against a 4-shard ``FlightClusterServer`` over real loopback TCP, executed
  (a) shard-side via ``GetFlightInfo(QueryCommand)`` per-shard endpoints
  and (b) as a full parallel scan with client-side filtering.  Pushdown
  ships only surviving columns/rows, so the wire-bytes ratio is the win.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import RecordBatch
from repro.core.flight import FlightClusterClient, FlightClusterServer
from repro.query import QueryPlan, aggregate, col, execute
from repro.query.odbc_sim import FlightColumnarProtocol, OdbcProtocol, TurbodbcProtocol

from .common import Timing, taxi_batch


def _protocol_sims(quick: bool) -> list[Timing]:
    out: list[Timing] = []
    row_counts = [100_000, 400_000] if quick else [100_000, 1_000_000, 4_000_000]
    plan = QueryPlan("taxi",
                     projection=["fare_amount", "trip_distance", "pickup_datetime"],
                     predicate=col("trip_distance") > 1.0)

    for n in row_counts:
        batches = [taxi_batch(n // 4, seed=s) for s in range(4)]
        for proto in (OdbcProtocol(), TurbodbcProtocol(), FlightColumnarProtocol()):
            # ODBC on >100k python-object rows is minutes; cap its input
            use = batches if proto.name != "odbc" else [b.slice(0, min(25_000, b.num_rows))
                                                        for b in batches]
            scale = n / sum(b.num_rows for b in use)
            _, st = proto.transfer(plan, use)
            out.append(Timing(f"fig8_{proto.name}_{n}rows", st.total_s * scale,
                              int(st.wire_bytes * scale),
                              extra={"ser_s": st.serialize_s * scale,
                                     "de_s": st.deserialize_s * scale}))
    # headline ratios at the largest size
    last = {t.name.split("_")[1]: t.seconds for t in out[-3:]}
    if "odbc" in last and "flight" in last:
        out.append(Timing("fig8_speedup_flight_vs_odbc", last["odbc"] / last["flight"] / 1e6, 0,
                          extra={"x": last["odbc"] / last["flight"]}))
        out.append(Timing("fig8_speedup_flight_vs_turbodbc",
                          last["turbodbc"] / last["flight"] / 1e6, 0,
                          extra={"x": last["turbodbc"] / last["flight"]}))
    return out


def _pushdown_vs_fullscan(quick: bool) -> list[Timing]:
    rows = 50_000 if quick else 250_000
    n_batches, n_shards = 8, 4
    batches = [taxi_batch(rows // n_batches, seed=s, with_strings=False)
               for s in range(n_batches)]
    plan = QueryPlan("taxi", projection=["fare_amount", "trip_distance"],
                     predicate=col("trip_distance") > 3.0)
    cluster = FlightClusterServer(num_shards=n_shards).serve_tcp()
    out: list[Timing] = []
    try:
        cluster.add_dataset("taxi", batches)
        cc = FlightClusterClient(f"tcp://127.0.0.1:{cluster.port}",
                                 max_streams=n_shards)
        # warm both paths (connection setup, encode-once cache build)
        cc.query(plan)
        cc.read("taxi")

        best_push, push_stats = float("inf"), None
        best_scan, scan_rows = float("inf"), 0
        for _ in range(3):
            table, st = cc.query(plan)
            if st.seconds < best_push:
                best_push, push_stats = st.seconds, (table.num_rows, st.bytes)
            import time as _time
            t0 = _time.perf_counter()
            full, fst = cc.read("taxi")
            filtered = list(execute(plan, full.batches))
            dt = _time.perf_counter() - t0
            if dt < best_scan:
                best_scan, scan_rows = dt, sum(b.num_rows for b in filtered)
                scan_bytes = fst.bytes
        assert push_stats[0] == scan_rows, "pushdown and client filter disagree"
        out.append(Timing(f"pushdown_{n_shards}shard_{rows}rows", best_push,
                          push_stats[1], extra={"rows_out": push_stats[0]}))
        out.append(Timing(f"fullscan_clientfilter_{n_shards}shard_{rows}rows",
                          best_scan, scan_bytes, extra={"rows_out": scan_rows}))
        out.append(Timing("pushdown_speedup_vs_fullscan", best_scan / best_push / 1e6, 0,
                          extra={"x": best_scan / best_push,
                                 "wire_bytes_ratio": scan_bytes / max(push_stats[1], 1)}))
    finally:
        cluster.shutdown()
    return out


def _groupby_partial_vs_shipall(quick: bool) -> list[Timing]:
    """Grouped aggregation sweep: shard-side partial states vs shipping every
    surviving row and aggregating client-side.

    Each shard folds its slice into one per-group state batch (``sum+count``
    pairs for means, running extrema), so the wire carries group-sized state
    instead of row-sized data.  Swept over group cardinality: the low-card
    ratio is the headline (state is thousands of times smaller than the
    rows); high cardinality shrinks the win and is exactly the regime the
    hash-shuffle path exists for."""
    rows = 50_000 if quick else 250_000
    n_batches, n_shards = 8, 4
    aggs = [("mean", "fare_amount"), ("sum", "total_amount"),
            ("min", "trip_distance"), ("max", "trip_distance"),
            ("count", "fare_amount")]
    out: list[Timing] = []
    cluster = FlightClusterServer(num_shards=n_shards).serve_tcp()
    try:
        rng = np.random.default_rng(7)
        batches = []
        for s in range(n_batches):
            d = taxi_batch(rows // n_batches, seed=s, with_strings=False).to_pydict()
            # high-cardinality synthetic key alongside passenger_count (6 groups)
            d["ride_id"] = rng.integers(0, rows // 50, rows // n_batches).astype(np.int64)
            batches.append(RecordBatch.from_pydict(d))
        cluster.add_dataset("taxi_g", batches)
        cc = FlightClusterClient(f"tcp://127.0.0.1:{cluster.port}",
                                 max_streams=n_shards)
        for key, card in (("passenger_count", 6), ("ride_id", rows // 50)):
            plan = QueryPlan("taxi_g", aggregations=aggs, group_by=[key])
            ship = QueryPlan("taxi_g", projection=plan.required_columns(
                [f.name for f in batches[0].schema.fields]))
            cc.aggregate(plan)  # warm connections + encode-once cache
            best_part = best_ship = float("inf")
            part_bytes = ship_bytes = 0
            for _ in range(3):
                grouped, st = cc.aggregate(plan)
                if st.seconds < best_part:
                    best_part, part_bytes = st.seconds, st.bytes
                t0 = time.perf_counter()
                table, fst = cc.query(ship)
                ref = aggregate(plan, table.batches)
                dt = time.perf_counter() - t0
                if dt < best_ship:
                    best_ship, ship_bytes = dt, fst.bytes
            assert grouped.num_rows == ref.num_rows, "partial merge disagrees"
            out.append(Timing(f"groupby_partial_{card}groups_{rows}rows",
                              best_part, part_bytes,
                              extra={"groups": grouped.num_rows}))
            out.append(Timing(f"groupby_shipall_{card}groups_{rows}rows",
                              best_ship, ship_bytes,
                              extra={"groups": ref.num_rows}))
            out.append(Timing(f"groupby_wire_ratio_{card}groups",
                              best_ship / best_part / 1e6, 0,
                              extra={"x": best_ship / best_part,
                                     "wire_bytes_ratio": ship_bytes / max(part_bytes, 1)}))
        # one shuffled equi-join for the trajectory record
        half = {"ride_id": np.arange(rows // 50, dtype=np.int64),
                "zone": rng.integers(0, 200, rows // 50).astype(np.int64)}
        cluster.add_dataset("zones", [RecordBatch.from_pydict(half)])
        t0 = time.perf_counter()
        joined, jst = cc.join("taxi_g", "zones", "ride_id", "taxi_zoned")
        out.append(Timing(f"shuffle_join_{rows}rows", time.perf_counter() - t0,
                          jst.bytes, extra={"rows_out": joined.num_rows}))
    finally:
        cluster.shutdown()
    return out


def run(quick: bool = True) -> list[Timing]:
    return (_protocol_sims(quick) + _pushdown_vs_fullscan(quick)
            + _groupby_partial_vs_shipall(quick))


if __name__ == "__main__":
    for t in run():
        print(t.csv(), t.extra or "")

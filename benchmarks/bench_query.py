"""Fig 8/9: query-to-client time — ODBC vs turbodbc vs Flight columnar.

NYC-taxi-like table (ints/floats + datetime strings, faithfully painful for
row protocols), single select query, varying result set size.  Reproduces
the paper's 20×/30× turbodbc/ODBC gaps.
"""
from __future__ import annotations

from repro.query import QueryPlan, col
from repro.query.odbc_sim import FlightColumnarProtocol, OdbcProtocol, TurbodbcProtocol

from .common import Timing, taxi_batch


def run(quick: bool = True) -> list[Timing]:
    out: list[Timing] = []
    row_counts = [100_000, 400_000] if quick else [100_000, 1_000_000, 4_000_000]
    plan = QueryPlan("taxi",
                     projection=["fare_amount", "trip_distance", "pickup_datetime"],
                     predicate=col("trip_distance") > 1.0)

    for n in row_counts:
        batches = [taxi_batch(n // 4, seed=s) for s in range(4)]
        for proto in (OdbcProtocol(), TurbodbcProtocol(), FlightColumnarProtocol()):
            # ODBC on >100k python-object rows is minutes; cap its input
            use = batches if proto.name != "odbc" else [b.slice(0, min(25_000, b.num_rows))
                                                        for b in batches]
            scale = n / sum(b.num_rows for b in use)
            _, st = proto.transfer(plan, use)
            out.append(Timing(f"fig8_{proto.name}_{n}rows", st.total_s * scale,
                              int(st.wire_bytes * scale),
                              extra={"ser_s": st.serialize_s * scale,
                                     "de_s": st.deserialize_s * scale}))
    # headline ratios at the largest size
    last = {t.name.split("_")[1]: t.seconds for t in out[-3:]}
    if "odbc" in last and "flight" in last:
        out.append(Timing("fig8_speedup_flight_vs_odbc", last["odbc"] / last["flight"] / 1e6, 0,
                          extra={"x": last["odbc"] / last["flight"]}))
        out.append(Timing("fig8_speedup_flight_vs_turbodbc",
                          last["turbodbc"] / last["flight"] / 1e6, 0,
                          extra={"x": last["turbodbc"] / last["flight"]}))
    return out


if __name__ == "__main__":
    for t in run():
        print(t.csv(), t.extra or "")

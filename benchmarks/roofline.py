"""Roofline table generator — reads experiments/artifacts/*.json into the
EXPERIMENTS.md §Roofline table and prints a console summary.

Per (arch × shape × mesh): the three terms (compute/memory/collective
seconds), dominant bottleneck, MODEL_FLOPS/HLO_FLOPS useful ratio, and a
one-line "what would move the dominant term".
"""
from __future__ import annotations

import json
from pathlib import Path

ARTIFACTS = Path(__file__).resolve().parents[1] / "experiments" / "artifacts"

_ADVICE = {
    "compute_s": "at the compute roofline -- only model/precision changes help",
    "memory_s": "cut activation traffic: fewer saved residuals, fused ops, bf16 stacks",
    "collective_s": "cut wire bytes: reshard (less TP for small models), quantized collectives, overlap",
}


def load_records(mesh: str | None = None) -> list[dict]:
    out = []
    for f in sorted(ARTIFACTS.glob("*.json")):
        r = json.loads(f.read_text())
        if mesh and r.get("mesh") != mesh:
            continue
        out.append(r)
    return out


def fmt_row(r: dict) -> str:
    if r.get("status") == "skipped":
        return f"| {r['arch']} | {r['shape']} | — | — | — | — | skipped: {r.get('reason','')[:40]} | — |"
    if r.get("status") != "ok":
        return f"| {r['arch']} | {r['shape']} | — | — | — | — | {r.get('status')} | — |"
    t = r["roofline"]
    dom = t["dominant"].replace("_s", "")
    useful = r.get("useful_flops_ratio")
    frac = t.get("roofline_fraction_vs_compute")
    return (f"| {r['arch']} | {r['shape']} | {t['compute_s']:.3f} | {t['memory_s']:.3f} | "
            f"{t['collective_s']:.3f} | **{dom}** | {useful:.2f} | {frac:.2%} |")


def table(mesh: str = "pod_16x16") -> str:
    rows = [
        f"### Roofline — {mesh} (per-device terms, seconds/step)",
        "",
        "| arch | shape | compute s | memory s | collective s | bottleneck | useful FLOPs ratio | roofline fraction |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in load_records(mesh):
        rows.append(fmt_row(r))
    return "\n".join(rows)


def worst_cells(mesh: str = "pod_16x16", k: int = 6) -> list[tuple]:
    recs = [r for r in load_records(mesh) if r.get("status") == "ok"]
    scored = []
    for r in recs:
        t = r["roofline"]
        frac = t.get("roofline_fraction_vs_compute") or 0.0
        scored.append((frac, r["arch"], r["shape"], t["dominant"]))
    return sorted(scored)[:k]


def main() -> None:
    print(table("pod_16x16"))
    print()
    print("worst roofline fractions (hillclimb candidates):")
    for frac, arch, shape, dom in worst_cells():
        print(f"  {frac:7.2%}  {arch} × {shape}  ({dom})")


if __name__ == "__main__":
    main()

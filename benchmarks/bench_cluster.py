"""Shard-scaling: aggregate DoGet/DoPut throughput × shard count × batch size.

Reproduces the paper's cores-vs-throughput curve (§3, Fig 2: parallel streams
up to ~half the system cores keep adding bandwidth) over the cluster layer:

* ``inproc`` — shards serve through ``netsim.paced_stream`` at the modeled
  per-stream Flight-over-IB rate.  Pacing sleeps release the GIL, so the
  measured aggregate over N parallel shard streams shows the real scaling
  shape this container's core count cannot produce from loopback CPU work.
* ``tcp`` — unpaced loopback sockets, measured as-is (saturates immediately
  on a small-core box; recorded for the trajectory anyway).

The DoPut side is swept twice per shard count: plain parallel writes and
**transactional** writes (stage fan-out + the head's prepare→commit round).
Each transactional timing records ``pct_of_plain`` — the acceptance bar is
that atomic visibility costs ≤20% of plain parallel DoPut throughput.

``run.py`` emits the timings to BENCH_cluster.json so the shard-scaling
trajectory is recorded per-commit (see docs/benchmarks.md for the schema).
"""
from __future__ import annotations

import statistics
import time

from repro.core.flight import FlightClusterClient, FlightClusterServer, InMemoryFlightServer
from repro.core.flight.netsim import FLIGHT_O_IB_GET, paced_stream

from .common import Timing, records_batch


class PacedShardServer(InMemoryFlightServer):
    """Shard whose DoGet streams at the modeled per-stream wire rate."""

    link = FLIGHT_O_IB_GET

    def do_get_impl(self, ticket):
        schema, batches = super().do_get_impl(ticket)
        return schema, paced_stream(batches, self.link)


def _paced_factory(i: int, loc_name: str) -> PacedShardServer:
    # one endpoint (= one stream) per shard: the paper's topology, and the
    # thing under test — shard count alone sets the parallelism
    return PacedShardServer(location_name=loc_name, batches_per_endpoint=0, shard_id=i)


def _best_of(fn, repeats: int = 3):
    best, out = float("inf"), None
    for _ in range(repeats):
        t0 = time.perf_counter()
        r = fn()
        dt = time.perf_counter() - t0
        if dt < best:
            best, out = dt, r
    return best, out


def run(quick: bool = True) -> list[Timing]:
    out: list[Timing] = []
    shard_counts = (1, 2, 4) if quick else (1, 2, 4, 8)
    # paper shape: fixed-width 32 B records; sweep records-per-batch
    batch_rows = (20_000, 80_000) if quick else (20_000, 80_000, 320_000)
    n_batches = 8

    for rows in batch_rows:
        batches = [records_batch(rows, seed=s) for s in range(n_batches)]
        nbytes = sum(b.nbytes() for b in batches)

        base_inproc = None
        for n in shard_counts:
            # -- in-proc, wire-paced shards: the shard-scaling curve -------- #
            cl = FlightClusterServer(num_shards=n, shard_factory=_paced_factory)
            cl.add_dataset("bench", batches)
            cc = FlightClusterClient(cl, max_streams=max(shard_counts))
            secs, table = _best_of(lambda: cc.read("bench")[0])
            assert table.num_rows == rows * n_batches
            if n == 1:
                base_inproc = secs
            out.append(Timing(
                f"cluster_doget_inproc_shards{n}_rows{rows}", secs, nbytes,
                extra={"shards": n, "transport": "inproc", "batch_rows": rows,
                       "speedup_vs_1shard": round(base_inproc / secs, 2)}))

            # -- sharded parallel DoPut (reference-move, unpaced) ----------- #
            # each repeat writes a fresh dataset: re-writing the same name
            # with identical bytes would hit the shard dedup guard and time
            # a no-op instead of a write
            seq = iter(range(100))
            wsecs, _ = _best_of(lambda: cc.write(f"up{next(seq)}", batches))
            out.append(Timing(
                f"cluster_doput_inproc_shards{n}_rows{rows}", wsecs, nbytes,
                extra={"shards": n, "transport": "inproc", "batch_rows": rows}))

            # -- transactional DoPut: stage fan-out + head 2PC commit ------- #
            # same parallel shard streams, plus the prepare→commit round;
            # the paper's Fig 5 write-throughput story with atomicity on.
            # pct_of_plain is the acceptance metric (target ≥ 80%).
            txsecs, _ = _best_of(
                lambda: cc.write(f"uptx{next(seq)}", batches, transactional=True))
            out.append(Timing(
                f"cluster_doput_txn_inproc_shards{n}_rows{rows}", txsecs, nbytes,
                extra={"shards": n, "transport": "inproc", "batch_rows": rows,
                       "transactional": True,
                       "pct_of_plain": round(100 * wsecs / txsecs, 1)}))

        # -- TCP loopback, measured (unpaced) ------------------------------- #
        for n in shard_counts:
            cl = FlightClusterServer(num_shards=n).serve_tcp()
            try:
                cl.add_dataset("bench", batches)
                cc = FlightClusterClient(
                    f"tcp://127.0.0.1:{cl.port}", max_streams=max(shard_counts))
                secs, table = _best_of(lambda: cc.read("bench")[0])
                assert table.num_rows == rows * n_batches
                out.append(Timing(
                    f"cluster_doget_tcp_shards{n}_rows{rows}", secs, nbytes,
                    extra={"shards": n, "transport": "tcp", "batch_rows": rows}))
                # plain vs transactional DoPut over real sockets: the stage
                # leg streams the same bytes; the commit round adds one
                # head action (prepare+commit fan-out is in-proc at the head)
                seq = iter(range(100))
                wsecs, _ = _best_of(lambda: cc.write(f"up{next(seq)}", batches))
                out.append(Timing(
                    f"cluster_doput_tcp_shards{n}_rows{rows}", wsecs, nbytes,
                    extra={"shards": n, "transport": "tcp", "batch_rows": rows}))
                txsecs, _ = _best_of(
                    lambda: cc.write(f"uptx{next(seq)}", batches,
                                     transactional=True))
                out.append(Timing(
                    f"cluster_doput_txn_tcp_shards{n}_rows{rows}", txsecs, nbytes,
                    extra={"shards": n, "transport": "tcp", "batch_rows": rows,
                           "transactional": True,
                           "pct_of_plain": round(100 * wsecs / txsecs, 1)}))
            finally:
                cl.shutdown()

    # the transactional acceptance metric, robust to per-config scheduler
    # noise on loaded containers: the median pct_of_plain across the sweep
    # (individual configs wobble ±30% between runs; the median sits at
    # parity because the stage leg streams the same bytes as a plain write)
    txn_pcts = sorted(t.extra["pct_of_plain"] for t in out
                      if t.extra and t.extra.get("transactional"))
    if txn_pcts:
        out.append(Timing(
            "cluster_doput_txn_summary", 0.0, 0,
            extra={"median_pct_of_plain": round(statistics.median(txn_pcts), 1),
                   "min_pct_of_plain": txn_pcts[0],
                   "max_pct_of_plain": txn_pcts[-1],
                   "configs": len(txn_pcts),
                   "acceptance_floor_pct": 80}))

    # modeled endpoint-parallel bulk curve for reference (paper Fig 6 regime)
    payload = 8 * 320_000 * 32
    from repro.core.flight.netsim import FLIGHT_O_IB_BULK
    for n in (1, 2, 4, 8, 16):
        t = FLIGHT_O_IB_BULK.transfer_seconds(payload, n)
        out.append(Timing(f"cluster_model_bulk_ib_shards{n}", t, payload,
                          extra={"shards": n, "transport": "model"}))
    return out


if __name__ == "__main__":
    from .common import emit_bench_json

    timings = run()
    for t in timings:
        print(t.csv() + (f" {t.extra}" if t.extra else ""))
    print(f"# wrote {emit_bench_json('cluster', timings)}")

"""Shard-scaling: aggregate DoGet/DoPut throughput × shard count × batch size.

Reproduces the paper's cores-vs-throughput curve (§3, Fig 2: parallel streams
up to ~half the system cores keep adding bandwidth) over the cluster layer:

* ``inproc`` — shards serve through ``netsim.paced_stream`` at the modeled
  per-stream Flight-over-IB rate.  Pacing sleeps release the GIL, so the
  measured aggregate over N parallel shard streams shows the real scaling
  shape this container's core count cannot produce from loopback CPU work.
* ``tcp`` — unpaced loopback sockets, measured as-is (saturates immediately
  on a small-core box; recorded for the trajectory anyway).

``run.py`` emits the timings to BENCH_cluster.json so the shard-scaling
trajectory is recorded per-commit.
"""
from __future__ import annotations

import time

from repro.core.flight import FlightClusterClient, FlightClusterServer, InMemoryFlightServer
from repro.core.flight.netsim import FLIGHT_O_IB_GET, paced_stream

from .common import Timing, records_batch


class PacedShardServer(InMemoryFlightServer):
    """Shard whose DoGet streams at the modeled per-stream wire rate."""

    link = FLIGHT_O_IB_GET

    def do_get_impl(self, ticket):
        schema, batches = super().do_get_impl(ticket)
        return schema, paced_stream(batches, self.link)


def _paced_factory(i: int, loc_name: str) -> PacedShardServer:
    # one endpoint (= one stream) per shard: the paper's topology, and the
    # thing under test — shard count alone sets the parallelism
    return PacedShardServer(location_name=loc_name, batches_per_endpoint=0, shard_id=i)


def _best_of(fn, repeats: int = 3):
    best, out = float("inf"), None
    for _ in range(repeats):
        t0 = time.perf_counter()
        r = fn()
        dt = time.perf_counter() - t0
        if dt < best:
            best, out = dt, r
    return best, out


def run(quick: bool = True) -> list[Timing]:
    out: list[Timing] = []
    shard_counts = (1, 2, 4) if quick else (1, 2, 4, 8)
    # paper shape: fixed-width 32 B records; sweep records-per-batch
    batch_rows = (20_000, 80_000) if quick else (20_000, 80_000, 320_000)
    n_batches = 8

    for rows in batch_rows:
        batches = [records_batch(rows, seed=s) for s in range(n_batches)]
        nbytes = sum(b.nbytes() for b in batches)

        base_inproc = None
        for n in shard_counts:
            # -- in-proc, wire-paced shards: the shard-scaling curve -------- #
            cl = FlightClusterServer(num_shards=n, shard_factory=_paced_factory)
            cl.add_dataset("bench", batches)
            cc = FlightClusterClient(cl, max_streams=max(shard_counts))
            secs, table = _best_of(lambda: cc.read("bench")[0])
            assert table.num_rows == rows * n_batches
            if n == 1:
                base_inproc = secs
            out.append(Timing(
                f"cluster_doget_inproc_shards{n}_rows{rows}", secs, nbytes,
                extra={"shards": n, "transport": "inproc", "batch_rows": rows,
                       "speedup_vs_1shard": round(base_inproc / secs, 2)}))

            # -- sharded parallel DoPut (reference-move, unpaced) ----------- #
            wsecs, _ = _best_of(lambda: cc.write("up", batches), repeats=1)
            out.append(Timing(
                f"cluster_doput_inproc_shards{n}_rows{rows}", wsecs, nbytes,
                extra={"shards": n, "transport": "inproc", "batch_rows": rows}))

        # -- TCP loopback, measured (unpaced) ------------------------------- #
        for n in shard_counts:
            cl = FlightClusterServer(num_shards=n).serve_tcp()
            try:
                cl.add_dataset("bench", batches)
                cc = FlightClusterClient(
                    f"tcp://127.0.0.1:{cl.port}", max_streams=max(shard_counts))
                secs, table = _best_of(lambda: cc.read("bench")[0])
                assert table.num_rows == rows * n_batches
                out.append(Timing(
                    f"cluster_doget_tcp_shards{n}_rows{rows}", secs, nbytes,
                    extra={"shards": n, "transport": "tcp", "batch_rows": rows}))
            finally:
                cl.shutdown()

    # modeled endpoint-parallel bulk curve for reference (paper Fig 6 regime)
    payload = 8 * 320_000 * 32
    from repro.core.flight.netsim import FLIGHT_O_IB_BULK
    for n in (1, 2, 4, 8, 16):
        t = FLIGHT_O_IB_BULK.transfer_seconds(payload, n)
        out.append(Timing(f"cluster_model_bulk_ib_shards{n}", t, payload,
                          extra={"shards": n, "transport": "model"}))
    return out


if __name__ == "__main__":
    from .common import emit_bench_json

    timings = run()
    for t in timings:
        print(t.csv() + (f" {t.extra}" if t.extra else ""))
    print(f"# wrote {emit_bench_json('cluster', timings)}")

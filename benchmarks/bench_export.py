"""Fig 4 (DB-X export): export throughput vs % frozen (pre-materialized) blocks.

The paper's C6: when blocks are already columnar ("frozen"), Flight export
moves at wire speed; blocks needing row→column materialization drop it to
vectorized-protocol speed.  We store a table as N blocks, a fraction frozen
(RecordBatch) and the rest hot (python row tuples needing materialization),
and export over in-proc Flight; memcpy is the RDMA-analogue ceiling.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import RecordBatch, batch_from_rows, write_stream

from .common import Timing, records_batch


def run(quick: bool = True) -> list[Timing]:
    out: list[Timing] = []
    n_blocks = 24
    rows_per_block = 20_000 if quick else 60_000
    frozen_template = records_batch(rows_per_block, seed=1)
    hot_rows = frozen_template.to_rows()  # row-major (the OLTP working set)
    schema = frozen_template.schema
    nbytes_block = frozen_template.nbytes()

    for pct in (0, 25, 50, 75, 100):
        n_frozen = n_blocks * pct // 100
        t0 = time.perf_counter()
        total = 0
        for i in range(n_blocks):
            if i < n_frozen:
                block = frozen_template           # zero-copy export path
            else:
                block = batch_from_rows(schema, hot_rows)  # materialize row->col
            total += len(write_stream([block]))   # serialize to the wire
        dt = time.perf_counter() - t0
        out.append(Timing(f"fig4_export_frozen{pct}pct", dt, total))

    # memcpy ceiling (RDMA analogue)
    payload = np.frombuffer(write_stream([frozen_template]) * 4, dtype=np.uint8)
    dst = np.empty_like(payload)
    t0 = time.perf_counter()
    np.copyto(dst, payload)
    out.append(Timing("fig4_rdma_analogue_memcpy", time.perf_counter() - t0,
                      payload.nbytes))
    return out


if __name__ == "__main__":
    for t in run():
        print(t.csv())

"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV per the assignment.  ``--full`` runs
the paper-scale sizes (slower); default is CPU-quick.
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default="")
    args, _ = ap.parse_known_args()
    quick = not args.full

    from . import (bench_cluster, bench_concurrency, bench_endpoints,
                   bench_exchange, bench_export, bench_fault, bench_kernels,
                   bench_protocols, bench_query, bench_serde, bench_storage,
                   bench_telemetry, bench_transfer, bench_wire)
    from .common import emit_bench_json
    suites = {
        "transfer": bench_transfer,    # Fig 2/3
        "export": bench_export,        # Fig 4
        "protocols": bench_protocols,  # Fig 5/6
        "query": bench_query,          # Fig 8/9
        "endpoints": bench_endpoints,  # Fig 10
        "cluster": bench_cluster,      # shard scaling (Fig 2 over N servers)
        "wire": bench_wire,            # data plane: codec × coalescing × size
        "exchange": bench_exchange,    # Fig 11: streaming DoExchange microservices
        "storage": bench_storage,      # provider plane: disk vs memory DoGet
        "concurrency": bench_concurrency,  # C10k: event loop vs thread/conn
        "fault": bench_fault,          # kill-a-shard-mid-read recovery sweep
        "telemetry": bench_telemetry,  # observability overhead: off/metrics/full
        "serde": bench_serde,          # §1 claim
        "kernels": bench_kernels,      # ours
    }
    # recorded to BENCH_<name>.json
    json_suites = {"cluster", "wire", "query", "exchange", "storage",
                   "concurrency", "fault", "telemetry"}
    only = set(args.only.split(",")) if args.only else None

    print("name,us_per_call,derived")
    failures = 0
    for name, mod in suites.items():
        if only and name not in only:
            continue
        try:
            timings = list(mod.run(quick=quick))
            for t in timings:
                extra = f" {t.extra}" if t.extra else ""
                print(t.csv() + extra, flush=True)
            if name in json_suites:
                print(f"# wrote {emit_bench_json(name, timings)}", file=sys.stderr)
        except Exception as e:
            failures += 1
            print(f"{name},ERROR,{type(e).__name__}: {e}", flush=True)
            traceback.print_exc(file=sys.stderr)

    # roofline summary from the dry-run artifacts
    try:
        from .roofline import load_records
        recs = [r for r in load_records("pod_16x16") if r.get("status") == "ok"]
        for r in recs:
            t = r["roofline"]
            print(f"roofline_{r['arch']}__{r['shape']},"
                  f"{t['step_time_lower_bound_s']*1e6:.0f},"
                  f"dom={t['dominant']};frac={t['roofline_fraction_vs_compute']:.3f}")
    except Exception as e:
        print(f"roofline,ERROR,{e}")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()

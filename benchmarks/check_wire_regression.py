"""CI gate: fail when wire-plane msgs/s regresses >30% vs the committed baseline.

Raw msgs/s scales with runner hardware, so by default the guard compares
**normalized** msgs/s: each non-seed config's msgs/s divided by the same-run
``seed`` config's msgs/s at the same batch size (the seed config reproduces
the pre-binary-metadata data plane, so the ratio isolates the optimization
and cancels machine speed).  A normalized value below ``(1 - tolerance)`` of
the committed ``benchmarks/wire_baseline.json`` fails the build.

The default tolerance is 0.30: normalized ratios are a quotient of two
noisy measurements, and a 20% floor tripped on random configs on loaded
containers even at unmodified commits (see docs/benchmarks.md, "Tolerance:
why 30%").  The regressions this gate exists for — losing the encode
cache, the binary codec silently falling back to JSON, broken coalescing —
show up as 2x+ normalized drops and still fail comfortably.

``--absolute`` compares raw msgs/s instead — useful for same-machine
trajectories, too flaky across heterogeneous CI runners.

A config that lands below the floor gets **one retry**: the wire suite is
re-run in-process and the config passes if either run clears the floor.
A noise spike (CI neighbour stealing the core mid-window) is a one-off,
so best-of-two absorbs it; a real regression — lost encode cache, codec
fallback — reproduces and fails both runs.  ``--retries 0`` disables.

Refresh the baseline after an intentional perf change::

    PYTHONPATH=src python -m benchmarks.run --only wire
    PYTHONPATH=src python -m benchmarks.check_wire_regression --update
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

BASELINE = Path(__file__).parent / "wire_baseline.json"
TOLERANCE = 0.30  # see docs/benchmarks.md for the derivation


def load_results(path: Path) -> dict[tuple[str, int], float]:
    """(config, batch_bytes) -> msgs_per_s from a BENCH_wire.json."""
    payload = json.loads(path.read_text())
    out: dict[tuple[str, int], float] = {}
    for r in payload["results"]:
        extra = r.get("extra", {})
        if "config" in extra and "msgs_per_s" in extra:
            out[(extra["config"], extra["batch_bytes"])] = extra["msgs_per_s"]
    return out


def results_from_timings(timings) -> dict[tuple[str, int], float]:
    """Same shape as ``load_results``, from an in-process suite run."""
    out: dict[tuple[str, int], float] = {}
    for t in timings:
        extra = getattr(t, "extra", None) or {}
        if "config" in extra and "msgs_per_s" in extra:
            out[(extra["config"], extra["batch_bytes"])] = extra["msgs_per_s"]
    return out


def normalize(results: dict[tuple[str, int], float]) -> dict[str, float]:
    """msgs/s of each config relative to the same-size seed config."""
    out: dict[str, float] = {}
    for (config, size), msgs in results.items():
        if config == "seed":
            continue
        seed = results.get(("seed", size))
        if seed:
            out[f"{config}_b{size}"] = round(msgs / seed, 3)
    return out


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("bench", nargs="?", default="BENCH_wire.json",
                    help="BENCH_wire.json produced by benchmarks.run")
    ap.add_argument("--absolute", action="store_true",
                    help="compare raw msgs/s instead of seed-normalized")
    ap.add_argument("--tolerance", type=float, default=TOLERANCE)
    ap.add_argument("--retries", type=int, default=1,
                    help="re-run the wire suite this many times for configs "
                         "below the floor; best run wins (0 disables)")
    ap.add_argument("--full", action="store_true",
                    help="retry runs use paper-scale sizes (match the run "
                         "that produced the bench file)")
    ap.add_argument("--update", action="store_true",
                    help="rewrite the committed baseline from this run")
    args = ap.parse_args()

    results = load_results(Path(args.bench))
    if not results:
        print(f"no wire results in {args.bench}", file=sys.stderr)
        return 2
    current = {
        "normalized": normalize(results),
        "absolute": {f"{c}_b{s}": m for (c, s), m in results.items()},
    }
    if args.update:
        BASELINE.write_text(json.dumps(current, indent=2, sort_keys=True) + "\n")
        print(f"baseline updated: {BASELINE}")
        return 0
    if not BASELINE.exists():
        print(f"no baseline at {BASELINE}; run with --update to create one",
              file=sys.stderr)
        return 2

    baseline = json.loads(BASELINE.read_text())
    mode = "absolute" if args.absolute else "normalized"
    old, new = baseline[mode], current[mode]
    below: list[tuple[str, float, float | None, float]] = []
    for key, prev in sorted(old.items()):
        got = new.get(key)
        floor = prev * (1 - args.tolerance)
        if got is None:
            print(f"{key}: missing vs baseline {prev:.3f}")
            below.append((key, prev, None, floor))
            continue
        status = "FAIL" if got < floor else "ok"
        print(f"{key}: {got:.3f} vs baseline {prev:.3f} (floor {floor:.3f}) {status}")
        if got < floor:
            below.append((key, prev, got, floor))

    for attempt in range(args.retries if below else 0):
        print(f"\n{len(below)} config(s) below floor — re-running the wire "
              f"suite (retry {attempt + 1}/{args.retries}); a noise spike "
              "won't reproduce, a real regression will", file=sys.stderr)
        from .bench_wire import run as run_wire
        rerun = results_from_timings(run_wire(quick=not args.full))
        retried = (normalize(rerun) if mode == "normalized"
                   else {f"{c}_b{s}": m for (c, s), m in rerun.items()})
        still = []
        for key, prev, got, floor in below:
            again = retried.get(key)
            best = max((v for v in (got, again) if v is not None), default=None)
            if best is None or best < floor:
                still.append((key, prev, best, floor))
            else:
                print(f"{key}: recovered on retry "
                      f"({again:.3f} >= floor {floor:.3f})")
        below = still
        if not below:
            break

    failures = [
        (f"{key}: missing (baseline {prev:.3f})" if got is None else
         f"{key}: {got:.3f} < {floor:.3f} (-{args.tolerance:.0%} of {prev:.3f})")
        for key, prev, got, floor in below
    ]
    if failures:
        print("\nwire msgs/s regression:", *failures, sep="\n  ", file=sys.stderr)
        return 1
    print(f"\nall {len(old)} wire {mode} msgs/s within {args.tolerance:.0%} of baseline")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

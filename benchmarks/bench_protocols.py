"""Fig 5/6: protocol comparison across payload sizes.

Measured on loopback: Flight framing vs raw TCP (same socket, no framing)
vs memcpy (the RDMA-analogue zero-protocol ceiling).  Modeled: the paper's
TCP-o-IB / RDMA-o-IB / Flight-o-IB at 56 Gbit/s via netsim.
"""
from __future__ import annotations

import socket
import threading
import time

import numpy as np

from repro.core import RecordBatch, read_stream, write_stream
from repro.core.flight import FlightClient, FlightDescriptor, InMemoryFlightServer
from repro.core.flight.netsim import FLIGHT_O_IB_BULK, RDMA_O_IB, TCP_O_IB

from .common import Timing, timeit


def _raw_tcp_roundtrip(payload: bytes) -> float:
    """One-way raw TCP send of payload on loopback (no protocol)."""
    srv = socket.socket()
    srv.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
    srv.bind(("127.0.0.1", 0))
    srv.listen(1)
    port = srv.getsockname()[1]
    received = threading.Event()

    def sink():
        conn, _ = srv.accept()
        got = 0
        buf = bytearray(1 << 20)
        while got < len(payload):
            n = conn.recv_into(buf)
            if not n:
                break
            got += n
        conn.close()
        received.set()

    t = threading.Thread(target=sink, daemon=True)
    t.start()
    cli = socket.create_connection(("127.0.0.1", port))
    t0 = time.perf_counter()
    cli.sendall(payload)
    received.wait()
    dt = time.perf_counter() - t0
    cli.close()
    srv.close()
    return dt


def run(quick: bool = True) -> list[Timing]:
    out: list[Timing] = []
    sizes = [1 << 10, 1 << 16, 1 << 20, 1 << 24] + ([] if quick else [1 << 27])

    for size in sizes:
        n_rows = max(size // 32, 8)
        batch = RecordBatch.from_numpy({
            f"f{i}": np.arange(n_rows, dtype=np.int64) for i in range(4)})
        nbytes = batch.nbytes()

        # measured: Flight over loopback TCP
        srv = InMemoryFlightServer().serve_tcp()
        srv.add_dataset("p", [batch])
        client = FlightClient(f"tcp://127.0.0.1:{srv.port}")
        info = client.get_flight_info(FlightDescriptor.for_path("p"))

        def flight_get():
            list(client.do_get(info.endpoints[0].ticket))

        dt = timeit(flight_get, repeats=3)
        out.append(Timing(f"fig6_flight_loopback_{size}B", dt, nbytes))
        srv.shutdown()

        # measured: raw TCP (no framing, no columnar) — protocol floor
        payload = write_stream([batch])
        dt = _raw_tcp_roundtrip(payload)
        out.append(Timing(f"fig6_rawtcp_loopback_{size}B", dt, len(payload)))

        # measured: memcpy ceiling (RDMA analogue on one host)
        src = np.frombuffer(payload, dtype=np.uint8)
        dst = np.empty_like(src)
        dt = timeit(lambda: np.copyto(dst, src), repeats=3)
        out.append(Timing(f"fig6_memcpy_ceiling_{size}B", dt, len(payload)))

    # modeled 56 Gbit/s IB curves at the paper's sizes
    for size in (256, 1 << 10, 1 << 20, 1 << 28, int(2.6e9)):
        for link, name in ((FLIGHT_O_IB_BULK, "flight"), (TCP_O_IB, "tcp"),
                           (RDMA_O_IB, "rdma")):
            t = link.transfer_seconds(size, 1)
            out.append(Timing(f"fig6_model_{name}_ib_{size}B", t, size))
    # the paper's headline ratio: Flight/RDMA at >=2.6 GB
    f = FLIGHT_O_IB_BULK.throughput(int(2.6e9))
    r = RDMA_O_IB.throughput(int(2.6e9))
    out.append(Timing("fig6_model_flight_vs_rdma_2.6GB", r / f / 1e6, 0,
                      extra={"ratio": f / r}))
    return out


if __name__ == "__main__":
    for t in run():
        extra = f" {t.extra}" if t.extra else ""
        print(t.csv() + extra)

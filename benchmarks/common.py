"""Shared helpers for the benchmark harness."""
from __future__ import annotations

import json
import time
from dataclasses import dataclass
from pathlib import Path

import numpy as np

from repro.core import RecordBatch


def taxi_batch(n: int, seed: int = 0, with_strings: bool = True) -> RecordBatch:
    """NYC-taxi-like rows: ints, floats and (faithfully) datetime strings."""
    rng = np.random.default_rng(seed)
    cols = {
        "vendor_id": rng.integers(1, 3, n).astype(np.int32),
        "passenger_count": rng.integers(1, 7, n).astype(np.int32),
        "trip_distance": rng.gamma(2.0, 1.5, n).astype(np.float32),
        "fare_amount": rng.gamma(3.0, 5.0, n).astype(np.float64),
        "tip_amount": rng.gamma(1.0, 2.0, n).astype(np.float64),
        "total_amount": rng.gamma(4.0, 5.0, n).astype(np.float64),
    }
    batch = RecordBatch.from_numpy(cols)
    if with_strings:
        base = np.datetime64("2015-01-01T00:00:00")
        secs = rng.integers(0, 365 * 24 * 3600, n)
        strs = [(str(base + np.timedelta64(int(s), "s"))) for s in secs]
        d = batch.to_pydict()
        d["pickup_datetime"] = strs
        batch = RecordBatch.from_pydict(d)
    return batch


def records_batch(n_records: int, record_bytes: int = 32, seed: int = 0) -> RecordBatch:
    """The paper's microbenchmark shape: fixed-width records (32 B each)."""
    rng = np.random.default_rng(seed)
    n_cols = record_bytes // 8
    return RecordBatch.from_numpy({
        f"f{i}": rng.integers(0, 1 << 40, n_records).astype(np.int64)
        for i in range(n_cols)
    })


@dataclass
class Timing:
    name: str
    seconds: float
    nbytes: int = 0
    extra: dict | None = None

    @property
    def mb_per_s(self) -> float:
        return self.nbytes / max(self.seconds, 1e-12) / 1e6

    def csv(self, derived: str = "") -> str:
        us = self.seconds * 1e6
        return f"{self.name},{us:.1f},{derived or f'{self.mb_per_s:.1f}MB/s'}"


def emit_bench_json(suite: str, timings: list[Timing], path: str | Path | None = None) -> Path:
    """Write ``BENCH_<suite>.json`` — the per-commit perf-trajectory record.

    CI uploads these as artifacts; diffing two commits' files shows where a
    suite's throughput moved."""
    path = Path(path) if path is not None else Path(f"BENCH_{suite}.json")
    payload = {
        "suite": suite,
        "results": [
            {
                "name": t.name,
                "seconds": t.seconds,
                "nbytes": t.nbytes,
                "mb_per_s": round(t.mb_per_s, 3),
                "extra": t.extra or {},
            }
            for t in timings
        ],
    }
    path.write_text(json.dumps(payload, indent=2) + "\n")
    return path


def timeit(fn, repeats: int = 3, warmup: int = 1):
    for _ in range(warmup):
        fn()
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best

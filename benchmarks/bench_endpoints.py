"""Fig 10 (Spark DataSource): serial vs parallel Flight endpoints as partitions.

N workers each DoGet one endpoint and run a non-trivial aggregation on their
partition (the paper's test does exactly this against Dremio).  Compared:
single serial stream vs `streams=N` parallel endpoints, and the JDBC-like
row-iterator baseline.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.flight import FlightClient, FlightDescriptor, InMemoryFlightServer

from .common import Timing, taxi_batch


def _analyze(batches) -> float:
    """Non-trivial per-partition computation (the 'Spark executor' work)."""
    acc = 0.0
    for b in batches:
        fare = b.column("fare_amount").to_numpy()
        dist = b.column("trip_distance").to_numpy()
        acc += float(np.sum(fare / np.maximum(dist, 0.1)) + np.std(fare))
    return acc


def run(quick: bool = True) -> list[Timing]:
    out: list[Timing] = []
    n_parts = 8
    rows = 100_000 if quick else 400_000
    batches = [taxi_batch(rows // n_parts, seed=s, with_strings=False)
               for s in range(n_parts)]
    nbytes = sum(b.nbytes() for b in batches)
    srv = InMemoryFlightServer(batches_per_endpoint=1).serve_tcp()
    srv.add_dataset("parts", batches)
    client = FlightClient(f"tcp://127.0.0.1:{srv.port}")
    info = client.get_flight_info(FlightDescriptor.for_path("parts"))

    # JDBC-like: serial, row-iterator materialization
    t0 = time.perf_counter()
    got = []
    for ep in info.endpoints:
        for b in client.do_get(ep.ticket):
            rows_ = b.to_rows()  # the row-at-a-time sin
            got.append(len(rows_))
    out.append(Timing("fig10_jdbc_like_serial_rows", time.perf_counter() - t0, nbytes))

    # serial flight (columnar, 1 stream)
    t0 = time.perf_counter()
    for ep in info.endpoints:
        _analyze(list(client.do_get(ep.ticket)))
    out.append(Timing("fig10_flight_serial", time.perf_counter() - t0, nbytes))

    # parallel flight (columnar, N streams + per-partition compute)
    from concurrent.futures import ThreadPoolExecutor
    t0 = time.perf_counter()

    def work(ep):
        return _analyze(list(client.do_get(ep.ticket)))

    with ThreadPoolExecutor(max_workers=n_parts) as pool:
        list(pool.map(work, info.endpoints))
    out.append(Timing("fig10_flight_parallel8", time.perf_counter() - t0, nbytes))
    srv.shutdown()
    return out


if __name__ == "__main__":
    for t in run():
        print(t.csv())

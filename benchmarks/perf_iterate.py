"""§Perf hillclimb driver: named experiments on the three chosen cells.

Each experiment = (cell, change) → lower + analyze → JSON in
experiments/perf/<name>.json.  EXPERIMENTS.md §Perf narrates the
hypothesis → change → before/after → verdict chain from these artifacts.

  PYTHONPATH=src python benchmarks/perf_iterate.py <experiment> [...]
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import json
import sys
import time
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]
PERF = REPO / "experiments" / "perf"


def run(name: str, arch: str, shape: str, **kw):
    from repro.launch.dryrun import analyze, lower_cell
    from repro.launch.mesh import make_production_mesh

    mesh = make_production_mesh()
    t0 = time.time()
    lowered, compiled, meta = lower_cell(arch, shape, mesh, **kw)
    rec = {"experiment": name, "arch": arch, "shape": shape,
           "change": {k: str(v) for k, v in kw.items()},
           **analyze(compiled, meta["cfg"], meta["shape"], mesh),
           "wall_s": round(time.time() - t0, 1)}
    PERF.mkdir(parents=True, exist_ok=True)
    (PERF / f"{name}.json").write_text(json.dumps(rec, indent=2, default=str))
    t = rec["roofline"]
    print(f"[{name}] compute={t['compute_s']:.3f}s memory={t['memory_s']:.3f}s "
          f"collective={t['collective_s']:.3f}s dominant={t['dominant']} "
          f"bound={t['step_time_lower_bound_s']:.3f}s useful={rec['useful_flops_ratio']}")
    return rec


EXPERIMENTS = {
    # -- cell A: deepseek decode_32k (worst-fraction, memory-bound) --------
    "A0_deepseek_decode_base": dict(arch="deepseek_coder_33b", shape="decode_32k"),
    # A1 happened in code: carry-based cache (vs ys-stacking) — rerun = after
    "A2_deepseek_decode_seqshard": dict(
        arch="deepseek_coder_33b", shape="decode_32k",
        # shard the KV cache on sequence over data (distributed flash-decode)
        # instead of sharding batch: per-chip KV reads drop 16x
        rules={"batch": None, "kv_seq": ("data",)},
        extra_cfg={"force_seq_sharded_decode": True},
    ),
    # -- cell B: xlstm train_4k (most collective-bound) ---------------------
    "B0_xlstm_train_base": dict(arch="xlstm_350m", shape="train_4k"),
    "B1_xlstm_train_dp_remap": dict(
        arch="xlstm_350m", shape="train_4k",
        # a 350M model has no business being TP=16: remap the model axis to
        # batch (pure DP over 256 chips); params stay FSDP over data
        rules={"batch": ("pod", "data", "model"), "ff": None, "inner": None,
               "heads": None, "kv_heads": None, "vocab": None},
    ),
    "B2_xlstm_train_dp_fsdp_both": dict(
        arch="xlstm_350m", shape="train_4k",
        # B1 + shard params over model too (FSDP over 256) to cut the
        # all-gather sizes per layer
        rules={"batch": ("pod", "data", "model"), "ff": None, "inner": None,
               "heads": None, "kv_heads": None, "vocab": None,
               "embed": ("data", "model")},
    ),
    "A3_deepseek_decode_fp8_cache": dict(
        arch="deepseek_coder_33b", shape="decode_32k",
        # the paper's wire-compression theme applied to the KV cache: fp8
        # storage halves the per-token cache reads (dequant on the fly)
        cache_dtype="float8_e4m3fn",
    ),
    "A4_deepseek_decode_fp8_seqshard": dict(
        arch="deepseek_coder_33b", shape="decode_32k",
        cache_dtype="float8_e4m3fn",
        rules={"batch": None, "kv_seq": ("data",)},
        extra_cfg={"force_seq_sharded_decode": True},
    ),
    "B3_xlstm_train_dp_bf16acc": dict(
        arch="xlstm_350m", shape="train_4k",
        rules={"batch": ("pod", "data", "model"), "ff": None, "inner": None,
               "heads": None, "kv_heads": None, "vocab": None},
        matmul_accum="bfloat16",
    ),
    # -- cell C: moonshot train_4k (MoE, paper-representative) --------------
    "C0_moonshot_train_base": dict(arch="moonshot_v1_16b_a3b", shape="train_4k"),
    "C1_moonshot_train_remat_dots": dict(
        arch="moonshot_v1_16b_a3b", shape="train_4k",
        remat_policy="dots",  # save dot outputs: no fwd recompute in bwd
    ),
    "C2_moonshot_train_bigger_microbatch": dict(
        arch="moonshot_v1_16b_a3b", shape="train_4k",
        # halve TP: model=16 -> experts sharded 16-way is fine, but FFN/heads
        # over 8 with data=32 — expressed via remapping batch over model too
        rules={"batch": ("pod", "data")},
    ),
    "C3_moonshot_train_bf16_accum": dict(
        arch="moonshot_v1_16b_a3b", shape="train_4k",
        # backward activation psums run on the pre-cast f32 partials; bf16
        # accumulation halves every TP/MoE collective's bytes
        matmul_accum="bfloat16",
    ),
    "C4_moonshot_train_bf16acc_dprouter": dict(
        arch="moonshot_v1_16b_a3b", shape="train_4k",
        matmul_accum="bfloat16",
        remat_policy="dots",
    ),
    # -- bonus cell D: jamba train_4k (largest memory term in the table) ----
    "D0_jamba_train": dict(arch="jamba_1_5_large_398b", shape="train_4k"),
    "D2_jamba_train_chunk128": dict(arch="jamba_1_5_large_398b", shape="train_4k",
                                    extra_cfg={"mamba_chunk": 128}),
    "D3_jamba_train_chunk32": dict(arch="jamba_1_5_large_398b", shape="train_4k",
                                   extra_cfg={"mamba_chunk": 32}),
    "D4_jamba_train_chunk16": dict(arch="jamba_1_5_large_398b", shape="train_4k",
                                   extra_cfg={"mamba_chunk": 16}),
    "D5_jamba_train_chunk8": dict(arch="jamba_1_5_large_398b", shape="train_4k",
                                  extra_cfg={"mamba_chunk": 8}),
}


def main():
    names = sys.argv[1:] or list(EXPERIMENTS)
    for n in names:
        if n not in EXPERIMENTS:
            print(f"unknown experiment {n!r}; have {list(EXPERIMENTS)}")
            continue
        try:
            run(n, **EXPERIMENTS[n])
        except Exception as e:
            import traceback
            print(f"[{n}] FAILED: {e}")
            traceback.print_exc()


if __name__ == "__main__":
    main()

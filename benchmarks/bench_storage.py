"""Storage provider plane: memory vs disk-cold vs disk-warm DoGet + recovery.

The provider split (core/flight/storage.py) claims the serving layer pays
for durability only where it must: a disk-backed dataset costs one
mmap+decode+encode pass on the *first* DoGet after a (re)start, after which
the encode-once cache serves the identical wire bytes a memory-backed
server would — so the steady-state read path is storage-agnostic.  This
suite measures that claim over loopback TCP:

* ``storage_memory``     — the baseline: DoGet against the historical
  in-memory store (warm encode cache);
* ``storage_disk_cold``  — a server *freshly constructed* on an existing
  disk root: the read pays catalog recovery, part-file mmap, zero-copy
  decode and the one-time encode;
* ``storage_disk_warm``  — the same server's steady state: every batch
  served from the encode-once cache, zero disk traffic
  (``warm_vs_memory`` on this row is the acceptance ratio — expect ~1x,
  flag > 2x);
* ``storage_recovery``   — server construction alone on a root holding the
  dataset plus a prepared staged txn: the restart-recovery cost of the
  durable 2PC plane (catalog listing + stage scan, no batch decode for
  the catalog itself).

``run.py`` emits ``BENCH_storage.json`` and CI uploads it.
"""
from __future__ import annotations

import shutil
import tempfile
import time

from repro.core.flight import (
    FlightClient,
    FlightDescriptor,
    InMemoryFlightServer,
    StagedPutCommand,
    Ticket,
)

from .common import Timing, records_batch

BATCH_BYTES = 64 << 10
RECORD_BYTES = 32


def _timed(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def _best_of(fn, repeats: int = 3) -> float:
    return min(_timed(fn) for _ in range(repeats))


def _drain(client: FlightClient, name: str) -> int:
    return sum(b.num_rows for b in client.do_get(Ticket.for_range(name, 0, -1)))


def run(quick: bool = True) -> list[Timing]:
    out: list[Timing] = []
    n_batches = 32 if quick else 128
    rows = BATCH_BYTES // RECORD_BYTES
    batches = [records_batch(rows, seed=s) for s in range(n_batches)]
    schema = batches[0].schema
    nbytes = sum(b.nbytes() for b in batches)
    total_rows = rows * n_batches
    root = tempfile.mkdtemp(prefix="bench_storage_")
    spec = f"disk:{root}/store"
    try:
        # -- memory baseline ------------------------------------------------ #
        srv = InMemoryFlightServer().serve_tcp()
        srv.add_dataset("ds", batches)
        c = FlightClient(f"tcp://127.0.0.1:{srv.port}")
        assert _drain(c, "ds") == total_rows  # warm the encode cache
        secs = _best_of(lambda: _drain(c, "ds"))
        mem_secs = secs
        out.append(Timing("storage_memory", secs, nbytes, extra={
            "backend": "memory", "n_batches": n_batches,
            "mbps": round(nbytes / secs / 1e6, 1)}))
        srv.shutdown()

        # -- disk: spill once, then measure a fresh server's cold read ------ #
        writer = InMemoryFlightServer(storage=spec)
        spill_secs = _timed(lambda: writer.add_dataset("ds", batches))
        # leave a prepared staged txn behind for the recovery row
        wclient = FlightClient(writer)
        w = wclient.do_put(FlightDescriptor.for_command(
            StagedPutCommand("staged-ds", "bench-txn", "stage")), schema)
        w.write_batches(batches[: max(1, n_batches // 8)])
        w.close()
        writer.shutdown()
        out.append(Timing("storage_disk_spill", spill_secs, nbytes, extra={
            "backend": "disk", "n_batches": n_batches,
            "mbps": round(nbytes / spill_secs / 1e6, 1)}))

        cold_srv: list[InMemoryFlightServer] = []

        def cold_read() -> None:
            s = InMemoryFlightServer(storage=spec).serve_tcp()
            cold_srv.append(s)
            n = _drain(FlightClient(f"tcp://127.0.0.1:{s.port}"), "ds")
            assert n == total_rows, n

        # cold is a one-shot cost per process: report each repeat's fresh
        # server, best-of like every other row (page cache stays warm —
        # this measures the software path, not the platter)
        cold_secs = float("inf")
        for _ in range(3):
            cold_secs = min(cold_secs, _timed(cold_read))
            cold_srv.pop().shutdown()

        out.append(Timing("storage_disk_cold", cold_secs, nbytes, extra={
            "backend": "disk", "n_batches": n_batches,
            "mbps": round(nbytes / cold_secs / 1e6, 1),
            "cold_vs_memory": round(cold_secs / mem_secs, 2)}))

        srv2 = InMemoryFlightServer(storage=spec).serve_tcp()
        c2 = FlightClient(f"tcp://127.0.0.1:{srv2.port}")
        assert _drain(c2, "ds") == total_rows  # pay the cold pass here
        warm_secs = _best_of(lambda: _drain(c2, "ds"))
        pstats = srv2.storage.stats()
        out.append(Timing("storage_disk_warm", warm_secs, nbytes, extra={
            "backend": "disk", "n_batches": n_batches,
            "mbps": round(nbytes / warm_secs / 1e6, 1),
            "warm_vs_memory": round(warm_secs / mem_secs, 2),
            "spills": pstats["spills"], "mmap_reads": pstats["mmap_reads"],
            "disk_bytes": pstats["disk_bytes"]}))
        srv2.shutdown()

        # -- restart recovery: construction on a populated root ------------- #
        rec_srv: list[InMemoryFlightServer] = []
        rec_secs = _best_of(lambda: rec_srv.append(InMemoryFlightServer(storage=spec)))
        recovered = rec_srv[-1]
        rstats = recovered.storage.stats()
        out.append(Timing("storage_recovery", rec_secs, 0, extra={
            "backend": "disk",
            "recovered_datasets": rstats["recovered_datasets"],
            "recovered_stages": rstats["recovered_stages"],
            "staged_txns": len(recovered._staged)}))
        for s in rec_srv:
            s.shutdown()
    finally:
        shutil.rmtree(root, ignore_errors=True)
    return out


if __name__ == "__main__":
    from .common import emit_bench_json

    timings = run()
    for t in timings:
        print(t.csv() + (f" {t.extra}" if t.extra else ""))
    print(f"# wrote {emit_bench_json('storage', timings)}")

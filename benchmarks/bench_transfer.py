"""Fig 2/3: DoPut/DoGet throughput × parallel streams × records-per-stream.

Measured: localhost loopback TCP + in-proc (this container).  Modeled: the
paper's IB client-server rates via netsim (labeled `model:`).  One CPU core
means measured stream-scaling saturates immediately — the netsim columns
carry the paper's curve shapes (EXPERIMENTS.md discusses both).
"""
from __future__ import annotations

import numpy as np

from repro.core.flight import FlightClient, FlightDescriptor, InMemoryFlightServer
from repro.core.flight.netsim import FLIGHT_O_IB_GET, FLIGHT_O_IB_PUT

from .common import Timing, records_batch


def run(quick: bool = True) -> list[Timing]:
    out: list[Timing] = []
    # paper: records of 32 B; 10-90 M records/stream.  CPU-scaled: 0.5-2 M.
    n_records = 500_000 if quick else 2_000_000
    batches = [records_batch(n_records // 8, seed=s) for s in range(8)]
    nbytes = sum(b.nbytes() for b in batches)

    srv = InMemoryFlightServer(batches_per_endpoint=1).serve_tcp()
    srv.add_dataset("bench", batches)
    stream_counts = (1, 2, 4) if quick else (1, 2, 4, 8, 16)

    for streams in stream_counts:
        # DoGet over TCP loopback
        client = FlightClient(f"tcp://127.0.0.1:{srv.port}")
        info = client.get_flight_info(FlightDescriptor.for_path("bench"))
        _, stats = client.read_all_parallel(info, max_streams=streams)
        out.append(Timing(f"fig2_doget_tcp_streams{streams}", stats.seconds, stats.bytes))
        # DoPut over TCP loopback
        stats = client.write_parallel(FlightDescriptor.for_path(f"up{streams}"),
                                      batches, max_streams=streams)
        out.append(Timing(f"fig2_doput_tcp_streams{streams}", stats.seconds, stats.bytes))

    # in-proc zero-copy reference (the shared-memory ceiling)
    c0 = FlightClient(srv)
    info = c0.get_flight_info(FlightDescriptor.for_path("bench"))
    _, stats = c0.read_all_parallel(info, max_streams=4)
    out.append(Timing("fig2_doget_inproc_zerocopy", stats.seconds, stats.bytes))
    srv.shutdown()

    # modeled IB client-server rates (paper Fig 3 endpoints)
    payload = 10_000_000 * 32  # 10M records × 32B, paper's smallest point
    for streams in (1, 2, 4, 8, 16):
        t = FLIGHT_O_IB_GET.transfer_seconds(payload, streams)
        out.append(Timing(f"fig3_model_doget_ib_streams{streams}", t, payload))
        t = FLIGHT_O_IB_PUT.transfer_seconds(payload, streams)
        out.append(Timing(f"fig3_model_doput_ib_streams{streams}", t, payload))
    return out


if __name__ == "__main__":
    for t in run():
        print(t.csv())

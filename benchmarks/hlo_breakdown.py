"""Per-opcode / per-site cost breakdown for one dry-run cell — the §Perf
profiling tool (our 'profile' is the partitioned HLO, per the assignment).

  PYTHONPATH=src python benchmarks/hlo_breakdown.py <arch> <shape> [k]
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import sys
from collections import defaultdict

from repro.launch.hloanalysis import HloAnalyzer, _shape_bytes, COLLECTIVE_OPS


def breakdown(hlo_text: str, default_trips: int = 1, k: int = 18):
    an = HloAnalyzer(hlo_text, default_trips)
    sites = []           # (bytes, kind, opcode, comp, meta)
    coll_sites = []
    by_opcode = defaultdict(float)

    def walk(name, mult):
        comp = an.comps.get(name)
        if comp is None:
            return
        for op in comp.ops:
            oc = op.opcode
            if oc in ("parameter", "constant", "tuple", "get-tuple-element",
                      "bitcast", "after-all", "iota"):
                continue
            if oc == "while":
                trips = an._while_trips(op, op.attr("condition"))
                walk(op.attr("body"), mult * trips)
                continue
            meta = ""
            if "op_name=" in op.rhs:
                meta = op.rhs.split('op_name="')[1].split('"')[0][-90:]
            if oc in COLLECTIVE_OPS:
                b = _shape_bytes(op.result_type) * (2 if oc == "all-reduce" else 1) * mult
                coll_sites.append((b, oc, op.result_type[:40], meta))
                by_opcode[oc] += b
                continue
            if oc == "fusion":
                target = op.attr("calls")
                inner = an.cost(target) if target else None
                if inner:
                    for cop in COLLECTIVE_OPS:
                        if inner.collective_bytes[cop]:
                            coll_sites.append((inner.collective_bytes[cop] * mult, cop,
                                               "(in fusion)", meta))
                            by_opcode[cop] += inner.collective_bytes[cop] * mult
                charges = an._fusion_param_charges(target) if target else []
                opnds = op.operands()
                b = an._fusion_result_charge(target, op)
                for i, o in enumerate(opnds):
                    ch = charges[i] if i < len(charges) else "full"
                    b += _shape_bytes(comp.symbols.get(o, "")) if ch == "full" else ch
                sites.append((b * mult, "bytes", oc, op.result_type[:44], meta))
                by_opcode[oc] += b * mult
                continue
            if oc in ("dynamic-slice", "gather"):
                b = 2 * _shape_bytes(op.result_type) * mult
            elif oc == "dynamic-update-slice":
                ops_c = op.operands()
                b = 2 * _shape_bytes(comp.symbols.get(ops_c[1], "")) * mult if len(ops_c) > 1 else 0
            elif oc in ("dot", "convolution", "custom-call", "reduce", "sort", "scatter",
                        "reduce-window", "call", "conditional"):
                b = (_shape_bytes(op.result_type) + sum(
                    _shape_bytes(comp.symbols.get(o, "")) for o in op.operands())) * mult
            else:
                b = 2 * _shape_bytes(op.result_type) * mult
            sites.append((b, "bytes", oc, op.result_type[:44], meta))
            by_opcode[oc] += b

    walk(an.entry, 1.0)
    total = an.cost()
    print(f"TOTAL flops={total.flops:.3e} bytes={total.bytes:.3e} "
          f"coll={total.total_collective_bytes:.3e}")
    print("\n-- bytes by opcode --")
    for oc, b in sorted(by_opcode.items(), key=lambda kv: -kv[1])[:12]:
        print(f"  {b:.3e}  {oc}")
    print(f"\n-- top {k} byte sites --")
    for b, kind, oc, t, meta in sorted(sites, reverse=True)[:k]:
        print(f"  {b:.3e}  {oc:16s} {t:44s} {meta}")
    print(f"\n-- top {k} collective sites --")
    for b, oc, t, meta in sorted(coll_sites, reverse=True)[:k]:
        print(f"  {b:.3e}  {oc:18s} {t:44s} {meta}")


def main():
    arch, shape = sys.argv[1], sys.argv[2]
    k = int(sys.argv[3]) if len(sys.argv) > 3 else 18
    from repro.launch.dryrun import lower_cell
    from repro.launch.mesh import make_production_mesh
    from repro.configs.base import get_config

    mesh = make_production_mesh()
    lowered, compiled, meta = lower_cell(arch, shape, mesh)
    breakdown(compiled.as_text(), default_trips=get_config(arch).n_superblocks, k=k)


if __name__ == "__main__":
    main()

"""Telemetry overhead: the bench_wire cache-warm DoGet hot path, swept over
``ServerConfig(telemetry=...)``.

The telemetry plane's acceptance bar is "observability is not a tax": with
histograms on (``metrics``) and with full caller-sampled tracing on *and a
trace actually riding every call* (``full`` — the client wraps each fetch in
``Tracer.trace`` so the server records spans and stage timings), cache-warm
DoGet throughput must stay within 5% of ``telemetry="off"``.

Configuration matches bench_wire's shipped default (binary metadata +
coalescing + encode cache) at the two interesting sizes: 4 KiB batches —
the metadata/syscall-bound regime where any per-RPC bookkeeping would show
up first — and 64 KiB for the mid-size path.  Reported per mode × size:
seconds, MB/s, msgs/s and ``ratio_vs_off`` (``full`` rows are the gated
figure; < 0.95 fails the issue's acceptance bar).  ``traced_spans`` on the
``full`` rows proves tracing was actually exercised, not just enabled.
"""
from __future__ import annotations

import time

from repro.core.flight import (FlightClient, FlightDescriptor,
                               InMemoryFlightServer, Tracer)
from repro.core.flight.server import ServerConfig

from .common import Timing, records_batch

MODES = ("off", "metrics", "full")


def _best_of(fn, repeats: int = 3):
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run(quick: bool = True) -> list[Timing]:
    out: list[Timing] = []
    for size in (4 << 10, 64 << 10):
        rows = max(1, size // 32)
        n_batches = 64 if size >= (64 << 10) else 256
        if not quick:
            n_batches *= 4
        batches = [records_batch(rows, seed=s) for s in range(n_batches)]
        nbytes = sum(b.nbytes() for b in batches)
        off_secs = None
        for mode in MODES:
            srv = InMemoryFlightServer(
                config=ServerConfig(batches_per_endpoint=0, telemetry=mode),
            ).serve_tcp()
            try:
                srv.add_dataset("t", batches)
                client = FlightClient(f"tcp://127.0.0.1:{srv.port}")
                ticket = client.get_flight_info(
                    FlightDescriptor.for_path("t")).endpoints[0].ticket
                tracer = Tracer()

                if mode == "full":
                    def fetch():
                        with tracer.trace("bench-fetch"):
                            n = sum(1 for _ in client.do_get(ticket))
                            assert n == n_batches
                else:
                    def fetch():
                        n = sum(1 for _ in client.do_get(ticket))
                        assert n == n_batches

                fetch()  # warm connections + the encode cache
                secs = _best_of(fetch)
                if mode == "off":
                    off_secs = secs
                extra = {
                    "mode": mode, "batch_bytes": size, "n_batches": n_batches,
                    "msgs_per_s": round(n_batches / secs, 1),
                }
                if off_secs and mode != "off":
                    extra["ratio_vs_off"] = round(off_secs / secs, 3)
                if mode == "full":
                    extra["traced_spans"] = srv.telemetry.spans.recorded
                out.append(Timing(
                    f"telemetry_doget_tcp_{mode}_b{size}", secs, nbytes,
                    extra=extra))
            finally:
                srv.shutdown()
    return out


if __name__ == "__main__":
    from .common import emit_bench_json

    timings = run()
    for t in timings:
        print(t.csv() + (f" {t.extra}" if t.extra else ""))
    emit_bench_json("telemetry", timings)

"""Kernel microbenchmarks: jnp reference-path timings on CPU (the Pallas
bodies themselves are validated in interpret mode; wall-clock on CPU measures
the ref path the models actually run here)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops

from .common import Timing, timeit


def run(quick: bool = True) -> list[Timing]:
    out = []
    rng = np.random.default_rng(0)

    # varlen_unpack: 8k docs -> padded 512
    lens = rng.integers(16, 1024, 8192)
    offs = np.zeros(8193, np.int32)
    np.cumsum(lens, out=offs[1:])
    vals = rng.integers(0, 50000, offs[-1]).astype(np.int32)
    offs_j, vals_j = jnp.asarray(offs), jnp.asarray(vals)

    def unpack():
        p, l = ops.varlen_unpack(offs_j, vals_j, 512, use_pallas=False)
        jax.block_until_ready(p)

    dt = timeit(unpack)
    out.append(Timing("kernel_varlen_unpack_8k_docs", dt, int(vals.nbytes)))

    # quantize 16 MB
    x = jnp.asarray(rng.standard_normal((4096, 1024)), jnp.float32)

    def quant():
        q, s = ops.quantize(x, use_pallas=False)
        jax.block_until_ready(q)

    dt = timeit(quant)
    out.append(Timing("kernel_quantize_16MB", dt, x.size * 4))

    # flash decode 32k cache
    B, H, S, d = 4, 8, 32768 if not quick else 8192, 128
    q = jnp.asarray(rng.standard_normal((B, H, d)), jnp.bfloat16)
    k = jnp.asarray(rng.standard_normal((B, S, H, d)), jnp.bfloat16)
    v = jnp.asarray(rng.standard_normal((B, S, H, d)), jnp.bfloat16)
    length = jnp.full((B,), S, jnp.int32)

    def decode():
        o = ops.flash_decode(q, k, v, length, use_pallas=False)
        jax.block_until_ready(o)

    dt = timeit(decode)
    out.append(Timing(f"kernel_flash_decode_S{S}", dt, int(2 * B * S * H * d * 2)))
    return out


if __name__ == "__main__":
    for t in run():
        print(t.csv())

"""Regenerates the data-driven sections of EXPERIMENTS.md from artifacts.

  PYTHONPATH=src python benchmarks/make_experiments_md.py
"""
from __future__ import annotations

import json
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]
ART = REPO / "experiments" / "artifacts"
PERF = REPO / "experiments" / "perf"


def dryrun_section() -> str:
    rows = ["## §Dry-run — 40 cells × 2 production meshes", ""]
    recs = [json.loads(f.read_text()) for f in sorted(ART.glob("*.json"))]
    ok = [r for r in recs if r.get("status") == "ok"]
    sk = [r for r in recs if r.get("status") == "skipped"]
    rows.append(f"**{len(ok)} cells lowered + compiled OK, {len(sk)} skipped per assignment "
                f"rules, {len(recs) - len(ok) - len(sk)} failed** "
                f"(meshes: `(16,16)`=256 chips and `(2,16,16)`=512 chips, "
                f"`--xla_force_host_platform_device_count=512`).")
    rows.append("")
    rows.append("| arch | shape | mesh | status | args GB/dev | temp GB/dev | "
                "collective GB/dev/step |")
    rows.append("|---|---|---|---|---|---|---|")
    for r in recs:
        if r.get("status") == "ok":
            ma = r["memory_analysis"]
            rows.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | "
                f"{ma['argument_bytes']/1e9:.2f} | {ma['temp_bytes']/1e9:.2f} | "
                f"{r['collectives']['total_collective_bytes']/1e9:.2f} |")
        else:
            rows.append(f"| {r['arch']} | {r['shape']} | {r.get('mesh','—')} | "
                        f"{r.get('status')} — {r.get('reason','')[:45]} | — | — | — |")
    return "\n".join(rows)


def roofline_section() -> str:
    import sys
    sys.path.insert(0, str(REPO))
    from benchmarks.roofline import table
    return ("## §Roofline — single-pod (16×16), per-device terms\n\n"
            "Terms: `compute = HLO_FLOPs/dev ÷ 197 TF/s`, `memory = bytes/dev ÷ "
            "819 GB/s`, `collective = collective_bytes/dev ÷ 50 GB/s`.  FLOPs/"
            "bytes/collectives come from the trip-count-aware HLO analyzer "
            "(launch/hloanalysis.py) over the compiled SPMD module — XLA's own "
            "cost analysis counts loop bodies once, undercounting scanned models "
            "24–94×.  `useful FLOPs ratio` = MODEL_FLOPS/HLO_FLOPs (remat "
            "recompute, causal-mask waste and head padding show up here).\n\n"
            + table("pod_16x16"))


def perf_section() -> str:
    rows = ["## §Perf — measured iterations (see narrative below the table)", ""]
    if PERF.exists():
        rows.append("| experiment | compute s | memory s | collective s | bound s | dominant |")
        rows.append("|---|---|---|---|---|---|")
        for f in sorted(PERF.glob("*.json")):
            r = json.loads(f.read_text())
            t = r["roofline"]
            rows.append(f"| {r['experiment']} | {t['compute_s']:.3f} | {t['memory_s']:.3f} | "
                        f"{t['collective_s']:.3f} | {t['step_time_lower_bound_s']:.3f} | "
                        f"{t['dominant'].replace('_s','')} |")
    return "\n".join(rows)


def main():
    out = REPO / "experiments" / "generated_sections.md"
    out.write_text("\n\n".join([dryrun_section(), roofline_section(), perf_section()]))
    print(f"wrote {out}")


if __name__ == "__main__":
    main()

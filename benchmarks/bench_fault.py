"""Fault sweep: kill a replica shard mid-read, measure the cost of surviving.

The replicated cluster's claim is that R=2 makes a shard death a *latency*
event, not an availability event: every in-flight DoGet fails over to the
slice's surviving holder (resume-skip keeps already-emitted batches), every
subsequent plan routes around the corpse, and nothing the client sees is an
error.  This sweep prices that claim on the modeled wire:

* ``healthy``        — parallel read, all shards up (the baseline).
* ``kill_mid_read``  — same read; one shard is ``FaultInjector.kill``-ed
  after the first batches arrive.  The timing includes the failover stalls;
  ``pct_of_healthy`` is the headline number (acceptance: the degraded read
  keeps >= 70% of healthy throughput).
* ``degraded``       — a fresh read with the shard already declared DEAD:
  the steady-state cost of running one replica down (plans skip the corpse,
  so this prices replica-holder load skew, not failover).
* ``detect``         — kill → failure-detector-declares-DEAD latency via the
  active prober (the membership plane's contribution to recovery time).

Shards serve through ``netsim.paced_stream`` at the modeled per-stream
Flight-over-IB rate (pacing sleeps release the GIL), so stream scheduling —
not this container's loopback CPU — sets the shape.  ``run.py`` emits
BENCH_fault.json per commit."""
from __future__ import annotations

import time

from repro.core.flight import FaultInjector, FlightClusterClient, FlightClusterServer
from repro.core.flight.membership import ShardState
from repro.core.flight.netsim import FLIGHT_O_IB_GET, paced_stream

from .common import Timing, records_batch


class _PacedShard:
    """Shard factory: DoGet streams at the modeled per-stream wire rate."""

    def __call__(self, i: int, loc_name: str):
        from repro.core.flight import InMemoryFlightServer

        class PacedShardServer(InMemoryFlightServer):
            def do_get_impl(self, ticket):
                schema, batches = super().do_get_impl(ticket)
                return schema, paced_stream(batches, FLIGHT_O_IB_GET)

        return PacedShardServer(location_name=loc_name, shard_id=i,
                                batches_per_endpoint=0)


def _read_seconds(cc: FlightClusterClient, name: str, expect_rows: int) -> float:
    t0 = time.perf_counter()
    table, _ = cc.read(name)
    dt = time.perf_counter() - t0
    assert table.num_rows == expect_rows, (table.num_rows, expect_rows)
    return dt


def run(quick: bool = True) -> list[Timing]:
    out: list[Timing] = []
    shard_counts = (3,) if quick else (3, 4, 6)
    rows, n_batches = (20_000, 8) if quick else (80_000, 8)

    for n in shard_counts:
        batches = [records_batch(rows, seed=s) for s in range(n_batches)]
        nbytes = sum(b.nbytes() for b in batches)
        total_rows = rows * n_batches
        cl = FlightClusterServer(
            num_shards=n, replicas=2, shard_factory=_PacedShard(),
            suspect_after=0.05, dead_after=0.1)
        try:
            cl.add_dataset("bench", batches)
            cc = FlightClusterClient(cl, max_streams=n)
            inj = FaultInjector(cl)

            # -- healthy baseline ------------------------------------------ #
            healthy = min(_read_seconds(cc, "bench", total_rows) for _ in range(2))
            out.append(Timing(f"fault_healthy_read_shards{n}", healthy, nbytes,
                              extra={"shards": n, "replicas": 2}))

            # -- kill one shard mid-read ----------------------------------- #
            got_rows, killed = 0, False
            t0 = time.perf_counter()
            for i, b in enumerate(cc.stream("bench")):
                got_rows += b.num_rows
                if i == 1 and not killed:
                    inj.kill(0)
                    killed = True
            mid = time.perf_counter() - t0
            assert got_rows == total_rows, (got_rows, total_rows)
            out.append(Timing(
                f"fault_kill_mid_read_shards{n}", mid, nbytes,
                extra={"shards": n, "replicas": 2,
                       "pct_of_healthy": round(100 * healthy / mid, 1),
                       "rows_complete": got_rows == total_rows}))

            # -- detection latency (kill -> detector says DEAD) ------------ #
            t0 = time.perf_counter()
            deadline = t0 + 10.0
            while cl.membership.state(0) is not ShardState.DEAD:
                cl.prober.tick()
                time.sleep(0.02)
                if time.perf_counter() > deadline:
                    raise RuntimeError("failure detector never fired")
            detect = time.perf_counter() - t0
            out.append(Timing(f"fault_detect_dead_shards{n}", detect, 0,
                              extra={"shards": n,
                                     "dead_after_s": cl.membership.dead_after}))

            # -- degraded steady state (plans route around the corpse) ----- #
            degraded = min(_read_seconds(cc, "bench", total_rows) for _ in range(2))
            pct = round(100 * healthy / degraded, 1)
            out.append(Timing(
                f"fault_degraded_read_shards{n}", degraded, nbytes,
                extra={"shards": n, "replicas": 2, "pct_of_healthy": pct,
                       "meets_70pct_floor": pct >= 70.0}))

            # -- revive: detector readmits, plans use it again -------------- #
            inj.revive(0)
            t0 = time.perf_counter()
            while cl.membership.state(0) is not ShardState.HEALTHY:
                cl.prober.tick()
                time.sleep(0.02)
                if time.perf_counter() - t0 > 10.0:
                    raise RuntimeError("revived shard never readmitted")
            out.append(Timing(
                f"fault_readmit_revived_shards{n}", time.perf_counter() - t0, 0,
                extra={"shards": n}))
        finally:
            cl.shutdown()
    return out


if __name__ == "__main__":
    from .common import emit_bench_json

    timings = run(quick=True)
    for t in timings:
        print(t.csv() + (f" {t.extra}" if t.extra else ""))
    print(f"# wrote {emit_bench_json('fault', timings)}")

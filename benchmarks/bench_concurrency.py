"""Many-clients concurrency: event-loop vs thread-per-connection serving.

The paper's headline claim is serving *many parallel streams* at wire
speed; this suite measures the server architecture itself.  A Flight
server runs in its own process (``io_mode="eventloop"`` — the selector
core from core/flight/eventloop.py — vs ``io_mode="threads"`` — the
historical thread-per-connection ``SocketListener``) and N concurrent
clients hammer it from **separate processes**, so the server's GIL and
scheduler behaviour is the thing measured, not a shared client/server
GIL.  Two verbs:

* ``doget`` — the C10k shape: each round a client *opens its share of the
  N connections concurrently*, issues ``DoGet(ds)`` on each, collects the
  responses, closes, repeats.  Connections are genuinely open at the same
  time, so the threads server really holds N live handler threads while
  the event loop holds N epoll registrations.  Clients are deliberately
  thin: one warm-up response is frame-parsed to learn the (deterministic)
  response length and batch count, then steady-state reads just count
  bytes — client CPU per connection is a connect + send + recv loop, so
  the server side dominates what the sweep measures;
* ``exchange`` — real ``open_exchange`` echo clients over persistent
  bidirectional streams (the microservice plane at fan-in).

Above ``MAX_PROCS`` client processes, each process runs its share of the
connections (hybrid process x connection) — connection count is what's
swept.

Both servers are up for the whole run and repeats alternate
eventloop/threads back-to-back, so machine-load drift hits both modes
alike; each mode's best repeat is scored (container noise only ever
subtracts).  Rows record aggregate msgs/s, per-connection p50/p99, and
mid-run server ``/proc`` samples (open fds, thread count — the
O(workers)-not-O(clients) claim made observable).  ``ratio`` rows pin
the event-loop speedup at each client count; the acceptance bar is
>=1.5x aggregate DoGet msgs/s at the top of the sweep (>=64 clients).
``run.py`` emits ``BENCH_concurrency.json``.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

from repro.core.flight import FlightClient, FlightDescriptor

from .common import Timing

DOGET_COUNTS_QUICK = (1, 4, 16)
DOGET_COUNTS_FULL = (1, 16, 64, 256)
EXCHANGE_COUNTS_QUICK = (1, 4, 16)
EXCHANGE_COUNTS_FULL = (1, 16, 64)
DURATION_QUICK = 1.2
DURATION_FULL = 2.0
REPEATS_QUICK = 2
REPEATS_FULL = 3
# Hybrid cap: beyond this many client processes, each multiplexes several
# connections.  8 measured best on small CI boxes: more processes spend the
# shared cores on client-side scheduler churn, which dilutes the server
# difference the sweep exists to show (and burst-opening a proc's whole
# connection share keeps the concurrency genuine).
MAX_PROCS = 8
BATCH_ROWS = 128        # 4 KiB batches: RPC-rate-bound, not bandwidth-bound
DATASET_BATCHES = 1     # one batch per stream: the RPC itself is the cost

_SERVER = """
import os, sys, threading
import numpy as np
from repro.core import RecordBatch
from repro.core.flight import InMemoryFlightServer

srv = InMemoryFlightServer(io_mode=sys.argv[1]).serve_tcp()
rng = np.random.default_rng(0)
srv.add_dataset("ds", [RecordBatch.from_numpy({
    f"f{i}": rng.integers(0, 1 << 40, %(rows)d).astype(np.int64)
    for i in range(4)}) for _ in range(%(nbatches)d)])
print(srv.port, os.getpid(), flush=True)
threading.Event().wait()
""" % {"rows": BATCH_ROWS, "nbatches": DATASET_BATCHES}

# Thin burst-churn DoGet client: argv = port n_conns duration ticket_json.
# Prints "ready", blocks for "go", runs rounds of n_conns concurrently-open
# connections for the window, prints {"msgs": total, "conns": n, "secs": s}.
_DOGET_CLIENT = """
import json, socket, struct, sys, time

port, n_conns, duration = int(sys.argv[1]), int(sys.argv[2]), float(sys.argv[3])
ticket = json.loads(sys.argv[4])
FRAME = struct.Struct("<IBIQ")
MAGIC = 0xF117A77C
meta = json.dumps({"method": "DoGet", "ticket": ticket}).encode()
REQ = FRAME.pack(MAGIC, 0, len(meta), 0) + meta

def connect():
    s = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    s.connect(("127.0.0.1", port))
    s.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
    return s

def parse_stream(s):
    # one full frame parse: learns the fixed response length + batch count
    f = s.makefile("rb", 1 << 16)
    s.sendall(REQ)
    n = 0; bodyless = 0; total = 0
    while True:
        magic, kind, mlen, blen = FRAME.unpack(f.read(17))
        m = f.read(mlen)
        if blen:
            f.read(blen)
        total += 17 + mlen + blen
        if kind == 0:  # ctrl: the ok (or error) envelope
            if b'"error"' in m:
                raise RuntimeError(m)
            continue
        if blen:
            n += 1     # a batch frame
        else:
            bodyless += 1          # schema first, eos last
            if bodyless == 2:
                f.detach()
                return n, total

s = connect()
MSGS, RESP_LEN = parse_stream(s)
s.close()
buf = bytearray(1 << 16)

def one_round():
    socks = [connect() for _ in range(n_conns)]   # N genuinely open at once
    for s in socks:
        s.sendall(REQ)
    got_msgs = 0
    for s in socks:
        got = 0
        while got < RESP_LEN:  # deterministic length: count, don't parse
            n = s.recv_into(buf)
            if not n:
                raise ConnectionError("short response")
            got += n
        s.close()
        got_msgs += MSGS
    return got_msgs

one_round()  # warm: encode cache + inline certificate on the server
print("ready", flush=True)
sys.stdin.readline()  # "go"
total = 0
t0 = time.monotonic()
t_end = t0 + duration
while time.monotonic() < t_end:
    total += one_round()
print(json.dumps({"msgs": total, "conns": n_conns,
                  "secs": time.monotonic() - t0}), flush=True)
"""

# Exchange client: argv = port n_conns duration.  Persistent bidirectional
# echo streams through the real client stack, one thread per stream.
_EXCHANGE_CLIENT = """
import json, sys, threading, time
import numpy as np
from repro.core import RecordBatch
from repro.core.flight import FlightClient, open_exchange

port, n_conns, duration = int(sys.argv[1]), int(sys.argv[2]), float(sys.argv[3])
rng = np.random.default_rng(0)
batches = [RecordBatch.from_numpy({
    f"f{i}": rng.integers(0, 1 << 40, 128).astype(np.int64)
    for i in range(4)}) for _ in range(4)]
schema = batches[0].schema
clients = [FlightClient(f"tcp://127.0.0.1:{port}") for _ in range(n_conns)]

def one_stream(client):
    return sum(1 for _ in open_exchange(client, "echo", schema, batches))

for c in clients:
    one_stream(c)  # warm
msgs = [0] * n_conns
secs = [0.0] * n_conns

def run(i):
    c = clients[i]
    t0 = time.monotonic()
    t_end = t0 + duration
    n = 0
    while time.monotonic() < t_end:
        n += one_stream(c)
    msgs[i] = n
    secs[i] = time.monotonic() - t0

print("ready", flush=True)
sys.stdin.readline()  # "go"
workers = [threading.Thread(target=run, args=(i,)) for i in range(n_conns)]
for w in workers:
    w.start()
for w in workers:
    w.join()
print(json.dumps({"msgs": sum(msgs), "conns": n_conns,
                  "secs": max(secs)}), flush=True)
"""


def _env() -> dict:
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    return env


def _spawn_server(io_mode: str) -> tuple[subprocess.Popen, int, int]:
    proc = subprocess.Popen([sys.executable, "-c", _SERVER, io_mode],
                            stdout=subprocess.PIPE, text=True, env=_env())
    port, pid = (int(x) for x in proc.stdout.readline().split())
    return proc, port, pid


def _proc_sample(pid: int) -> dict:
    """Server-side /proc observables: open fds and thread count."""
    sample = {"fds": None, "threads": None}
    try:
        sample["fds"] = len(os.listdir(f"/proc/{pid}/fd"))
        with open(f"/proc/{pid}/status") as f:
            for line in f:
                if line.startswith("Threads:"):
                    sample["threads"] = int(line.split()[1])
                    break
    except OSError:
        pass  # non-procfs platform: samples stay None
    return sample


def _percentile(sorted_vals: list[float], q: float) -> float:
    if not sorted_vals:
        return 0.0
    idx = min(len(sorted_vals) - 1, int(q * len(sorted_vals)))
    return sorted_vals[idx]


def _sweep(script: str, port: int, pid: int, n_clients: int, duration: float,
           argv_tail: list[str]) -> dict:
    n_procs = min(n_clients, MAX_PROCS)
    per_proc = [n_clients // n_procs] * n_procs
    for i in range(n_clients % n_procs):
        per_proc[i] += 1
    procs = [
        subprocess.Popen(
            [sys.executable, "-c", script, str(port), str(k), str(duration)]
            + argv_tail,
            stdin=subprocess.PIPE, stdout=subprocess.PIPE, text=True,
            env=_env())
        for k in per_proc
    ]
    try:
        for p in procs:
            assert p.stdout.readline().strip() == "ready"
        for p in procs:  # the barrier: every process is warm before "go"
            p.stdin.write("go\n")
            p.stdin.flush()
        time.sleep(duration / 2)
        mid = _proc_sample(pid)
        per_conn: list[float] = []
        total = 0.0
        for p in procs:
            rep = json.loads(p.stdout.readline())
            rate = rep["msgs"] / rep["secs"]
            total += rate
            per_conn += [rate / rep["conns"]] * rep["conns"]
        for p in procs:
            p.wait(timeout=30)
    finally:
        for p in procs:
            if p.poll() is None:
                p.kill()
                p.wait()
    per_conn.sort()
    return {
        "aggregate_msgs_per_s": round(total, 1),
        "p50_client_msgs_per_s": round(_percentile(per_conn, 0.50), 1),
        "p99_client_msgs_per_s": round(_percentile(per_conn, 0.99), 1),
        "server_fds_midrun": mid["fds"],
        "server_threads_midrun": mid["threads"],
        "client_procs": n_procs,
    }


def run(quick: bool = True) -> list[Timing]:
    duration = DURATION_QUICK if quick else DURATION_FULL
    repeats = REPEATS_QUICK if quick else REPEATS_FULL
    sweeps = {
        "doget": DOGET_COUNTS_QUICK if quick else DOGET_COUNTS_FULL,
        "exchange": EXCHANGE_COUNTS_QUICK if quick else EXCHANGE_COUNTS_FULL,
    }
    modes = ("eventloop", "threads")
    servers = {m: _spawn_server(m) for m in modes}  # both up: drift-neutral
    best: dict[tuple[str, str, int], dict] = {}
    out: list[Timing] = []
    try:
        _, port0, _ = servers[modes[0]]
        info = FlightClient(f"tcp://127.0.0.1:{port0}").get_flight_info(
            FlightDescriptor.for_path("ds"))
        ticket_json = json.dumps(info.endpoints[0].ticket.to_json())
        for verb, counts in sweeps.items():
            script = _DOGET_CLIENT if verb == "doget" else _EXCHANGE_CLIENT
            tail = [ticket_json] if verb == "doget" else []
            for n in counts:
                for _ in range(repeats):  # alternate modes inside the repeat
                    for mode in modes:
                        _, port, pid = servers[mode]
                        res = _sweep(script, port, pid, n, duration, tail)
                        key = (mode, verb, n)
                        if (key not in best
                                or res["aggregate_msgs_per_s"]
                                > best[key]["aggregate_msgs_per_s"]):
                            best[key] = res
    finally:
        for proc, _, _ in servers.values():
            proc.kill()
            proc.wait()
    for (mode, verb, n), res in sorted(best.items()):
        out.append(Timing(
            f"concurrency_{verb}_{mode}_c{n}", duration, 0,
            extra={"verb": verb, "io_mode": mode, "clients": n,
                   "duration_s": duration, "repeats": repeats, **res}))
    # the acceptance rows: event-loop speedup over thread-per-connection
    for (mode, verb, n), res in sorted(best.items()):
        if mode != "eventloop":
            continue
        th = best.get(("threads", verb, n))
        if th is None:
            continue
        ev_rate = res["aggregate_msgs_per_s"]
        th_rate = th["aggregate_msgs_per_s"]
        out.append(Timing(f"concurrency_ratio_{verb}_c{n}", 0.0, 0, extra={
            "verb": verb, "clients": n,
            "eventloop_msgs_per_s": ev_rate, "threads_msgs_per_s": th_rate,
            "eventloop_vs_threads": round(ev_rate / th_rate, 3) if th_rate else None,
        }))
    return out


if __name__ == "__main__":
    from .common import emit_bench_json

    timings = run(quick="--full" not in sys.argv)
    for t in timings:
        print(t.csv() + (f" {t.extra}" if t.extra else ""))
    print(f"# wrote {emit_bench_json('concurrency', timings)}")

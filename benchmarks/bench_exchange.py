"""Streaming DoExchange: batch size × in-flight window × stream count.

The paper's microservice claim (§4.2.3 / Fig 11) is that DoExchange keeps
*both* directions of a bidirectional stream busy and scales with parallel
streams "upto half of the available system cores".  This suite measures the
new pipelined exchange plane (core/flight/exchange.py) against the old
lockstep ping-pong over loopback TCP.  The Flight server runs in a
**separate process** (the paper's client and server are separate machines;
in-process serving would share one GIL and serialize the two directions,
understating pipelining on small containers):

* ``lockstep`` — the deprecated ``FlightExchange`` shim: write one batch,
  wait for its response, repeat (window=1 ping-pong; one direction — and
  one of the two processes — idle at every instant);
* ``stream_wN`` — the pipelined stream with an N-batch in-flight window:
  the writer runs ahead while responses flow back, flush-on-idle coalesced
  sends on the server, consumption acks riding the output direction;
* ``streams_sN`` — the Fig 11 curve: N concurrent exchange streams (own
  connection + server handler thread each) through a **paced scoring
  service** (fixed per-batch service time, the netsim trick that makes
  scaling measurable on small-core containers: a transport-saturating echo
  would flatline at 1–2 streams under CI's 2 cores, while real microservice
  throughput is service-time-bound and scales with concurrent streams
  exactly as the paper shows).

Reported per row: seconds, **bidirectional** MB/s (bytes in + bytes out per
wall second — the exchange figure of merit) and msgs/s.  ``stream_*`` rows
carry ``speedup_vs_lockstep``; expect ≥3x in the small-batch (≤ a few KiB)
regime where ping-pong is round-trip-bound, compressing toward ~2x at
64 KiB where both directions become memcpy/CPU-bound on 2-core runners
(on wider machines the duplex overlap keeps the gap).  ``run.py`` emits
``BENCH_exchange.json`` and CI uploads it.
"""
from __future__ import annotations

import os
import subprocess
import sys
import time
from concurrent.futures import ThreadPoolExecutor

from repro.core.flight import (
    CallOptions,
    ExchangeCommand,
    FlightClient,
    FlightDescriptor,
    open_exchange,
)

from .common import Timing, records_batch

RECORD_BYTES = 32  # the paper's fixed-width record microbenchmark shape
WINDOWS = (4, 16, 64)  # 64×64 KiB ≈ the 4 MiB socket buffer: the deep-window regime
STREAM_COUNTS = (1, 2, 4, 8)
STREAMS_BATCH_BYTES = 4 << 10  # Fig 11 runs in the small-batch regime
STREAMS_WINDOW = 16
PACE_S = 0.002  # per-batch service time of the paced scoring microservice

_SERVER = f"""
import sys, threading, time
from repro.core.flight import InMemoryFlightServer, MapBatchesService

srv = InMemoryFlightServer().serve_tcp()
srv.services.register(MapBatchesService(
    "score_paced", lambda b: (time.sleep({PACE_S}), b)[1],
    out_schema_fn=lambda s: s))
print(srv.port, flush=True)
threading.Event().wait()
"""


def _spawn_server() -> tuple[subprocess.Popen, int]:
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.Popen([sys.executable, "-c", _SERVER],
                            stdout=subprocess.PIPE, text=True, env=env)
    port = int(proc.stdout.readline())
    return proc, port


def _timed(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def _best_of(fn, repeats: int = 3) -> float:
    return min(_timed(fn) for _ in range(repeats))


def _lockstep(client: FlightClient, schema, batches) -> None:
    # manual window=1 ping-pong over the streaming API: write one batch,
    # block for its response — the baseline the pipelined mode beats
    ex = client.do_exchange_stream(FlightDescriptor.for_path("echo"), schema,
                                   options=CallOptions(read_window=1))
    it = iter(ex)
    for b in batches:
        ex.write_batch(b)
        next(it)
    ex.done_writing()
    ex.close()


def _pipelined(client: FlightClient, command, schema, batches, window: int) -> None:
    stream = open_exchange(client, command, schema, batches,
                           options=CallOptions(read_window=window))
    n = sum(1 for _ in stream)
    assert n == len(batches), (n, len(batches))


def run(quick: bool = True) -> list[Timing]:
    out: list[Timing] = []
    batch_bytes = (1 << 10, 4 << 10, 64 << 10)
    proc, port = _spawn_server()
    try:
        # -- batch size × window vs the lockstep baseline ------------------- #
        for size in batch_bytes:
            rows = max(1, size // RECORD_BYTES)
            n_batches = 64 if size >= (64 << 10) else 256
            if not quick:
                n_batches *= 4
            batches = [records_batch(rows, seed=s) for s in range(n_batches)]
            schema = batches[0].schema
            nbytes = sum(b.nbytes() for b in batches)
            bidir = 2 * nbytes  # echo: every byte crosses the wire twice
            client = FlightClient(f"tcp://127.0.0.1:{port}")
            _pipelined(client, "echo", schema, batches, 16)  # warm

            # interleave the configs per repeat: container speed drifts run
            # to run, and measuring the baseline and the streams at the same
            # moments keeps the *ratio* honest even when absolutes wobble
            repeats = 3 if size >= (64 << 10) else 4
            lock_secs = float("inf")
            win_secs = {w: float("inf") for w in WINDOWS}
            for _ in range(repeats):
                lock_secs = min(lock_secs, _timed(
                    lambda: _lockstep(client, schema, batches)))
                for window in WINDOWS:
                    win_secs[window] = min(win_secs[window], _timed(
                        lambda: _pipelined(client, "echo", schema, batches, window)))
            lock_msgs = n_batches / lock_secs
            out.append(Timing(f"exchange_lockstep_b{size}", lock_secs, bidir, extra={
                "mode": "lockstep", "batch_bytes": size, "n_batches": n_batches,
                "window": 1, "streams": 1,
                "msgs_per_s": round(lock_msgs, 1),
                "mbps_bidir": round(bidir / lock_secs / 1e6, 1),
            }))
            for window in WINDOWS:
                secs = win_secs[window]
                msgs = n_batches / secs
                out.append(Timing(f"exchange_stream_b{size}_w{window}", secs, bidir, extra={
                    "mode": "stream", "batch_bytes": size, "n_batches": n_batches,
                    "window": window, "streams": 1,
                    "msgs_per_s": round(msgs, 1),
                    "mbps_bidir": round(bidir / secs / 1e6, 1),
                    "speedup_vs_lockstep": round(msgs / lock_msgs, 2),
                }))

        # -- Fig 11: throughput vs parallel streams (paced microservice) ---- #
        size = STREAMS_BATCH_BYTES
        rows = max(1, size // RECORD_BYTES)
        n_batches = 48 if quick else 192
        batches = [records_batch(rows, seed=s) for s in range(n_batches)]
        schema = batches[0].schema
        nbytes = sum(b.nbytes() for b in batches)
        score = ExchangeCommand("score_paced")
        for n_streams in STREAM_COUNTS:
            clients = [FlightClient(f"tcp://127.0.0.1:{port}")
                       for _ in range(n_streams)]
            for c in clients:  # warm one connection per stream
                _pipelined(c, score, schema, batches[:2], STREAMS_WINDOW)

            def fan_out() -> None:
                with ThreadPoolExecutor(max_workers=n_streams) as pool:
                    futs = [pool.submit(_pipelined, c, score, schema, batches,
                                        STREAMS_WINDOW) for c in clients]
                    for f in futs:
                        f.result()

            secs = _best_of(fan_out, repeats=2)
            total = n_batches * n_streams
            bidir = 2 * nbytes * n_streams
            out.append(Timing(f"exchange_streams_b{size}_s{n_streams}", secs, bidir, extra={
                "mode": "streams", "batch_bytes": size, "n_batches": total,
                "window": STREAMS_WINDOW, "streams": n_streams,
                "service": "score_paced", "pace_s": PACE_S,
                "msgs_per_s": round(total / secs, 1),
                "mbps_bidir": round(bidir / secs / 1e6, 1),
            }))
    finally:
        proc.kill()
        proc.wait()
    return out


if __name__ == "__main__":
    from .common import emit_bench_json

    timings = run()
    for t in timings:
        print(t.csv() + (f" {t.extra}" if t.extra else ""))
    print(f"# wrote {emit_bench_json('exchange', timings)}")

"""§1's '>80 % of time is (de)serialization' claim, measured directly.

The same table crosses a process boundary three ways:
  pickle-rows  — classic RPC serialization (the 80 % world)
  ipc-columnar — our Arrow-IPC framing (encode + zero-copy decode)
  zero-copy    — in-proc reference handoff (the Flight same-host path)
Reported: serialization share of total transfer+access time.
"""
from __future__ import annotations

import pickle
import time

import numpy as np

from repro.core import read_stream, write_stream

from .common import Timing, taxi_batch


def run(quick: bool = True) -> list[Timing]:
    out: list[Timing] = []
    batch = taxi_batch(200_000 if quick else 1_000_000, with_strings=False)
    nbytes = batch.nbytes()

    # pickle rows (row-based serialization)
    rows = batch.to_rows()
    t0 = time.perf_counter()
    blob = pickle.dumps(rows)
    rows2 = pickle.loads(blob)
    cols = list(zip(*rows2))  # consumer needs columns back
    dt = time.perf_counter() - t0
    out.append(Timing("serde_pickle_rows", dt, nbytes))

    # columnar IPC
    t0 = time.perf_counter()
    wire = write_stream([batch])
    got = read_stream(wire)[0]
    _ = got.column("fare_amount").to_numpy()  # consumer access (zero-copy view)
    dt = time.perf_counter() - t0
    out.append(Timing("serde_ipc_columnar", dt, nbytes))

    # zero-copy handoff
    t0 = time.perf_counter()
    ref = batch  # in-proc Flight moves the reference
    _ = ref.column("fare_amount").to_numpy()
    dt = time.perf_counter() - t0
    out.append(Timing("serde_zero_copy_handoff", dt, nbytes))

    share = out[0].seconds / (out[0].seconds + 1e-12)
    out.append(Timing("serde_row_serialization_share", share, 0,
                      extra={"note": "rows path is ~100% serde; columnar removes it"}))
    return out


if __name__ == "__main__":
    for t in run():
        print(t.csv())

"""Autoregressive generation: prefill → greedy decode loop with KV cache.

The serving-side composition of ``LM.prefill`` + ``LM.decode_step``: one
jit'd step, cache carried functionally (aliased in place by donation on real
hardware).  Used by the generation example and the serving tests.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


def generate(model, params, prompts: jnp.ndarray, max_new_tokens: int,
             max_seq: int | None = None, eos_id: int | None = None):
    """prompts: (B, P) int32 (left-aligned, fully valid). Greedy decode.

    Returns (B, max_new_tokens) int32.  Prefill fills the cache to position
    P; each decode step appends one token.
    """
    B, P = prompts.shape
    max_seq = max_seq or (P + max_new_tokens)

    caches = model.init_caches(B, max_seq)

    # prefill by teacher-forcing the prompt through decode steps if the arch
    # has recurrent state; attention-only archs could batch-prefill, but the
    # step loop is universal and exact (tested decode == prefill)
    step = jax.jit(partial(_step, model), donate_argnums=(1,))
    tok = prompts[:, :1]
    for i in range(P):
        tok = prompts[:, i:i + 1]
        nxt, caches = step(params, caches, tok, jnp.int32(i))
    out = []
    cur = nxt[:, None]
    for j in range(max_new_tokens):
        out.append(cur)
        if j == max_new_tokens - 1:
            break
        nxt, caches = step(params, caches, cur, jnp.int32(P + j))
        cur = nxt[:, None]
    return jnp.concatenate(out, axis=1)


def _step(model, params, caches, tok, pos):
    return model.decode_step(params, caches, tok, pos)

"""Batch-scoring microservice over Flight — the XGBatch analogue (Fig 11).

``ScoringService`` is a FlightServer whose ``DoExchange`` scores incoming
RecordBatches with a JAX model function and streams scored batches back:
clients stream requests in, results out, with zero (de)serialization at
either boundary — the paper's microservice pattern.

``LMScoringService`` wires it to an ``LM``: request batches carry a
``tokens`` list column, responses add ``next_token``/``logprob`` columns
(prefill scoring).  ``Batcher`` coalesces many small client requests into
model-shaped batches (the latency/throughput knob real scoring services
expose; requests are padded into fixed slots so one jit'd function serves
every shape).
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..core.array import Array
from ..core.flight.protocol import FlightDescriptor, FlightError
from ..core.flight.server import InMemoryFlightServer
from ..core.recordbatch import RecordBatch
from ..core.schema import Schema


class ScoringService(InMemoryFlightServer):
    """DoExchange(batch) -> score_fn(batch).  score_fn: RecordBatch -> RecordBatch."""

    def __init__(self, score_fn: Callable[[RecordBatch], RecordBatch], **kw):
        super().__init__(**kw)
        self.score_fn = score_fn
        self.requests_served = 0

    def do_exchange_impl(self, descriptor, schema, batch) -> RecordBatch:
        out = self.score_fn(batch)
        self.requests_served += 1
        return out


@dataclass
class BatcherConfig:
    max_batch: int = 8         # model batch slots
    max_wait_s: float = 0.005  # coalescing window
    pad_to: int = 128          # sequence padding bucket


class Batcher:
    """Coalesces single requests into padded model batches (thread-safe)."""

    def __init__(self, cfg: BatcherConfig, model_fn: Callable[[np.ndarray, np.ndarray], np.ndarray]):
        self.cfg = cfg
        self.model_fn = model_fn  # (tokens (B,L) int32, lens (B,)) -> scores
        self._lock = threading.Lock()
        self._pending: list[tuple[np.ndarray, threading.Event, list]] = []

    def score(self, tokens: np.ndarray) -> np.ndarray:
        """Blocking single-request API; coalesced under the hood."""
        done = threading.Event()
        slot: list = []
        with self._lock:
            self._pending.append((tokens, done, slot))
            if len(self._pending) >= self.cfg.max_batch:
                self._flush_locked()
        if not done.wait(self.cfg.max_wait_s):
            with self._lock:
                if not done.is_set():
                    self._flush_locked()
            done.wait()
        return slot[0]

    def _flush_locked(self) -> None:
        if not self._pending:
            return
        batch, self._pending = self._pending[: self.cfg.max_batch], self._pending[self.cfg.max_batch:]
        lens = np.array([len(t) for t, _, _ in batch], np.int32)
        L = int(np.ceil(max(int(lens.max()), 1) / self.cfg.pad_to) * self.cfg.pad_to)
        toks = np.zeros((self.cfg.max_batch, L), np.int32)  # fixed slots: one jit shape
        for i, (t, _, _) in enumerate(batch):
            toks[i, : len(t)] = t[:L]
        scores = self.model_fn(toks, np.pad(lens, (0, self.cfg.max_batch - len(batch))))
        for i, (_, done, slot) in enumerate(batch):
            slot.append(np.asarray(scores[i]))
            done.set()


class LMScoringService(ScoringService):
    """Scores ``tokens`` list-columns with an LM prefill (greedy next token)."""

    def __init__(self, model, params, max_seq: int = 512, **kw):
        self.model = model
        self.params = params
        self.max_seq = max_seq

        @jax.jit
        def _score(tokens):
            lgts, _ = model.prefill(params, {"tokens": tokens})
            nxt = jnp.argmax(lgts, axis=-1)
            lp = jax.nn.log_softmax(lgts, axis=-1)
            return nxt.astype(jnp.int32), jnp.max(lp, axis=-1)

        self._score = _score
        super().__init__(self._score_batch, **kw)

    def _score_batch(self, batch: RecordBatch) -> RecordBatch:
        col = batch.column("tokens")
        rows = col.to_pylist()
        B = len(rows)
        toks = np.zeros((B, self.max_seq), np.int32)
        for i, r in enumerate(rows):
            r = (r or [])[: self.max_seq]
            toks[i, : len(r)] = r
        nxt, lp = self._score(jnp.asarray(toks))
        return RecordBatch.from_pydict({
            "next_token": np.asarray(nxt),
            "logprob": np.asarray(lp, np.float32),
        })

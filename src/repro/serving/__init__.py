from .service import Batcher, BatcherConfig, LMScoringService, ScoringService  # noqa: F401

"""Production mesh builders (functions, never module-level constants — importing
this module must not touch jax device state)."""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single pod (256 chips) or 2×16×16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1, pod: int | None = None) -> Mesh:
    """Arbitrary small mesh over available (possibly forced-host) devices."""
    n = (pod or 1) * data * model
    devs = np.array(jax.devices()[:n])
    if pod is not None:
        return Mesh(devs.reshape(pod, data, model), ("pod", "data", "model"))
    return Mesh(devs.reshape(data, model), ("data", "model"))


# TPU v5e hardware constants (§Roofline sources)
PEAK_FLOPS_BF16 = 197e12      # per chip
HBM_BW = 819e9                # B/s per chip
ICI_BW = 50e9                 # B/s per link (~what one all-reduce hop sees)

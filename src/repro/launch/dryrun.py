import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count on first init.
# This forcing is dry-run-only — tests/benches see the single real device.

"""Multi-pod dry-run: lower + compile every (arch × shape) on the production
meshes and extract the roofline terms from the compiled artifact.

Usage:
  python -m repro.launch.dryrun --arch internlm2_1_8b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --all [--multi-pod]   # every supported cell

Each cell writes experiments/artifacts/<arch>__<shape>__<mesh>.json with:
  memory_analysis (per-device bytes), cost_analysis (FLOPs/bytes),
  per-collective byte totals parsed from the partitioned HLO, model FLOPs,
  and the three roofline terms (seconds) with the dominant bottleneck.
"""
import argparse
import json
import re
import time
import traceback
from functools import partial
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import SHAPES, ARCH_IDS, cell_supported, get_config, input_specs
from ..distributed.sharding import ShardingCtx, tree_shardings
from ..models.lm import LM
from ..train.optimizer import OptimizerConfig
from ..train.step import TrainConfig, build_train_step, step_shardings
from .mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16, make_production_mesh

ARTIFACTS = Path(__file__).resolve().parents[3] / "experiments" / "artifacts"

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_TYPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _type_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def parse_collectives(hlo_text: str) -> dict:
    """Sum per-device operand bytes of every collective op in partitioned HLO."""
    out = {op: {"count": 0, "bytes": 0} for op in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        for op in _COLLECTIVES:
            marker = f" {op}("
            idx = stripped.find(marker)
            if idx < 0 or stripped.startswith("//"):
                continue
            # result types appear before the op name on the line
            types = _TYPE_RE.findall(stripped[:idx])
            nbytes = sum(_type_bytes(t, d) for t, d in types)
            mult = 2.0 if op == "all-reduce" else 1.0  # ring AR moves ~2x
            out[op]["count"] += 1
            out[op]["bytes"] += int(nbytes * mult)
            break
    out["total_bytes"] = sum(v["bytes"] for k, v in out.items() if isinstance(v, dict))
    return out


def model_flops(cfg, shape) -> float:
    """6·N_active·tokens (train), 2·N_active·tokens (prefill/decode fwd)."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n_active * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.global_batch * shape.seq_len
    return 2.0 * n_active * shape.global_batch  # decode: one token per seq


def lower_cell(arch: str, shape_name: str, mesh, *, remat_policy=None, rules=None,
               extra_cfg=None, matmul_accum=None, cache_dtype=None):
    """Build + lower + compile one cell; returns (lowered, compiled, meta)."""
    import dataclasses

    if matmul_accum is not None:  # §Perf lever: bf16 halves backward psums
        from ..models.layers import set_matmul_accum_dtype
        set_matmul_accum_dtype(getattr(jnp, matmul_accum))

    cfg = get_config(arch)
    if remat_policy:
        cfg = dataclasses.replace(cfg, remat_policy=remat_policy)
    if extra_cfg:
        cfg = dataclasses.replace(cfg, **extra_cfg)
    shape = SHAPES[shape_name]
    ok, why = cell_supported(cfg, shape)
    if not ok:
        raise ValueError(f"unsupported cell: {why}")

    base_rules = dict(cfg.logical_rules)
    if rules:
        base_rules.update(rules)
    if shape.kind == "decode":
        # batch=1 long-context cells can't shard batch; the KV cache shards
        # on sequence instead (distributed flash-decode)
        dp = int(np.prod([mesh.shape[a] for a in ("pod", "data") if a in mesh.axis_names]))
        if shape.global_batch % dp:
            base_rules["batch"] = None
    ctx = ShardingCtx(mesh, base_rules)
    model = LM(cfg, ctx)
    params_abs, axes = model.init(jax.random.key(0), abstract=True)
    p_sh = tree_shardings(axes, mesh, ctx.rules)
    specs = input_specs(cfg, shape)

    if shape.kind == "train":
        opt_name = "adafactor" if cfg.param_count() > 20e9 else "adamw"
        tc = TrainConfig(optimizer=OptimizerConfig(name=opt_name))
        train_step, opt_init = build_train_step(model, tc, axes)
        opt_abs = jax.eval_shape(opt_init, params_abs)
        (p_s, o_s, b_s), (po_s, oo_s, m_s) = step_shardings(model, tc, axes, params_abs, shape)
        fn = jax.jit(train_step, in_shardings=(p_s, o_s, b_s),
                     out_shardings=(po_s, oo_s, m_s), donate_argnums=(0, 1))
        lowered = fn.lower(params_abs, opt_abs, specs)
    elif shape.kind == "prefill":
        def prefill_step(params, batch):
            return model.prefill(params, batch)
        from ..configs.base import batch_logical_axes
        b_sh = tree_shardings(batch_logical_axes(cfg, shape), mesh, ctx.rules)
        fn = jax.jit(prefill_step, in_shardings=(p_sh, b_sh), out_shardings=None)
        lowered = fn.lower(params_abs, specs)
    else:  # decode
        B = shape.global_batch
        kv_dtype = getattr(jnp, cache_dtype) if cache_dtype else jnp.bfloat16
        caches_abs = jax.eval_shape(
            partial(model.init_caches, B, shape.seq_len, dtype=kv_dtype))
        seq_sharded = model._seq_sharded_decode((B,))
        c_axes = model.cache_logical_axes(seq_sharded)
        c_sh = tree_shardings(c_axes, mesh, ctx.rules)
        tok_sh = tree_shardings({"tokens": (None if seq_sharded else "batch", None)},
                                mesh, ctx.rules)["tokens"]

        def serve_step(params, caches, tokens, pos):
            return model.decode_step(params, caches, tokens, pos)

        fn = jax.jit(serve_step, in_shardings=(p_sh, c_sh, tok_sh, None),
                     out_shardings=(None, c_sh), donate_argnums=(1,))
        lowered = fn.lower(params_abs, caches_abs, specs["tokens"], specs["pos"])

    compiled = lowered.compile()
    return lowered, compiled, {"cfg": cfg, "shape": shape}


def analyze(compiled, cfg, shape, mesh) -> dict:
    from .hloanalysis import analyze_module

    chips = mesh.devices.size
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    # trip-count-aware analysis (XLA's own counts loop bodies once)
    c = analyze_module(hlo, default_trips=cfg.n_superblocks)
    flops_dev = c.flops
    bytes_dev = c.bytes
    mf = model_flops(cfg, shape)

    compute_s = flops_dev / PEAK_FLOPS_BF16
    memory_s = bytes_dev / HBM_BW
    collective_s = c.total_collective_bytes / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s, "collective_s": collective_s}
    dominant = max(terms, key=terms.get)

    return {
        "chips": chips,
        "memory_analysis": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "code_bytes": getattr(mem, "generated_code_size_in_bytes", 0),
        },
        "hlo_flops_per_device": flops_dev,
        "hlo_bytes_per_device": bytes_dev,
        "xla_cost_analysis_raw": {"flops": float(cost.get("flops", 0.0)),
                                  "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
                                  "note": "loop bodies counted once by XLA"},
        "collectives": c.as_dict(),
        "model_flops_global": mf,
        "model_flops_per_device": mf / chips,
        "useful_flops_ratio": (mf / chips) / flops_dev if flops_dev else None,
        "roofline": {**terms, "dominant": dominant,
                     "step_time_lower_bound_s": max(terms.values()),
                     "roofline_fraction_vs_compute": (
                         compute_s / max(terms.values()) if max(terms.values()) > 0 else None)},
    }


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: Path = ARTIFACTS,
             **kw) -> dict:
    mesh_name = "multipod_2x16x16" if multi_pod else "pod_16x16"
    record: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_name}
    t0 = time.time()
    try:
        cfg = get_config(arch)
        ok, why = cell_supported(cfg, SHAPES[shape_name])
        if not ok:
            record.update(status="skipped", reason=why)
        else:
            mesh = make_production_mesh(multi_pod=multi_pod)
            lowered, compiled, meta = lower_cell(arch, shape_name, mesh, **kw)
            record.update(status="ok", **analyze(compiled, meta["cfg"], meta["shape"], mesh))
            print(f"[dryrun] {arch} × {shape_name} × {mesh_name}: OK "
                  f"dominant={record['roofline']['dominant']}")
            print(f"  memory_analysis: {record['memory_analysis']}")
            print(f"  cost_analysis: flops/dev={record['hlo_flops_per_device']:.3e} "
                  f"bytes/dev={record['hlo_bytes_per_device']:.3e}")
    except Exception as e:  # a failed cell is a bug — record it loudly
        record.update(status="error", error=f"{type(e).__name__}: {e}",
                      traceback=traceback.format_exc()[-4000:])
        print(f"[dryrun] {arch} × {shape_name} × {mesh_name}: FAILED {e}")
    record["wall_s"] = round(time.time() - t0, 1)
    out_dir.mkdir(parents=True, exist_ok=True)
    path = out_dir / f"{arch}__{shape_name}__{mesh_name}.json"
    path.write_text(json.dumps(record, indent=2, default=str))
    return record


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    args = ap.parse_args()

    if args.all:
        for arch in ARCH_IDS:
            for shape in SHAPES:
                run_cell(arch, shape, args.multi_pod)
        return
    assert args.arch and args.shape, "--arch/--shape or --all"
    rec = run_cell(args.arch, args.shape, args.multi_pod)
    if rec["status"] == "error":
        raise SystemExit(1)


if __name__ == "__main__":
    main()

"""End-to-end training driver: Flight data service → loader → pjit trainer.

  PYTHONPATH=src python -m repro.launch.train --arch internlm2_1_8b --smoke \\
      --steps 200 --batch-size 8 --seq-len 256 [--d-model 512 --layers 8]

On this CPU container it trains the reduced config; on a TPU pod the same
driver takes ``--arch <id>`` (full config) with the production mesh.  The
supervisor restarts from the last committed checkpoint on failure.
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2_1_8b")
    ap.add_argument("--smoke", action="store_true", help="use the reduced config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--d-model", type=int, default=0, help="override width (0=config)")
    ap.add_argument("--layers", type=int, default=0)
    ap.add_argument("--vocab", type=int, default=0)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--checkpoint-every", type=int, default=100)
    ap.add_argument("--docs", type=int, default=20000)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--streams", type=int, default=4)
    args = ap.parse_args()

    from ..configs import get_config, get_smoke_config
    from ..core.flight import FlightClient, InMemoryFlightServer
    from ..data import FlightDataLoader, synthesize_corpus
    from ..distributed.fault import RestartPolicy, TrainSupervisor
    from ..distributed.sharding import single_device_ctx
    from ..models.lm import LM
    from ..train.loop import Trainer, TrainerConfig
    from ..train.optimizer import OptimizerConfig
    from ..train.step import TrainConfig

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    overrides = {}
    if args.d_model:
        overrides.update(d_model=args.d_model)
    if args.layers:
        overrides.update(n_layers=args.layers)
    if args.vocab:
        overrides.update(vocab=args.vocab)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)

    ctx = single_device_ctx(cfg.logical_rules)
    model = LM(cfg, ctx)
    n_params = cfg.param_count()
    print(f"[train] {cfg.name}: ~{n_params/1e6:.1f}M params, "
          f"batch {args.batch_size}×{args.seq_len}")

    # data plane: local Flight service over a synthetic corpus
    data_srv = InMemoryFlightServer(batches_per_endpoint=1).serve_tcp()
    data_srv.add_dataset("corpus", synthesize_corpus(
        args.docs, cfg.vocab, mean_len=args.seq_len, seed=args.seed))
    loader = FlightDataLoader(FlightClient(f"tcp://127.0.0.1:{data_srv.port}"),
                              "corpus", batch_size=args.batch_size,
                              seq_len=args.seq_len, streams=args.streams)

    tcfg = TrainerConfig(
        total_steps=args.steps,
        checkpoint_every=args.checkpoint_every,
        train=TrainConfig(optimizer=OptimizerConfig(
            learning_rate=args.lr, warmup_steps=max(10, args.steps // 20),
            total_steps=args.steps)),
    )
    trainer = Trainer(model, tcfg, args.ckpt_dir, loader)

    def run(start_step: int) -> int:
        state, loader_state = trainer.restore_or_init(args.seed)
        final = trainer.run(state)
        losses = final["losses"]
        k = max(len(losses) // 10, 1)
        print(f"[train] loss first-{k}-mean {np.mean(losses[:k]):.4f} -> "
              f"last-{k}-mean {np.mean(losses[-k:]):.4f}")
        return final["step"]

    sup = TrainSupervisor(RestartPolicy(max_restarts=3, backoff_s=1.0), trainer.ckpt)
    sup.run(run)
    loader.close()
    data_srv.shutdown()


if __name__ == "__main__":
    main()

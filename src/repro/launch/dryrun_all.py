"""Sweep driver: run every (arch × shape × mesh) dry-run cell as a separate
subprocess (isolates compile memory; a crash in one cell can't kill the
sweep).  Writes/updates experiments/artifacts/*.json incrementally and prints
a summary table at the end.

  python -m repro.launch.dryrun_all [--multi-pod] [--only arch1,arch2] [--redo]
"""
from __future__ import annotations

import argparse
import json
import subprocess
import sys
import time
from pathlib import Path

REPO = Path(__file__).resolve().parents[3]
ARTIFACTS = REPO / "experiments" / "artifacts"


def cells():
    from ..configs.base import ARCH_IDS, SHAPES, cell_supported, get_config
    out = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape, spec in SHAPES.items():
            ok, why = cell_supported(cfg, spec)
            out.append((arch, shape, ok, why))
    return out


def artifact_path(arch: str, shape: str, multi_pod: bool) -> Path:
    mesh = "multipod_2x16x16" if multi_pod else "pod_16x16"
    return ARTIFACTS / f"{arch}__{shape}__{mesh}.json"


def run_one(arch: str, shape: str, multi_pod: bool, timeout: int = 5400) -> dict:
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch, "--shape", shape]
    if multi_pod:
        cmd.append("--multi-pod")
    env = {"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin:/usr/local/bin"}
    t0 = time.time()
    try:
        proc = subprocess.run(cmd, capture_output=True, text=True, timeout=timeout,
                              env=env, cwd=str(REPO))
        status = "ok" if proc.returncode == 0 else "error"
        tail = (proc.stdout + proc.stderr)[-1500:]
    except subprocess.TimeoutExpired:
        status, tail = "timeout", ""
    p = artifact_path(arch, shape, multi_pod)
    if p.exists():
        rec = json.loads(p.read_text())
    else:
        rec = {"arch": arch, "shape": shape, "status": status, "log_tail": tail,
               "wall_s": round(time.time() - t0, 1)}
        ARTIFACTS.mkdir(parents=True, exist_ok=True)
        p.write_text(json.dumps(rec, indent=2))
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--only", default="")
    ap.add_argument("--redo", action="store_true")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    todo = cells()
    for multi_pod in meshes:
        for arch, shape, ok, why in todo:
            if only and arch not in only:
                continue
            p = artifact_path(arch, shape, multi_pod)
            if not ok:
                ARTIFACTS.mkdir(parents=True, exist_ok=True)
                p.write_text(json.dumps({"arch": arch, "shape": shape,
                                         "mesh": p.stem.split("__")[-1],
                                         "status": "skipped", "reason": why}, indent=2))
                print(f"SKIP  {arch} × {shape}: {why}")
                continue
            if p.exists() and not args.redo:
                rec = json.loads(p.read_text())
                if rec.get("status") == "ok":
                    print(f"HAVE  {arch} × {shape} × {'multi' if multi_pod else 'single'}")
                    continue
            t0 = time.time()
            rec = run_one(arch, shape, multi_pod)
            print(f"{rec.get('status','?').upper():5s} {arch} × {shape} × "
                  f"{'multi' if multi_pod else 'single'}  ({time.time()-t0:.0f}s)",
                  flush=True)

    # summary
    n_ok = n_err = n_skip = 0
    for f in sorted(ARTIFACTS.glob("*.json")):
        rec = json.loads(f.read_text())
        s = rec.get("status")
        n_ok += s == "ok"
        n_err += s in ("error", "timeout")
        n_skip += s == "skipped"
    print(f"\nsummary: {n_ok} ok, {n_err} failed, {n_skip} skipped")


if __name__ == "__main__":
    main()

"""Serving driver: LM scoring microservice behind Flight (paper Fig 11).

  PYTHONPATH=src python -m repro.launch.serve --arch internlm2_1_8b --smoke \\
      --requests 64 --port 0
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2_1_8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--batch-rows", type=int, default=16)
    ap.add_argument("--port", type=int, default=0)
    ap.add_argument("--serve-forever", action="store_true")
    args = ap.parse_args()

    from ..configs import get_config, get_smoke_config
    from ..core import RecordBatch
    from ..core.flight import FlightClient, FlightDescriptor
    from ..distributed.sharding import single_device_ctx
    from ..models.lm import LM
    from ..serving import LMScoringService

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = LM(cfg, single_device_ctx(cfg.logical_rules))
    params, _ = model.init(jax.random.key(0))
    svc = LMScoringService(model, params, max_seq=args.max_seq).serve_tcp(port=args.port)
    print(f"[serve] {cfg.name} scoring service on tcp://127.0.0.1:{svc.port}")

    if args.serve_forever:
        try:
            while True:
                time.sleep(3600)
        except KeyboardInterrupt:
            return

    # demo client: stream request batches through DoExchange
    rng = np.random.default_rng(0)
    client = FlightClient(f"tcp://127.0.0.1:{svc.port}")
    lens = rng.integers(4, args.max_seq, args.requests)
    reqs = [[int(t) for t in rng.integers(1, cfg.vocab, l)] for l in lens]
    schema = RecordBatch.from_pydict({"tokens": [reqs[0]]}).schema
    chunks = [
        RecordBatch.from_pydict({"tokens": reqs[s:s + args.batch_rows]}, schema)
        for s in range(0, args.requests, args.batch_rows)
    ]
    # pipelined streaming exchange: a feeder thread pushes request batches
    # while this thread drains scored results (no per-batch round trips)
    ex = client.do_exchange_stream(FlightDescriptor.for_path("score"), schema)
    t0 = time.perf_counter()
    ex.feed(chunks)
    scored = sum(out.num_rows for out in ex)
    dt = time.perf_counter() - t0
    ex.close()
    print(f"[serve] scored {scored} requests in {dt:.2f}s "
          f"({scored / dt:.1f} req/s, batched {args.batch_rows}/exchange)")
    svc.shutdown()


if __name__ == "__main__":
    main()

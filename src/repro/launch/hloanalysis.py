"""Trip-count-aware HLO cost analysis (the §Roofline engine).

XLA's ``HloCostAnalysis`` (what ``compiled.cost_analysis()`` wraps) visits a
``while`` body **once** — for scan-over-layers models that undercounts FLOPs,
bytes, and collective traffic by the layer count (24-94×).  This module
parses the compiled module text, builds a per-computation symbol table
(operand types are *not* inline in modern HLO), and evaluates costs
bottom-up with loop bodies multiplied by parsed trip counts.

Cost model:
  flops   — dot/convolution: 2 · numel(result) · K (K = product of the lhs
            contracting dims, resolved through the symbol table).
  bytes   — per op: result + operand buffer bytes, with three refinements:
            (a) fusion ops charge boundary buffers only (inner ops are
                registers — this *is* the HBM-traffic view);
            (b) a fusion param whose only inner consumer is a
                dynamic-slice/gather charges the slice size, not the full
                operand — critical for scan-stacked layer weights, which
                would otherwise be charged layers× their footprint;
            (c) standalone dynamic-slice / gather / dynamic-update-slice
                charge ~2× the moved slice, not the whole table.
  collectives — per-class byte totals (all-reduce ×2 for ring up+down),
            trip-multiplied like everything else.

Trip counts parse from the canonical scan condition (`compare(iv,
constant(N)), direction=LT`); unparseable loops fall back to
``default_trips``.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3b11fnuz": 1, "f8e4m3fnuz": 1, "f8e5m2fnuz": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1, "c64": 8, "c128": 16,
}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")
_SLICY = ("dynamic-slice", "gather", "dynamic-update-slice")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\](?:\{[^}]*\})?")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+)$")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_TRIP_CONST_RE = re.compile(r"constant\((\d+)\)")
_KNOWN_TRIPS_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_OPCODE_AFTER_TYPE_RE = re.compile(r"\s*([\w\-]+)\(")


def _split_type_opcode(rhs: str) -> tuple[str, str] | None:
    """Split 'TYPE opcode(...)' handling tuple types with /*index=N*/ comments."""
    if rhs.startswith("("):
        depth = 0
        for j, ch in enumerate(rhs):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        else:
            return None
        type_str, rest = rhs[: j + 1], rhs[j + 1 :]
    else:
        m = _SHAPE_RE.match(rhs)
        if not m:
            return None
        type_str, rest = m.group(0), rhs[m.end():]
    om = _OPCODE_AFTER_TYPE_RE.match(rest)
    if not om:
        return None
    return type_str, om.group(1)


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _numel(type_str: str) -> int:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return 0
    n = 1
    for d in m.group(2).split(","):
        if d:
            n *= int(d)
    return n


@dataclass
class Op:
    name: str
    result_type: str
    opcode: str
    rhs: str

    def operands(self, upto: str | None = None) -> list[str]:
        """Operand names inside the op's parens (before attribute section)."""
        i = self.rhs.find("(")
        if i < 0:
            return []
        depth, j = 0, i
        for j in range(i, len(self.rhs)):
            if self.rhs[j] == "(":
                depth += 1
            elif self.rhs[j] == ")":
                depth -= 1
                if depth == 0:
                    break
        return _OPERAND_RE.findall(self.rhs[i + 1 : j])

    def attr(self, name: str) -> str | None:
        m = re.search(name + r"=\{?%?([\w.\-]+)", self.rhs)
        return m.group(1) if m else None


@dataclass
class Computation:
    name: str
    ops: list = field(default_factory=list)
    symbols: dict = field(default_factory=dict)  # op name -> result type


def parse_module(hlo_text: str) -> tuple[dict[str, Computation], str]:
    comps: dict[str, Computation] = {}
    entry_name = None
    current: Computation | None = None
    for raw in hlo_text.splitlines():
        line = raw.rstrip()
        s = line.strip()
        if not s or s.startswith("//"):
            continue
        if s.endswith("{") and "->" in s and "=" not in s.split("->")[0][:16]:
            is_entry = s.startswith("ENTRY")
            name = s.split()[1] if is_entry else s.split()[0]
            name = name.lstrip("%").split("(")[0].strip()
            current = Computation(name)
            comps[name] = current
            if is_entry:
                entry_name = name
            continue
        if s == "}":
            current = None
            continue
        if current is None:
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        opname, rhs = m.group(1), m.group(2)
        split = _split_type_opcode(rhs)
        if split is None:
            continue
        result_type, opcode = split
        op = Op(opname, result_type, opcode, rhs)
        current.ops.append(op)
        current.symbols[opname] = result_type
    if entry_name is None:
        # fall back: last computation
        entry_name = list(comps)[-1] if comps else ""
    return comps, entry_name


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: dict = field(default_factory=lambda: {k: 0.0 for k in COLLECTIVE_OPS})
    collective_counts: dict = field(default_factory=lambda: {k: 0.0 for k in COLLECTIVE_OPS})

    def add(self, other: "Cost", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        for k in COLLECTIVE_OPS:
            self.collective_bytes[k] += other.collective_bytes[k] * mult
            self.collective_counts[k] += other.collective_counts[k] * mult

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())

    def as_dict(self) -> dict:
        return {
            "flops": self.flops,
            "bytes": self.bytes,
            "collective_bytes": dict(self.collective_bytes),
            "collective_counts": dict(self.collective_counts),
            "total_collective_bytes": self.total_collective_bytes,
        }


class HloAnalyzer:
    def __init__(self, hlo_text: str, default_trips: int = 1):
        self.comps, self.entry = parse_module(hlo_text)
        self.default_trips = default_trips
        self._cost_memo: dict[str, Cost] = {}
        self._charge_memo: dict[str, list] = {}

    # -- helpers ---------------------------------------------------------- #
    def _dot_flops(self, comp: Computation, op: Op) -> float:
        numel = _numel(op.result_type)
        ops = op.operands()
        cdims = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.rhs)
        if not ops or cdims is None:
            return 2.0 * numel
        lhs_t = comp.symbols.get(ops[0], "")
        m = _SHAPE_RE.search(lhs_t)
        if not m:
            return 2.0 * numel
        lhs_dims = [int(d) for d in m.group(2).split(",") if d]
        K = 1
        for ci in cdims.group(1).split(","):
            if ci and int(ci) < len(lhs_dims):
                K *= lhs_dims[int(ci)]
        return 2.0 * numel * K

    def _fusion_param_charges(self, fname: str) -> list:
        """Per-parameter byte charge for a fusion computation.

        Returns list indexed by parameter number: 'full' or int byte count
        (when the param's only consumers — looking *through convert chains*,
        which are XLA:CPU bf16-legalization artifacts absent on TPU — are
        slicing ops)."""
        if fname in self._charge_memo:
            return self._charge_memo[fname]
        comp = self.comps.get(fname)
        if comp is None:
            self._charge_memo[fname] = []
            return []
        params: dict[str, int] = {}
        for op in comp.ops:
            if op.opcode == "parameter":
                m = re.search(r"parameter\((\d+)\)", op.rhs)
                if m:
                    params[op.name] = int(m.group(1))
        consumers: dict[str, list[Op]] = {}
        for op in comp.ops:
            for o in op.operands():
                consumers.setdefault(o, []).append(op)

        def effective_consumers(name: str, depth: int = 0) -> list[Op]:
            """Consumers with convert/bitcast/copy chains expanded."""
            out: list[Op] = []
            for c in consumers.get(name, []):
                if c.opcode in ("convert", "bitcast", "copy") and depth < 6:
                    out.extend(effective_consumers(c.name, depth + 1))
                else:
                    out.append(c)
            return out

        n_params = max(params.values()) + 1 if params else 0
        out: list = ["full"] * n_params
        for pname, idx in params.items():
            cs = effective_consumers(pname)
            if cs and all(c.opcode in _SLICY for c in cs):
                total = 0
                for c in cs:
                    if c.opcode == "dynamic-update-slice":
                        # moved bytes = update operand size (2nd operand)
                        ops_c = c.operands()
                        upd_t = comp.symbols.get(ops_c[1], "") if len(ops_c) > 1 else ""
                        total += 2 * _shape_bytes(upd_t)
                    else:
                        total += _shape_bytes(c.result_type)
                out[idx] = total
        self._charge_memo[fname] = out
        return out

    def _fusion_result_charge(self, fname: str | None, op: Op) -> int:
        """Result-side byte charge for a fusion.  If the fusion's root is a
        dynamic-update-slice (possibly behind convert/bitcast chains — CPU
        bf16 legalization), XLA updates in place — charge the moved slice,
        not the whole carried buffer (critical: scan carries update stacked
        buffers every iteration)."""
        comp = self.comps.get(fname or "")
        if comp and comp.ops:
            root = comp.ops[-1]
            hops = 0
            while root.opcode in ("convert", "bitcast", "copy") and hops < 6:
                opnds = root.operands()
                nxt = next((o for o in comp.ops if opnds and o.name == opnds[0]), None)
                if nxt is None:
                    break
                root, hops = nxt, hops + 1
            if root.opcode == "dynamic-update-slice":
                ops_c = root.operands()
                upd_t = comp.symbols.get(ops_c[1], "") if len(ops_c) > 1 else ""
                if upd_t:
                    return 2 * _shape_bytes(upd_t)
        return _shape_bytes(op.result_type)

    def _while_trips(self, op: "Op", cond_name: str | None) -> int:
        # authoritative: XLA's own analysis in backend_config
        m = _KNOWN_TRIPS_RE.search(op.rhs)
        if m:
            return max(int(m.group(1)), 1)
        comp = self.comps.get(cond_name or "")
        if comp is None:
            return self.default_trips
        consts = []
        for o in comp.ops:
            consts += [int(c) for c in _TRIP_CONST_RE.findall(o.rhs)]
        if not consts:
            return self.default_trips
        return max(max(consts), 1)

    # -- main ------------------------------------------------------------- #
    def cost(self, comp_name: str | None = None, in_loop: bool = False) -> Cost:
        name = comp_name or self.entry
        key = f"{name}|{in_loop}"
        if key in self._cost_memo:
            return self._cost_memo[key]
        comp = self.comps.get(name)
        total = Cost()
        self._cost_memo[key] = total
        if comp is None:
            return total
        for op in comp.ops:
            oc = op.opcode
            if oc in ("parameter", "constant", "tuple", "get-tuple-element",
                      "bitcast", "after-all", "iota"):
                continue
            if oc == "copy" and in_loop:
                # XLA:TPU aliases while-loop carries in place; carry copies
                # are CPU-backend artifacts — elide them from the HBM model
                continue
            if oc in ("dot", "convolution"):
                total.flops += self._dot_flops(comp, op)
                total.bytes += _shape_bytes(op.result_type) + sum(
                    _shape_bytes(comp.symbols.get(o, "")) for o in op.operands())
                continue
            if oc in COLLECTIVE_OPS:
                nbytes = _shape_bytes(op.result_type)
                total.collective_bytes[oc] += nbytes * (2.0 if oc == "all-reduce" else 1.0)
                total.collective_counts[oc] += 1
                total.bytes += 2 * nbytes
                continue
            if oc == "while":
                body, cond = op.attr("body"), op.attr("condition")
                trips = self._while_trips(op, cond)
                if body:
                    total.add(self.cost(body, in_loop=True), trips)
                if cond:
                    total.add(self.cost(cond, in_loop=True), trips)
                continue
            if oc == "fusion":
                target = op.attr("calls")
                inner = self.cost(target, in_loop=in_loop) if target else Cost()
                total.flops += inner.flops
                for k in COLLECTIVE_OPS:
                    total.collective_bytes[k] += inner.collective_bytes[k]
                    total.collective_counts[k] += inner.collective_counts[k]
                charges = self._fusion_param_charges(target) if target else []
                opnds = op.operands()
                b = self._fusion_result_charge(target, op)
                for i, o in enumerate(opnds):
                    ch = charges[i] if i < len(charges) else "full"
                    b += _shape_bytes(comp.symbols.get(o, "")) if ch == "full" else ch
                total.bytes += b
                continue
            if oc in _SLICY:
                if oc == "dynamic-update-slice":
                    ops_c = op.operands()
                    upd_t = comp.symbols.get(ops_c[1], "") if len(ops_c) > 1 else ""
                    total.bytes += 2 * _shape_bytes(upd_t)
                else:
                    total.bytes += 2 * _shape_bytes(op.result_type)
                continue
            if oc == "call":
                # XLA:CPU wraps thread-partitioned ops in `call`s of
                # `parallel_*` computations.  The call is transparent — cost
                # the callee (whose fusions apply slice-charging) instead of
                # boundary-charging full operands, which would re-charge a
                # scan's stacked weights every iteration.
                target = op.attr("to_apply")
                if target and target in self.comps:
                    total.add(self.cost(target, in_loop=in_loop))
                    continue
            if oc in ("call", "conditional", "sort", "reduce", "reduce-window",
                      "scatter", "map", "select-and-scatter", "custom-call",
                      "async-start"):
                for attr in ("to_apply", "calls"):
                    t = op.attr(attr)
                    if t and t in self.comps:
                        inner = self.cost(t, in_loop=in_loop)
                        total.flops += inner.flops
                        for k in COLLECTIVE_OPS:
                            total.collective_bytes[k] += inner.collective_bytes[k]
                            total.collective_counts[k] += inner.collective_counts[k]
                # bytes: boundary (write result + read operands once)
                total.bytes += _shape_bytes(op.result_type) + sum(
                    _shape_bytes(comp.symbols.get(o, "")) for o in op.operands())
                continue
            # generic elementwise-ish op: boundary model — write the result,
            # read each operand once.  Matches the fusion boundary charge, so
            # a module where XLA fused the op and one where it stayed bare
            # score the same bytes (the scale-with-shapes invariant).
            total.bytes += _shape_bytes(op.result_type) + sum(
                _shape_bytes(comp.symbols.get(o, "")) for o in op.operands())
        return total


def analyze_module(hlo_text: str, default_trips: int = 1) -> Cost:
    return HloAnalyzer(hlo_text, default_trips).cost()

"""Pallas TPU kernel: selection-vector row gather (query-filter materialization).

The server-side work behind the paper's Fig 8 query path: after a predicate
produces a selection vector, the surviving rows must be compacted into a
dense output batch for the wire.  TPU mapping: row indices ride in SMEM
(scalar prefetch); each grid step copies ``block_rows`` rows of the (N, D)
values block into an output tile with dynamic-start row loads.  Negative
indices produce zero rows (null semantics).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gather_kernel(idx_ref, values_ref, out_ref, *, block_rows: int):
    pid = pl.program_id(0)
    row0 = pid * block_rows

    def body(i, _):
        src = idx_ref[row0 + i]
        safe = jnp.clip(src, 0, values_ref.shape[0] - 1)
        row = values_ref[pl.ds(safe, 1), :]
        out_ref[pl.ds(i, 1), :] = jnp.where(src >= 0, row, jnp.zeros_like(row))
        return 0

    jax.lax.fori_loop(0, block_rows, body, 0)


def selection_gather(values: jax.Array, indices: jax.Array, block_rows: int = 8,
                     interpret: bool = True):
    """values (N, D), indices (M,) int32 -> (M, D)."""
    N, D = values.shape
    M = indices.shape[0]
    assert M % block_rows == 0, (M, block_rows)
    grid = (M // block_rows,)
    kernel = functools.partial(_gather_kernel, block_rows=block_rows)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[pl.BlockSpec(values.shape, lambda i, *_: (0, 0))],
            out_specs=pl.BlockSpec((block_rows, D), lambda i, *_: (i, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((M, D), values.dtype),
        interpret=interpret,
    )(indices.astype(jnp.int32), values)

"""Pallas TPU kernels for the paper's data-movement hot-spots.

varlen_unpack     — columnar->padded-dense (deserialization)
quantize/dequant  — int8 wire compression (collectives / transfer)
selection_gather  — query-filter row materialization
flash_decode      — KV-cache decode attention (scoring microservice)

Validated in interpret mode against ref.py oracles (tests/test_kernels.py).
"""
from . import ops, ref  # noqa: F401

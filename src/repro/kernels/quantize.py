"""Pallas TPU kernels: blockwise int8 quantize / dequantize.

The paper's wire-compression theme ("use ~95 % of the bandwidth") applied to
TPU fabrics: gradients/activations are quantized to int8 with one f32 scale
per (row, 128-lane block) before crossing ICI/DCN (see
distributed/collectives.py), quartering collective bytes.

TPU mapping: tiles of (block_m, 128) in VMEM — 128 matches the VPU lane
count, so the per-block |max| reduction is a native cross-lane reduce and the
scale broadcast stays in-register.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE_BLOCK = 128


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)            # (bm, 128)
    amax = jnp.max(jnp.abs(x), axis=-1)           # (bm,)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x / scale[:, None]), -127, 127)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale[:, None]


def _dequant_kernel(q_ref, s_ref, x_ref, *, out_dtype):
    q = q_ref[...].astype(jnp.float32)
    x_ref[...] = (q * s_ref[...]).astype(out_dtype)


def quantize(x: jax.Array, block_m: int = 256, interpret: bool = True):
    """x (M, K) float -> (q int8 (M, K), scales f32 (M, K/128))."""
    M, K = x.shape
    assert K % LANE_BLOCK == 0, (K,)
    bm = min(block_m, M)
    assert M % bm == 0, (M, bm)
    grid = (M // bm, K // LANE_BLOCK)
    q, s = pl.pallas_call(
        _quant_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bm, LANE_BLOCK), lambda i, j: (i, j))],
        out_specs=[
            pl.BlockSpec((bm, LANE_BLOCK), lambda i, j: (i, j)),
            pl.BlockSpec((bm, 1), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((M, K), jnp.int8),
            jax.ShapeDtypeStruct((M, K // LANE_BLOCK), jnp.float32),
        ],
        interpret=interpret,
    )(x)
    return q, s


def dequantize(q: jax.Array, s: jax.Array, out_dtype=jnp.float32,
               block_m: int = 256, interpret: bool = True):
    M, K = q.shape
    bm = min(block_m, M)
    assert M % bm == 0 and K % LANE_BLOCK == 0
    grid = (M // bm, K // LANE_BLOCK)
    kernel = functools.partial(_dequant_kernel, out_dtype=out_dtype)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, LANE_BLOCK), lambda i, j: (i, j)),
            pl.BlockSpec((bm, 1), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((bm, LANE_BLOCK), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, K), out_dtype),
        interpret=interpret,
    )(q, s)

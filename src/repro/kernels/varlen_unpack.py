"""Pallas TPU kernel: Arrow varlen column → padded dense matrix.

The deserialization hot-spot of the paper (row→column materialization,
Fig 4's cliff), TPU-adapted: the ragged ``values`` buffer of an Arrow
``list<T>`` column is unpacked into an (8,128)-aligned padded (N, L) matrix
the MXU can consume directly.

TPU mapping (DESIGN.md §6):
  * ``offsets`` ride in **SMEM** via ``PrefetchScalarGridSpec`` — they're
    control data (DMA descriptors), exactly what scalar prefetch is for.
  * the whole ``values`` region sits in **ANY/VMEM** as one block; each grid
    step copies ``block_rows`` rows with dynamic-start fixed-size slices
    (``pl.ds(start, L)``) and masks the tail with an iota comparison — the
    dynamic-slice+mask idiom replaces per-row variable-length DMA, which the
    TPU DMA engine can't express efficiently.
  * output is tiled (block_rows, L) in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _unpack_kernel(offsets_ref, values_ref, out_ref, lens_ref, *, max_len: int,
                   block_rows: int, pad_id):
    pid = pl.program_id(0)
    row0 = pid * block_rows

    def body(i, _):
        row = row0 + i
        start = offsets_ref[row]
        end = offsets_ref[row + 1]
        length = jnp.minimum(end - start, max_len)
        # fixed-size dynamic-start load; the wrapper pads `values` by max_len
        # so start+max_len is always in bounds without shifting the window
        vals = values_ref[pl.ds(start, max_len)]
        mask = jax.lax.iota(jnp.int32, max_len) < length
        out_ref[i, :] = jnp.where(mask, vals, jnp.asarray(pad_id, vals.dtype))
        lens_ref[i] = length
        return 0

    jax.lax.fori_loop(0, block_rows, body, 0)


def varlen_unpack(offsets: jax.Array, values: jax.Array, max_len: int,
                  pad_id: int = 0, block_rows: int = 8, interpret: bool = True):
    """offsets (N+1,) int32, values (total,) -> (padded (N,max_len), lens (N,))."""
    N = offsets.shape[0] - 1
    assert N % block_rows == 0, (N, block_rows)
    values = jnp.concatenate([values, jnp.zeros((max_len,), values.dtype)])
    grid = (N // block_rows,)
    kernel = functools.partial(_unpack_kernel, max_len=max_len,
                               block_rows=block_rows, pad_id=pad_id)
    out, lens = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,            # offsets land in SMEM
            grid=grid,
            in_specs=[
                pl.BlockSpec(values.shape, lambda i, *_: (0,)),  # whole values block
            ],
            out_specs=[
                pl.BlockSpec((block_rows, max_len), lambda i, *_: (i, 0)),
                pl.BlockSpec((block_rows,), lambda i, *_: (i,)),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((N, max_len), values.dtype),
            jax.ShapeDtypeStruct((N,), jnp.int32),
        ],
        interpret=interpret,
    )(offsets.astype(jnp.int32), values)
    return out, lens

"""Pallas TPU kernel: flash-decode (single-token KV-cache attention).

The latency hot-spot of the batch-scoring microservice (paper Fig 11) and of
``serve_step``: one query token attends over a long KV cache.  TPU mapping:

  * grid = (batch × heads, S/block_s): K/V stream HBM→VMEM block by block
    while the (1, d) query stays resident.
  * online softmax carried in VMEM scratch (m, l, acc) across the S-grid
    dim; finalized on the last block — the same partial-softmax combine that
    ``flash_decode_shardmap`` runs *across chips*, here run *across blocks*.
  * block_s × d tiles are (8,128)-aligned for the VPU/MXU.

This kernel is the single-shard inner loop of the distributed decode path.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _flash_decode_kernel(q_ref, k_ref, v_ref, len_ref, o_ref, m_ref, l_ref,
                         acc_ref, *, block_s: int, scale: float):
    sblk = pl.program_id(1)
    nblk = pl.num_programs(1)

    @pl.when(sblk == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -1e30)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, :].astype(jnp.float32)                 # (d,)
    k = k_ref[...].astype(jnp.float32)                  # (block_s, d)
    v = v_ref[...].astype(jnp.float32)
    length = len_ref[0]

    s = (k @ q) * scale                                  # (block_s,)
    pos = sblk * block_s + jax.lax.iota(jnp.int32, block_s)
    s = jnp.where(pos < length, s, -1e30)

    m_prev = m_ref[0]
    m_new = jnp.maximum(m_prev, jnp.max(s))
    p = jnp.exp(s - m_new)                               # (block_s,)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_ref[0] * corr + jnp.sum(p)
    acc_ref[...] = acc_ref[...] * corr + (p[None, :] @ v)  # (1, d)
    m_ref[0] = m_new
    l_ref[0] = l_new

    @pl.when(sblk == nblk - 1)
    def _finalize():
        o_ref[...] = (acc_ref[...] / jnp.maximum(l_ref[0], 1e-30)).astype(o_ref.dtype)


def flash_decode(q: jax.Array, k: jax.Array, v: jax.Array, length,
                 block_s: int = 512, interpret: bool = True):
    """q (BH, d); k/v (BH, S, d); length (BH,) int32 -> (BH, d).

    Callers flatten (batch, heads) into BH (GQA repeats kv externally).
    """
    BH, d = q.shape
    S = k.shape[1]
    bs = min(block_s, S)
    assert S % bs == 0, (S, bs)
    grid = (BH, S // bs)
    scale = 1.0 / math.sqrt(d)
    kernel = functools.partial(_flash_decode_kernel, block_s=bs, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, d), lambda b, s: (b, 0)),
            pl.BlockSpec((None, bs, d), lambda b, s: (b, s, 0)),
            pl.BlockSpec((None, bs, d), lambda b, s: (b, s, 0)),
            pl.BlockSpec((1,), lambda b, s: (b,)),
        ],
        out_specs=pl.BlockSpec((1, d), lambda b, s: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),    # m: running max
            pltpu.VMEM((1,), jnp.float32),    # l: running denom
            pltpu.VMEM((1, d), jnp.float32),  # acc
        ],
        interpret=interpret,
    )(q, k, v, jnp.asarray(length, jnp.int32))

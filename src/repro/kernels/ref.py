"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

Each function is the *mathematical definition* the kernel must match; tests
sweep shapes/dtypes and assert allclose between ``ops.py`` (interpret-mode
Pallas) and these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def varlen_unpack_ref(offsets: jax.Array, values: jax.Array, max_len: int,
                      pad_id: int = 0):
    """Arrow list<int> column -> padded dense (N, max_len) + lengths.

    offsets: (N+1,) int32 monotone; values: (total,) — the deserialization
    hot-spot: ragged columnar rows become an MXU-friendly padded matrix.
    Rows longer than max_len are truncated.
    """
    N = offsets.shape[0] - 1
    starts = offsets[:-1]
    lens = jnp.minimum(offsets[1:] - starts, max_len)
    idx = starts[:, None] + jnp.arange(max_len, dtype=offsets.dtype)[None, :]
    idx = jnp.clip(idx, 0, values.shape[0] - 1)
    out = values[idx]
    mask = jnp.arange(max_len, dtype=offsets.dtype)[None, :] < lens[:, None]
    out = jnp.where(mask, out, jnp.asarray(pad_id, values.dtype))
    return out, lens.astype(jnp.int32)


def quantize_ref(x: jax.Array, block: int = 128):
    """Blockwise symmetric int8 quantization along the last dim.

    x: (..., K) float -> (q int8 (..., K), scales f32 (..., K//block)).
    """
    *lead, K = x.shape
    assert K % block == 0, (K, block)
    xb = x.astype(jnp.float32).reshape(*lead, K // block, block)
    amax = jnp.max(jnp.abs(xb), axis=-1)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(xb / scale[..., None]), -127, 127).astype(jnp.int8)
    return q.reshape(*lead, K), scale


def dequantize_ref(q: jax.Array, scale: jax.Array, block: int = 128,
                   dtype=jnp.float32):
    *lead, K = q.shape
    qb = q.reshape(*lead, K // block, block).astype(jnp.float32)
    return (qb * scale[..., None]).reshape(*lead, K).astype(dtype)


def selection_gather_ref(values: jax.Array, indices: jax.Array):
    """Query-filter materialization: rows of ``values`` (N, D) at ``indices``
    (M,) int32 (may repeat / be unsorted).  Negative index = zero row."""
    safe = jnp.maximum(indices, 0)
    out = values[safe]
    return jnp.where((indices >= 0)[:, None], out, jnp.zeros_like(out))


def flash_decode_ref(q: jax.Array, k: jax.Array, v: jax.Array, length,
                     softmax_scale: float | None = None):
    """Single-step KV-cache attention (the serving hot-spot).

    q: (B, H, d); k/v: (B, S, H, d); length: scalar/(B,) valid prefix.
    """
    import math
    B, H, d = q.shape
    S = k.shape[1]
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(d)
    s = jnp.einsum("bhd,bshd->bhs", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    pos = jnp.arange(S)
    valid = pos[None, :] < jnp.broadcast_to(jnp.asarray(length).reshape(-1, 1), (B, S))
    s = jnp.where(valid[:, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhs,bshd->bhd", p, v.astype(jnp.float32)).astype(q.dtype)

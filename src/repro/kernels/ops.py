"""Jit'd public wrappers for the Pallas kernels.

On TPU the wrappers run the compiled kernels (``interpret=False``); on CPU
(this container) they run the kernel bodies in interpret mode for
correctness, or fall back to the ``ref.py`` oracle where interpret overhead
is prohibitive for large inputs.  The data plane / serving layers call only
these entry points.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import ref
from .flash_decode import flash_decode as _flash_decode
from .quantize import dequantize as _dequantize
from .quantize import quantize as _quantize
from .selection_gather import selection_gather as _selection_gather
from .varlen_unpack import varlen_unpack as _varlen_unpack


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _use_pallas(override: bool | None) -> bool:
    if override is not None:
        return override
    return on_tpu()


@partial(jax.jit, static_argnames=("max_len", "pad_id", "use_pallas", "interpret"))
def varlen_unpack(offsets, values, max_len: int, pad_id: int = 0,
                  use_pallas: bool | None = None, interpret: bool | None = None):
    """Arrow list column -> padded (N, max_len) + lengths (the data plane's
    columnar->tensor conversion; see data/loader.py)."""
    if _use_pallas(use_pallas):
        return _varlen_unpack(offsets, values, max_len, pad_id,
                              interpret=not on_tpu() if interpret is None else interpret)
    return ref.varlen_unpack_ref(offsets, values, max_len, pad_id)


@partial(jax.jit, static_argnames=("block", "use_pallas"))
def quantize(x, block: int = 128, use_pallas: bool | None = None):
    if _use_pallas(use_pallas):
        return _quantize(x, interpret=not on_tpu())
    return ref.quantize_ref(x, block)


@partial(jax.jit, static_argnames=("block", "out_dtype", "use_pallas"))
def dequantize(q, scales, block: int = 128, out_dtype=jnp.float32,
               use_pallas: bool | None = None):
    if _use_pallas(use_pallas):
        return _dequantize(q, scales, out_dtype, interpret=not on_tpu())
    return ref.dequantize_ref(q, scales, block, out_dtype)


@partial(jax.jit, static_argnames=("use_pallas",))
def selection_gather(values, indices, use_pallas: bool | None = None):
    if _use_pallas(use_pallas):
        return _selection_gather(values, indices, interpret=not on_tpu())
    return ref.selection_gather_ref(values, indices)


@partial(jax.jit, static_argnames=("block_s", "use_pallas"))
def flash_decode(q, k, v, length, block_s: int = 512, use_pallas: bool | None = None):
    """q (B,H,d), k/v (B,S,H,d), length (B,) -> (B,H,d)."""
    if _use_pallas(use_pallas):
        B, H, d = q.shape
        S = k.shape[1]
        qf = q.reshape(B * H, d)
        kf = jnp.swapaxes(k, 1, 2).reshape(B * H, S, d)
        vf = jnp.swapaxes(v, 1, 2).reshape(B * H, S, d)
        lf = jnp.repeat(jnp.asarray(length, jnp.int32).reshape(-1), H)
        out = _flash_decode(qf, kf, vf, lf, block_s=block_s, interpret=not on_tpu())
        return out.reshape(B, H, d)
    return ref.flash_decode_ref(q, k, v, length)

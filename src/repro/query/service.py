"""FlightQueryService — retired shim, now a pure re-export.

Query pushdown is native to the Flight control plane:
``InMemoryFlightServer`` plans ``GetFlightInfo(QueryCommand)`` into
per-range query endpoints, executes ``QueryCommand`` tickets via
``query.engine.execute``, and serves the ``aggregate`` DoAction (filtered
aggregation server-side — only scalars cross the wire).  Use
``InMemoryFlightServer`` (or ``FlightClusterServer`` +
``FlightClusterClient.query`` for sharded pushdown) with
``FlightDescriptor.for_query(plan)``; the typed-command wire format is
specified in docs/wire-format.md ("0xC2 — the Command union").

The alias below keeps existing imports working for one release.
"""
from __future__ import annotations

from ..core.flight.server import InMemoryFlightServer

FlightQueryService = InMemoryFlightServer

__all__ = ["FlightQueryService"]

"""FlightQueryService — the Dremio analogue (paper §4.1, Fig 8).

**Deprecated shim.**  Query pushdown is native to the Flight control plane:
``InMemoryFlightServer`` plans ``GetFlightInfo(QueryCommand)`` into
per-range query endpoints and executes ``QueryCommand`` tickets via
``query.engine.execute``.  Use ``InMemoryFlightServer`` (or
``FlightClusterServer`` + ``FlightClusterClient.query`` for sharded
pushdown) with ``FlightDescriptor.for_query(plan)`` — the typed-command
wire format, including ``QueryCommand``'s byte layout, is specified in
docs/wire-format.md ("0xC2 — the Command union"); README.md's quickstart
shows the replacement call pattern.

This class remains for one release so existing imports keep working; the
only behavior it still adds is the ``aggregate`` action (filtered
aggregation server-side — only scalars cross the wire).
"""
from __future__ import annotations

import json

from ..core.flight.protocol import ActionResult
from ..core.flight.server import InMemoryFlightServer
from .engine import QueryPlan, aggregate


class FlightQueryService(InMemoryFlightServer):
    """InMemory store + query pushdown over Flight (deprecated alias)."""

    def __init__(self, endpoints_per_query: int = 4, **kw):
        super().__init__(endpoints_per_query=endpoints_per_query, **kw)

    def do_action_impl(self, action):
        if action.type == "aggregate":
            plan = QueryPlan.deserialize(action.body)
            with self._lock:
                batches = self._store[plan.dataset]
            out = aggregate(plan, batches)
            return [ActionResult(json.dumps(out).encode())]
        return super().do_action_impl(action)

"""FlightQueryService — the Dremio analogue (paper §4.1, Fig 8).

A Flight server whose ``GetFlightInfo(command=<QueryPlan>)`` plans a query:
the returned endpoints carry tickets that execute the plan server-side
(filter/project on columnar batches) and stream only surviving columns/rows.
One endpoint per stored batch-range → clients parallelize with
``read_all_parallel`` exactly like the Spark DataSource does (Fig 10).
"""
from __future__ import annotations

import json
from typing import Iterator

from ..core.flight.protocol import (
    FlightDescriptor,
    FlightEndpoint,
    FlightError,
    FlightInfo,
    Ticket,
)
from ..core.flight.server import InMemoryFlightServer
from ..core.recordbatch import RecordBatch
from ..core.schema import Schema
from .engine import QueryPlan, aggregate, execute


class FlightQueryService(InMemoryFlightServer):
    """InMemory store + query pushdown over Flight."""

    def __init__(self, endpoints_per_query: int = 4, **kw):
        super().__init__(**kw)
        self.endpoints_per_query = endpoints_per_query

    def get_flight_info_impl(self, descriptor: FlightDescriptor) -> FlightInfo:
        if descriptor.command is None:
            return super().get_flight_info_impl(descriptor)
        plan = QueryPlan.deserialize(descriptor.command)
        with self._lock:
            if plan.dataset not in self._store:
                raise FlightError(f"no such dataset: {plan.dataset}")
            batches = self._store[plan.dataset]
            schema = self._schemas[plan.dataset]
        out_schema = schema.select(plan.projection) if plan.projection else schema
        n = len(batches)
        per = max(1, -(-n // self.endpoints_per_query))
        endpoints = [
            FlightEndpoint(
                Ticket.for_range(plan.dataset, i, min(i + per, n),
                                 plan=descriptor.command.decode()),
                self.locations(),
            )
            for i in range(0, n, per)
        ]
        return FlightInfo(out_schema, descriptor, endpoints, total_records=-1, total_bytes=-1)

    def do_get_impl(self, ticket: Ticket) -> tuple[Schema, Iterator[RecordBatch]]:
        r = ticket.range()
        if "plan" not in r:
            return super().do_get_impl(ticket)
        plan = QueryPlan.deserialize(r["plan"].encode())
        with self._lock:
            batches = self._store[plan.dataset][r["start"]:r["stop"]]
            schema = self._schemas[plan.dataset]
        out_schema = schema.select(plan.projection) if plan.projection else schema
        results = list(execute(plan, batches))
        if not results:  # empty result set still needs a schema'd stream
            results = []
        return out_schema, iter(results)

    def do_action_impl(self, action):
        if action.type == "aggregate":
            plan = QueryPlan.deserialize(action.body)
            with self._lock:
                batches = self._store[plan.dataset]
            out = aggregate(plan, batches)
            from ..core.flight.protocol import ActionResult
            return [ActionResult(json.dumps(out).encode())]
        return super().do_action_impl(action)

"""Query execution over RecordBatches: projection / predicate / aggregation.

``QueryPlan`` is the wire-serializable plan a Flight descriptor carries
(``FlightDescriptor.for_command(plan.serialize())``).  Execution is fully
columnar: predicates produce selection masks, projections are zero-copy
column subsets, and only then do surviving rows materialize — the ordering
the paper credits for the 20-30× over row-based protocols.

Aggregation follows the partial/final operator split (the "Mainlining
Databases" shape): ``partial_aggregate`` folds batches into a per-group
*state* RecordBatch wherever the data lives, and ``merge_partials`` merges
any number of state batches — from one node or from N shards — into the
final result.  The state for ``mean`` is a ``(sum, count)`` pair, so the
merge is exact up to float-summation order regardless of how the rows were
split into batches or shards; ``min``/``max`` states keep the value
column's native dtype.  A plan with ``group_by`` keys produces one output
row per distinct key tuple; without keys the same code path degenerates to
a single global group and ``aggregate`` returns the historical scalar dict.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Iterator

import numpy as np

from ..core.recordbatch import RecordBatch
from ..core.schema import Field, PrimitiveType, Schema, float64, int64
from .expr import (
    Expr,
    evaluate,
    key_column,
    key_sort_token,
    key_tuples,
    referenced_columns,
)

AGG_OPS = ("sum", "mean", "min", "max", "count")


@dataclass
class QueryPlan:
    dataset: str
    projection: list[str] | None = None          # None = all columns
    predicate: Expr | None = None
    aggregations: list[tuple[str, str]] = field(default_factory=list)  # (op, col)
    limit: int | None = None
    group_by: list[str] = field(default_factory=list)  # aggregation key columns

    def serialize(self) -> bytes:
        return json.dumps({
            "dataset": self.dataset,
            "projection": self.projection,
            "predicate": self.predicate.to_json() if self.predicate else None,
            "aggregations": self.aggregations,
            "limit": self.limit,
            "group_by": self.group_by,
        }).encode()

    @classmethod
    def deserialize(cls, raw: bytes) -> "QueryPlan":
        o = json.loads(raw.decode())
        return cls(
            dataset=o["dataset"],
            projection=o["projection"],
            predicate=Expr.from_json(o["predicate"]) if o["predicate"] else None,
            aggregations=[tuple(a) for a in o["aggregations"]],
            limit=o["limit"],
            # pre-group-by plans (PR <= 8) carry no "group_by" key: they
            # deserialize to an ungrouped plan, byte-compatible behavior
            group_by=list(o.get("group_by") or []),
        )

    def is_passthrough(self, all_names: list[str]) -> bool:
        """True when executing this plan returns the stored batches verbatim.

        A pass-through plan (no predicate, no limit, no aggregation, full
        in-order projection) is a range read in disguise — Flight servers use
        this to serve it from the encode-once cache with zero re-encoding."""
        return (
            self.predicate is None
            and self.limit is None
            and not self.aggregations
            and not self.group_by
            and (self.projection is None or list(self.projection) == list(all_names))
        )

    def required_columns(self, all_names: list[str]) -> list[str]:
        need = set(self.projection or all_names)
        if self.predicate is not None:
            need |= referenced_columns(self.predicate)
        for _, c in self.aggregations:
            need.add(c)
        need |= set(self.group_by)
        return [n for n in all_names if n in need]


def execute_batch(plan: QueryPlan, batch: RecordBatch) -> RecordBatch:
    """Columnar filter → project → limit on one batch."""
    # read only referenced columns (projection pushdown: zero-copy select)
    batch = batch.select(plan.required_columns(batch.schema.names))
    if plan.predicate is not None:
        mask = evaluate(plan.predicate, batch)
        batch = batch.filter(mask)
    if plan.projection is not None:
        batch = batch.select([n for n in plan.projection if n in batch.schema.names])
    if plan.limit is not None:
        batch = batch.slice(0, min(plan.limit, batch.num_rows))
    return batch


def execute(plan: QueryPlan, batches: list[RecordBatch]) -> Iterator[RecordBatch]:
    remaining = plan.limit
    for b in batches:
        sub = QueryPlan(plan.dataset, plan.projection, plan.predicate, [], remaining)
        out = execute_batch(sub, b)
        if out.num_rows:
            yield out
        if remaining is not None:
            remaining -= out.num_rows
            if remaining <= 0:
                return


# ---------------------------------------------------------------------------
# partial/final aggregation
# ---------------------------------------------------------------------------
#
# State-column contract (the shard <-> merger wire schema): for output key
# k = "op(col)" a partial batch carries, after the group-by key columns,
#   sum   -> k        (int64 for integer/bool columns, else float64)
#   count -> k        (int64; counts surviving rows)
#   min   -> k        (value column's native dtype)
#   max   -> k        (value column's native dtype)
#   mean  -> k#sum (float64) and k#cnt (int64)
# Merging state batches is itself a grouped aggregation: sum/count/#sum/#cnt
# columns merge by addition, min by minimum, max by maximum.


def _state_fields(plan: QueryPlan, in_schema: Schema) -> list[tuple[str, str, str | None]]:
    """(state column name, merge kind, source column) per state column.

    kind: 'sum' folds by addition from source values, 'cnt' counts rows,
    'min'/'max' fold by extremum.  At merge level 'cnt' columns fold by
    addition over the state values."""
    out: list[tuple[str, str, str | None]] = []
    for op, c in plan.aggregations:
        if op not in AGG_OPS:
            raise ValueError(f"unknown aggregation op {op!r}")
        key = f"{op}({c})"
        if op == "mean":
            out.append((f"{key}#sum", "sum", c))
            out.append((f"{key}#cnt", "cnt", c))
        elif op == "count":
            out.append((key, "cnt", c))
        elif op == "sum":
            out.append((key, "sum", c))
        else:
            out.append((key, op, c))
    return out


def _state_dtype(kind: str, vtype) -> PrimitiveType:
    if kind == "cnt":
        return int64
    if kind == "sum":
        if not isinstance(vtype, PrimitiveType):
            raise TypeError(f"cannot sum non-primitive column of type {vtype!r}")
        return float64 if np.issubdtype(vtype.np_dtype, np.floating) else int64
    if not isinstance(vtype, PrimitiveType):
        raise TypeError(f"cannot {kind} non-primitive column of type {vtype!r}")
    return vtype  # min/max keep the native dtype


def partial_schema(plan: QueryPlan, in_schema: Schema) -> Schema:
    """The per-group state schema a partial-aggregate stream carries."""
    fields = [Field(k, in_schema.field(k).type) for k in plan.group_by]
    for name, kind, c in _state_fields(plan, in_schema):
        fields.append(Field(name, _state_dtype(kind, in_schema.field(c).type)))
    return Schema(tuple(fields))


def _extremum_init(kind: str, dtype):
    """Identity element for a grouped min/max accumulator of ``dtype``."""
    if dtype == np.dtype(bool):
        return dtype.type(kind == "min")
    if np.issubdtype(dtype, np.floating):
        return np.inf if kind == "min" else -np.inf
    info = np.iinfo(dtype)
    return info.max if kind == "min" else info.min


def _accumulate(plan: QueryPlan, batches, state_schema: Schema, merging: bool):
    """Fold batches into (ordered key tuples, per-state-column arrays).

    ``merging=False`` folds raw data batches (already filtered); the source
    of each state column is the aggregation's value column.  ``merging=True``
    folds state batches: the source is the state column itself and 'cnt'
    columns fold by addition.  Both passes share the grouping machinery, so
    a merge of partials equals re-aggregating the state rows."""
    n_keys = len(plan.group_by)
    kinds = []  # (state name, fold kind, source column, state dtype)
    for f, (name, kind, src) in zip(
            state_schema.fields[n_keys:], _state_fields(plan, state_schema)):
        if merging:
            kinds.append((f.name, "sum" if kind == "cnt" else kind, f.name,
                          f.type.np_dtype))
        else:
            kinds.append((f.name, kind, src, f.type.np_dtype))

    ids: dict[tuple, int] = {}
    order: list[tuple] = []
    accs = [np.empty(0, dtype=k[3]) for k in kinds]
    total = 0
    for b in batches:
        if b.num_rows == 0:
            continue
        keys = key_tuples(b, plan.group_by)
        inv = np.empty(len(keys), dtype=np.int64)
        for i, k in enumerate(keys):
            g = ids.get(k)
            if g is None:
                g = len(order)
                ids[k] = g
                order.append(k)
            inv[i] = g
        n = len(order)
        for j, (name, kind, src, dtype) in enumerate(kinds):
            acc = accs[j]
            if len(acc) < n:  # new groups this batch: pad with fold identity
                fillv = 0 if kind in ("sum", "cnt") else _extremum_init(kind, dtype)
                acc = np.concatenate(
                    [acc, np.full(n - len(acc), fillv, dtype=dtype)])
            if kind == "cnt":
                acc = acc + np.bincount(inv, minlength=n).astype(np.int64)
            else:
                vals = b.column(src).to_numpy()
                if kind == "sum":
                    cur = np.zeros(n, dtype=dtype)
                    np.add.at(cur, inv, vals.astype(dtype, copy=False))
                    acc = acc + cur
                else:
                    ufunc = np.minimum if kind == "min" else np.maximum
                    ufunc.at(acc, inv, vals.astype(dtype, copy=False))
            accs[j] = acc
        total += b.num_rows
    # deterministic group order: sorted by canonical key (stable across a
    # single pass and any shard/batch split of the same rows)
    perm = sorted(range(len(order)), key=lambda g: key_sort_token(order[g]))
    keys_sorted = [order[g] for g in perm]
    take = np.array(perm, dtype=np.int64)
    cols = [acc[take] for acc in accs]
    return keys_sorted, cols, total


def _state_batch(plan: QueryPlan, state_schema: Schema, keys, cols) -> RecordBatch:
    from ..core.array import Array

    arrays = []
    for i, name in enumerate(plan.group_by):
        f = state_schema.fields[i]
        vals = key_column([k[i] for k in keys], f.type)
        arrays.append(Array.from_numpy(vals) if isinstance(vals, np.ndarray)
                      else Array.from_pylist(vals, f.type))
    for f, col in zip(state_schema.fields[len(plan.group_by):], cols):
        arrays.append(Array.from_numpy(col))
    return RecordBatch(state_schema, arrays)


def partial_aggregate(
    plan: QueryPlan, batches: list[RecordBatch], schema: Schema | None = None
) -> RecordBatch:
    """Shard-side half of the operator split: fold batches into one
    per-group state batch (filter first, then grouped accumulation).

    Returns a zero-row state batch when no rows survive — `merge_partials`
    treats it as "this shard saw nothing", so empty shards/batches and
    empty-after-filter inputs never poison the merge (the pre-split
    ``mean`` produced NaN here)."""
    if schema is None:
        if not batches:
            raise ValueError("partial_aggregate needs batches or an explicit schema")
        schema = batches[0].schema
    if not plan.aggregations:
        raise ValueError("partial_aggregate needs at least one aggregation")
    for k in plan.group_by:
        schema.field(k)  # raises KeyError on unknown key columns
    state_schema = partial_schema(plan, schema)
    filtered = execute(QueryPlan(plan.dataset, None, plan.predicate), batches)
    keys, cols, _ = _accumulate(plan, filtered, state_schema, merging=False)
    return _state_batch(plan, state_schema, keys, cols)


def merge_partials(
    plan: QueryPlan, partials: list[RecordBatch]
) -> "RecordBatch | dict[str, float]":
    """Final half of the operator split: merge state batches, finalize.

    Grouped plans return a RecordBatch (key columns + one column per
    aggregation; ``mean`` finalized as sum/count in float64, other ops in
    their state dtype).  Ungrouped plans return the historical scalar dict
    (``count`` 0.0 and other ops NaN when nothing survived anywhere)."""
    if not partials:
        raise ValueError("merge_partials needs at least one state batch")
    state_schema = partials[0].schema
    keys, cols, _ = _accumulate(plan, partials, state_schema, merging=True)
    merged = _state_batch(plan, state_schema, keys, cols)
    n_keys = len(plan.group_by)
    states = {f.name: c for f, c in zip(
        merged.schema.fields[n_keys:], cols)}

    def final(op: str, c: str) -> np.ndarray:
        key = f"{op}({c})"
        if op == "mean":
            s, n = states[f"{key}#sum"], states[f"{key}#cnt"]
            return np.where(n > 0, s / np.maximum(n, 1), np.nan)
        return states[key]

    if plan.group_by:
        from ..core.array import Array

        out_fields = list(merged.schema.fields[:n_keys])
        arrays = list(merged.columns[:n_keys])
        for op, c in plan.aggregations:
            vals = final(op, c)
            out_fields.append(Field(f"{op}({c})", PrimitiveType(vals.dtype)))
            arrays.append(Array.from_numpy(vals))
        return RecordBatch(Schema(tuple(out_fields)), arrays)
    out: dict[str, float] = {}
    empty = merged.num_rows == 0
    for op, c in plan.aggregations:
        key = f"{op}({c})"
        if empty:
            out[key] = 0.0 if op == "count" else float("nan")
        else:
            out[key] = float(final(op, c)[0])
    return out


def aggregate(
    plan: QueryPlan, batches: list[RecordBatch], schema: Schema | None = None
) -> "dict[str, float] | RecordBatch":
    """Single-node aggregation — the oracle the distributed path must match.

    Runs the same partial/final split in one process: one state pass over
    the filtered batches, one merge.  ``mean`` therefore accumulates
    (sum, count) pairs instead of concatenating value arrays — the historic
    concat-then-average path both wasted memory and returned NaN on
    empty-after-filter inputs where count should be 0."""
    return merge_partials(plan, [partial_aggregate(plan, batches, schema)])


# ---------------------------------------------------------------------------
# equi-join kernel
# ---------------------------------------------------------------------------


def join_schema(left: Schema, right: Schema, on: list[str],
                suffix: str = "_r") -> Schema:
    """Output schema of an inner equi-join: left fields, then right fields
    minus the join keys, name-collisions suffixed."""
    taken = set(left.names)
    fields = list(left.fields)
    for f in right.fields:
        if f.name in on:
            continue
        name = f.name if f.name not in taken else f.name + suffix
        taken.add(name)
        fields.append(Field(name, f.type))
    return Schema(tuple(fields))


def hash_join(
    left_batches: list[RecordBatch],
    right_batches: list[RecordBatch],
    on: list[str],
    left_schema: Schema | None = None,
    right_schema: Schema | None = None,
    suffix: str = "_r",
) -> RecordBatch:
    """Inner equi-join on same-named key columns (build right, probe left).

    Keys canonicalize like group-by keys (NaNs join each other, masked
    varlen keys join as null) — the same semantics whether the join runs
    single-node or per-partition after a hash shuffle, which is what makes
    the shuffled join's union of partition joins equal this oracle."""
    from ..core.array import Array
    from ..core.recordbatch import Table

    if left_schema is None:
        if not left_batches:
            raise ValueError("hash_join needs left batches or left_schema")
        left_schema = left_batches[0].schema
    if right_schema is None:
        if not right_batches:
            raise ValueError("hash_join needs right batches or right_schema")
        right_schema = right_batches[0].schema
    out_schema = join_schema(left_schema, right_schema, on, suffix)
    left_batches = [b for b in left_batches if b.num_rows]
    right_batches = [b for b in right_batches if b.num_rows]
    if not left_batches or not right_batches:
        return RecordBatch(
            out_schema, [Array.from_pylist([], f.type) for f in out_schema.fields])
    lb = Table(left_batches).combine()
    rb = Table(right_batches).combine()
    build: dict[tuple, list[int]] = {}
    for i, k in enumerate(key_tuples(rb, on)):
        build.setdefault(k, []).append(i)
    l_idx: list[int] = []
    r_idx: list[int] = []
    for i, k in enumerate(key_tuples(lb, on)):
        for j in build.get(k, ()):
            l_idx.append(i)
            r_idx.append(j)
    li = np.array(l_idx, dtype=np.int64)
    ri = np.array(r_idx, dtype=np.int64)
    lt = lb.take(li)
    rt = rb.take(ri)
    cols = list(lt.columns)
    for f, c in zip(rb.schema.fields, rt.columns):
        if f.name in on:
            continue
        cols.append(c)
    return RecordBatch(out_schema, cols)

"""Query execution over RecordBatches: projection / predicate / aggregation.

``QueryPlan`` is the wire-serializable plan a Flight descriptor carries
(``FlightDescriptor.for_command(plan.serialize())``).  Execution is fully
columnar: predicates produce selection masks, projections are zero-copy
column subsets, and only then do surviving rows materialize — the ordering
the paper credits for the 20-30× over row-based protocols.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Iterator

import numpy as np

from ..core.recordbatch import RecordBatch
from .expr import Expr, evaluate, referenced_columns

_AGGS = {"sum": np.sum, "mean": np.mean, "min": np.min, "max": np.max, "count": len}


@dataclass
class QueryPlan:
    dataset: str
    projection: list[str] | None = None          # None = all columns
    predicate: Expr | None = None
    aggregations: list[tuple[str, str]] = field(default_factory=list)  # (op, col)
    limit: int | None = None

    def serialize(self) -> bytes:
        return json.dumps({
            "dataset": self.dataset,
            "projection": self.projection,
            "predicate": self.predicate.to_json() if self.predicate else None,
            "aggregations": self.aggregations,
            "limit": self.limit,
        }).encode()

    @classmethod
    def deserialize(cls, raw: bytes) -> "QueryPlan":
        o = json.loads(raw.decode())
        return cls(
            dataset=o["dataset"],
            projection=o["projection"],
            predicate=Expr.from_json(o["predicate"]) if o["predicate"] else None,
            aggregations=[tuple(a) for a in o["aggregations"]],
            limit=o["limit"],
        )

    def is_passthrough(self, all_names: list[str]) -> bool:
        """True when executing this plan returns the stored batches verbatim.

        A pass-through plan (no predicate, no limit, no aggregation, full
        in-order projection) is a range read in disguise — Flight servers use
        this to serve it from the encode-once cache with zero re-encoding."""
        return (
            self.predicate is None
            and self.limit is None
            and not self.aggregations
            and (self.projection is None or list(self.projection) == list(all_names))
        )

    def required_columns(self, all_names: list[str]) -> list[str]:
        need = set(self.projection or all_names)
        if self.predicate is not None:
            need |= referenced_columns(self.predicate)
        for _, c in self.aggregations:
            need.add(c)
        return [n for n in all_names if n in need]


def execute_batch(plan: QueryPlan, batch: RecordBatch) -> RecordBatch:
    """Columnar filter → project → limit on one batch."""
    # read only referenced columns (projection pushdown: zero-copy select)
    batch = batch.select(plan.required_columns(batch.schema.names))
    if plan.predicate is not None:
        mask = evaluate(plan.predicate, batch)
        batch = batch.filter(mask)
    if plan.projection is not None:
        batch = batch.select([n for n in plan.projection if n in batch.schema.names])
    if plan.limit is not None:
        batch = batch.slice(0, min(plan.limit, batch.num_rows))
    return batch


def execute(plan: QueryPlan, batches: list[RecordBatch]) -> Iterator[RecordBatch]:
    remaining = plan.limit
    for b in batches:
        sub = QueryPlan(plan.dataset, plan.projection, plan.predicate, [], remaining)
        out = execute_batch(sub, b)
        if out.num_rows:
            yield out
        if remaining is not None:
            remaining -= out.num_rows
            if remaining <= 0:
                return


def aggregate(plan: QueryPlan, batches: list[RecordBatch]) -> dict[str, float]:
    """Filtered aggregation (server-side; only scalars cross the wire)."""
    acc: dict[str, list] = {f"{op}({c})": [] for op, c in plan.aggregations}
    n = 0
    for b in execute(QueryPlan(plan.dataset, None, plan.predicate), batches):
        n += b.num_rows
        for op, c in plan.aggregations:
            if op == "count":
                continue
            acc[f"{op}({c})"].append(b.column(c).to_numpy())
    out: dict[str, float] = {}
    for op, c in plan.aggregations:
        key = f"{op}({c})"
        if op == "count":
            out[key] = float(n)
        elif acc[key]:
            arr = np.concatenate(acc[key])
            out[key] = float(_AGGS[op](arr))
        else:
            out[key] = float("nan")
    return out

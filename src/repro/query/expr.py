"""Vectorized expression tree over RecordBatch columns (the pushdown IR).

``col("fare") > 10.0`` builds an ``Expr``; ``evaluate`` runs it columnar
(numpy-vectorized) server-side.  This is the mini query engine behind the
Dremio-analogue Flight service — predicates/projections execute where the
data lives and only surviving columns/rows cross the wire (paper §4.1).
"""
from __future__ import annotations

import json
from dataclasses import dataclass
from typing import Any

import numpy as np

from ..core.recordbatch import RecordBatch


class Expr:
    def _bin(self, op: str, other) -> "Expr":
        return BinOp(op, self, other if isinstance(other, Expr) else Literal(other))

    def __gt__(self, o): return self._bin(">", o)
    def __ge__(self, o): return self._bin(">=", o)
    def __lt__(self, o): return self._bin("<", o)
    def __le__(self, o): return self._bin("<=", o)
    def __eq__(self, o): return self._bin("==", o)  # type: ignore[override]
    def __ne__(self, o): return self._bin("!=", o)  # type: ignore[override]
    def __and__(self, o): return self._bin("&", o)
    def __or__(self, o): return self._bin("|", o)
    def __add__(self, o): return self._bin("+", o)
    def __sub__(self, o): return self._bin("-", o)
    def __mul__(self, o): return self._bin("*", o)
    def __hash__(self):
        return hash(json.dumps(self.to_json(), sort_keys=True))

    def to_json(self) -> dict:
        raise NotImplementedError

    @staticmethod
    def from_json(o: dict) -> "Expr":
        k = o["kind"]
        if k == "col":
            return Col(o["name"])
        if k == "lit":
            return Literal(o["value"])
        if k == "bin":
            return BinOp(o["op"], Expr.from_json(o["lhs"]), Expr.from_json(o["rhs"]))
        raise ValueError(k)


@dataclass(frozen=True, eq=False)
class Col(Expr):
    name: str

    def to_json(self):
        return {"kind": "col", "name": self.name}


@dataclass(frozen=True, eq=False)
class Literal(Expr):
    value: Any

    def to_json(self):
        return {"kind": "lit", "value": self.value}


@dataclass(frozen=True, eq=False)
class BinOp(Expr):
    op: str
    lhs: Expr
    rhs: Expr

    def to_json(self):
        return {"kind": "bin", "op": self.op,
                "lhs": self.lhs.to_json(), "rhs": self.rhs.to_json()}


def col(name: str) -> Col:
    return Col(name)


def lit(v) -> Literal:
    return Literal(v)


_OPS = {
    ">": np.greater, ">=": np.greater_equal, "<": np.less, "<=": np.less_equal,
    "==": np.equal, "!=": np.not_equal,
    "&": np.logical_and, "|": np.logical_or,
    "+": np.add, "-": np.subtract, "*": np.multiply,
}


def evaluate(expr: Expr, batch: RecordBatch) -> np.ndarray:
    """Columnar evaluation -> numpy array (bool for predicates)."""
    if isinstance(expr, Col):
        return batch.column(expr.name).to_numpy()
    if isinstance(expr, Literal):
        return np.asarray(expr.value)
    if isinstance(expr, BinOp):
        return _OPS[expr.op](evaluate(expr.lhs, batch), evaluate(expr.rhs, batch))
    raise TypeError(expr)


def referenced_columns(expr: Expr) -> set[str]:
    if isinstance(expr, Col):
        return {expr.name}
    if isinstance(expr, BinOp):
        return referenced_columns(expr.lhs) | referenced_columns(expr.rhs)
    return set()

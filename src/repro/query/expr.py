"""Vectorized expression tree over RecordBatch columns (the pushdown IR).

``col("fare") > 10.0`` builds an ``Expr``; ``evaluate`` runs it columnar
(numpy-vectorized) server-side.  This is the mini query engine behind the
Dremio-analogue Flight service — predicates/projections execute where the
data lives and only surviving columns/rows cross the wire (paper §4.1).
"""
from __future__ import annotations

import json
from dataclasses import dataclass
from typing import Any

import numpy as np

from ..core.recordbatch import RecordBatch


class Expr:
    def _bin(self, op: str, other) -> "Expr":
        return BinOp(op, self, other if isinstance(other, Expr) else Literal(other))

    def __gt__(self, o): return self._bin(">", o)
    def __ge__(self, o): return self._bin(">=", o)
    def __lt__(self, o): return self._bin("<", o)
    def __le__(self, o): return self._bin("<=", o)
    def __eq__(self, o): return self._bin("==", o)  # type: ignore[override]
    def __ne__(self, o): return self._bin("!=", o)  # type: ignore[override]
    def __and__(self, o): return self._bin("&", o)
    def __or__(self, o): return self._bin("|", o)
    def __add__(self, o): return self._bin("+", o)
    def __sub__(self, o): return self._bin("-", o)
    def __mul__(self, o): return self._bin("*", o)
    def __hash__(self):
        return hash(json.dumps(self.to_json(), sort_keys=True))

    def to_json(self) -> dict:
        raise NotImplementedError

    @staticmethod
    def from_json(o: dict) -> "Expr":
        k = o["kind"]
        if k == "col":
            return Col(o["name"])
        if k == "lit":
            return Literal(o["value"])
        if k == "bin":
            return BinOp(o["op"], Expr.from_json(o["lhs"]), Expr.from_json(o["rhs"]))
        raise ValueError(k)


@dataclass(frozen=True, eq=False)
class Col(Expr):
    name: str

    def to_json(self):
        return {"kind": "col", "name": self.name}


@dataclass(frozen=True, eq=False)
class Literal(Expr):
    value: Any

    def to_json(self):
        return {"kind": "lit", "value": self.value}


@dataclass(frozen=True, eq=False)
class BinOp(Expr):
    op: str
    lhs: Expr
    rhs: Expr

    def to_json(self):
        return {"kind": "bin", "op": self.op,
                "lhs": self.lhs.to_json(), "rhs": self.rhs.to_json()}


def col(name: str) -> Col:
    return Col(name)


def lit(v) -> Literal:
    return Literal(v)


_OPS = {
    ">": np.greater, ">=": np.greater_equal, "<": np.less, "<=": np.less_equal,
    "==": np.equal, "!=": np.not_equal,
    "&": np.logical_and, "|": np.logical_or,
    "+": np.add, "-": np.subtract, "*": np.multiply,
}


def evaluate(expr: Expr, batch: RecordBatch) -> np.ndarray:
    """Columnar evaluation -> numpy array (bool for predicates)."""
    if isinstance(expr, Col):
        return batch.column(expr.name).to_numpy()
    if isinstance(expr, Literal):
        return np.asarray(expr.value)
    if isinstance(expr, BinOp):
        return _OPS[expr.op](evaluate(expr.lhs, batch), evaluate(expr.rhs, batch))
    raise TypeError(expr)


def referenced_columns(expr: Expr) -> set[str]:
    if isinstance(expr, Col):
        return {expr.name}
    if isinstance(expr, BinOp):
        return referenced_columns(expr.lhs) | referenced_columns(expr.rhs)
    return set()


# ---------------------------------------------------------------------------
# grouping/join key extraction
# ---------------------------------------------------------------------------


class _NanKey:
    """Canonical stand-in for float NaN in group/join keys.

    NaN != NaN would make every NaN row its own group (and make dict-based
    grouping diverge between a single pass and a merge of partials), so key
    extraction collapses all NaNs onto this singleton.  ``key_column`` maps
    it back to ``float("nan")`` when materializing output key columns."""

    __slots__ = ()

    def __repr__(self) -> str:
        return "<nan-key>"


NAN_KEY = _NanKey()


def canonical_key(v):
    """Canonicalize one key scalar so equal keys compare/hash equal.

    Floats: NaN -> ``NAN_KEY`` (all NaNs one group), -0.0 -> 0.0 (same
    group regardless of which batch/shard saw which sign first).  ``None``
    (a masked varlen value) passes through — nulls form their own group."""
    if isinstance(v, float):
        if v != v:
            return NAN_KEY
        if v == 0.0:
            return 0.0
    return v


def key_tuples(batch: RecordBatch, names: list[str]) -> list[tuple]:
    """Per-row key tuples for grouping/partitioning, canonicalized.

    Primitive columns read via ``to_numpy`` (validity masks do not affect
    the values, matching the aggregation kernels); varlen columns via
    ``to_pylist`` (masked entries surface as ``None`` keys)."""
    if not names:
        return [()] * batch.num_rows
    cols = []
    for n in names:
        arr = batch.column(n)
        try:
            vals = arr.to_numpy().tolist()
        except TypeError:
            vals = arr.to_pylist()
        cols.append([canonical_key(v) for v in vals])
    return list(zip(*cols))


def key_column(values: list, type) -> "np.ndarray | list":
    """Materialize one output key column from canonicalized key scalars."""
    from ..core.schema import PrimitiveType

    out = [float("nan") if v is NAN_KEY else v for v in values]
    if isinstance(type, PrimitiveType):
        return np.array(out, dtype=type.np_dtype)
    return out


def key_sort_token(key: tuple) -> tuple:
    """A total order over canonicalized key tuples (None/NaN sort last),
    so grouped output row order is deterministic on every node."""
    tok = []
    for v in key:
        if v is None:
            tok.append((2, ""))
        elif v is NAN_KEY:
            tok.append((1, ""))
        else:
            tok.append((0, v))
    return tuple(tok)

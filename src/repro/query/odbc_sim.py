"""Row-oriented client protocols — the paper's comparison baselines (Fig 8).

Faithful *mechanism* simulations of what ODBC/JDBC-class protocols do to a
result set, per Raasveldt & Mühleisen [RM17] (the paper's Fig 7 citation):

* ``OdbcProtocol``    — row-at-a-time: every row is materialized as python
  objects, serialized value-by-value with per-value type tags, then parsed
  back value-by-value client-side.  This is the (de)serialization the paper
  says eats >80 % of access time.
* ``TurbodbcProtocol`` — block-wise vectorized: rows are fetched in blocks
  and converted column-wise per block (turbodbc's design), saving much of
  the per-value overhead but still re-encoding data once per boundary.
* Flight (for contrast) ships the columnar buffers verbatim — see
  benchmarks/bench_query.py for the three side by side.

All three run over the same TCP framing so only the serialization layer
differs — that isolation is the experiment.
"""
from __future__ import annotations

import struct
import time
from dataclasses import dataclass

import numpy as np

from ..core.recordbatch import RecordBatch, batch_from_rows
from ..core.schema import PrimitiveType, Schema, Utf8Type
from .engine import QueryPlan, execute

_TYPE_TAGS = {int: b"i", float: b"f", str: b"s", bool: b"b", type(None): b"n"}


def _serialize_value(v) -> bytes:
    tag = _TYPE_TAGS.get(type(v), b"s")
    if v is None:
        return b"n"
    if tag == b"i":
        return b"i" + struct.pack("<q", v)
    if tag == b"f":
        return b"f" + struct.pack("<d", v)
    if tag == b"b":
        return b"b" + struct.pack("<?", v)
    enc = str(v).encode()
    return b"s" + struct.pack("<I", len(enc)) + enc


def _deserialize_value(buf: memoryview, pos: int):
    tag = bytes(buf[pos:pos + 1])
    pos += 1
    if tag == b"n":
        return None, pos
    if tag == b"i":
        return struct.unpack_from("<q", buf, pos)[0], pos + 8
    if tag == b"f":
        return struct.unpack_from("<d", buf, pos)[0], pos + 8
    if tag == b"b":
        return struct.unpack_from("<?", buf, pos)[0], pos + 1
    n = struct.unpack_from("<I", buf, pos)[0]
    return bytes(buf[pos + 4:pos + 4 + n]).decode(), pos + 4 + n


@dataclass
class ProtocolStats:
    rows: int = 0
    wire_bytes: int = 0
    serialize_s: float = 0.0
    deserialize_s: float = 0.0
    total_s: float = 0.0


class OdbcProtocol:
    """Row-at-a-time serialize → wire → row-at-a-time parse."""

    name = "odbc"

    def transfer(self, plan: QueryPlan, batches: list[RecordBatch]) -> tuple[list[tuple], ProtocolStats]:
        st = ProtocolStats()
        t0 = time.perf_counter()
        # server: execute, then flatten to rows and serialize per value
        wire = bytearray()
        ts = time.perf_counter()
        nrows = 0
        for out in execute(plan, batches):
            for row in out.iter_rows():
                wire += struct.pack("<H", len(row))
                for v in row:
                    wire += _serialize_value(v)
                nrows += 1
        st.serialize_s = time.perf_counter() - ts
        st.wire_bytes = len(wire)
        # client: parse value by value
        td = time.perf_counter()
        rows, pos, mv = [], 0, memoryview(bytes(wire))
        while pos < len(mv):
            (n,) = struct.unpack_from("<H", mv, pos)
            pos += 2
            row = []
            for _ in range(n):
                v, pos = _deserialize_value(mv, pos)
                row.append(v)
            rows.append(tuple(row))
        st.deserialize_s = time.perf_counter() - td
        st.rows = nrows
        st.total_s = time.perf_counter() - t0
        return rows, st


class TurbodbcProtocol:
    """Block-wise fetch: rows serialized per block, parsed column-wise."""

    name = "turbodbc"

    def __init__(self, block_rows: int = 20000):
        self.block_rows = block_rows

    def transfer(self, plan: QueryPlan, batches: list[RecordBatch]) -> tuple[list[RecordBatch], ProtocolStats]:
        st = ProtocolStats()
        t0 = time.perf_counter()
        blocks: list[bytes] = []
        schema: Schema | None = None
        ts = time.perf_counter()
        for out in execute(plan, batches):
            schema = out.schema
            for s in range(0, out.num_rows, self.block_rows):
                blk = out.slice(s, min(self.block_rows, out.num_rows - s))
                # vectorized per column, but still re-encodes into the block
                parts = []
                for f, c in zip(blk.schema.fields, blk.columns):
                    if isinstance(f.type, PrimitiveType):
                        parts.append(np.ascontiguousarray(c.to_numpy()).tobytes())
                    else:
                        joined = "\x00".join(str(v) for v in c.to_pylist())
                        parts.append(joined.encode())
                blocks.append(struct.pack("<I", blk.num_rows) + b"".join(
                    struct.pack("<I", len(p)) + p for p in parts))
                st.rows += blk.num_rows
        st.serialize_s = time.perf_counter() - ts
        st.wire_bytes = sum(len(b) for b in blocks)
        td = time.perf_counter()
        out_batches = []
        for blk in blocks:
            (n,) = struct.unpack_from("<I", blk, 0)
            pos = 4
            cols = {}
            for f in schema.fields:
                (ln,) = struct.unpack_from("<I", blk, pos)
                pos += 4
                raw = blk[pos:pos + ln]
                pos += ln
                if isinstance(f.type, PrimitiveType):
                    cols[f.name] = np.frombuffer(raw, dtype=f.type.np_dtype).copy()
                else:
                    cols[f.name] = raw.decode().split("\x00") if raw else []
            out_batches.append(RecordBatch.from_pydict(cols))
        st.deserialize_s = time.perf_counter() - td
        st.total_s = time.perf_counter() - t0
        return out_batches, st


class FlightColumnarProtocol:
    """The paper's path: execute columnar, ship IPC buffers verbatim."""

    name = "flight"

    def transfer(self, plan: QueryPlan, batches: list[RecordBatch]) -> tuple[list[RecordBatch], ProtocolStats]:
        from ..core.ipc import read_stream, write_stream

        st = ProtocolStats()
        t0 = time.perf_counter()
        ts = time.perf_counter()
        outs = list(execute(plan, batches))
        if outs:
            wire = write_stream(outs)
        else:
            wire = b""
        st.serialize_s = time.perf_counter() - ts
        st.wire_bytes = len(wire)
        td = time.perf_counter()
        result = read_stream(wire) if wire else []
        st.deserialize_s = time.perf_counter() - td
        st.rows = sum(b.num_rows for b in result)
        st.total_s = time.perf_counter() - t0
        return result, st

"""Query subsystem: pushdown engine + Flight query service + row baselines."""
from .engine import (  # noqa: F401
    QueryPlan,
    aggregate,
    execute,
    execute_batch,
    hash_join,
    join_schema,
    merge_partials,
    partial_aggregate,
    partial_schema,
)
from .expr import col, lit  # noqa: F401
from .service import FlightQueryService  # noqa: F401

"""Query subsystem: pushdown engine + Flight query service + row baselines."""
from .engine import QueryPlan, aggregate, execute, execute_batch  # noqa: F401
from .expr import col, lit  # noqa: F401
from .service import FlightQueryService  # noqa: F401

"""phi-3-vision-4.2b [hf:microsoft/Phi-3-vision-128k-instruct] — VLM.

32L d_model=3072 32H (MHA kv=32) d_ff=8192 vocab=32064; phi3-mini backbone +
CLIP ViT-L/14 frontend.  Per the assignment the frontend is a STUB:
``input_specs()`` supplies 256 precomputed patch embeddings (CLIP d=1024)
which the model projects and prepends to the text sequence.
"""
import jax.numpy as jnp

from ..models.lm import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=32064,
    frontend="vision",
    frontend_dim=1024,
    frontend_tokens=256,
    param_dtype=jnp.bfloat16,
)

SMOKE_CONFIG = ModelConfig(
    name="phi3v-smoke",
    family="vlm",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab=512,
    frontend="vision",
    frontend_dim=32,
    frontend_tokens=8,
    shard_groups=1,
)

"""xlstm-350m [arXiv:2405.04517] — sLSTM + mLSTM blocks.

24L d_model=1024 4H d_ff=0 (mixers carry their own projections) vocab=50304.
Block ratio 3:1 mLSTM:sLSTM (paper's xLSTM[7:1] rounded to a 4-block
superblock for the layer scan).  Runs long_500k via O(1) recurrent decode.
"""
import jax.numpy as jnp

from ..models.lm import ModelConfig
from ..models.xlstm import XLSTMConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    norm_type="ln",
    slstm_period=4,
    xlstm=XLSTMConfig(d_model=1024, n_heads=4),
    param_dtype=jnp.float32,
)

SMOKE_CONFIG = ModelConfig(
    name="xlstm-smoke",
    family="ssm",
    n_layers=4,
    d_model=64,
    n_heads=2,
    n_kv_heads=2,
    d_ff=0,
    vocab=512,
    norm_type="ln",
    slstm_period=4,
    xlstm=XLSTMConfig(d_model=64, n_heads=2),
    shard_groups=1,
    mamba_chunk=8,
)

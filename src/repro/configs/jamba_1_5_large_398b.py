"""jamba-1.5-large-398b [arXiv:2403.19887] — hybrid Mamba+attention, MoE.

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536, MoE 16e top-2.
Superblock of 8: attention at index 4, Mamba elsewhere (1:7); MoE every
2nd layer (Jamba's e=16/2-layer period), dense FFN otherwise.
Runs long_500k: Mamba state is O(1), the 9 attention layers use the
sequence-sharded distributed flash-decode over the 500k KV cache.
"""
import jax.numpy as jnp

from ..models.lm import ModelConfig
from ..models.mamba import MambaConfig
from ..models.moe import MoEConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab=65536,
    attn_period=8,
    attn_index=4,
    moe=MoEConfig(n_experts=16, top_k=2, d_model=8192, d_ff=24576),
    moe_every=2,
    moe_offset=1,
    mamba=MambaConfig(d_model=8192, d_state=16, d_conv=4, expand=2),
    param_dtype=jnp.bfloat16,
    mamba_chunk=32,  # §Perf D3: best memory term of {16,32,64,128}
)

SMOKE_CONFIG = ModelConfig(
    name="jamba-smoke",
    family="hybrid",
    n_layers=8,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab=512,
    attn_period=8,
    attn_index=4,
    moe=MoEConfig(n_experts=4, top_k=2, d_model=64, d_ff=128, capacity_factor=4.0),
    moe_every=2,
    moe_offset=1,
    mamba=MambaConfig(d_model=64, d_state=4, d_conv=4, expand=2),
    shard_groups=1,
    mamba_chunk=8,
)

from .base import (  # noqa: F401
    ARCH_IDS,
    SHAPES,
    ShapeSpec,
    batch_logical_axes,
    cell_supported,
    get_config,
    get_smoke_config,
    input_specs,
    make_smoke_batch,
    supported_cells,
)

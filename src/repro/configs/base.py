"""Config registry: assigned architectures × input shapes.

Each arch module defines ``CONFIG`` (exact assignment numbers) and
``SMOKE_CONFIG`` (reduced same-family config for CPU tests).  ``input_specs``
builds ShapeDtypeStruct stand-ins (weak-type-correct, shardable, zero
allocation) for every model input of a (config, shape) cell.
"""
from __future__ import annotations

import importlib
from dataclasses import dataclass, replace

import jax
import jax.numpy as jnp
import numpy as np

from ..models.lm import ModelConfig

ARCH_IDS = [
    "moonshot_v1_16b_a3b",
    "qwen3_moe_235b_a22b",
    "deepseek_coder_33b",
    "phi4_mini_3_8b",
    "yi_6b",
    "internlm2_1_8b",
    "jamba_1_5_large_398b",
    "xlstm_350m",
    "phi_3_vision_4_2b",
    "hubert_xlarge",
]


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def get_config(arch_id: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{arch_id}")
    return mod.CONFIG


def get_smoke_config(arch_id: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{arch_id}")
    return mod.SMOKE_CONFIG


def cell_supported(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """Assignment skip rules (DESIGN.md §5)."""
    if cfg.family == "audio" and shape.kind == "decode":
        return False, "encoder-only arch has no decode step"
    if shape.name == "long_500k" and cfg.family not in ("hybrid", "ssm"):
        return False, "long_500k needs sub-quadratic attention (full-attention arch)"
    return True, ""


def supported_cells(arch_id: str) -> list[str]:
    cfg = get_config(arch_id)
    return [s for s, spec in SHAPES.items() if cell_supported(cfg, spec)[0]]


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """ShapeDtypeStructs for every input of the lowered step (no allocation)."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    f32 = jnp.float32
    sds = jax.ShapeDtypeStruct

    if shape.kind in ("train", "prefill"):
        if cfg.frontend == "audio":
            batch = {
                "frames": sds((B, S, cfg.frontend_dim), f32),
                "labels": sds((B, S), i32),
                "mask": sds((B, S), f32),
            }
        elif cfg.frontend == "vision":
            P = cfg.frontend_tokens
            batch = {
                "tokens": sds((B, S - P), i32),
                "patches": sds((B, P, cfg.frontend_dim), f32),
                "labels": sds((B, S - P), i32),
            }
        else:
            batch = {"tokens": sds((B, S), i32), "labels": sds((B, S), i32)}
        if shape.kind == "prefill":
            batch.pop("labels", None)
            batch.pop("mask", None)
        return batch

    # decode: one new token against a seq_len cache
    return {"tokens": sds((B, 1), i32), "pos": sds((), i32)}


def batch_logical_axes(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """Logical sharding axes for the input batch pytree."""
    seq_shardable = shape.global_batch == 1  # long_500k: nothing to split on batch
    b = None if seq_shardable else "batch"
    if shape.kind in ("train", "prefill"):
        if cfg.frontend == "audio":
            axes = {"frames": (b, "seq", None), "labels": (b, "seq"), "mask": (b, "seq")}
        elif cfg.frontend == "vision":
            axes = {"tokens": (b, "seq"), "patches": (b, "seq", None), "labels": (b, "seq")}
        else:
            axes = {"tokens": (b, "seq"), "labels": (b, "seq")}
        if shape.kind == "prefill":
            axes.pop("labels", None)
            axes.pop("mask", None)
        return axes
    return {"tokens": (b, None), "pos": ()}


def make_smoke_batch(cfg: ModelConfig, batch: int = 2, seq: int = 32, rng=None) -> dict:
    """Tiny concrete batch for CPU smoke tests."""
    rng = rng or np.random.default_rng(0)
    if cfg.frontend == "audio":
        return {
            "frames": jnp.asarray(rng.standard_normal((batch, seq, cfg.frontend_dim)), jnp.float32),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab, (batch, seq)), jnp.int32),
            "mask": jnp.asarray(rng.integers(0, 2, (batch, seq)), jnp.float32),
        }
    if cfg.frontend == "vision":
        P = cfg.frontend_tokens
        return {
            "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (batch, seq - P)), jnp.int32),
            "patches": jnp.asarray(rng.standard_normal((batch, P, cfg.frontend_dim)), jnp.float32),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab, (batch, seq - P)), jnp.int32),
        }
    return {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (batch, seq)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (batch, seq)), jnp.int32),
    }

"""deepseek-coder-33b [arXiv:2401.14196] — llama-arch dense.

62L d_model=7168 56H (GQA kv=8) d_ff=19200 vocab=32256.
56 q heads don't divide the 16-way model axis: the grouped head layout pads
q-heads 56→64 per kv group with exactly-masked zero heads (attention.py).
"""
import jax.numpy as jnp

from ..models.lm import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-coder-33b",
    family="dense",
    n_layers=62,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=19200,
    vocab=32256,
    rope_theta=100000.0,
    param_dtype=jnp.bfloat16,
)

SMOKE_CONFIG = ModelConfig(
    name="deepseek-coder-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=7,      # deliberately non-divisible: exercises head padding
    n_kv_heads=1,
    head_dim=16,
    d_ff=128,
    vocab=512,
    shard_groups=2,  # pads 7q -> 8 over 2 groups; head_mask kills the pad
)

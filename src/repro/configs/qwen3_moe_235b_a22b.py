"""qwen3-moe-235b-a22b [hf:Qwen/Qwen3-*; assignment numbers].

94L d_model=4096 64H (GQA kv=4) d_ff=1536 vocab=151936, MoE 128e top-8.
Qwen3 uses qk-norm and no shared experts.
"""
import jax.numpy as jnp

from ..models.lm import ModelConfig
from ..models.moe import MoEConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    head_dim=128,
    d_ff=1536,
    vocab=151936,
    qk_norm=True,
    rope_theta=1000000.0,
    moe=MoEConfig(n_experts=128, top_k=8, d_model=4096, d_ff=1536),
    param_dtype=jnp.bfloat16,
)

SMOKE_CONFIG = ModelConfig(
    name="qwen3-moe-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=8,
    n_kv_heads=2,
    head_dim=8,
    d_ff=48,
    vocab=512,
    qk_norm=True,
    moe=MoEConfig(n_experts=16, top_k=4, d_model=64, d_ff=48),
    shard_groups=1,
)

"""phi4-mini-3.8b [arXiv:2412.08905] — dense, RoPE SwiGLU GQA.

32L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=200064.
24 q heads pad to 32 in the grouped TP layout (masked, exact).
"""
import jax.numpy as jnp

from ..models.lm import ModelConfig

CONFIG = ModelConfig(
    name="phi4-mini-3.8b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    d_ff=8192,
    vocab=200064,
    param_dtype=jnp.bfloat16,
)

SMOKE_CONFIG = ModelConfig(
    name="phi4-mini-smoke",
    family="dense",
    n_layers=2,
    d_model=48,
    n_heads=3,
    n_kv_heads=1,
    head_dim=16,
    d_ff=128,
    vocab=512,
    shard_groups=2,
)

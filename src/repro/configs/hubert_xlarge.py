"""hubert-xlarge [arXiv:2106.07447] — encoder-only audio (w2v2 arch).

48L d_model=1280 16H (kv=16) d_ff=5120 vocab=504 (codebook targets).
Encoder-only: bidirectional attention, masked-prediction loss, no decode
shapes.  The conv waveform frontend is a STUB per the assignment:
``input_specs()`` supplies precomputed 512-d frame embeddings.
"""
import jax.numpy as jnp

from ..models.lm import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5120,
    vocab=504,
    causal=False,
    norm_type="ln",
    activation="gelu",
    frontend="audio",
    frontend_dim=512,
    param_dtype=jnp.float32,
    # 504-way codebook can't shard 16 ways; the table is 2.6 MB -- replicate
    logical_rules={"vocab": None},
)

SMOKE_CONFIG = ModelConfig(
    name="hubert-smoke",
    family="audio",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab=64,
    causal=False,
    norm_type="ln",
    activation="gelu",
    frontend="audio",
    frontend_dim=32,
    shard_groups=1,
)

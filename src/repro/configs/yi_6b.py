"""yi-6b [arXiv:2403.04652] — llama-arch GQA dense.

32L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000.
"""
import jax.numpy as jnp

from ..models.lm import ModelConfig

CONFIG = ModelConfig(
    name="yi-6b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=4,
    d_ff=11008,
    vocab=64000,
    rope_theta=5000000.0,
    param_dtype=jnp.bfloat16,
)

SMOKE_CONFIG = ModelConfig(
    name="yi-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=160,
    vocab=512,
    shard_groups=1,
)

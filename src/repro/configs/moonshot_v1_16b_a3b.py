"""moonshot-v1-16b-a3b (Moonlight-16B-A3B) [hf:moonshotai/Moonlight-16B-A3B].

48L d_model=2048 16H (GQA kv=16) d_ff=1408 vocab=163840, MoE 64e top-6.
DeepSeek-V3-style family; we add 2 shared experts (Moonlight does; the
first-layer-dense detail is dropped to keep the layer scan homogeneous —
noted in DESIGN.md §Arch-applicability).
"""
import jax.numpy as jnp

from ..models.lm import ModelConfig
from ..models.moe import MoEConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab=163840,
    moe=MoEConfig(n_experts=64, top_k=6, d_model=2048, d_ff=1408, n_shared_experts=2),
    param_dtype=jnp.bfloat16,
)

SMOKE_CONFIG = ModelConfig(
    name="moonshot-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=96,
    vocab=512,
    moe=MoEConfig(n_experts=8, top_k=2, d_model=64, d_ff=96, n_shared_experts=2, capacity_factor=4.0),
    shard_groups=1,
)

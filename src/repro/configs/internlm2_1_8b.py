"""internlm2-1.8b [arXiv:2403.17297] — GQA dense.

24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92544.
"""
import jax.numpy as jnp

from ..models.lm import ModelConfig

CONFIG = ModelConfig(
    name="internlm2-1.8b",
    family="dense",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_ff=8192,
    vocab=92544,
    param_dtype=jnp.float32,   # small enough for f32 master params
)

SMOKE_CONFIG = ModelConfig(
    name="internlm2-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=192,
    vocab=512,
    shard_groups=1,
)

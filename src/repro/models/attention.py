"""GQA attention: TP-divisible grouped layout, flash prefill, two decode paths.

The production mesh has model=16, but the assigned archs have q/kv head
counts that don't all divide 16 (deepseek 56q/8kv, phi4 24q/8kv, qwen3 4kv…).
We therefore compute attention in a **grouped layout** ``(Ke, Gq, hd)``:

  * ``Ke`` ("effective kv heads") = true kv heads K replicated up to
    ``shard_groups`` (=16) when K < 16.  Replicating a kv head and splitting
    its q-group across the replicas is *exact* — each q head still sees its
    original kv head.
  * ``Gq`` = ceil(G / R) q heads per effective kv head (G = q per true kv
    head, R = replication).  When G doesn't divide evenly, the layout is
    zero-padded and a constant ``head_mask`` kills the padded heads' outputs
    (and their gradients), so the math equals the unpadded model exactly.

Sharding is then always over ``Ke`` (divisible by 16 by construction).
wk/wv stay at the *true* K (faithful params; replication happens on
activations, post-RoPE, where it commutes).

Three attention paths:
  * ``flash_attention``  — train/prefill: double-scan online softmax
    (q-chunks × kv-chunks), O(qc·kc) memory, causal or bidirectional.
  * ``decode_attention`` — serve_step when batch shards: plain einsum over
    the (batch-sharded, head-sharded) KV cache.
  * ``flash_decode_shardmap`` — serve_step when the KV cache is
    *sequence-sharded* (long-context, batch=1): partial softmax per shard +
    psum combine (distributed flash-decode).  The Pallas kernel
    ``kernels/flash_decode.py`` is the single-shard TPU version of the same
    loop.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from .layers import ParamBuilder, apply_rope, einsum


@dataclass(frozen=True)
class HeadLayout:
    n_heads: int            # true q heads H
    n_kv_heads: int         # true kv heads K
    head_dim: int
    shard_groups: int       # target divisibility (16 in production, 1 in smoke)

    @property
    def repl(self) -> int:  # kv replication factor R
        if self.n_kv_heads >= self.shard_groups:
            return 1
        assert self.shard_groups % self.n_kv_heads == 0, (self.n_kv_heads, self.shard_groups)
        return self.shard_groups // self.n_kv_heads

    @property
    def eff_kv(self) -> int:  # Ke
        return self.n_kv_heads * self.repl

    @property
    def group(self) -> int:  # true q heads per true kv head
        assert self.n_heads % self.n_kv_heads == 0
        return self.n_heads // self.n_kv_heads

    @property
    def q_per_kv(self) -> int:  # Gq (padded)
        return -(-self.group // self.repl)

    @property
    def padded_heads(self) -> int:
        return self.eff_kv * self.q_per_kv

    def head_mask(self) -> np.ndarray:
        """(Ke, Gq) 1.0 for real q heads, 0.0 for pads (constant, not a param)."""
        m = np.zeros((self.eff_kv, self.q_per_kv), np.float32)
        for k in range(self.n_kv_heads):
            for g in range(self.group):
                m[k * self.repl + g // self.q_per_kv, g % self.q_per_kv] = 1.0
        return m

    @property
    def kv_logical(self) -> str:
        # true-K projections shard over model only when K divides the groups
        return "kv_heads" if self.repl == 1 else "kv_heads_rep"


def init_attention(pb: ParamBuilder, d_model: int, layout: HeadLayout,
                   stack: int | None = None, qk_norm: bool = False) -> None:
    lead = (stack,) if stack is not None else ()
    lax_ = ("layers",) if stack is not None else ()
    hd, Ke, Gq, K = layout.head_dim, layout.eff_kv, layout.q_per_kv, layout.n_kv_heads
    pb.param("wq", lead + (d_model, Ke, Gq, hd), lax_ + ("embed", "kv_heads", "q_per_kv", "head_dim"))
    pb.param("wk", lead + (d_model, K, hd), lax_ + ("embed", layout.kv_logical, "head_dim"))
    pb.param("wv", lead + (d_model, K, hd), lax_ + ("embed", layout.kv_logical, "head_dim"))
    pb.param("wo", lead + (Ke, Gq, hd, d_model), lax_ + ("kv_heads", "q_per_kv", "head_dim", "embed"))
    if qk_norm:
        pb.param("q_norm", lead + (hd,), lax_ + ("head_dim",), init="ones")
        pb.param("k_norm", lead + (hd,), lax_ + ("head_dim",), init="ones")


def _rope_kg(x, positions, theta):
    """RoPE over (..., S, A, B, hd) by flattening the two head dims."""
    B, S = x.shape[0], x.shape[1]
    a, b, hd = x.shape[2], x.shape[3], x.shape[4]
    flat = x.reshape(B, S, a * b, hd)
    return apply_rope(flat, positions, theta).reshape(B, S, a, b, hd)


def _qk_norm(x, w, eps=1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)).astype(x.dtype)


def project_qkv(params, x, positions, layout: HeadLayout, ctx, rope_theta=10000.0,
                use_rope=True):
    """x (B,S,D) -> q (B,S,Ke,Gq,hd), k/v (B,S,Ke,hd) — all model-sharded."""
    q = einsum("bsd,dkgh->bskgh", x, params["wq"])
    k = einsum("bsd,dkh->bskh", x, params["wk"])
    v = einsum("bsd,dkh->bskh", x, params["wv"])
    if "q_norm" in params:
        q, k = _qk_norm(q, params["q_norm"]), _qk_norm(k, params["k_norm"])
    if use_rope:
        q = _rope_kg(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)
    if layout.repl > 1:
        k = jnp.repeat(k, layout.repl, axis=2)
        v = jnp.repeat(v, layout.repl, axis=2)
    q = ctx.constrain(q.astype(jnp.bfloat16), ("batch", "seq", "kv_heads", "q_per_kv", "head_dim"))
    k = ctx.constrain(k.astype(jnp.bfloat16), ("batch", "seq", "kv_heads", "head_dim"))
    v = ctx.constrain(v.astype(jnp.bfloat16), ("batch", "seq", "kv_heads", "head_dim"))
    return q, k, v


def output_proj(params, attn, layout: HeadLayout, ctx):
    """attn (B,S,Ke,Gq,hd) -> (B,S,D); head_mask kills padded heads exactly."""
    mask = jnp.asarray(layout.head_mask())[None, None, :, :, None]
    attn = attn * mask
    out = einsum("bskgh,kghd->bsd", attn, params["wo"])
    return ctx.constrain(out.astype(jnp.bfloat16), ("batch", "seq", "embed_nosplit"))


# ---------------------------------------------------------------------------
# flash attention (train / prefill)
# ---------------------------------------------------------------------------


def flash_attention(q, k, v, *, causal: bool, q_chunk: int = 512, kv_chunk: int = 1024,
                    softmax_scale: float | None = None):
    """Double-scan online-softmax attention.

    q: (B, S, Ke, Gq, hd); k/v: (B, S, Ke, hd).  Returns (B, S, Ke, Gq, hd).
    Memory per step is O(q_chunk × kv_chunk) — never the S×S matrix.
    """
    B, S, Ke, Gq, hd = q.shape
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(hd)
    qc, kc = min(q_chunk, S), min(kv_chunk, S)
    nq, nk = S // qc, S // kc
    assert S % qc == 0 and S % kc == 0, (S, qc, kc)

    qs = q.reshape(B, nq, qc, Ke, Gq, hd).transpose(1, 0, 3, 4, 2, 5)  # (nq,B,Ke,Gq,qc,hd)
    ks = k.reshape(B, nk, kc, Ke, hd).transpose(1, 0, 3, 2, 4)          # (nk,B,Ke,kc,hd)
    vs = v.reshape(B, nk, kc, Ke, hd).transpose(1, 0, 3, 2, 4)

    q_pos = jnp.arange(S, dtype=jnp.int32).reshape(nq, qc)
    k_pos = jnp.arange(S, dtype=jnp.int32).reshape(nk, kc)

    def q_step(_, qi):
        qb, qp = qi  # (B,Ke,Gq,qc,hd), (qc,)

        def kv_step(carry, ki):
            m, l, acc = carry
            kb, vb, kp = ki
            s = jnp.einsum("bkgqh,bkch->bkgqc", qb.astype(jnp.bfloat16),
                           kb.astype(jnp.bfloat16),
                           preferred_element_type=jnp.float32) * scale
            if causal:
                msk = qp[:, None] >= kp[None, :]  # (qc, kc)
                s = jnp.where(msk[None, None, None], s, -1e30)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))          # (b,Ke,Gq,qc)
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bkgqc,bkch->bkgqh", p.astype(jnp.bfloat16),
                            vb.astype(jnp.bfloat16), preferred_element_type=jnp.float32)
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, Ke, Gq, qc), -1e30, jnp.float32)
        l0 = jnp.zeros((B, Ke, Gq, qc), jnp.float32)
        a0 = jnp.zeros((B, Ke, Gq, qc, hd), jnp.float32)
        # remat the kv step: without it, scan-vjp stacks the (qc,kc) score
        # blocks across all kv chunks for backward — the exact memory blow-up
        # flash attention exists to avoid (measured: 21.5 GB -> see §Perf).
        (m, l, acc), _ = jax.lax.scan(jax.checkpoint(kv_step, prevent_cse=False),
                                      (m0, l0, a0), (ks, vs, k_pos))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, out.astype(q.dtype)

    _, outs = jax.lax.scan(jax.checkpoint(q_step, prevent_cse=False),
                           None, (qs, q_pos))  # (nq,B,Ke,Gq,qc,hd)
    return outs.transpose(1, 0, 4, 2, 3, 5).reshape(B, S, Ke, Gq, hd)


# ---------------------------------------------------------------------------
# decode paths
# ---------------------------------------------------------------------------


def decode_attention(q, k_cache, v_cache, cache_len, *, softmax_scale=None):
    """One-token attention over a (B, Smax, Ke, hd) cache (batch-sharded path).

    q: (B, 1, Ke, Gq, hd); cache_len: scalar or (B,) — valid prefix length.
    """
    B, _, Ke, Gq, hd = q.shape
    Smax = k_cache.shape[1]
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(hd)
    s = jnp.einsum("bokgh,bskh->bkgs", q.astype(jnp.bfloat16), k_cache.astype(jnp.bfloat16),
                   preferred_element_type=jnp.float32) * scale
    pos = jnp.arange(Smax, dtype=jnp.int32)
    valid = pos[None, :] < jnp.broadcast_to(jnp.asarray(cache_len, jnp.int32).reshape(-1, 1), (B, Smax))
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskh->bkgh", p.astype(jnp.bfloat16), v_cache.astype(jnp.bfloat16),
                     preferred_element_type=jnp.float32)
    return out[:, None].astype(q.dtype)  # (B,1,Ke,Gq,hd)


def flash_decode_shardmap(q, k_cache, v_cache, cache_len, ctx, *, softmax_scale=None):
    """Distributed flash-decode: KV cache sharded on sequence over the data
    (and pod) axes; each shard computes a partial softmax, combined via psum.
    q: (B,1,Ke,Gq,hd) replicated over data; caches (B,Smax,Ke,hd) seq-sharded.
    """
    mesh = ctx.mesh
    seq_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    model_ax = "model" if "model" in mesh.axis_names else None
    B, _, Ke, Gq, hd = q.shape
    Smax = k_cache.shape[1]
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(hd)
    n_shards = int(np.prod([mesh.shape[a] for a in seq_axes])) if seq_axes else 1
    S_loc = Smax // max(n_shards, 1)

    qspec = P(None, None, model_ax, None, None)
    kvspec = P(None, seq_axes if seq_axes else None, model_ax, None)
    outspec = P(None, None, model_ax, None, None)

    def kernel(q_l, k_l, v_l, clen):
        # global offset of this shard's sequence slice
        if seq_axes:
            idx = jnp.int32(0)
            for a in seq_axes:  # row-major linearization over the seq axes
                idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
            off = idx * S_loc
        else:
            off = 0
        s = jnp.einsum("bokgh,bskh->bkgs", q_l.astype(jnp.bfloat16), k_l.astype(jnp.bfloat16),
                       preferred_element_type=jnp.float32) * scale
        pos = off + jnp.arange(S_loc, dtype=jnp.int32)
        valid = pos[None, :] < jnp.broadcast_to(jnp.asarray(clen, jnp.int32).reshape(-1, 1), (s.shape[0], S_loc))
        s = jnp.where(valid[:, None, None, :], s, -1e30)
        m_loc = jnp.max(s, axis=-1)                       # (b,Ke,Gq)
        p = jnp.exp(s - m_loc[..., None])
        l_loc = jnp.sum(p, axis=-1)
        o_loc = jnp.einsum("bkgs,bskh->bkgh", p.astype(jnp.bfloat16), v_l.astype(jnp.bfloat16),
                           preferred_element_type=jnp.float32)
        if seq_axes:
            m_g = jax.lax.pmax(m_loc, seq_axes)
            corr = jnp.exp(m_loc - m_g)
            o = jax.lax.psum(o_loc * corr[..., None], seq_axes)
            l = jax.lax.psum(l_loc * corr, seq_axes)
        else:
            o, l = o_loc, l_loc
        return (o / jnp.maximum(l, 1e-30)[..., None])[:, None].astype(q_l.dtype)

    from jax.experimental.shard_map import shard_map

    fn = shard_map(
        kernel, mesh=mesh,
        in_specs=(qspec, kvspec, kvspec, P()),
        out_specs=outspec,
        check_rep=False,
    )
    return fn(q, k_cache, v_cache, jnp.asarray(cache_len, jnp.int32).reshape(-1))


def update_kv_cache(k_cache, v_cache, k_new, v_new, position):
    """Insert one step's (B,1,Ke,hd) at ``position`` (scalar int32)."""
    idx = (0, position, 0, 0)
    k_cache = jax.lax.dynamic_update_slice(k_cache, k_new.astype(k_cache.dtype), idx)
    v_cache = jax.lax.dynamic_update_slice(v_cache, v_new.astype(v_cache.dtype), idx)
    return k_cache, v_cache

"""Mamba (S6) selective-state-space block — Jamba's majority mixer.

Training/prefill uses a **chunked selective scan**: the sequence is split
into chunks; within a chunk the recurrence h_t = Ā_t h_{t-1} + B̄_t x_t is
evaluated with an associative scan in log-space-stable f32, and a
``lax.scan`` carries the (B, d_inner, N) state across chunks.  This bounds
the materialized (B, c, d_inner, N) tensor to the chunk size — the memory
shape that makes 398 B Jamba trainable — and is TP-clean: everything is
elementwise over d_inner, which shards over ``model``.

Decode is the O(1) recurrence on the carried state (this is why Jamba runs
the ``long_500k`` cell that full-attention archs must skip).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .layers import ParamBuilder


@dataclass(frozen=True)
class MambaConfig:
    d_model: int
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def dt_rank(self) -> int:
        return -(-self.d_model // 16)


def init_mamba(pb: ParamBuilder, cfg: MambaConfig, stack: int | None = None) -> None:
    lead = (stack,) if stack is not None else ()
    lax_ = ("layers",) if stack is not None else ()
    D, Din, N, R = cfg.d_model, cfg.d_inner, cfg.d_state, cfg.dt_rank
    pb.param("w_in", lead + (D, 2 * Din), lax_ + ("embed", "inner"))
    pb.param("conv_w", lead + (cfg.d_conv, Din), lax_ + ("conv", "inner"), scale=0.5)
    pb.param("conv_b", lead + (Din,), lax_ + ("inner",), init="zeros")
    pb.param("w_x", lead + (Din, R + 2 * N), lax_ + ("inner", "dt"))
    pb.param("w_dt", lead + (R, Din), lax_ + ("dt", "inner"))
    pb.param("b_dt", lead + (Din,), lax_ + ("inner",), init=-4.6)  # softplus≈0.01
    pb.param("A_log", lead + (Din, N), lax_ + ("inner", "state"), init=0.5)
    pb.param("D_skip", lead + (Din,), lax_ + ("inner",), init="ones")
    pb.param("w_out", lead + (Din, D), lax_ + ("inner", "embed"))


def _causal_conv(x, w, b, state=None):
    """Depthwise causal conv along seq via K shifted adds (K=4: cheap, TP-clean).

    x: (B, S, Din); w: (K, Din).  ``state``: (B, K-1, Din) tail of previous
    chunk/step (decode); returns (y, new_state).
    """
    K = w.shape[0]
    B, S, Din = x.shape
    if state is None:
        state = jnp.zeros((B, K - 1, Din), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)  # (B, S+K-1, Din)
    y = jnp.zeros((B, S, Din), jnp.float32)
    for i in range(K):
        y = y + xp[:, i : i + S].astype(jnp.float32) * w[i].astype(jnp.float32)
    new_state = xp[:, S:][:, -(K - 1):] if S >= K - 1 else xp[:, -(K - 1):]
    return (y + b.astype(jnp.float32)).astype(x.dtype), new_state


def _ssm_params(params, x):
    """x: (..., Din) post-conv activations -> (dt, B_in, C_out) f32."""
    N = params["A_log"].shape[-1]
    R = params["w_dt"].shape[-2 if params["w_dt"].ndim == 2 else 0]
    proj = jnp.einsum("...d,dr->...r", x.astype(jnp.float32), params["w_x"].astype(jnp.float32))
    dt_in, Bc = proj[..., :R], proj[..., R:]
    B_in, C_out = Bc[..., :N], Bc[..., N:]
    dt = jax.nn.softplus(
        jnp.einsum("...r,rd->...d", dt_in, params["w_dt"].astype(jnp.float32))
        + params["b_dt"].astype(jnp.float32)
    )
    return dt, B_in, C_out


def _scan_chunk(h0, dA, dBx):
    """Associative scan of h_t = dA_t * h_{t-1} + dBx_t within a chunk.

    dA, dBx: (B, c, Din, N) f32; h0: (B, Din, N).  Returns (hs, h_last).
    """
    def combine(a, b):
        (A1, X1), (A2, X2) = a, b
        return A1 * A2, X1 * A2 + X2

    As, Xs = jax.lax.associative_scan(combine, (dA, dBx), axis=1)
    hs = As * h0[:, None] + Xs
    return hs, hs[:, -1]


def mamba_mix(params: dict, x: jax.Array, ctx, chunk: int = 64,
              state: dict | None = None):
    """x: (B, S, D) -> (B, S, D).  ``state`` (decode): {h:(B,Din,N), conv:(B,K-1,Din)}.

    Returns (out, new_state).  Training path passes state=None and S % chunk == 0.
    """
    B, S, D = x.shape
    N = params["A_log"].shape[-1]
    Din = params["w_in"].shape[-1] // 2
    A = -jnp.exp(params["A_log"].astype(jnp.float32))  # (Din, N), negative

    xz = jnp.einsum("bsd,de->bse", x.astype(jnp.bfloat16), params["w_in"].astype(jnp.bfloat16),
                    preferred_element_type=jnp.float32)
    xin, z = jnp.split(xz, 2, axis=-1)
    xin = ctx.constrain(xin.astype(jnp.bfloat16), ("batch", "seq", "inner"))
    z = ctx.constrain(z.astype(jnp.bfloat16), ("batch", "seq", "inner"))

    conv_state = None if state is None else state["conv"]
    xc, new_conv = _causal_conv(xin, params["conv_w"], params["conv_b"], conv_state)
    xc = jax.nn.silu(xc.astype(jnp.float32)).astype(jnp.bfloat16)

    dt, B_in, C_out = _ssm_params(params, xc)          # (B,S,Din) (B,S,N) (B,S,N)

    h0 = jnp.zeros((B, Din, N), jnp.float32) if state is None else state["h"]

    if S == 1:  # decode: plain recurrence
        dA = jnp.exp(dt[:, 0, :, None] * A[None])
        dBx = (dt[:, 0] * xc[:, 0].astype(jnp.float32))[..., None] * B_in[:, 0, None, :]
        h = dA * h0 + dBx
        ys = jnp.einsum("bdn,bn->bd", h, C_out[:, 0])[:, None]
        h_last = h
    else:
        nc = S // chunk if S % chunk == 0 else 1
        c = S // nc
        r3 = lambda t: t.reshape(B, nc, c, t.shape[-1]).swapaxes(0, 1)
        dt_c, x_c = r3(dt), r3(xc.astype(jnp.float32))
        B_c, C_c = r3(B_in), r3(C_out)

        def step(h, inp):
            # discretize *inside* the chunk: the (B,S,Din,N) dA/dBx tensors
            # never materialize across the whole sequence (2×2.1 GB/device on
            # jamba train_4k — §Perf D-cell), and under remat they rebuild
            # chunk-by-chunk in backward
            dtc, xcc, bc, cc = inp
            da = jnp.exp(dtc[..., None] * A[None, None])          # (B,c,Din,N)
            dbx = (dtc * xcc)[..., None] * bc[..., None, :]
            hs, h_next = _scan_chunk(h, da, dbx)
            return h_next, jnp.einsum("bcdn,bcn->bcd", hs, cc)

        h_last, ys = jax.lax.scan(step, h0, (dt_c, x_c, B_c, C_c))
        ys = ys.swapaxes(0, 1).reshape(B, S, Din)

    y = ys + xc.astype(jnp.float32) * params["D_skip"].astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(jnp.bfloat16)
    y = ctx.constrain(y, ("batch", "seq", "inner"))
    out = jnp.einsum("bse,ed->bsd", y, params["w_out"].astype(jnp.bfloat16),
                     preferred_element_type=jnp.float32)
    out = ctx.constrain(out.astype(x.dtype), ("batch", "seq", "embed_nosplit"))
    new_state = {"h": h_last, "conv": new_conv}
    return out, new_state


def mamba_init_state(B: int, cfg: MambaConfig, dtype=jnp.bfloat16) -> dict:
    return {
        "h": jnp.zeros((B, cfg.d_inner, cfg.d_state), jnp.float32),
        "conv": jnp.zeros((B, cfg.d_conv - 1, cfg.d_inner), dtype),
    }

"""Mixture-of-Experts: top-k router + capacity-bounded sort-based dispatch.

Distribution (DESIGN.md §4): activations between blocks are TP-replicated
over ``model``, so each model shard already *has* every token.  Experts are
sharded over ``model``; each shard locally gathers the tokens routed to its
local experts (argsort grouping, fixed capacity, dropped overflow), runs the
expert FFNs as one batched einsum, scatters back weighted by router probs,
and a single ``psum`` over ``model`` combines shards — the same collective
pattern as Megatron TP, with **no all-to-all** on the critical path.

Memory never materializes the (B,S,E,C) one-hot dispatch tensor that the
GShard-style formulation needs — at E=128, k=8 that tensor is ~4e13 elements.
The sort-based grouping is O(N·k) and is also the *numerics-exact* approach
(capacity drops aside, which are standard).

FSDP composition: expert weights are additionally sharded over ``data`` on
d_model; the shard_map body all-gathers the current layer's local-expert
weights over ``data`` just-in-time (classic FSDP; re-gathered in backward
under remat).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from .layers import ParamBuilder, swiglu


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_model: int
    d_ff: int                   # per-expert hidden
    n_shared_experts: int = 0   # dense "shared expert" path (DeepSeek/Moonlight)
    capacity_factor: float = 1.25
    router_jitter: float = 0.0


def init_moe(pb: ParamBuilder, cfg: MoEConfig, stack: int | None = None) -> None:
    lead = (stack,) if stack is not None else ()
    lax_ = ("layers",) if stack is not None else ()
    E, D, F = cfg.n_experts, cfg.d_model, cfg.d_ff
    # router weights replicated: an experts-sharded router drags a softmax +
    # top_k across the model axis into EVERY layer (measured 0.4 s/step of
    # collectives on moonshot train_4k — §Perf C-cell)
    pb.param("w_router", lead + (D, E), lax_ + ("embed_nosplit", "experts_rep"), scale=0.02)
    pb.param("w_gate", lead + (E, D, F), lax_ + ("experts", "embed", "ff_nosplit"))
    pb.param("w_up", lead + (E, D, F), lax_ + ("experts", "embed", "ff_nosplit"))
    pb.param("w_down", lead + (E, F, D), lax_ + ("experts", "ff_nosplit", "embed"))
    if cfg.n_shared_experts:
        Fs = F * cfg.n_shared_experts
        pb.param("ws_gate", lead + (D, Fs), lax_ + ("embed", "ff"))
        pb.param("ws_up", lead + (D, Fs), lax_ + ("embed", "ff"))
        pb.param("ws_down", lead + (Fs, D), lax_ + ("ff", "embed"))


def _group_by_expert(expert_idx: jax.Array, weights: jax.Array, n_local: int, capacity: int):
    """Sort-based grouping of N·k routed assignments into (n_local, capacity)
    token slots.  ``expert_idx``: (N, k) local expert id or -1; returns
    (slot_token[n_local*capacity], slot_weight[n_local*capacity]) where
    slot_token indexes the flat token list (N) and -1 marks empty slots.
    """
    N, k = expert_idx.shape
    flat_e = expert_idx.reshape(-1)                       # (N*k,)
    flat_w = weights.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(N, dtype=jnp.int32), k)
    # stable sort by expert id; -1 (not-local) sorts first
    order = jnp.argsort(flat_e, stable=True)
    se, sw, st = flat_e[order], flat_w[order], flat_t[order]
    # position of each assignment within its expert's run
    same = jnp.cumsum(jnp.ones_like(se), dtype=jnp.int32) - 1
    run_start = jnp.where(se != jnp.concatenate([jnp.array([-2], se.dtype), se[:-1]]),
                          same, -1)
    run_start = jax.lax.associative_scan(jnp.maximum, run_start)
    pos_in_run = same - run_start
    keep = (se >= 0) & (pos_in_run < capacity)
    slot = jnp.where(keep, se * capacity + pos_in_run, n_local * capacity)  # overflow slot
    slot_token = jnp.full((n_local * capacity + 1,), -1, jnp.int32).at[slot].set(
        jnp.where(keep, st, -1))[:-1]
    slot_weight = jnp.zeros((n_local * capacity + 1,), jnp.float32).at[slot].set(
        jnp.where(keep, sw, 0.0))[:-1]
    return slot_token, slot_weight


def _expert_ffn(x_g, wg, wu, wd):
    """x_g: (E_loc, C, D); weights (E_loc, D, F)/(E_loc, F, D)."""
    h = swiglu(
        jnp.einsum("ecd,edf->ecf", x_g.astype(jnp.bfloat16), wg.astype(jnp.bfloat16),
                   preferred_element_type=jnp.float32),
        jnp.einsum("ecd,edf->ecf", x_g.astype(jnp.bfloat16), wu.astype(jnp.bfloat16),
                   preferred_element_type=jnp.float32),
    )
    return jnp.einsum("ecf,efd->ecd", h.astype(jnp.bfloat16), wd.astype(jnp.bfloat16),
                      preferred_element_type=jnp.float32)


def moe_ffn(params: dict, x: jax.Array, cfg: MoEConfig, ctx) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, D) TP-replicated / batch-sharded. Returns (out, aux_loss)."""
    mesh = ctx.mesh
    model_ax = "model" if "model" in mesh.axis_names else None
    tp = mesh.shape[model_ax] if model_ax else 1
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    fsdp_ax = "data" if "data" in mesh.axis_names else None
    E, k = cfg.n_experts, cfg.top_k
    assert E % tp == 0, (E, tp)
    E_loc = E // tp
    B, S, D = x.shape
    dp = int(np.prod([mesh.shape[a] for a in batch_axes])) if batch_axes else 1
    if B % dp:  # decode with batch < data parallelism: replicate over data
        batch_axes, dp = (), 1
    N_loc = (B // dp) * S
    capacity = max(8, int(np.ceil(N_loc * k * cfg.capacity_factor / E)))

    # ---- router (replicated, f32) — aux load-balancing loss (Switch-style)
    router_logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                               params["w_router"].astype(jnp.float32))
    probs = jax.nn.softmax(router_logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, k)                     # (B,S,k)
    top_w = top_w / jnp.maximum(jnp.sum(top_w, axis=-1, keepdims=True), 1e-9)
    # aux loss: E * sum_e (fraction_tokens_e * mean_prob_e)
    counts = jnp.mean(jnp.sum(jax.nn.one_hot(top_e, E, dtype=jnp.float32), axis=2), axis=(0, 1))
    aux = E * jnp.sum(counts * jnp.mean(probs, axis=(0, 1)))

    in_x = P(batch_axes if batch_axes else None, None, None)

    def body(x_l, te_l, tw_l, wg, wu, wd):
        # gather this model-shard's expert weights over the FSDP axis
        if fsdp_ax is not None:
            wg = jax.lax.all_gather(wg, fsdp_ax, axis=1, tiled=True)
            wu = jax.lax.all_gather(wu, fsdp_ax, axis=1, tiled=True)
            wd = jax.lax.all_gather(wd, fsdp_ax, axis=2, tiled=True)
        b_l, s_l, d = x_l.shape
        n = b_l * s_l
        xf = x_l.reshape(n, d)
        shard = jax.lax.axis_index(model_ax) if model_ax else 0
        lo = shard * E_loc
        te = te_l.reshape(n, k)
        local = te - lo
        local = jnp.where((local >= 0) & (local < E_loc), local, -1)
        slot_token, slot_weight = _group_by_expert(local, tw_l.reshape(n, k), E_loc, capacity)
        safe_tok = jnp.maximum(slot_token, 0)
        x_g = xf[safe_tok].reshape(E_loc, capacity, d)
        x_g = jnp.where((slot_token >= 0).reshape(E_loc, capacity, 1), x_g, 0.0)
        y_g = _expert_ffn(x_g, wg, wu, wd)                      # (E_loc, C, D) f32
        y_g = y_g * slot_weight.reshape(E_loc, capacity, 1)
        y = jnp.zeros((n, d), jnp.float32).at[safe_tok.reshape(-1)].add(
            jnp.where((slot_token >= 0).reshape(-1, 1), y_g.reshape(-1, d), 0.0))
        y = y.astype(x_l.dtype)  # psum in bf16: halves the TP collective bytes
        if model_ax is not None:
            y = jax.lax.psum(y, model_ax)
        return y.reshape(b_l, s_l, d)

    out = shard_map(
        body, mesh=mesh,
        in_specs=(in_x, in_x, in_x,
                  P(model_ax, fsdp_ax, None), P(model_ax, fsdp_ax, None), P(model_ax, None, fsdp_ax)),
        out_specs=in_x,
        check_rep=False,
    )(x, top_e.astype(jnp.int32), top_w.astype(jnp.float32),
      params["w_gate"], params["w_up"], params["w_down"])

    if cfg.n_shared_experts:
        h = swiglu(
            jnp.einsum("bsd,df->bsf", x.astype(jnp.bfloat16), params["ws_gate"].astype(jnp.bfloat16),
                       preferred_element_type=jnp.float32),
            jnp.einsum("bsd,df->bsf", x.astype(jnp.bfloat16), params["ws_up"].astype(jnp.bfloat16),
                       preferred_element_type=jnp.float32),
        )
        h = ctx.constrain(h.astype(x.dtype), ("batch", "seq", "ff"))
        shared = jnp.einsum("bsf,fd->bsd", h.astype(jnp.bfloat16),
                            params["ws_down"].astype(jnp.bfloat16),
                            preferred_element_type=jnp.float32)
        out = out + shared.astype(out.dtype)

    return ctx.constrain(out, ("batch", "seq", "embed_nosplit")), aux

"""Unified language-model assembly for all 10 assigned architectures.

One config → one model.  Layers are **stacked and scanned**: parameters carry
a leading ``n_superblocks`` dim and ``jax.lax.scan`` + ``jax.checkpoint``
(remat) run the stack, so HLO size and compile time are O(1) in depth — the
property that makes 62 production-mesh dry-run compiles feasible and what
MaxText-class frameworks do in production.

A *superblock* is the smallest repeating pattern of heterogeneous layers:
  dense/moe/vlm : 1 layer  (attention + FFN/MoE)
  hybrid(jamba) : 8 layers (attn at index 4, mamba elsewhere; MoE every 2nd)
  ssm(xlstm)    : 4 layers (3 mLSTM + 1 sLSTM)
  audio(hubert) : 1 layer  (bidirectional attention + FFN)

Modality frontends (vlm patch embeddings / audio frames) are stubs per the
assignment: ``input_specs()`` supplies precomputed embeddings, the model owns
only the projection into d_model.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..distributed.sharding import ShardingCtx
from . import layers as L
from .attention import (
    HeadLayout,
    decode_attention,
    flash_attention,
    flash_decode_shardmap,
    init_attention,
    output_proj,
    project_qkv,
    update_kv_cache,
)
from .mamba import MambaConfig, init_mamba, mamba_init_state, mamba_mix
from .moe import MoEConfig, init_moe, moe_ffn
from .xlstm import (
    XLSTMConfig,
    init_mlstm,
    init_slstm,
    mlstm_init_state,
    mlstm_mix,
    slstm_init_state,
    slstm_mix,
)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int | None = None
    rope_theta: float = 10000.0
    norm_type: str = "rms"           # rms | ln
    norm_eps: float = 1e-5
    activation: str = "swiglu"
    causal: bool = True
    qk_norm: bool = False
    # MoE
    moe: MoEConfig | None = None
    moe_every: int = 1               # apply MoE at layer idx % moe_every == moe_offset
    moe_offset: int = 0
    # hybrid (jamba)
    mamba: MambaConfig | None = None
    attn_period: int = 8             # 1 attention layer per this many (jamba 1:7)
    attn_index: int = 4
    # ssm (xlstm)
    xlstm: XLSTMConfig | None = None
    slstm_period: int = 4            # 1 sLSTM per this many blocks
    # frontends
    frontend: str | None = None      # vision | audio
    frontend_dim: int = 0
    frontend_tokens: int = 0         # vlm: patches prepended
    # engineering
    shard_groups: int = 16           # attention TP divisibility target
    param_dtype: Any = jnp.float32
    remat: bool = True
    remat_policy: str = "nothing"    # nothing | dots
    scan_layers: bool = True
    force_seq_sharded_decode: bool = False
    lm_loss_chunk: int = 512
    attn_q_chunk: int = 512
    attn_kv_chunk: int = 1024
    mamba_chunk: int = 64
    logical_rules: dict = field(default_factory=dict)

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def superblock(self) -> int:
        if self.family == "hybrid":
            return self.attn_period
        if self.family == "ssm":
            return self.slstm_period
        return 1

    @property
    def n_superblocks(self) -> int:
        assert self.n_layers % self.superblock == 0, (self.n_layers, self.superblock)
        return self.n_layers // self.superblock

    @property
    def head_layout(self) -> HeadLayout:
        return HeadLayout(self.n_heads, self.n_kv_heads, self.resolved_head_dim,
                          self.shard_groups)

    def layer_kind(self, idx_in_superblock: int) -> dict:
        """What sub-layers layer ``idx`` of a superblock contains."""
        i = idx_in_superblock
        if self.family == "hybrid":
            mixer = "attn" if i == self.attn_index else "mamba"
            ffn = "moe" if (self.moe is not None and i % self.moe_every == self.moe_offset) else "mlp"
            return {"mixer": mixer, "ffn": ffn}
        if self.family == "ssm":
            return {"mixer": "slstm" if i == self.slstm_period - 1 else "mlstm", "ffn": None}
        mixer = "attn"
        ffn = "moe" if self.moe is not None else "mlp"
        return {"mixer": mixer, "ffn": ffn}

    def param_count(self) -> int:
        """Analytic parameter count (true heads, not padded)."""
        D, hd = self.d_model, self.resolved_head_dim
        n_attn = sum(1 for i in range(self.superblock)
                     if self.layer_kind(i)["mixer"] == "attn") * self.n_superblocks
        attn = n_attn * (D * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * D)
        total = attn + self.vocab * D
        for i in range(self.superblock):
            kind = self.layer_kind(i)
            per = 0
            if kind["mixer"] == "mamba":
                m = self.mamba
                per += D * 2 * m.d_inner + m.d_inner * (m.dt_rank + 2 * m.d_state)
                per += m.dt_rank * m.d_inner + m.d_inner * m.d_state + m.d_inner * D
            if kind["mixer"] == "mlstm":
                xc = self.xlstm
                Di = xc.d_inner_m
                per += D * 2 * Di + 3 * Di * Di + Di * D
            if kind["mixer"] == "slstm":
                xc = self.xlstm
                dff = int(D * xc.proj_factor_s)
                per += D * 4 * D + self.n_heads * (D // self.n_heads) * 4 * (D // self.n_heads)
                per += D * 2 * dff + dff * D
            if kind["ffn"] == "mlp":
                per += D * self.d_ff * (3 if self.activation == "swiglu" else 2)
            if kind["ffn"] == "moe":
                mo = self.moe
                per += D * mo.n_experts + mo.n_experts * 3 * D * mo.d_ff
                per += mo.n_shared_experts * 3 * D * mo.d_ff
            total += per * self.n_superblocks
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k + shared experts only)."""
        if self.moe is None:
            return self.param_count()
        full = self.param_count()
        mo = self.moe
        n_moe = sum(1 for i in range(self.superblock) if self.layer_kind(i)["ffn"] == "moe")
        n_moe *= self.n_superblocks
        unused = n_moe * (mo.n_experts - mo.top_k) * 3 * self.d_model * mo.d_ff
        return full - unused


# ---------------------------------------------------------------------------


class LM:
    """Pure-function model: ``init`` → params/axes, ``loss``/``prefill``/``decode_step``."""

    def __init__(self, cfg: ModelConfig, ctx: ShardingCtx):
        self.cfg = cfg
        self.ctx = ctx

    # ---------------------------------------------------------------- init --
    def init(self, key: jax.Array, abstract: bool = False):
        cfg = self.cfg
        pb = L.ParamBuilder(key, cfg.param_dtype, abstract=abstract)
        L.init_embedding(pb, cfg.vocab, cfg.d_model)
        nsb = cfg.n_superblocks

        for i in range(cfg.superblock):
            kind = cfg.layer_kind(i)
            sb = pb.scope(f"layer{i}")
            if kind["mixer"] == "attn":
                init_attention(sb.scope("attn"), cfg.d_model, cfg.head_layout,
                               stack=nsb, qk_norm=cfg.qk_norm)
                self._init_norm(sb, "norm_attn", nsb)
            elif kind["mixer"] == "mamba":
                init_mamba(sb.scope("mamba"), cfg.mamba, stack=nsb)
                self._init_norm(sb, "norm_mixer", nsb)
            elif kind["mixer"] == "mlstm":
                init_mlstm(sb.scope("mlstm"), cfg.xlstm, stack=nsb)
                self._init_norm(sb, "norm_mixer", nsb)
            elif kind["mixer"] == "slstm":
                init_slstm(sb.scope("slstm"), cfg.xlstm, stack=nsb)
                self._init_norm(sb, "norm_mixer", nsb)
            if kind["ffn"] == "mlp":
                L.init_mlp(sb.scope("mlp"), cfg.d_model, cfg.d_ff, stack=nsb,
                           activation=cfg.activation)
                self._init_norm(sb, "norm_ffn", nsb)
            elif kind["ffn"] == "moe":
                init_moe(sb.scope("moe"), cfg.moe, stack=nsb)
                self._init_norm(sb, "norm_ffn", nsb)

        fb = pb.scope("final")
        self._init_norm(fb, "norm_out", None)
        if cfg.frontend == "vision":
            pb.param("patch_proj", (cfg.frontend_dim, cfg.d_model), ("patch", "embed"))
        elif cfg.frontend == "audio":
            pb.param("frame_proj", (cfg.frontend_dim, cfg.d_model), ("patch", "embed"))
        return pb.params, pb.axes

    def _init_norm(self, pb: L.ParamBuilder, name: str, stack: int | None):
        lead = (stack,) if stack is not None else ()
        lax_ = ("layers",) if stack is not None else ()
        sub = pb.scope(name)
        sub.param("w", lead + (self.cfg.d_model,), lax_ + ("embed_nosplit",), init="ones")
        if self.cfg.norm_type == "ln":
            sub.param("b", lead + (self.cfg.d_model,), lax_ + ("embed_nosplit",), init="zeros")

    def _norm(self, p, x):
        if self.cfg.norm_type == "ln":
            return L.layer_norm(x, p["w"], p["b"], self.cfg.norm_eps)
        return L.rms_norm(x, p["w"], self.cfg.norm_eps)

    # ------------------------------------------------------------- embed --
    def _embed_inputs(self, params, batch) -> tuple[jax.Array, jax.Array]:
        """Returns (x (B,S,D), positions (B,S))."""
        cfg, ctx = self.cfg, self.ctx
        if cfg.frontend == "audio":
            # encoder-only masked prediction: inputs are frames alone
            frames = batch["frames"].astype(jnp.bfloat16)    # (B,S,frontend_dim)
            x = L.dot(frames, params["frame_proj"]).astype(jnp.bfloat16)
        else:
            x = L.embed(params, batch["tokens"], ctx)
        if cfg.frontend == "vision":
            patches = batch["patches"].astype(jnp.bfloat16)  # (B,P,frontend_dim)
            pe = L.dot(patches, params["patch_proj"]).astype(x.dtype)
            x = jnp.concatenate([pe, x], axis=1)
        B, S = x.shape[0], x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        x = ctx.constrain(x, ("batch", "seq", "embed_nosplit"))
        return x, positions

    # -------------------------------------------------------------- block --
    def _superblock(self, sb_params: dict, x: jax.Array, positions: jax.Array,
                    mode: str, caches: dict | None):
        """Run one superblock.  mode: train | prefill | decode.
        ``caches``: this superblock's cache slice (decode/prefill-out)."""
        cfg, ctx = self.cfg, self.ctx
        aux_total = jnp.float32(0)
        new_caches: dict = {}
        for i in range(cfg.superblock):
            kind = cfg.layer_kind(i)
            p = sb_params[f"layer{i}"]
            if kind["mixer"] == "attn":
                h = self._norm(p["norm_attn"], x)
                attn_out, kv = self._attention(p["attn"], h, positions, mode, caches)
                if kv is not None:
                    new_caches.update(kv)
                x = x + attn_out
            else:
                h = self._norm(p["norm_mixer"], x)
                if kind["mixer"] == "mamba":
                    st = None if caches is None else caches.get(f"mamba{i}")
                    out, st_new = mamba_mix(p["mamba"], h, ctx, cfg.mamba_chunk, st)
                    if caches is not None or mode != "train":
                        new_caches[f"mamba{i}"] = st_new
                elif kind["mixer"] == "mlstm":
                    st = None if caches is None else caches.get(f"mlstm{i}")
                    out, st_new = mlstm_mix(p["mlstm"], h, ctx, cfg.mamba_chunk, st)
                    if caches is not None or mode != "train":
                        new_caches[f"mlstm{i}"] = st_new
                else:
                    st = None if caches is None else caches.get(f"slstm{i}")
                    out, st_new = slstm_mix(p["slstm"], h, ctx, st)
                    if caches is not None or mode != "train":
                        new_caches[f"slstm{i}"] = st_new
                x = x + out
            if kind["ffn"] == "mlp":
                h = self._norm(p["norm_ffn"], x)
                x = x + L.mlp(p["mlp"], h, ctx, cfg.activation)
            elif kind["ffn"] == "moe":
                h = self._norm(p["norm_ffn"], x)
                out, aux = moe_ffn(p["moe"], h, cfg.moe, ctx)
                aux_total = aux_total + aux
                x = x + out
        return x, aux_total, new_caches

    def _attention(self, p, h, positions, mode, caches):
        cfg, ctx = self.cfg, self.ctx
        layout = cfg.head_layout
        q, k, v = project_qkv(p, h, positions, layout, ctx, cfg.rope_theta,
                              use_rope=cfg.family != "audio")
        if mode in ("train", "prefill"):
            attn = flash_attention(q, k, v, causal=cfg.causal,
                                   q_chunk=cfg.attn_q_chunk, kv_chunk=cfg.attn_kv_chunk)
            out = output_proj(p, attn, layout, ctx)
            kv = None
            if mode == "prefill":
                kv = {"k": k.astype(jnp.bfloat16), "v": v.astype(jnp.bfloat16)}
            return out, kv
        # decode: one token; caches carry (B, Smax, Ke, hd)
        k_cache, v_cache = caches["k"], caches["v"]
        pos = caches["pos"]  # scalar int32 current length
        k_cache, v_cache = update_kv_cache(k_cache, v_cache, k, v, pos)
        cache_len = pos + 1
        if self._seq_sharded_decode(k_cache.shape):
            attn = flash_decode_shardmap(q, k_cache, v_cache,
                                         jnp.full((q.shape[0],), cache_len, jnp.int32), ctx)
        else:
            attn = decode_attention(q, k_cache, v_cache,
                                    jnp.full((q.shape[0],), cache_len, jnp.int32))
        out = output_proj(p, attn, layout, ctx)
        return out, {"k": k_cache, "v": v_cache}

    def _seq_sharded_decode(self, cache_shape) -> bool:
        """Shard the KV cache on sequence when batch can't cover the dp axes
        (or when the config forces it — a serving-latency optimization)."""
        if self.cfg.force_seq_sharded_decode:
            return True
        B = cache_shape[0]
        dp = self.ctx.data_parallelism
        return B % max(dp, 1) != 0 or B < dp

    # ------------------------------------------------------------ forward --
    def _run_stack(self, params, x, positions, mode, caches=None):
        """Scan over superblocks.  caches: pytree with leading (nsb,) dim.

        Decode carries the stacked caches through the scan *carry* with
        per-layer dynamic slice/update — passing them as scan xs/ys makes
        XLA rewrite the entire multi-GB cache every token (measured 1.08 TB
        per token on deepseek decode_32k; see EXPERIMENTS.md §Perf)."""
        cfg = self.cfg
        sb_keys = [k for k in params if k.startswith("layer")]
        sb_params = {k: params[k] for k in sb_keys}
        decode = mode == "decode"

        pos = None
        if decode:
            pos = caches["pos"]
            caches = {k: v for k, v in caches.items() if k != "pos"}

        def body(carry, scanned):
            if decode:
                xc, aux, cache_full, i = carry
                sbp = scanned
                cache_slice = jax.tree.map(
                    lambda t: jax.lax.dynamic_index_in_dim(t, i, 0, keepdims=False),
                    cache_full)
                cache_slice["pos"] = pos
                xo, aux_sb, new_cache = self._superblock(sbp, xc, positions, mode, cache_slice)
                cache_full = jax.tree.map(
                    lambda full, new: jax.lax.dynamic_update_index_in_dim(
                        full, new.astype(full.dtype), i, 0),
                    cache_full, new_cache)
                return (xo, aux + aux_sb, cache_full, i + 1), None
            xc, aux = carry
            sbp, cache_slice = scanned
            xo, aux_sb, new_cache = self._superblock(sbp, xc, positions, mode, cache_slice)
            return (xo, aux + aux_sb), new_cache

        if cfg.remat and not decode:
            policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                      if cfg.remat_policy == "dots" else None)
            body = jax.checkpoint(body, policy=policy, prevent_cse=False)

        if cfg.scan_layers:
            if decode:
                (x, aux, out_caches, _), _ = jax.lax.scan(
                    body, (x, jnp.float32(0), caches, jnp.int32(0)), sb_params)
            else:
                (x, aux), out_caches = jax.lax.scan(
                    body, (x, jnp.float32(0)), (sb_params, caches))
        else:
            aux = jnp.float32(0)
            if decode:
                out_caches = caches
                for i in range(cfg.n_superblocks):
                    sbp = jax.tree.map(lambda t: t[i], sb_params)
                    (x, aux, out_caches, _), _ = body((x, aux, out_caches, jnp.int32(i)), sbp)
            else:
                out_list = []
                for i in range(cfg.n_superblocks):
                    sbp = jax.tree.map(lambda t: t[i], sb_params)
                    csl = None if caches is None else jax.tree.map(lambda t: t[i], caches)
                    (x, aux), oc = body((x, aux), (sbp, csl))
                    out_list.append(oc)
                out_caches = (jax.tree.map(lambda *ts: jnp.stack(ts), *out_list)
                              if out_list and out_list[0] else None)
        x = self._norm(params["final"]["norm_out"], x)
        return x, aux, out_caches

    # -------------------------------------------------------------- modes --
    def loss_fn(self, params, batch):
        """Training loss. batch: tokens (B,S), labels (B,S), [mask, patches, frames]."""
        cfg, ctx = self.cfg, self.ctx
        x, positions = self._embed_inputs(params, batch)
        x, aux, _ = self._run_stack(params, x, positions, "train", self._empty_caches_like(x))
        labels = batch["labels"]
        mask = batch.get("mask")
        if cfg.frontend == "vision":  # loss over text positions only
            P = cfg.frontend_tokens
            x = x[:, P:]
        nll = L.chunked_lm_loss(params, x, labels, ctx, cfg.lm_loss_chunk, mask)
        loss = nll + (0.01 * aux if cfg.moe is not None else 0.0)
        return loss, {"nll": nll, "aux": aux}

    def prefill(self, params, batch):
        """Forward building decode state; returns (next_token_logits, caches)."""
        cfg, ctx = self.cfg, self.ctx
        x, positions = self._embed_inputs(params, batch)
        caches = self._empty_caches_like(x)
        x, _, out_caches = self._run_stack(params, x, positions, "prefill", caches)
        last = x[:, -1:]
        lgts = L.logits(params, last, ctx)[:, 0]
        return lgts, out_caches

    def decode_step(self, params, caches, tokens, pos, return_logits: bool = False):
        """tokens (B,1) int32, pos scalar int32 → (next_tokens (B,), new caches)."""
        cfg, ctx = self.cfg, self.ctx
        x = L.embed(params, tokens, ctx)
        B = x.shape[0]
        pos = jnp.asarray(pos, jnp.int32)
        positions = jnp.full((B, 1), pos, jnp.int32)
        withpos = {**{k: v for k, v in caches.items() if k != "pos"}, "pos": pos}
        x, _, new_caches = self._run_stack(params, x, positions, "decode", withpos)
        lgts = L.logits(params, x, ctx)[:, 0]
        next_tokens = jnp.argmax(lgts, axis=-1).astype(jnp.int32)
        out_caches = {**new_caches, "pos": pos + 1}
        if return_logits:
            return next_tokens, out_caches, lgts
        return next_tokens, out_caches

    # -------------------------------------------------------------- caches --
    def _empty_caches_like(self, x) -> dict | None:
        """Scan requires xs pytrees even in train mode (None works)."""
        return None

    def init_caches(self, batch_size: int, max_seq: int, dtype=jnp.bfloat16,
                    seq_sharded: bool | None = None) -> dict:
        """Decode caches with leading (n_superblocks,) for the layer scan."""
        cfg = self.cfg
        nsb = cfg.n_superblocks
        layout = cfg.head_layout
        caches: dict[str, Any] = {}
        for i in range(cfg.superblock):
            kind = cfg.layer_kind(i)
            if kind["mixer"] == "attn":
                shape = (nsb, batch_size, max_seq, layout.eff_kv, layout.head_dim)
                caches["k"] = jnp.zeros(shape, dtype)
                caches["v"] = jnp.zeros(shape, dtype)
            elif kind["mixer"] == "mamba":
                st = mamba_init_state(batch_size, cfg.mamba)
                caches[f"mamba{i}"] = jax.tree.map(
                    lambda t: jnp.broadcast_to(t[None], (nsb, *t.shape)), st)
            elif kind["mixer"] == "mlstm":
                st = mlstm_init_state(batch_size, cfg.xlstm)
                caches[f"mlstm{i}"] = jax.tree.map(
                    lambda t: jnp.broadcast_to(t[None], (nsb, *t.shape)), st)
            elif kind["mixer"] == "slstm":
                st = slstm_init_state(batch_size, cfg.d_model)
                caches[f"slstm{i}"] = jax.tree.map(
                    lambda t: jnp.broadcast_to(t[None], (nsb, *t.shape)), st)
        caches["pos"] = jnp.int32(0)
        return caches

    def cache_logical_axes(self, seq_sharded: bool) -> dict:
        """Logical axes for cache pytree leaves (for pjit in/out shardings)."""
        cfg = self.cfg
        kv_seq = "kv_seq" if seq_sharded else "seq"
        batch = None if seq_sharded else "batch"
        axes: dict[str, Any] = {}
        for i in range(cfg.superblock):
            kind = cfg.layer_kind(i)
            if kind["mixer"] == "attn":
                axes["k"] = ("layers", batch, kv_seq, "kv_heads", "head_dim")
                axes["v"] = ("layers", batch, kv_seq, "kv_heads", "head_dim")
            elif kind["mixer"] == "mamba":
                axes[f"mamba{i}"] = {
                    "h": ("layers", batch, "inner", "state"),
                    "conv": ("layers", batch, "conv", "inner"),
                }
            elif kind["mixer"] == "mlstm":
                axes[f"mlstm{i}"] = {
                    "C": ("layers", batch, "heads_nosplit", "head_dim", "head_dim"),
                    "n": ("layers", batch, "heads_nosplit", "head_dim"),
                    "m": ("layers", batch, "heads_nosplit"),
                }
            elif kind["mixer"] == "slstm":
                axes[f"slstm{i}"] = {k: ("layers", batch, "inner")
                                     for k in ("c", "n", "h", "m")}
        axes["pos"] = ()
        return axes

"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory) + sLSTM (scalar).

mLSTM is a linear-attention-class mixer: C_t = f_t·C_{t-1} + i_t·v_t k_tᵀ,
h_t = (C_t q_t) / max(|n_tᵀ q_t|, 1).  Training uses the **chunkwise
stabilized form** (GLA-style): a lax.scan carries (C, n, m) across chunks —
intra-chunk contributions use log-space cumulative gates with the running
max stabilizer m (exactly the paper's exponential-gating trick), so
exp() never overflows.  Decode is the O(1) recurrence — xLSTM runs the
``long_500k`` cell for this reason.

sLSTM keeps per-head scalar memories with a block-diagonal recurrent matrix
R_h; its recurrence is inherently sequential → lax.scan over time.  It's the
minority block (1:3 here), and its FLOPs are negligible; we keep its
recurrence replicated over ``model`` (documented in DESIGN.md §4) while all
projections are TP-sharded.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .layers import ParamBuilder, layer_norm


@dataclass(frozen=True)
class XLSTMConfig:
    d_model: int
    n_heads: int
    proj_factor_m: float = 2.0   # mLSTM up-projection
    proj_factor_s: float = 4 / 3  # sLSTM ffn factor
    conv_k: int = 4

    @property
    def d_inner_m(self) -> int:
        return int(self.d_model * self.proj_factor_m)

    @property
    def head_dim_m(self) -> int:
        return self.d_inner_m // self.n_heads

    @property
    def d_ff_s(self) -> int:
        """sLSTM ffn hidden, rounded up to 128 for TP divisibility (the 2730
        the exact 4/3 factor gives cannot shard 16 ways; noted in DESIGN)."""
        raw = int(self.d_model * self.proj_factor_s)
        return -(-raw // 128) * 128 if raw >= 128 else raw


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def init_mlstm(pb: ParamBuilder, cfg: XLSTMConfig, stack: int | None = None) -> None:
    lead = (stack,) if stack is not None else ()
    lax_ = ("layers",) if stack is not None else ()
    D, Di, H = cfg.d_model, cfg.d_inner_m, cfg.n_heads
    pb.param("w_up", lead + (D, 2 * Di), lax_ + ("embed", "inner"))
    pb.param("w_q", lead + (Di, Di), lax_ + ("inner", "inner_nosplit"))
    pb.param("w_k", lead + (Di, Di), lax_ + ("inner", "inner_nosplit"))
    pb.param("w_v", lead + (Di, Di), lax_ + ("inner", "inner_nosplit"))
    pb.param("w_if", lead + (Di, 2 * H), lax_ + ("inner", "heads_nosplit"), scale=0.02)
    pb.param("b_if", lead + (2 * H,), lax_ + ("heads_nosplit",), init="zeros")
    pb.param("ln_w", lead + (Di,), lax_ + ("inner",), init="ones")
    pb.param("ln_b", lead + (Di,), lax_ + ("inner",), init="zeros")
    pb.param("w_down", lead + (Di, D), lax_ + ("inner", "embed"))


def _mlstm_chunk(carry, inp, H, dh):
    """One chunk of the stabilized chunkwise mLSTM recurrence.

    carry: C (B,H,dh,dh) f32, n (B,H,dh), m (B,H)
    inp:   q,k,v (B,c,H,dh) bf16; logi, logf (B,c,H) f32
    """
    C, n, m = carry
    q, k, v, logi, logf = inp
    B, c = q.shape[0], q.shape[1]
    # cumulative forget products within the chunk (log space)
    F = jnp.cumsum(logf, axis=1)                      # (B,c,H): log prod_{1..t} f
    # stabilizer: per chunk running max of (m_prev + F_t ... , logi + ...)
    # intra-chunk decay for pair (t, s<=t): F_t - F_s + logi_s
    a = F + m[:, None]                                # log weight of initial state at t
    b_ts = logi - F                                   # (B,c,H): per-source term
    m_new = jnp.maximum(jnp.max(a, axis=1), m)        # (B,H) coarse stabilizer
    m_new = jnp.maximum(m_new, jnp.max(logi + 0.0, axis=1))

    # inter-chunk: h_inter_t = exp(a_t - m_new) * (C q_t)
    # C is [key, value]-indexed (update: k⊗v) — contract the KEY dim with q
    qf = q.astype(jnp.float32)
    inter = jnp.einsum("bhde,bthd->bthe", C, qf)      # (B,c,H,dh)
    inter_n = jnp.einsum("bhd,bthd->bth", n, qf)
    w_inter = jnp.exp(a - m_new[:, None])[..., None]  # (B,c,H,1)

    # intra-chunk: weights exp(F_t - F_s + logi_s - m_new) for s<=t
    logw = F[:, :, None] - F[:, None, :] + logi[:, None, :]  # (B,t,s,H)
    tri = jnp.tril(jnp.ones((c, c), bool))
    logw = jnp.where(tri[None, :, :, None], logw, -jnp.inf)
    w = jnp.exp(logw - m_new[:, None, None])          # (B,t,s,H)
    scores = jnp.einsum("bthd,bshd->btsh", qf, k.astype(jnp.float32))
    wscore = w * scores
    intra = jnp.einsum("btsh,bshd->bthd", wscore, v.astype(jnp.float32))
    intra_n = jnp.sum(wscore, axis=2)                 # (B,t,H)

    h_num = inter * w_inter + intra
    h_den = inter_n * w_inter[..., 0] + intra_n
    # xLSTM eq. (15): in stabilized space the |n| floor is exp(-m), not 1 —
    # a constant floor binds differently for different stabilizer
    # trajectories and breaks chunked==sequential equivalence.
    floor = jnp.exp(-m_new)[:, None, :]
    h = h_num / jnp.maximum(jnp.abs(h_den), floor)[..., None]

    # state update to end of chunk
    wk = jnp.exp(logi - F + F[:, -1:] - m_new[:, None])      # (B,c,H)
    C_new = C * jnp.exp(F[:, -1] + m - m_new)[..., None, None] + jnp.einsum(
        "bsh,bshd,bshe->bhde", wk, k.astype(jnp.float32), v.astype(jnp.float32))
    n_new = n * jnp.exp(F[:, -1] + m - m_new)[..., None] + jnp.einsum(
        "bsh,bshd->bhd", wk, k.astype(jnp.float32))
    return (C_new, n_new, m_new), h


def mlstm_mix(params: dict, x: jax.Array, ctx, chunk: int = 64, state: dict | None = None):
    """x: (B,S,D) -> (B,S,D); state carries (C,n,m,conv-free) for decode."""
    B, S, D = x.shape
    Di = params["w_q"].shape[-1]
    H = params["w_if"].shape[-1] // 2
    dh = Di // H

    up = jnp.einsum("bsd,de->bse", x.astype(jnp.bfloat16), params["w_up"].astype(jnp.bfloat16),
                    preferred_element_type=jnp.float32)
    xin, z = jnp.split(up, 2, axis=-1)
    xin = ctx.constrain(xin.astype(jnp.bfloat16), ("batch", "seq", "inner"))
    z = ctx.constrain(z.astype(jnp.bfloat16), ("batch", "seq", "inner"))

    def proj(w):
        return jnp.einsum("bse,ef->bsf", xin, w.astype(jnp.bfloat16),
                          preferred_element_type=jnp.float32).reshape(B, S, H, dh)

    q, k, v = proj(params["w_q"]), proj(params["w_k"]), proj(params["w_v"])
    k = k / jnp.sqrt(jnp.float32(dh))
    gates = jnp.einsum("bse,eg->bsg", xin.astype(jnp.float32),
                       params["w_if"].astype(jnp.float32)) + params["b_if"].astype(jnp.float32)
    logi, logf = gates[..., :H], jax.nn.log_sigmoid(gates[..., H:])

    if state is None:
        C0 = jnp.zeros((B, H, dh, dh), jnp.float32)
        n0 = jnp.zeros((B, H, dh), jnp.float32)
        m0 = jnp.full((B, H), -1e30, jnp.float32)
    else:
        C0, n0, m0 = state["C"], state["n"], state["m"]

    if S == 1:
        (C1, n1, m1), h = _mlstm_chunk((C0, n0, m0),
                                       (q, k, v, logi, logf), H, dh)
        new_state = {"C": C1, "n": n1, "m": m1}
        hs = h
    else:
        nc = S // chunk if S % chunk == 0 else 1
        c = S // nc
        r = lambda t: t.reshape(B, nc, c, *t.shape[2:]).swapaxes(0, 1)
        def step(carry, inp):
            return _mlstm_chunk(carry, inp, H, dh)
        (C1, n1, m1), hs = jax.lax.scan(step, (C0, n0, m0),
                                        (r(q), r(k), r(v), r(logi), r(logf)))
        hs = hs.swapaxes(0, 1).reshape(B, S, H, dh)
        new_state = {"C": C1, "n": n1, "m": m1}

    h = hs.reshape(B, S, Di)
    h = layer_norm(h.astype(jnp.float32), params["ln_w"], params["ln_b"]).astype(jnp.bfloat16)
    h = h * jax.nn.silu(z.astype(jnp.float32)).astype(jnp.bfloat16)
    h = ctx.constrain(h, ("batch", "seq", "inner"))
    out = jnp.einsum("bse,ed->bsd", h, params["w_down"].astype(jnp.bfloat16),
                     preferred_element_type=jnp.float32)
    return ctx.constrain(out.astype(x.dtype), ("batch", "seq", "embed_nosplit")), new_state


def mlstm_init_state(B: int, cfg: XLSTMConfig) -> dict:
    H, dh = cfg.n_heads, cfg.head_dim_m
    return {
        "C": jnp.zeros((B, H, dh, dh), jnp.float32),
        "n": jnp.zeros((B, H, dh), jnp.float32),
        "m": jnp.full((B, H), -1e30, jnp.float32),
    }


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def init_slstm(pb: ParamBuilder, cfg: XLSTMConfig, stack: int | None = None) -> None:
    lead = (stack,) if stack is not None else ()
    lax_ = ("layers",) if stack is not None else ()
    D, H = cfg.d_model, cfg.n_heads
    dh = D // H
    pb.param("w_gates", lead + (D, 4 * D), lax_ + ("embed", "inner"))
    pb.param("r_gates", lead + (H, dh, 4 * dh), lax_ + ("heads_nosplit", "head_dim", "head_dim"), scale=0.4)
    pb.param("b_gates", lead + (4 * D,), lax_ + ("inner",), init="zeros")
    pb.param("ln_w", lead + (D,), lax_ + ("embed_nosplit",), init="ones")
    pb.param("ln_b", lead + (D,), lax_ + ("embed_nosplit",), init="zeros")
    dff = cfg.d_ff_s
    pb.param("w_ff1", lead + (D, 2 * dff), lax_ + ("embed", "ff"))
    pb.param("w_ff2", lead + (dff, D), lax_ + ("ff", "embed"))


def _slstm_scan(pre, st0, r_gates, H: int):
    """The sequential time scan (factored so it can run inside shard_map)."""
    B, S, G4 = pre.shape
    D = G4 // 4
    dh = D // H

    def step(st, pre_t):
        # recurrent contribution: block-diagonal per head
        hprev = st["h"].reshape(B, H, dh)
        rec = jnp.einsum("bhd,hdg->bhg", hprev, r_gates.astype(jnp.float32))
        g = pre_t + rec.reshape(B, 4 * D)
        zi, ii, fi, oi = jnp.split(g, 4, axis=-1)
        zt = jnp.tanh(zi)
        ot = jax.nn.sigmoid(oi)
        logf = jax.nn.log_sigmoid(fi)
        m_new = jnp.maximum(logf + st["m"], ii)
        i_ = jnp.exp(ii - m_new)
        f_ = jnp.exp(logf + st["m"] - m_new)
        c_new = f_ * st["c"] + i_ * zt
        n_new = f_ * st["n"] + i_
        h_new = ot * c_new / jnp.maximum(n_new, 1.0)
        return {"c": c_new, "n": n_new, "h": h_new, "m": m_new}, h_new

    if S == 1:
        st1, h = step(st0, pre[:, 0])
        return st1, h[:, None]
    st1, hs = jax.lax.scan(step, st0, pre.swapaxes(0, 1))
    return st1, hs.swapaxes(0, 1)


def _batch_shard_axes(ctx, B: int) -> tuple:
    import numpy as _np
    spec = ctx.spec(("batch",))
    if not len(spec) or spec[0] is None:
        return ()
    ax = spec[0] if isinstance(spec[0], tuple) else (spec[0],)
    n = int(_np.prod([ctx.mesh.shape[a] for a in ax]))
    return ax if n > 1 and B % n == 0 else ()


def slstm_mix(params: dict, x: jax.Array, ctx, state: dict | None = None):
    """Sequential sLSTM over time.  x: (B,S,D).  State: {c,n,h,m} each (B,D).

    The time scan runs inside shard_map over the batch axes: under plain
    GSPMD the r_gates weight-gradient gets all-reduced *every time step*
    (measured 0.2 TB/step on xlstm train_4k — §Perf B-cell); per-shard
    accumulation syncs it once at the boundary instead.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    B, S, D = x.shape
    H = params["r_gates"].shape[0]
    pre = jnp.einsum("bsd,dg->bsg", x.astype(jnp.bfloat16), params["w_gates"].astype(jnp.bfloat16),
                     preferred_element_type=jnp.float32) + params["b_gates"].astype(jnp.float32)

    if state is None:
        zeros = jnp.zeros((B, D), jnp.float32)
        st0 = {"c": zeros, "n": zeros, "h": zeros, "m": zeros - 1e30}
    else:
        st0 = state

    axes = _batch_shard_axes(ctx, B)
    if axes:
        bspec = P(axes)
        st_spec = {k: bspec for k in st0}
        st1, hs = shard_map(
            lambda p, s, r: _slstm_scan(p, s, r, H),
            mesh=ctx.mesh,
            in_specs=(bspec, st_spec, P()),
            out_specs=(st_spec, bspec),
            check_rep=False,
        )(pre, st0, params["r_gates"])
    else:
        st1, hs = _slstm_scan(pre, st0, params["r_gates"], H)

    y = layer_norm(hs, params["ln_w"], params["ln_b"]).astype(jnp.bfloat16)
    # GEGLU-ish ffn (projects up 2*dff, gates, projects down)
    ff = jnp.einsum("bsd,df->bsf", y, params["w_ff1"].astype(jnp.bfloat16),
                    preferred_element_type=jnp.float32)
    a, b = jnp.split(ff, 2, axis=-1)
    h = (jax.nn.gelu(a) * b).astype(jnp.bfloat16)
    h = ctx.constrain(h, ("batch", "seq", "ff"))
    out = jnp.einsum("bsf,fd->bsd", h, params["w_ff2"].astype(jnp.bfloat16),
                     preferred_element_type=jnp.float32)
    return ctx.constrain(out.astype(x.dtype), ("batch", "seq", "embed_nosplit")), st1


def slstm_init_state(B: int, d_model: int) -> dict:
    zeros = jnp.zeros((B, d_model), jnp.float32)
    return {"c": zeros, "n": zeros, "h": zeros, "m": zeros - 1e30}

"""Shared neural-net building blocks (pure functions over param pytrees).

Params are nested dicts of jnp arrays; a parallel tree of *logical axis
tuples* (see distributed/sharding.py) describes how each leaf shards.  The
``ParamBuilder`` keeps both trees in sync during init.

Precision policy (framework-wide):
  * params: ``cfg.param_dtype`` (f32 small models, bf16 for the ≥30 B ones)
  * matmul compute: bf16 inputs, f32 accumulation (``preferred_element_type``)
  * norms / softmax / router / scan carries: f32
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np


# --------------------------------------------------------------------------
# param construction
# --------------------------------------------------------------------------


class ParamBuilder:
    """Collects params and their logical axes; splits keys deterministically.

    ``abstract=True`` builds ShapeDtypeStructs instead of arrays — used by the
    dry-run to get the full param tree of 100B+ models with zero allocation.
    """

    def __init__(self, key: jax.Array, dtype=jnp.float32, abstract: bool = False):
        self._key = key
        self.dtype = dtype
        self.abstract = abstract
        self.params: dict[str, Any] = {}
        self.axes: dict[str, Any] = {}

    def _next_key(self) -> jax.Array:
        self._key, k = jax.random.split(self._key)
        return k

    def param(
        self,
        name: str,
        shape: tuple[int, ...],
        logical: tuple,
        init: str | float = "normal",
        scale: float | None = None,
        dtype=None,
    ):
        assert len(shape) == len(logical), f"{name}: {shape} vs {logical}"
        dtype = dtype or self.dtype
        if self.abstract:
            self.params[name] = jax.ShapeDtypeStruct(shape, dtype)
            self.axes[name] = logical
            return self.params[name]
        if init == "normal":
            fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
            std = scale if scale is not None else 1.0 / math.sqrt(max(fan_in, 1))
            w = jax.random.normal(self._next_key(), shape, jnp.float32) * std
        elif init == "zeros":
            w = jnp.zeros(shape, jnp.float32)
        elif init == "ones":
            w = jnp.ones(shape, jnp.float32)
        elif isinstance(init, float):
            w = jnp.full(shape, init, jnp.float32)
        else:
            raise ValueError(init)
        self.params[name] = w.astype(dtype)
        self.axes[name] = logical
        return self.params[name]

    def scope(self, name: str) -> "ParamBuilder":
        sub = ParamBuilder(self._next_key(), self.dtype, self.abstract)
        self.params[name] = sub.params
        self.axes[name] = sub.axes
        return sub

    def set(self, name: str, params: dict, axes: dict) -> None:
        self.params[name] = params
        self.axes[name] = axes


def count_params(params) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))


# --------------------------------------------------------------------------
# numerics
# --------------------------------------------------------------------------


# Accumulation dtype for matmuls.  f32 default; bf16 halves the backward
# activation psums (GSPMD reduces the pre-cast partials) — a §Perf lever.
_ACCUM_DTYPE = jnp.float32


def set_matmul_accum_dtype(dtype) -> None:
    global _ACCUM_DTYPE
    _ACCUM_DTYPE = dtype


def dot(x, w, compute_dtype=jnp.bfloat16):
    """Matmul with bf16 inputs and configurable accumulation."""
    return jax.lax.dot_general(
        x.astype(compute_dtype),
        w.astype(compute_dtype),
        (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=_ACCUM_DTYPE,
    )


def einsum(spec: str, *args, compute_dtype=jnp.bfloat16):
    args = [a.astype(compute_dtype) for a in args]
    return jnp.einsum(spec, *args, preferred_element_type=_ACCUM_DTYPE)


def rms_norm(x, weight, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * weight.astype(jnp.float32)
    return out.astype(x.dtype)


def layer_norm(x, weight, bias, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps) * weight.astype(jnp.float32) + bias.astype(jnp.float32)
    return out.astype(x.dtype)


def swiglu(gate, up):
    return jax.nn.silu(gate) * up


def gelu(x):
    return jax.nn.gelu(x, approximate=True)


# --------------------------------------------------------------------------
# rotary embeddings
# --------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float = 10000.0) -> jax.Array:
    exponents = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponents)  # (head_dim/2,)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq) int32."""
    freqs = rope_frequencies(x.shape[-1], theta)  # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    cos = jnp.cos(angles)[..., None, :]  # (..., seq, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# FFN blocks
# --------------------------------------------------------------------------


def init_mlp(pb: ParamBuilder, d_model: int, d_ff: int, stack: int | None = None,
             activation: str = "swiglu") -> None:
    """SwiGLU (gate+up+down) or GELU (up+down) MLP, optionally layer-stacked."""
    lead = (stack,) if stack is not None else ()
    lax = ("layers",) if stack is not None else ()
    if activation == "swiglu":
        pb.param("w_gate", lead + (d_model, d_ff), lax + ("embed", "ff"))
        pb.param("w_up", lead + (d_model, d_ff), lax + ("embed", "ff"))
    else:
        pb.param("w_up", lead + (d_model, d_ff), lax + ("embed", "ff"))
    pb.param("w_down", lead + (d_ff, d_model), lax + ("ff", "embed"))


def mlp(params: dict, x: jax.Array, ctx, activation: str = "swiglu") -> jax.Array:
    """x: (B, S, D) -> (B, S, D).  TP over the ff dim; psum via GSPMD on w_down."""
    if activation == "swiglu":
        h = swiglu(dot(x, params["w_gate"]), dot(x, params["w_up"]))
    else:
        h = gelu(dot(x, params["w_up"]))
    h = ctx.constrain(h.astype(x.dtype), ("batch", "seq", "ff"))
    out = dot(h, params["w_down"])
    return ctx.constrain(out.astype(x.dtype), ("batch", "seq", "embed_nosplit"))


# --------------------------------------------------------------------------
# embeddings / lm head
# --------------------------------------------------------------------------


def init_embedding(pb: ParamBuilder, vocab: int, d_model: int) -> None:
    pb.param("embedding", (vocab, d_model), ("vocab", "embed"), scale=0.02)


def embed(params: dict, tokens: jax.Array, ctx) -> jax.Array:
    out = params["embedding"].astype(jnp.bfloat16)[tokens]
    return ctx.constrain(out, ("batch", "seq", "embed_nosplit"))


def logits(params: dict, x: jax.Array, ctx) -> jax.Array:
    """(B, S, D) -> (B, S, V) f32, vocab-sharded over model."""
    out = einsum("bsd,vd->bsv", x, params["embedding"])
    return ctx.constrain(out, ("batch", "seq", "vocab"))


def cross_entropy_loss(lgts: jax.Array, labels: jax.Array, mask: jax.Array | None = None):
    """Mean token NLL; logits f32 (B, S, V), labels int (B, S)."""
    lse = jax.nn.logsumexp(lgts, axis=-1)
    picked = jnp.take_along_axis(lgts, labels[..., None], axis=-1)[..., 0]
    nll = lse - picked
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def chunked_lm_loss(emb_params: dict, x: jax.Array, labels: jax.Array, ctx,
                    chunk: int = 512, mask: jax.Array | None = None):
    """LM head + xent scanned over seq chunks so (B,S,V) never materializes."""
    B, S, D = x.shape
    n = max(1, S // chunk)
    while S % n:  # nearest divisor ≤ desired chunk count (static python)
        n -= 1
    chunk = S // n
    xs = x.reshape(B, n, chunk, D).swapaxes(0, 1)  # (n, B, c, D)
    ls = labels.reshape(B, n, chunk).swapaxes(0, 1)
    ms = None if mask is None else mask.reshape(B, n, chunk).swapaxes(0, 1)

    def body(carry, inp):
        tot, cnt = carry
        if ms is None:
            xc, lc = inp
            mc = jnp.ones_like(lc, jnp.float32)
        else:
            xc, lc, mc = inp
            mc = mc.astype(jnp.float32)
        lg = logits(emb_params, xc, ctx)
        lse = jax.nn.logsumexp(lg, axis=-1)
        picked = jnp.take_along_axis(lg, lc[..., None], axis=-1)[..., 0]
        tot = tot + jnp.sum((lse - picked) * mc)
        cnt = cnt + jnp.sum(mc)
        return (tot, cnt), None

    inps = (xs, ls) if ms is None else (xs, ls, ms)
    # remat: recompute each chunk's logits in backward instead of saving the
    # (B, chunk, V/shard) f32 stack (1.5 GB/device on internlm2 — see §Perf)
    body = jax.checkpoint(body, prevent_cse=False)
    (tot, cnt), _ = jax.lax.scan(body, (jnp.float32(0), jnp.float32(0)), inps)
    return tot / jnp.maximum(cnt, 1.0)

"""Arrow-IPC-style message framing (Fig 1(d) of the paper).

A stream is::

    SCHEMA message | RECORDBATCH message * | EOS

Each message = 8-byte header (magic ``0xA77C0DE1`` + metadata length) +
metadata + 64-byte-aligned body holding every buffer of the batch
back-to-back at aligned offsets.

Metadata comes in two codecs, discriminated by the first byte:

* **binary** (default, ``0xB1`` first byte) — a struct-packed fixed header
  followed by flat node/buffer placement tables::

      <BBHIIQQ>  magic=0xB1, msg kind, reserved, n_nodes, n_buffers,
                 rows, body_len                                  (28 B)
      n_nodes   × <QB>  node: logical length, flags (bit0 = has validity)
      n_buffers × <QQ>  buffer placement: body offset, byte length

  Nodes and buffers are laid out in column-major preorder (a node, its
  buffers — validity first, then offsets, then values — then its children);
  the decoder recovers the nesting by walking the schema's type tree, so no
  per-message structure is serialized.  ``json.dumps``/``loads`` never run
  on the data path.
* **json** — ``{"msg": ..., ...}``, kept for the schema message (per-stream,
  off the hot path), for control frames one level down in transport.py, and
  as the comparison codec in ``benchmarks/bench_wire.py``.  JSON always
  starts with ``{`` (0x7B), so the 0xB1 first byte is an unambiguous kind
  bit and old JSON frames keep decoding.

The performance-critical properties (the whole point of the paper):

* **encode** produces ``(metadata, [buffer views])`` — scatter/gather ready;
  the socket transport hands the views straight to ``sendmsg`` with **zero
  copies** of value data.
* **decode** returns Arrays whose buffers are **views into the received body**
  — zero deserialization.  Nothing row-wise ever runs.
"""
from __future__ import annotations

import json
import struct
from dataclasses import dataclass

import numpy as np

from .array import Array
from .buffer import ALIGNMENT, Bitmap, Buffer, pad_to
from .recordbatch import RecordBatch
from .schema import (
    BinaryType,
    DataType,
    FixedSizeListType,
    ListType,
    PrimitiveType,
    Schema,
    Utf8Type,
    type_from_json,
)

MAGIC = 0xA77C0DE1
HEADER = struct.Struct("<II")  # magic, metadata length
MSG_SCHEMA, MSG_BATCH, MSG_EOS = "schema", "batch", "eos"

CODEC_JSON, CODEC_BINARY = "json", "binary"
DEFAULT_CODEC = CODEC_BINARY

# binary metadata layout (see module docstring)
META_MAGIC = 0xB1  # never a JSON first byte ('{' == 0x7B)
BIN_BATCH, BIN_EOS = 1, 2
BIN_HEADER = struct.Struct("<BBHIIQQ")  # magic, kind, reserved, n_nodes, n_buffers, rows, body_len
BIN_NODE = struct.Struct("<QB")  # length, flags (bit0 = has validity)
BIN_BUF = struct.Struct("<QQ")  # body offset, byte length
NODE_HAS_VALIDITY = 1


# --------------------------------------------------------------------------
# encode
# --------------------------------------------------------------------------


@dataclass
class EncodedMessage:
    """A wire message as (metadata bytes, body buffer views).

    ``body_parts`` are zero-copy numpy views (plus small pad arrays); total
    body size is ``body_len``.  ``to_bytes()`` is the single-copy
    materialization used by in-memory size accounting and tests.
    """

    metadata: bytes
    body_parts: list[np.ndarray]
    body_len: int

    def frame_parts(self) -> list[memoryview]:
        meta_len = pad_to(len(self.metadata), 8)
        head = HEADER.pack(MAGIC, meta_len)
        meta = self.metadata + b"\0" * (meta_len - len(self.metadata))
        parts = [memoryview(head), memoryview(meta)]
        parts += [memoryview(p).cast("B") for p in self.body_parts]
        return parts

    def nbytes(self) -> int:
        return HEADER.size + pad_to(len(self.metadata), 8) + self.body_len

    def to_bytes(self) -> bytes:
        return b"".join(self.frame_parts())


@dataclass
class BatchMeta:
    """Parsed RECORDBATCH metadata: flat placement tables, either codec."""

    __slots__ = ("rows", "body_len", "nodes", "buffers")

    rows: int
    body_len: int
    nodes: list[tuple[int, int]]  # (length, flags) preorder
    buffers: list[tuple[int, int]]  # (offset, nbytes) preorder


_PAD = np.zeros(ALIGNMENT, dtype=np.uint8)


class _BodyBuilder:
    def __init__(self):
        self.parts: list[np.ndarray] = []
        self.pos = 0

    def add(self, view: np.ndarray) -> tuple[int, int]:
        view = view.reshape(-1).view(np.uint8) if view.dtype != np.uint8 else view
        off, n = self.pos, view.nbytes
        self.parts.append(view)
        pad = pad_to(n) - n
        if pad:
            self.parts.append(_PAD[:pad])
        self.pos += n + pad
        return off, n


def _flatten_array(arr: Array, body: _BodyBuilder, nodes: list, bufs: list) -> None:
    """Depth-first walk emitting flat placement tables; compacts offsets."""
    t = arr.type
    flags = NODE_HAS_VALIDITY if arr.validity is not None else 0
    nodes.append((arr.length, flags))

    if arr.validity is not None:
        v = arr.validity.slice(arr.offset, arr.length) if arr.offset else arr.validity
        bufs.append(body.add(v.buffer.data[: (arr.length + 7) // 8]))

    if isinstance(t, PrimitiveType):
        bufs.append(body.add(np.ascontiguousarray(arr._values())))
    elif isinstance(t, (Utf8Type, BinaryType)):
        offs = arr._offsets()
        base = int(offs[0])
        if base:
            offs = offs - base  # rebase (copies n+1 int32 — metadata-sized)
        bufs.append(body.add(np.ascontiguousarray(offs)))
        values = arr.buffers[1].view(np.uint8)[base : base + int(offs[-1])]
        bufs.append(body.add(values))
    elif isinstance(t, ListType):
        offs = arr._offsets()
        base = int(offs[0])
        if base:
            offs = offs - base
        bufs.append(body.add(np.ascontiguousarray(offs)))
        child = arr.children[0].slice(base, int(offs[-1]))
        _flatten_array(child, body, nodes, bufs)
    elif isinstance(t, FixedSizeListType):
        child = arr.children[0].slice(arr.offset * t.list_size, arr.length * t.list_size)
        _flatten_array(child, body, nodes, bufs)
    else:
        raise TypeError(f"IPC: unsupported type {t!r}")


def encode_schema(s: Schema) -> EncodedMessage:
    meta = json.dumps({"msg": MSG_SCHEMA, "schema": s.to_json()}).encode()
    return EncodedMessage(meta, [], 0)


def encode_batch(batch: RecordBatch, codec: str = DEFAULT_CODEC) -> EncodedMessage:
    body = _BodyBuilder()
    nodes: list[tuple[int, int]] = []
    bufs: list[tuple[int, int]] = []
    for c in batch.columns:
        _flatten_array(c, body, nodes, bufs)
    if codec == CODEC_BINARY:
        meta = bytearray(
            BIN_HEADER.pack(META_MAGIC, BIN_BATCH, 0, len(nodes), len(bufs),
                            batch.num_rows, body.pos)
        )
        for node in nodes:
            meta += BIN_NODE.pack(*node)
        for buf in bufs:
            meta += BIN_BUF.pack(*buf)
        meta = bytes(meta)
    elif codec == CODEC_JSON:
        meta = json.dumps(
            {"msg": MSG_BATCH, "rows": batch.num_rows, "body_len": body.pos,
             "nodes": nodes, "buffers": bufs}
        ).encode()
    else:
        raise ValueError(f"unknown metadata codec {codec!r}")
    return EncodedMessage(meta, body.parts, body.pos)


def encode_eos(codec: str = DEFAULT_CODEC) -> EncodedMessage:
    if codec == CODEC_BINARY:
        return EncodedMessage(BIN_HEADER.pack(META_MAGIC, BIN_EOS, 0, 0, 0, 0, 0), [], 0)
    return EncodedMessage(json.dumps({"msg": MSG_EOS}).encode(), [], 0)


# --------------------------------------------------------------------------
# decode
# --------------------------------------------------------------------------


def _rebuild_node(meta: BatchMeta, typ: DataType, body: Buffer, pos: list[int]) -> Array:
    """Rebuild one array by advancing the (node, buffer) cursors in ``pos``."""
    length, flags = meta.nodes[pos[0]]
    pos[0] += 1
    validity = None
    if flags & NODE_HAS_VALIDITY:
        off, n = meta.buffers[pos[1]]
        pos[1] += 1
        validity = Bitmap(body.slice(off, n), length)

    if isinstance(typ, PrimitiveType):
        off, n = meta.buffers[pos[1]]
        pos[1] += 1
        return Array(typ, length, validity, [body.slice(off, n)])
    if isinstance(typ, (Utf8Type, BinaryType)):
        o_off, o_n = meta.buffers[pos[1]]
        v_off, v_n = meta.buffers[pos[1] + 1]
        pos[1] += 2
        return Array(typ, length, validity, [body.slice(o_off, o_n), body.slice(v_off, v_n)])
    if isinstance(typ, ListType):
        off, n = meta.buffers[pos[1]]
        pos[1] += 1
        child = _rebuild_node(meta, typ.value_type, body, pos)
        return Array(typ, length, validity, [body.slice(off, n)], [child])
    if isinstance(typ, FixedSizeListType):
        child = _rebuild_node(meta, typ.value_type, body, pos)
        return Array(typ, length, validity, [], [child])
    raise TypeError(typ)


@dataclass
class DecodedMessage:
    kind: str
    schema: Schema | None = None
    batch_meta: BatchMeta | None = None
    body: Buffer | None = None

    def batch(self, schema: Schema) -> RecordBatch:
        assert self.kind == MSG_BATCH and self.batch_meta is not None
        pos = [0, 0]  # (node cursor, buffer cursor)
        cols = [_rebuild_node(self.batch_meta, f.type, self.body, pos) for f in schema.fields]
        return RecordBatch(schema, cols)


def _parse_binary(data: bytes) -> dict | BatchMeta:
    magic, kind, _res, n_nodes, n_bufs, rows, body_len = BIN_HEADER.unpack_from(data, 0)
    if kind == BIN_EOS:
        return {"msg": MSG_EOS}
    if kind != BIN_BATCH:
        raise ValueError(f"bad binary metadata kind {kind}")
    off = BIN_HEADER.size
    nodes = list(BIN_NODE.iter_unpack(data[off : off + n_nodes * BIN_NODE.size]))
    off += n_nodes * BIN_NODE.size
    buffers = list(BIN_BUF.iter_unpack(data[off : off + n_bufs * BIN_BUF.size]))
    return BatchMeta(rows, body_len, nodes, buffers)


def parse_metadata(meta_bytes: bytes) -> dict | BatchMeta:
    """Parse message metadata of either codec (first byte discriminates)."""
    if meta_bytes and meta_bytes[0] == META_MAGIC:
        return _parse_binary(meta_bytes)
    obj = json.loads(meta_bytes.rstrip(b"\0").decode())
    if obj.get("msg") == MSG_BATCH:
        return BatchMeta(
            obj["rows"],
            obj["body_len"],
            [tuple(n) for n in obj["nodes"]],
            [tuple(b) for b in obj["buffers"]],
        )
    return obj


def decode_message(meta: dict | BatchMeta, body: Buffer | None) -> DecodedMessage:
    if isinstance(meta, BatchMeta):
        return DecodedMessage(MSG_BATCH, batch_meta=meta, body=body)
    kind = meta["msg"]
    if kind == MSG_SCHEMA:
        return DecodedMessage(MSG_SCHEMA, schema=Schema.from_json(meta["schema"]))
    if kind == MSG_EOS:
        return DecodedMessage(MSG_EOS)
    raise ValueError(f"bad message kind {kind!r}")


# --------------------------------------------------------------------------
# whole-stream helpers (files / tests); transports stream message-by-message
# --------------------------------------------------------------------------


def write_stream(
    batches: list[RecordBatch], schema: Schema | None = None, codec: str = DEFAULT_CODEC
) -> bytes:
    schema = schema or batches[0].schema
    out = [encode_schema(schema).to_bytes()]
    out += [encode_batch(b, codec).to_bytes() for b in batches]
    out.append(encode_eos(codec).to_bytes())
    return b"".join(out)


def read_stream_with_schema(data: bytes | Buffer) -> tuple[Schema, list[RecordBatch]]:
    """Decode a whole stream, returning its schema alongside the batches.

    Batch buffers are zero-copy views into ``data`` — hand in a Buffer over
    an mmap and the decoded batches serve straight off the page cache (the
    disk storage provider's re-serve path, ``core/flight/storage.py``)."""
    buf = data if isinstance(data, Buffer) else Buffer.from_bytes(data)
    pos, schema, batches = 0, None, []
    while pos < buf.nbytes:
        magic, meta_len = HEADER.unpack_from(buf.data, pos)
        if magic != MAGIC:
            raise ValueError(f"bad magic at {pos}: {magic:#x}")
        pos += HEADER.size
        meta = parse_metadata(buf.data[pos : pos + meta_len].tobytes())
        pos += meta_len
        body = None
        if isinstance(meta, BatchMeta):
            body = buf.slice(pos, meta.body_len)
            pos += meta.body_len
        msg = decode_message(meta, body)
        if msg.kind == MSG_SCHEMA:
            schema = msg.schema
        elif msg.kind == MSG_BATCH:
            batches.append(msg.batch(schema))
        else:
            break
    if schema is None:
        raise ValueError("stream carries no schema message")
    return schema, batches


def read_stream(data: bytes | Buffer) -> list[RecordBatch]:
    return read_stream_with_schema(data)[1]

"""Arrow-IPC-style message framing (Fig 1(d) of the paper).

A stream is::

    SCHEMA message | RECORDBATCH message * | EOS

Each message = 8-byte header (magic ``0xA77C0DE1`` + metadata length) +
metadata (compact JSON) + 64-byte-aligned body holding every buffer of the
batch back-to-back at aligned offsets.

The performance-critical properties (the whole point of the paper):

* **encode** produces ``(metadata, [buffer views])`` — scatter/gather ready;
  the socket transport hands the views straight to ``sendmsg`` with **zero
  copies** of value data.
* **decode** returns Arrays whose buffers are **views into the received body**
  — zero deserialization.  Nothing row-wise ever runs.
"""
from __future__ import annotations

import json
import struct
from dataclasses import dataclass

import numpy as np

from .array import Array
from .buffer import ALIGNMENT, Bitmap, Buffer, pad_to
from .recordbatch import RecordBatch
from .schema import (
    BinaryType,
    DataType,
    FixedSizeListType,
    ListType,
    PrimitiveType,
    Schema,
    Utf8Type,
    type_from_json,
)

MAGIC = 0xA77C0DE1
HEADER = struct.Struct("<II")  # magic, metadata length
MSG_SCHEMA, MSG_BATCH, MSG_EOS = "schema", "batch", "eos"


# --------------------------------------------------------------------------
# encode
# --------------------------------------------------------------------------


@dataclass
class EncodedMessage:
    """A wire message as (metadata bytes, body buffer views).

    ``body_parts`` are zero-copy numpy views (plus small pad arrays); total
    body size is ``body_len``.  ``to_bytes()`` is the single-copy
    materialization used by in-memory size accounting and tests.
    """

    metadata: bytes
    body_parts: list[np.ndarray]
    body_len: int

    def frame_parts(self) -> list[memoryview]:
        meta_len = pad_to(len(self.metadata), 8)
        head = HEADER.pack(MAGIC, meta_len)
        meta = self.metadata + b"\0" * (meta_len - len(self.metadata))
        parts = [memoryview(head), memoryview(meta)]
        parts += [memoryview(p).cast("B") for p in self.body_parts]
        return parts

    def nbytes(self) -> int:
        return HEADER.size + pad_to(len(self.metadata), 8) + self.body_len

    def to_bytes(self) -> bytes:
        return b"".join(self.frame_parts())


_PAD = np.zeros(ALIGNMENT, dtype=np.uint8)


class _BodyBuilder:
    def __init__(self):
        self.parts: list[np.ndarray] = []
        self.pos = 0

    def add(self, view: np.ndarray) -> tuple[int, int]:
        view = view.reshape(-1).view(np.uint8) if view.dtype != np.uint8 else view
        off, n = self.pos, view.nbytes
        self.parts.append(view)
        pad = pad_to(n) - n
        if pad:
            self.parts.append(_PAD[:pad])
        self.pos += n + pad
        return off, n


def _flatten_array(arr: Array, body: _BodyBuilder) -> dict:
    """Depth-first walk emitting buffer placements; compacts logical offsets."""
    t = arr.type
    node: dict = {"len": arr.length, "buffers": [], "children": []}

    if arr.validity is not None:
        v = arr.validity.slice(arr.offset, arr.length) if arr.offset else arr.validity
        node["validity"] = body.add(v.buffer.data[: (arr.length + 7) // 8])
    else:
        node["validity"] = None

    if isinstance(t, PrimitiveType):
        node["buffers"].append(body.add(np.ascontiguousarray(arr._values())))
    elif isinstance(t, (Utf8Type, BinaryType)):
        offs = arr._offsets()
        base = int(offs[0])
        if base:
            offs = offs - base  # rebase (copies n+1 int32 — metadata-sized)
        node["buffers"].append(body.add(np.ascontiguousarray(offs)))
        values = arr.buffers[1].view(np.uint8)[base : base + int(offs[-1])]
        node["buffers"].append(body.add(values))
    elif isinstance(t, ListType):
        offs = arr._offsets()
        base = int(offs[0])
        if base:
            offs = offs - base
        node["buffers"].append(body.add(np.ascontiguousarray(offs)))
        child = arr.children[0].slice(base, int(offs[-1]))
        node["children"].append(_flatten_array(child, body))
    elif isinstance(t, FixedSizeListType):
        child = arr.children[0].slice(arr.offset * t.list_size, arr.length * t.list_size)
        node["children"].append(_flatten_array(child, body))
    else:
        raise TypeError(f"IPC: unsupported type {t!r}")
    return node


def encode_schema(s: Schema) -> EncodedMessage:
    meta = json.dumps({"msg": MSG_SCHEMA, "schema": s.to_json()}).encode()
    return EncodedMessage(meta, [], 0)


def encode_batch(batch: RecordBatch) -> EncodedMessage:
    body = _BodyBuilder()
    nodes = [_flatten_array(c, body) for c in batch.columns]
    meta = json.dumps(
        {"msg": MSG_BATCH, "rows": batch.num_rows, "nodes": nodes, "body_len": body.pos}
    ).encode()
    return EncodedMessage(meta, body.parts, body.pos)


def encode_eos() -> EncodedMessage:
    return EncodedMessage(json.dumps({"msg": MSG_EOS}).encode(), [], 0)


# --------------------------------------------------------------------------
# decode
# --------------------------------------------------------------------------


def _rebuild_array(node: dict, typ: DataType, body: Buffer) -> Array:
    def view(placement) -> Buffer:
        off, n = placement
        return body.slice(off, n)

    validity = None
    if node["validity"] is not None:
        validity = Bitmap(view(node["validity"]), node["len"])

    if isinstance(typ, PrimitiveType):
        return Array(typ, node["len"], validity, [view(node["buffers"][0])])
    if isinstance(typ, (Utf8Type, BinaryType)):
        return Array(
            typ, node["len"], validity, [view(node["buffers"][0]), view(node["buffers"][1])]
        )
    if isinstance(typ, ListType):
        child = _rebuild_array(node["children"][0], typ.value_type, body)
        return Array(typ, node["len"], validity, [view(node["buffers"][0])], [child])
    if isinstance(typ, FixedSizeListType):
        child = _rebuild_array(node["children"][0], typ.value_type, body)
        return Array(typ, node["len"], validity, [], [child])
    raise TypeError(typ)


@dataclass
class DecodedMessage:
    kind: str
    schema: Schema | None = None
    batch_meta: dict | None = None
    body: Buffer | None = None

    def batch(self, schema: Schema) -> RecordBatch:
        assert self.kind == MSG_BATCH and self.batch_meta is not None
        cols = [
            _rebuild_array(node, f.type, self.body)
            for node, f in zip(self.batch_meta["nodes"], schema.fields)
        ]
        return RecordBatch(schema, cols)


def parse_metadata(meta_bytes: bytes) -> dict:
    return json.loads(meta_bytes.rstrip(b"\0").decode())


def decode_message(meta: dict, body: Buffer | None) -> DecodedMessage:
    kind = meta["msg"]
    if kind == MSG_SCHEMA:
        return DecodedMessage(MSG_SCHEMA, schema=Schema.from_json(meta["schema"]))
    if kind == MSG_BATCH:
        return DecodedMessage(MSG_BATCH, batch_meta=meta, body=body)
    if kind == MSG_EOS:
        return DecodedMessage(MSG_EOS)
    raise ValueError(f"bad message kind {kind!r}")


# --------------------------------------------------------------------------
# whole-stream helpers (files / tests); transports stream message-by-message
# --------------------------------------------------------------------------


def write_stream(batches: list[RecordBatch], schema: Schema | None = None) -> bytes:
    schema = schema or batches[0].schema
    out = [encode_schema(schema).to_bytes()]
    out += [encode_batch(b).to_bytes() for b in batches]
    out.append(encode_eos().to_bytes())
    return b"".join(out)


def read_stream(data: bytes | Buffer) -> list[RecordBatch]:
    buf = data if isinstance(data, Buffer) else Buffer.from_bytes(data)
    pos, schema, batches = 0, None, []
    while pos < buf.nbytes:
        magic, meta_len = HEADER.unpack_from(buf.data, pos)
        if magic != MAGIC:
            raise ValueError(f"bad magic at {pos}: {magic:#x}")
        pos += HEADER.size
        meta = parse_metadata(buf.data[pos : pos + meta_len].tobytes())
        pos += meta_len
        body = None
        if meta["msg"] == MSG_BATCH:
            body = buf.slice(pos, meta["body_len"])
            pos += meta["body_len"]
        msg = decode_message(meta, body)
        if msg.kind == MSG_SCHEMA:
            schema = msg.schema
        elif msg.kind == MSG_BATCH:
            batches.append(msg.batch(schema))
        else:
            break
    return batches

"""Aligned, zero-copy byte buffers — the bottom of the Arrow-style stack.

Arrow's performance story starts here: every value/validity/offset region is a
contiguous, 64-byte-aligned buffer that can cross process/wire boundaries as
raw bytes.  ``Buffer`` wraps a numpy ``uint8`` view and never copies unless
asked; slicing returns views.  ``Bitmap`` provides the validity-bitmap
semantics (LSB-first, like Arrow).
"""
from __future__ import annotations

import sys
import threading

import numpy as np

ALIGNMENT = 64  # bytes; Arrow IPC pads every buffer to 64B boundaries


def _aligned_empty(nbytes: int, alignment: int = ALIGNMENT) -> np.ndarray:
    """Allocate ``nbytes`` of uint8 whose data pointer is ``alignment``-aligned."""
    raw = np.empty(nbytes + alignment, dtype=np.uint8)
    offset = (-raw.ctypes.data) % alignment
    return raw[offset : offset + nbytes]


def pad_to(n: int, alignment: int = ALIGNMENT) -> int:
    return (n + alignment - 1) // alignment * alignment


class Buffer:
    """An immutable-by-convention contiguous byte region.

    Wraps a 1-D uint8 numpy array.  ``view(dtype)`` reinterprets zero-copy;
    ``slice`` returns a sub-``Buffer`` sharing memory.  Equality compares
    contents (used in tests / round-trips).
    """

    __slots__ = ("data",)

    def __init__(self, data: np.ndarray):
        if data.dtype != np.uint8 or data.ndim != 1:
            raise TypeError(f"Buffer wants 1-D uint8, got {data.dtype} ndim={data.ndim}")
        self.data = data

    # -- constructors -------------------------------------------------------
    @classmethod
    def allocate(cls, nbytes: int) -> "Buffer":
        return cls(_aligned_empty(nbytes))

    @classmethod
    def from_array(cls, arr: np.ndarray, copy: bool = False) -> "Buffer":
        """Zero-copy when ``arr`` is C-contiguous; copies otherwise."""
        arr = np.ascontiguousarray(arr)
        flat = arr.view(np.uint8).reshape(-1)
        if copy:
            out = cls.allocate(flat.nbytes)
            out.data[:] = flat
            return out
        return cls(flat)

    @classmethod
    def from_bytes(cls, b: bytes) -> "Buffer":
        return cls(np.frombuffer(b, dtype=np.uint8))

    # -- accessors -----------------------------------------------------------
    @property
    def nbytes(self) -> int:
        return self.data.nbytes

    @property
    def address(self) -> int:
        return self.data.ctypes.data

    @property
    def is_aligned(self) -> bool:
        return self.address % ALIGNMENT == 0

    def view(self, dtype) -> np.ndarray:
        """Zero-copy reinterpretation as ``dtype`` items."""
        dtype = np.dtype(dtype)
        usable = self.nbytes - self.nbytes % dtype.itemsize
        return self.data[:usable].view(dtype)

    def slice(self, offset: int, length: int | None = None) -> "Buffer":
        end = self.nbytes if length is None else offset + length
        return Buffer(self.data[offset:end])

    def to_bytes(self) -> bytes:  # copies (by definition of bytes)
        return self.data.tobytes()

    def __len__(self) -> int:
        return self.nbytes

    def __eq__(self, other) -> bool:
        if not isinstance(other, Buffer):
            return NotImplemented
        return self.nbytes == other.nbytes and bool(np.array_equal(self.data, other.data))

    def __repr__(self) -> str:
        return f"Buffer({self.nbytes}B @0x{self.address:x}{' aligned' if self.is_aligned else ''})"


class BufferPool:
    """Recycling bump allocator of aligned slabs for receive bodies.

    ``Buffer.allocate`` per frame makes the small-message regime allocation
    bound; the pool instead bump-carves aligned views out of a bounded set
    of power-of-two slabs: consecutive small bodies pack side by side in the
    current slab (so a retained 1 KiB batch pins its share of one shared
    slab, not a whole private slab), and a new slab is opened only when the
    current one is exhausted.

    Safety without an explicit ``release``: every view of a slab (decoded
    Array buffers, Bitmap bytes, sub-slices) keeps a numpy ``.base``
    reference to the slab's backing array, so a slab is demonstrably free
    exactly when its refcount is back to the pool-only baseline — checked
    with ``sys.getrefcount`` under the pool lock.  A slab with any live
    carve is never reused (new carves from it are disjoint by construction).
    When every tracked slab is pinned, the eldest slot is evicted (its
    consumers keep it alive) so the pool keeps recycling recent slabs
    instead of degrading to always-miss.
    """

    MIN_SLAB = 64 << 10  # slab floor: many small bodies share one slab

    def __init__(self, max_slabs: int = 32):
        self._slabs: list[np.ndarray] = []
        self._cur: np.ndarray | None = None  # slab currently being bump-carved
        self._cur_end = 0  # next free byte in _cur
        self._lock = threading.Lock()
        self.max_slabs = max_slabs
        self.hits = 0
        self.misses = 0

    @staticmethod
    def _aligned_start(raw: np.ndarray, pos: int) -> int:
        return pos + (-(raw.ctypes.data + pos)) % ALIGNMENT

    def _open_slab(self, raw: np.ndarray, nbytes: int) -> "Buffer":
        self._cur = raw
        start = self._aligned_start(raw, 0)
        self._cur_end = start + nbytes
        return Buffer(raw[start : start + nbytes])

    def acquire(self, nbytes: int) -> "Buffer":
        """An aligned ``Buffer`` of ``nbytes``, recycled when possible."""
        with self._lock:
            if self._cur is not None:
                start = self._aligned_start(self._cur, self._cur_end)
                if start + nbytes <= self._cur.nbytes:
                    self._cur_end = start + nbytes
                    self.hits += 1
                    return Buffer(self._cur[start : start + nbytes])
                self._cur = None  # exhausted; drop our pin so it can free
            want = nbytes + ALIGNMENT  # headroom for the alignment shift
            for raw in self._slabs:
                # refs while free: pool list + loop binding + getrefcount arg
                if raw.nbytes >= want and sys.getrefcount(raw) == 3:
                    self.hits += 1
                    return self._open_slab(raw, nbytes)
            self.misses += 1
            raw = np.empty(max(self.MIN_SLAB, 1 << (want - 1).bit_length()), dtype=np.uint8)
            if len(self._slabs) >= self.max_slabs:
                self._slabs.pop(0)  # evict eldest; live carves keep it alive
            self._slabs.append(raw)
            return self._open_slab(raw, nbytes)


class Bitmap:
    """LSB-first validity bitmap over a ``Buffer`` (Arrow layout).

    Bit i of byte i//8 is (i % 8); set bit == valid (non-null).
    """

    __slots__ = ("buffer", "length")

    def __init__(self, buffer: Buffer, length: int):
        if buffer.nbytes * 8 < length:
            raise ValueError(f"bitmap buffer too small: {buffer.nbytes * 8} bits < {length}")
        self.buffer = buffer
        self.length = length

    @classmethod
    def from_bools(cls, mask: np.ndarray) -> "Bitmap":
        mask = np.asarray(mask, dtype=bool)
        packed = np.packbits(mask, bitorder="little")
        buf = Buffer.allocate(pad_to(packed.nbytes))
        buf.data[: packed.nbytes] = packed
        buf.data[packed.nbytes :] = 0
        return cls(buf, len(mask))

    @classmethod
    def all_valid(cls, length: int) -> "Bitmap":
        buf = Buffer.allocate(pad_to((length + 7) // 8))
        buf.data[:] = 0xFF
        return cls(buf, length)

    def to_bools(self) -> np.ndarray:
        return np.unpackbits(self.buffer.data, bitorder="little", count=self.length).astype(bool)

    def null_count(self) -> int:
        return int(self.length - self.to_bools().sum())

    def is_valid(self, i: int) -> bool:
        if not 0 <= i < self.length:
            raise IndexError(i)
        return bool(self.buffer.data[i // 8] >> (i % 8) & 1)

    def slice(self, offset: int, length: int) -> "Bitmap":
        # Bit-level slicing cannot stay zero-copy unless byte-aligned; Arrow
        # handles this with an "offset" field — we keep it simple and repack
        # only when misaligned (the common batch-aligned path stays zero-copy).
        if offset % 8 == 0:
            nbytes = (length + 7) // 8
            return Bitmap(self.buffer.slice(offset // 8, nbytes), length)
        return Bitmap.from_bools(self.to_bools()[offset : offset + length])

    def __repr__(self) -> str:
        return f"Bitmap({self.length} bits, {self.null_count()} nulls)"

"""RecordBatch / Table — the unit the paper ships over the wire.

A ``RecordBatch`` is a schema plus equal-length columnar ``Array``s.  All
row-wise APIs exist only for tests/interoperability; the hot paths
(slice/select/IPC) never touch individual rows — that is the paper's point.
"""
from __future__ import annotations

from typing import Any, Iterator, Sequence

import numpy as np

from .array import Array, concat_arrays
from .schema import Field, Schema

class RecordBatch:
    def __init__(self, schema: Schema, columns: list[Array]):
        if len(schema) != len(columns):
            raise ValueError(f"schema has {len(schema)} fields, got {len(columns)} columns")
        lengths = {len(c) for c in columns}
        if len(lengths) > 1:
            raise ValueError(f"ragged columns: {sorted(lengths)}")
        for f, c in zip(schema.fields, columns):
            if f.type != c.type:
                raise TypeError(f"column {f.name!r}: schema {f.type!r} != array {c.type!r}")
            if not f.nullable and c.null_count:
                raise ValueError(f"non-nullable column {f.name!r} has nulls")
        self.schema = schema
        self.columns = list(columns)

    # ------------------------------------------------------------------ #
    @classmethod
    def from_pydict(cls, data: dict[str, Any], schema: Schema | None = None) -> "RecordBatch":
        cols, fields = [], []
        for name, values in data.items():
            want = schema.field(name).type if schema is not None else None
            if isinstance(values, np.ndarray):
                arr = Array.from_numpy(values)
            elif isinstance(values, Array):
                arr = values
            else:
                arr = Array.from_pylist(values, want)
            cols.append(arr)
            fields.append(Field(name, arr.type, nullable=True))
        return cls(schema or Schema(tuple(fields)), cols)

    @classmethod
    def from_numpy(cls, data: dict[str, np.ndarray]) -> "RecordBatch":
        return cls.from_pydict(data)

    # ------------------------------------------------------------------ #
    @property
    def num_rows(self) -> int:
        return len(self.columns[0]) if self.columns else 0

    @property
    def num_columns(self) -> int:
        return len(self.columns)

    def column(self, key: str | int) -> Array:
        if isinstance(key, str):
            key = self.schema.index(key)
        return self.columns[key]

    def __getitem__(self, key: str | int) -> Array:
        return self.column(key)

    def nbytes(self) -> int:
        return sum(c.nbytes() for c in self.columns)

    # -- zero-copy transforms (the wire-speed ops) ----------------------- #
    def slice(self, offset: int, length: int | None = None) -> "RecordBatch":
        if length is None:
            length = self.num_rows - offset
        return RecordBatch(self.schema, [c.slice(offset, length) for c in self.columns])

    def select(self, names: Sequence[str]) -> "RecordBatch":
        """Projection pushdown primitive: column subset, zero-copy."""
        idx = [self.schema.index(n) for n in names]
        return RecordBatch(self.schema.select(list(names)), [self.columns[i] for i in idx])

    def take(self, indices: np.ndarray) -> "RecordBatch":
        return RecordBatch(self.schema, [c.take(indices) for c in self.columns])

    def filter(self, mask: np.ndarray) -> "RecordBatch":
        return self.take(np.nonzero(np.asarray(mask, dtype=bool))[0])

    # -- row-wise views (tests / baselines only) ------------------------- #
    def to_pydict(self) -> dict[str, list]:
        return {f.name: c.to_pylist() for f, c in zip(self.schema.fields, self.columns)}

    def to_rows(self) -> list[tuple]:
        """Row materialization — deliberately the slow path (ODBC-sim uses it)."""
        cols = [c.to_pylist() for c in self.columns]
        return list(zip(*cols)) if cols else []

    def iter_rows(self) -> Iterator[tuple]:
        for i in range(self.num_rows):
            yield tuple(c.value(i) for c in self.columns)

    def __eq__(self, other) -> bool:
        if not isinstance(other, RecordBatch):
            return NotImplemented
        return self.schema == other.schema and all(
            a == b for a, b in zip(self.columns, other.columns)
        )

    def __repr__(self) -> str:
        return f"RecordBatch({self.num_rows} rows, {self.num_columns} cols, {self.nbytes()}B)"


class Table:
    """A sequence of same-schema RecordBatches (a Flight stream's payload)."""

    def __init__(self, batches: list[RecordBatch]):
        if not batches:
            raise ValueError("Table needs >=1 batch")
        s = batches[0].schema
        for b in batches[1:]:
            if b.schema != s:
                raise ValueError("schema mismatch across batches")
        self.batches = list(batches)

    @property
    def schema(self) -> Schema:
        return self.batches[0].schema

    @property
    def num_rows(self) -> int:
        return sum(b.num_rows for b in self.batches)

    def nbytes(self) -> int:
        return sum(b.nbytes() for b in self.batches)

    def combine(self) -> RecordBatch:
        if len(self.batches) == 1:
            return self.batches[0]
        cols = [
            concat_arrays([b.columns[i] for b in self.batches])
            for i in range(self.batches[0].num_columns)
        ]
        return RecordBatch(self.schema, cols)

    def to_pydict(self) -> dict[str, list]:
        return self.combine().to_pydict()

    def __iter__(self):
        return iter(self.batches)

    def __repr__(self) -> str:
        return f"Table({len(self.batches)} batches, {self.num_rows} rows)"


def batch_from_rows(schema: Schema, rows: list[tuple]) -> RecordBatch:
    """Row→column materialization (the expensive direction; used by the
    'hot blocks' export benchmark to reproduce Fig 4's cliff)."""
    cols = []
    for i, f in enumerate(schema.fields):
        cols.append(Array.from_pylist([r[i] for r in rows], f.type))
    return RecordBatch(schema, cols)

"""Core columnar format + Flight protocol (the paper's contribution)."""
from . import schema as types  # noqa: F401
from .array import Array, concat_arrays  # noqa: F401
from .buffer import Bitmap, Buffer  # noqa: F401
from .ipc import read_stream, write_stream  # noqa: F401
from .recordbatch import RecordBatch, Table, batch_from_rows  # noqa: F401
from .schema import Field, Schema, schema  # noqa: F401

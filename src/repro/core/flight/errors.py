"""Typed Flight errors that round-trip over the wire.

Arrow Flight maps RPC failures onto gRPC status codes; our TCP transport
does the equivalent with a small registry of ``FlightError`` subclasses.
A server-side raise is serialized as a structured control frame
(``{"error": msg, "code": code, "detail": {...}}``) and rehydrated into the
*same class* client-side, so callers catch ``FlightNotFound`` /
``FlightTimedOut`` instead of string-matching one ad-hoc ``{"error": ...}``
dict.  ``detail`` carries machine-readable context (dataset name, timeout
seconds, shard id) untouched.

Back-compat: ``FlightError`` keeps its historical position as the base
class (re-exported from ``protocol``), and ``FlightUnavailableError``
remains as an alias of ``FlightUnavailable``.
"""
from __future__ import annotations

_REGISTRY: dict[str, type] = {}


class FlightError(RuntimeError):
    """Base Flight failure.  ``code`` discriminates on the wire."""

    code = "internal"

    def __init__(self, message: str = "", detail: dict | None = None):
        super().__init__(message)
        self.detail = dict(detail or {})

    def __init_subclass__(cls, **kw):
        super().__init_subclass__(**kw)
        _REGISTRY.setdefault(cls.code, cls)

    def to_wire(self) -> dict:
        """Control-frame payload; the peer rebuilds the typed error."""
        o = {"error": str(self) or self.code, "code": self.code}
        if self.detail:
            o["detail"] = self.detail
        return o


class FlightUnauthenticated(FlightError):
    """Bad or missing credentials — rejected by the auth middleware."""

    code = "unauthenticated"


class FlightNotFound(FlightError):
    """Unknown dataset / flight / shard."""

    code = "not_found"


class FlightUnavailable(FlightError):
    """Endpoint unreachable — callers may fail over to a replica location."""

    code = "unavailable"


class FlightTimedOut(FlightError):
    """A ``CallOptions.timeout`` deadline expired before the RPC finished."""

    code = "timed_out"


class FlightInvalidArgument(FlightError):
    """Malformed command / ticket / request."""

    code = "invalid_argument"


# deprecated alias (pre-hierarchy name); keeps old imports and excepts working
FlightUnavailableError = FlightUnavailable

_REGISTRY.setdefault("internal", FlightError)


def error_from_wire(meta: dict) -> FlightError:
    """Rebuild the typed error a peer serialized with ``to_wire``.

    Unknown codes (newer peer) degrade to the base ``FlightError`` so old
    clients still fail with the message instead of a decode error."""
    cls = _REGISTRY.get(meta.get("code", ""), FlightError)
    return cls(meta.get("error", "remote error"), meta.get("detail"))

"""Fault injection for Flight servers: kill, hang, slow, sever connections.

The harness the failure-handling claims are tested and benchmarked under.
Faults are injected by shadowing a live server's verb implementations in
its *instance* dict — the public surface (``FlightClient`` in-proc calls,
the TCP RPC dispatcher, the cluster head's direct ``*_impl`` calls, the
membership prober's ``health`` action) all route through the same methods,
so one patch point makes every access path observe the fault, without a
special "test mode" inside the server.

Shadowing the instance dict also disables the server's encode-cache and
inline-dispatch fast paths for the faulted instance (both are gated on
``*_impl`` being un-overridden) — exactly right: a faulted server must not
serve cached bytes around its own fault.

Modes per shard:

* ``kill`` — every verb raises ``FlightUnavailable`` and live connections
  are severed; indistinguishable from a crashed process to clients, probers
  and coordinators alike.
* ``hang`` — data verbs block (up to ``seconds``, or until ``revive``)
  before failing; actions fail fast so a prober detects the hang on its
  next tick instead of hanging with it.
* ``slow`` — DoGet streams pace ``delay`` seconds per batch; everything
  else works.  The replica a hedged read should beat.
* ``revive`` — restore the original verbs (and mark the recovery time, so
  tests and benchmarks can measure detect→recover latency).
"""
from __future__ import annotations

import threading
import time

from .protocol import FlightUnavailable

# the verb surface a fault shadows; locations()/shutdown() stay real —
# a dead process's endpoint address does not change, it just stops answering
_VERBS = (
    "do_get_impl",
    "do_put_impl",
    "do_exchange_impl",
    "get_flight_info_impl",
    "list_flights_impl",
    "do_action_impl",
)
_DATA_VERBS = frozenset(_VERBS) - {"do_action_impl"}
_MISSING = object()  # sentinel: verb was not instance-shadowed pre-fault


class FaultInjector:
    """Inject faults into the shards of a cluster (or any server list).

    ``target`` is a ``FlightClusterServer`` (its ``shards`` are used) or a
    plain list of servers.  All injections are reversible via ``revive``.
    """

    def __init__(self, target):
        self.servers = list(getattr(target, "shards", target))
        self._saved: dict[int, dict[str, object]] = {}
        self._revive: dict[int, threading.Event] = {}
        self.mode: dict[int, str] = {}
        self.killed_at: dict[int, float] = {}
        self.revived_at: dict[int, float] = {}

    # -- plumbing ---------------------------------------------------------- #
    def _server(self, sid: int):
        return self.servers[sid]

    def _install(self, sid: int, mode: str, impls: dict[str, object]) -> None:
        s = self._server(sid)
        if sid not in self._saved:
            # save the *instance* dict state (usually empty), not the bound
            # methods — revive must restore exactly what was there before
            self._saved[sid] = {v: s.__dict__.get(v, _MISSING) for v in _VERBS}
        for verb, fn in impls.items():
            setattr(s, verb, fn)
        self.mode[sid] = mode

    def _fail(self, sid: int, verb: str):
        def impl(*a, **k):
            raise FlightUnavailable(
                f"shard {sid} is down (injected fault)",
                detail={"shard": sid, "verb": verb, "fault": self.mode.get(sid)})
        return impl

    # -- faults ------------------------------------------------------------ #
    def kill(self, sid: int) -> None:
        """Hard crash: every verb fails, live connections drop."""
        self._install(sid, "kill", {v: self._fail(sid, v) for v in _VERBS})
        self.killed_at[sid] = time.perf_counter()
        self.drop_connections(sid)

    def hang(self, sid: int, seconds: float = 30.0) -> None:
        """Data verbs stall (a wedged process), actions fail fast.

        The stall ends early when ``revive`` fires — a revived shard's
        stalled requests fail over cleanly rather than completing late."""
        ev = self._revive.setdefault(sid, threading.Event())
        ev.clear()

        def hanging(verb: str):
            def impl(*a, **k):
                ev.wait(seconds)
                raise FlightUnavailable(
                    f"shard {sid} is hung (injected fault)",
                    detail={"shard": sid, "verb": verb, "fault": "hang"})
            return impl

        impls: dict[str, object] = {v: hanging(v) for v in _DATA_VERBS}
        impls["do_action_impl"] = self._fail(sid, "do_action_impl")
        self._install(sid, "hang", impls)
        self.killed_at[sid] = time.perf_counter()

    def slow(self, sid: int, delay: float = 0.01) -> None:
        """Pace DoGet: ``delay`` seconds before each batch."""
        s = self._server(sid)
        real_get = s.do_get_impl  # bound original (pre-fault)

        def paced(ticket):
            schema, batches = real_get(ticket)

            def gen():
                for b in batches:
                    time.sleep(delay)
                    yield b

            return schema, gen()

        self._install(sid, "slow", {"do_get_impl": paced})

    def drop_connections(self, sid: int) -> int:
        """Sever the shard's live TCP connections (listener stays bound)."""
        listener = getattr(self._server(sid), "_listener", None)
        drop = getattr(listener, "drop_connections", None)
        return drop() if drop is not None else 0

    def revive(self, sid: int) -> None:
        """Undo whatever fault is active on ``sid``."""
        saved = self._saved.pop(sid, None)
        if saved is None:
            return
        s = self._server(sid)
        for verb, orig in saved.items():
            if orig is _MISSING:
                s.__dict__.pop(verb, None)
            else:
                s.__dict__[verb] = orig
        ev = self._revive.get(sid)
        if ev is not None:
            ev.set()
        self.mode.pop(sid, None)
        self.revived_at[sid] = time.perf_counter()

    def revive_all(self) -> None:
        for sid in list(self._saved):
            self.revive(sid)

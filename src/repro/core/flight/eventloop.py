"""Event-loop server transport — the C10k core under Flight serving.

``SocketListener`` (transport.py) burns one handler thread per accepted
connection, so concurrent-client scaling is bounded by GIL contention and
thread churn long before the wire saturates (the paper's headline numbers —
~6000 MB/s DoGet at ~95% of link bandwidth — are about *many parallel
streams*, which a thread-per-connection Python server cannot sustain).
``EventLoopListener`` replaces it with the classic selector architecture:

* **one dispatch thread** owns every socket: non-blocking accept, framed
  reads (the incremental parser mirrors ``FrameConnection``'s buffered
  receive — header+metadata accumulate in a small buffer, large bodies are
  ``recv_into``'d straight into ``BufferPool`` slabs), and
  writability-gated sends (queued iovec batches flushed on EPOLLOUT);
* **a small worker pool** runs handler/encode work.  A worker is attached
  to a connection only while it has an RPC in progress; between RPCs the
  connection costs one epoll registration, not a thread.  Server thread
  count is O(worker pool), never O(clients);
* **provably-fast RPCs dispatch inline on the loop thread** (the nginx
  move): when the server's ``inline_ok`` predicate certifies a request as
  non-blocking and cheap — a cache-warm DoGet is pure memoryview queueing —
  it runs right inside the parse loop on an idle connection, skipping the
  worker handoff entirely (two GIL/condvar round-trips per RPC on a busy
  box).  Everything else — DoPut/DoExchange (they read further input),
  cold-cache or user-overridden handlers (arbitrary latency) — still goes
  to the pool;
* **the wire format is untouched**: ``ChannelConnection`` subclasses
  ``FrameConnection`` and overrides only the syscall layer (``_flush`` →
  outbox queue, ``recv_frame`` → parsed inbox), so frame construction —
  ``_frame_parts``, ``send_data_many`` coalescing under ``IOV_MAX`` and the
  byte budget — is inherited verbatim and stays byte-identical.

Flow control, both directions:

* **reads** — when a connection's parsed-but-unconsumed inbox exceeds the
  frame/byte high-water marks (a DoPut flood outrunning its worker), the
  loop drops the socket's read interest; the worker re-arms it when the
  inbox drains below half.  Backpressure lands on the peer's TCP window,
  exactly like the blocked ``recv`` it replaces.
* **writes** — handler sends are non-blocking: iovecs queue on the
  connection's outbox and flush inline while the socket accepts them, with
  EPOLLOUT picking up the remainder.  A sender blocks (that RPC only —
  never the loop, never other connections) once the outbox passes
  ``OUT_HIGH_WATER``, so one stalled reader pins one worker and a bounded
  buffer, not the server.

``receive_ready`` on a channel is answered from the inbox — the event loop
already knows readiness, so the exchange serve loop's flush-before-block
probe costs zero syscalls (it was one ``select`` per batch).
"""
from __future__ import annotations

import json
import os
import selectors
import socket
import threading
import time
from collections import deque
from itertools import islice
from typing import Callable

from ..ipc import parse_metadata
from .errors import FlightError
from .telemetry import HDR_TRACE, LogHistogram, add_stage
from .transport import (
    FRAME,
    FRAME_MAGIC,
    IOV_MAX,
    KIND_CTRL,
    KIND_DATA,
    RECV_CHUNK,
    FrameConnection,
)

# Flow-control water marks.  Resume points are half the limit so a
# connection hovering at the boundary doesn't thrash interest changes.
OUT_HIGH_WATER = 4 << 20   # queued unsent bytes before a sending RPC blocks
INBOX_MAX_FRAMES = 256     # parsed frames awaiting a worker before reads pause
INBOX_MAX_BYTES = 8 << 20

# Deferred-output batching: sends below this stay queued until the RPC
# reaches a flush point (handler returns, or blocks waiting for input), so
# a small response — ctrl ok + schema + a few batches + eos — leaves in ONE
# sendmsg / one peer wakeup instead of one per send_* call.  Wire bytes are
# identical; only the syscall grouping changes.  Correctness hinges on the
# flush points covering every wait: `_drain` flushes before detaching and
# `recv_frame` flushes before blocking, so the peer always holds everything
# it is owed before the server waits on it.
FLUSH_SMALL = 64 << 10

_READ = selectors.EVENT_READ
_WRITE = selectors.EVENT_WRITE


def default_workers() -> int:
    """Half the cores (the paper's serving sweet spot), floor 2, cap 8."""
    return max(2, min(8, (os.cpu_count() or 2) // 2 or 1))


class ChannelConnection(FrameConnection):
    """A ``FrameConnection`` whose socket belongs to the event loop.

    Handler code keeps the exact ``FrameConnection`` surface it already
    uses (``send_ctrl`` / ``send_data`` / ``send_data_many`` /
    ``recv_frame`` / ``receive_ready`` / ``close``) but never performs a
    blocking socket operation: frames arrive pre-parsed in ``_inbox`` (fed
    by the loop thread) and sends are queued iovecs flushed non-blocking
    inline and on EPOLLOUT.
    """

    def __init__(self, sock: socket.socket, listener: "EventLoopListener"):
        super().__init__(sock)
        sock.setblocking(False)
        self._listener = listener
        self.fd = sock.fileno()
        # loop-side incremental frame parser (loop thread only)
        self._phase = 0  # 0 = header, 1 = metadata, 2 = body
        self._acc = bytearray()  # header+meta accumulation (and body over-read)
        self._acc_pos = 0
        self._kind = 0
        self._meta_len = 0
        self._body_len = 0
        self._meta_raw = b""
        self._body = None
        self._body_filled = 0
        # worker-facing receive queue
        self._in_cv = threading.Condition()
        # (kind, meta_raw bytes, Buffer | None, arrival perf_counter or 0.0)
        self._inbox: deque = deque()
        self._inbox_bytes = 0
        self.last_queue_wait_s = 0.0  # inbox dwell of the last popped frame
        self._submit_t = 0.0          # when this channel was last scheduled
        self._active = False   # a pool worker is draining this channel
        self._paused = False   # read interest dropped (inbox over high water)
        # worker-facing send queue
        self._out_cv = threading.Condition()
        self._outq: deque = deque()  # memoryviews in frame order
        self._out_bytes = 0
        self._want_write = False
        self.closed = False
        self._fd_closed = False
        self._events = _READ  # current selector interest (loop thread only)

    # ------------------------------------------------------------- send --
    def _flush(self, parts: list, total: int) -> None:
        """Queue one frame group and flush as far as the socket allows.

        Called by the inherited ``send_ctrl``/``send_data``/
        ``send_data_many`` — frame construction and coalescing upstream of
        this point are ``FrameConnection``'s, byte for byte."""
        with self._out_cv:
            if self.closed:
                raise ConnectionError("connection closed")
            self._outq.extend(parts)
            self._out_bytes += total
            self.bytes_sent += total
            # small outputs stay queued until a flush point; bulk streams
            # pump inline as soon as a syscall's worth has accumulated
            if self._out_bytes >= FLUSH_SMALL:
                self._pump_or_arm_locked()
            # writability-gated backpressure: a peer slower than we produce
            # blocks this RPC's worker, never the loop or other connections.
            # The loop thread itself (inline RPCs) must never park here — it
            # is the thread that drains the outbox, so waiting would be a
            # self-deadlock.  Inline sends queue past the mark instead;
            # cached DoGet streams queue memoryviews over the encode-once
            # cache, so the overshoot is frame headers, not data copies.
            if threading.get_ident() == self._listener._loop_ident:
                return
            if self._out_bytes > OUT_HIGH_WATER and not self.closed:
                # backpressure stall: the peer is slower than we produce —
                # measured only when actually waiting, and attributed to the
                # active span (if any) so slow-consumer time is attributable
                t0 = time.perf_counter()
                while self._out_bytes > OUT_HIGH_WATER and not self.closed:
                    self._out_cv.wait(0.1)
                stall = time.perf_counter() - t0
                self._listener.stall_seconds += stall
                self._listener.hist_stall.observe(stall)
                add_stage("stall", stall)
            if self.closed:
                raise ConnectionError("connection closed")

    def flush_output(self) -> None:
        """Push any deferred output to the wire (or arm EPOLLOUT).

        The RPC-boundary flush: called when a handler finishes or is about
        to block waiting on the peer."""
        if not self._outq:
            return
        with self._out_cv:
            if self.closed or not self._outq:
                return
            self._pump_or_arm_locked()

    def _pump_or_arm_locked(self) -> None:
        if not self._want_write:
            if not self._pump_out_locked():
                self._want_write = True
                self._listener.write_arms += 1
                self._listener._post("write", self)

    def _pump_out_locked(self) -> bool:
        """Non-blocking drain of the outbox; True when fully flushed.

        Caller holds ``_out_cv``.  Takes up to ``IOV_MAX`` iovecs per
        ``sendmsg`` and resumes after short writes, like
        ``_sendall_vectored`` — just without ever blocking."""
        while self._outq:
            window = list(islice(self._outq, 0, IOV_MAX))
            try:
                sent = self.sock.sendmsg(window)
            except BlockingIOError:
                return False
            except OSError as e:
                self.closed = True
                self._out_cv.notify_all()
                self._listener._post("close", self)
                raise ConnectionError(f"send failed: {e}") from e
            self.sendmsg_calls += 1
            self._out_bytes -= sent
            while sent:
                head = self._outq[0]
                if sent >= len(head):
                    sent -= len(head)
                    self._outq.popleft()
                else:
                    self._outq[0] = head[sent:]
                    sent = 0
            self._out_cv.notify_all()  # senders blocked on the high-water mark
        return True

    # ------------------------------------------------------------- recv --
    def receive_ready(self) -> bool:
        """Readiness from the loop's last events — zero syscalls (the
        thread-mode path paid one ``select`` per probe)."""
        with self._in_cv:
            return bool(self._inbox) or self.closed

    def recv_frame(self):
        if not self._inbox:
            # about to wait on the peer: everything we owe it goes out
            # first (mid-RPC reads — DoPut / exchange acks — depend on it)
            self.flush_output()
        with self._in_cv:
            while not self._inbox:
                if self.closed:
                    raise ConnectionError("peer closed")
                self._in_cv.wait(0.1)
            kind, meta_raw, body, t_arr = self._inbox.popleft()
            self._inbox_bytes -= FRAME.size + len(meta_raw) + (
                body.nbytes if body is not None else 0)
            if t_arr:
                # inbox dwell: parsed-to-consumed (the accept-queue number)
                qw = time.perf_counter() - t_arr
                self.last_queue_wait_s = qw
                self._listener.hist_queue_wait.observe(qw)
            else:
                self.last_queue_wait_s = 0.0
            if self._paused and (len(self._inbox) <= INBOX_MAX_FRAMES // 2
                                 and self._inbox_bytes <= INBOX_MAX_BYTES // 2):
                self._paused = False
                self._listener._post("resume", self)
        self.bytes_received += FRAME.size + len(meta_raw) + (
            body.nbytes if body is not None else 0)
        meta = parse_metadata(meta_raw) if kind == KIND_DATA else json.loads(meta_raw)
        return kind, meta, body

    def close(self) -> None:
        """Thread-safe teardown request; the loop owns the actual fd."""
        with self._out_cv:
            if self._outq and not self._want_write:
                try:  # best-effort: a deferred error reply still gets out
                    self._pump_out_locked()
                except ConnectionError:
                    pass
            self.closed = True
            self._out_cv.notify_all()
        with self._in_cv:
            self._in_cv.notify_all()
        self._listener._post("close", self)

    # ---------------------------------------------- loop-thread parsing --
    def _loop_readable(self) -> bool:
        """Drain the socket (bounded per event) into parsed frames.

        Returns False on EOF / error / protocol violation — the loop then
        closes the connection.  Large bodies bypass the accumulation buffer
        and ``recv_into`` straight into their pooled slab (the zero-copy
        receive path of ``FrameConnection``, preserved)."""
        budget = 16
        while budget > 0 and not self._paused:
            budget -= 1
            if self._phase == 2 and self._acc_pos >= len(self._acc):
                view = memoryview(self._body.data)[self._body_filled:]
                try:
                    n = self.sock.recv_into(view, len(view))
                except BlockingIOError:
                    return True
                except OSError:
                    return False
                self.recv_calls += 1
                if n == 0:
                    return False
                self._body_filled += n
                if self._body_filled == self._body_len:
                    self._complete_frame()
                continue
            try:
                chunk = self.sock.recv(RECV_CHUNK)
            except BlockingIOError:
                return True
            except OSError:
                return False
            self.recv_calls += 1
            if not chunk:
                return False
            if self._acc_pos and self._acc_pos == len(self._acc):
                self._acc.clear()
                self._acc_pos = 0
            self._acc += chunk
            if not self._parse_acc():
                return False
        return True

    def _parse_acc(self) -> bool:
        """Consume complete header/meta/body spans from the accumulation
        buffer; False on bad frame magic (kill the connection)."""
        while True:
            avail = len(self._acc) - self._acc_pos
            if self._phase == 0:
                if avail < FRAME.size:
                    return True
                magic, kind, meta_len, body_len = FRAME.unpack_from(
                    self._acc, self._acc_pos)
                if magic != FRAME_MAGIC:
                    return False
                self._acc_pos += FRAME.size
                self._kind, self._meta_len, self._body_len = kind, meta_len, body_len
                self._phase = 1
            elif self._phase == 1:
                if avail < self._meta_len:
                    return True
                self._meta_raw = bytes(
                    self._acc[self._acc_pos:self._acc_pos + self._meta_len])
                self._acc_pos += self._meta_len
                if self._body_len:
                    self._body = self.pool.acquire(self._body_len)
                    self._body_filled = 0
                    self._phase = 2
                else:
                    self._body = None
                    self._complete_frame()
            else:
                if not avail:
                    return True
                take = min(avail, self._body_len - self._body_filled)
                memoryview(self._body.data)[
                    self._body_filled:self._body_filled + take
                ] = memoryview(self._acc)[self._acc_pos:self._acc_pos + take]
                self._acc_pos += take
                self._body_filled += take
                if self._body_filled < self._body_len:
                    return True
                self._complete_frame()
            if self._acc_pos == len(self._acc):
                self._acc.clear()
                self._acc_pos = 0

    def _complete_frame(self) -> None:
        self._listener.frames_parsed += 1
        frame = (self._kind, self._meta_raw, self._body)
        self._body = None
        self._meta_raw = b""
        self._phase = 0
        # fast path: an RPC-opening control frame on an idle connection
        # (no worker attached, nothing queued ahead of it) runs right here
        # on the loop thread when its verb can't block on further input.
        # `_active`/`_inbox` are safe to read lock-free: only this thread
        # sets `_active` True, and a worker that set it False has already
        # detached for good.
        if (frame[0] == KIND_CTRL and frame[2] is None and not self._active
                and not self._inbox
                and self._listener._try_inline(self, frame[1])):
            return
        # arrival stamp: queue-wait = pop time minus this (0.0 = untimed)
        t_arr = time.perf_counter() if self._listener._telemetry else 0.0
        with self._in_cv:
            self._inbox.append((frame[0], frame[1], frame[2], t_arr))
            self._inbox_bytes += FRAME.size + len(frame[1]) + (
                frame[2].nbytes if frame[2] is not None else 0)
            if (len(self._inbox) > INBOX_MAX_FRAMES
                    or self._inbox_bytes > INBOX_MAX_BYTES):
                self._paused = True  # interest applied by the loop after this
            schedule = not self._active
            if schedule:
                self._active = True
            self._in_cv.notify_all()
        if schedule:
            self._listener.submits += 1
            self._listener._submit(self)


class EventLoopListener:
    """Selector dispatch thread + worker pool (the server side).

    ``rpc`` is called as ``rpc(conn, kind, req)`` for each RPC-opening
    frame — ``FlightServerBase._dispatch_rpc``.  API-compatible with
    ``SocketListener``: ``start()`` / ``stop()`` / ``.host`` / ``.port``.
    """

    def __init__(self, rpc: Callable, host: str = "127.0.0.1", port: int = 0,
                 workers: int | None = None,
                 inline_ok: Callable[[dict], bool] | None = None,
                 telemetry: bool = True):
        self._rpc = rpc
        # per-frame/RPC clock reads cost ~50ns each; telemetry=False skips
        # them entirely (histograms stay allocated so scrapes always work)
        self._telemetry = telemetry
        # server-supplied certificate that a request is safe to run on the
        # loop thread: never reads another frame, never blocks, cheap
        self._inline_ok = inline_ok
        self._workers = workers or default_workers()
        self._lsock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._lsock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._lsock.bind((host, port))
        self._lsock.listen(1024)
        self._lsock.setblocking(False)
        self.host, self.port = self._lsock.getsockname()
        self._sel = selectors.DefaultSelector()
        self._wake_r, self._wake_w = socket.socketpair()
        self._wake_r.setblocking(False)
        self._wake_w.setblocking(False)
        self._cmds: deque = deque()  # (op, channel) from worker threads
        self._conns: dict[int, ChannelConnection] = {}
        # lean worker pool: a shared runnable-channel deque + one Condition.
        # An RPC activation is one append+notify — no Future / work-item /
        # executor-queue allocation on the per-request hot path.
        self._run_cv = threading.Condition()
        self._runnable: deque = deque()
        self._pool_stop = False
        self._pool = [
            threading.Thread(target=self._worker, daemon=True,
                             name=f"flight-io-{i}")
            for i in range(self._workers)
        ]
        self._thread: threading.Thread | None = None
        self._loop_ident = -1  # set by the loop thread before serving
        self._stopping = False
        self.connections_accepted = 0
        # diagnostics (approximate: bumped without dedicated locks)
        self.loop_wakeups = 0
        self.write_arms = 0
        self.submits = 0
        self.inline_rpcs = 0
        self.frames_parsed = 0
        # io-layer latency histograms (exported by ``server-metrics``):
        # where a request's wall time goes *before/around* the handler
        self.hist_queue_wait = LogHistogram()      # inbox dwell (accept queue)
        self.hist_inline = LogHistogram()          # inline fast-path RPC time
        self.hist_dispatch = LogHistogram()        # submit -> worker pickup
        self.hist_depth = LogHistogram(scale=1)    # runnable-queue depth
        self.hist_stall = LogHistogram()           # backpressure stall time
        self.stall_seconds = 0.0
        # structured handler-crash records (replaces stderr tracebacks)
        self.handler_errors = 0
        self.recent_errors: deque = deque(maxlen=64)

    # ------------------------------------------------------- lifecycle --
    def start(self) -> "EventLoopListener":
        self._sel.register(self._lsock, _READ, None)
        self._sel.register(self._wake_r, _READ, None)
        for w in self._pool:
            w.start()
        self._thread = threading.Thread(
            target=self._loop, daemon=True, name="flight-eventloop")
        self._thread.start()
        return self

    def stop(self) -> None:
        self._post("stop", None)
        if self._thread is not None:
            self._thread.join(timeout=5.0)
            self._thread = None
        with self._run_cv:
            self._pool_stop = True
            self._run_cv.notify_all()
        for w in self._pool:
            w.join(timeout=1.0)

    def open_connections(self) -> int:
        return len(self._conns)

    def drop_connections(self) -> int:
        """Sever every live connection (fault injection / admin drain).

        Runs on the loop thread — selector mutation mid-``select`` is not
        thread-safe — so this only *posts* the drop; returns the number of
        connections that were live when asked."""
        n = len(self._conns)
        self._post("dropconns", None)
        return n

    def stats(self) -> dict:
        return {
            "io_mode": "eventloop",
            "open_connections": len(self._conns),
            # every fd this listener owns: conns + listening socket + the
            # wakeup socketpair — the c10k headroom number an operator wants
            "open_fds": len(self._conns) + 3,
            "worker_queue_depth": len(self._runnable),
            "workers": self._workers,
            "accepted": self.connections_accepted,
            "loop_wakeups": self.loop_wakeups,
            "write_arms": self.write_arms,
            "submits": self.submits,
            "inline_rpcs": self.inline_rpcs,
            "frames_parsed": self.frames_parsed,
            "stall_seconds": round(self.stall_seconds, 6),
            "handler_errors": self.handler_errors,
            "recent_errors": list(self.recent_errors),
        }

    def histograms(self) -> dict:
        """IO-layer histograms for the ``server-metrics`` Arrow export."""
        return {
            "queue_wait": self.hist_queue_wait,
            "inline_rpc": self.hist_inline,
            "dispatch": self.hist_dispatch,
            "worker_queue_depth": self.hist_depth,
            "backpressure_stall": self.hist_stall,
        }

    def _record_error(self, ch: ChannelConnection, req: dict | None,
                      exc: Exception) -> None:
        """Structured record of a handler crash (was a stderr traceback):
        connection fd, verb, trace id when the request carried one."""
        self.handler_errors += 1
        rec = {
            "fd": ch.fd,
            "verb": (req or {}).get("method", "?"),
            "error": f"{type(exc).__name__}: {exc}",
        }
        trace = (((req or {}).get("options") or {}).get("headers")
                 or {}).get(HDR_TRACE)
        if trace:
            rec["trace_id"] = trace
        self.recent_errors.append(rec)

    # --------------------------------------------------- worker plumbing --
    def _post(self, op: str, ch: ChannelConnection | None) -> None:
        """Hand a selector mutation to the loop thread (selectors are not
        thread-safe to modify mid-``select``)."""
        self._cmds.append((op, ch))
        try:
            self._wake_w.send(b"x")
        except (BlockingIOError, OSError):
            pass  # wakeup pipe full: the loop is already awake

    def _submit(self, ch: ChannelConnection) -> None:
        if self._telemetry:
            ch._submit_t = time.perf_counter()
        with self._run_cv:
            self._runnable.append(ch)
            if self._telemetry:
                self.hist_depth.observe(len(self._runnable))
            self._run_cv.notify()

    def _try_inline(self, ch: ChannelConnection, meta_raw: bytes) -> bool:
        """Run a certified-fast RPC on the loop thread; False defers to the
        pool.  Mirrors ``_drain``'s error containment: any failure closes
        this channel only — the loop must survive arbitrary handler bugs."""
        if self._inline_ok is None:
            return False
        try:
            req = json.loads(meta_raw)
        except ValueError:
            return False  # let the worker path produce the protocol error
        try:
            if not self._inline_ok(req):
                return False
        except Exception:
            return False  # a broken predicate degrades to the worker path
        ch.bytes_received += FRAME.size + len(meta_raw)
        self.inline_rpcs += 1
        t0 = time.perf_counter() if self._telemetry else 0.0
        try:
            self._rpc(ch, KIND_CTRL, req)
            ch.flush_output()
        except FlightError as e:
            try:
                ch.send_ctrl(e.to_wire())
            except (ConnectionError, OSError):
                pass
            ch.close()
        except (ConnectionError, OSError):
            ch.close()
        except Exception as e:
            self._record_error(ch, req, e)
            ch.close()
        if self._telemetry:
            self.hist_inline.observe(time.perf_counter() - t0)
        return True

    def _worker(self) -> None:
        while True:
            with self._run_cv:
                while not self._runnable:
                    if self._pool_stop:
                        return
                    self._run_cv.wait()
                ch = self._runnable.popleft()
            if self._telemetry and ch._submit_t:
                self.hist_dispatch.observe(time.perf_counter() - ch._submit_t)
                ch._submit_t = 0.0
            try:
                self._drain(ch)
            except Exception:
                # handler bug: _drain already closed the channel and recorded
                # a structured error; the worker itself must survive
                pass
            ch = None  # no stale channel ref while parked on the condvar

    def _drain(self, ch: ChannelConnection) -> None:
        """Worker entry: serve RPCs off this channel until its inbox runs
        dry, then detach (the loop re-attaches a worker on the next frame)."""
        while True:
            if not ch._inbox:
                try:
                    ch.flush_output()  # responses out before we detach
                except ConnectionError:
                    pass
            with ch._in_cv:
                if not ch._inbox:
                    ch._active = False
                    return
            try:
                kind, req, _ = ch.recv_frame()
            except (ConnectionError, OSError):
                with ch._in_cv:
                    ch._active = False
                return
            try:
                self._rpc(ch, kind, req)
            except FlightError as e:
                # protocol violation (e.g. data frame opening an RPC):
                # report if the peer can still hear, then drop the channel
                try:
                    ch.send_ctrl(e.to_wire())
                except (ConnectionError, OSError):
                    pass
                ch.close()
                with ch._in_cv:
                    ch._active = False
                return
            except (ConnectionError, OSError):
                ch.close()
                with ch._in_cv:
                    ch._active = False
                return
            except Exception as e:
                # handler bug: contain it to this connection — the loop and
                # the worker pool must survive arbitrary handler failures
                self._record_error(ch, req if isinstance(req, dict) else None, e)
                ch.close()
                with ch._in_cv:
                    ch._active = False
                raise

    # ------------------------------------------------------ loop thread --
    def _loop(self) -> None:
        self._loop_ident = threading.get_ident()
        ch = key = None
        while not self._stopping:
            self.loop_wakeups += 1
            for key, mask in self._sel.select(timeout=1.0):
                ch = key.data
                if ch is None:
                    if key.fileobj is self._wake_r:
                        try:
                            while self._wake_r.recv(4096):
                                pass
                        except (BlockingIOError, OSError):
                            pass
                    else:
                        self._accept_ready()
                    continue
                try:
                    if mask & _READ and not ch._loop_readable():
                        self._close_channel(ch)
                        continue
                    if mask & _WRITE:
                        self._loop_writable(ch)
                    self._apply_interest(ch)
                except Exception:
                    self._close_channel(ch)
            self._run_cmds()
            # drop channel refs before blocking in select, so a closed
            # channel's BufferPool frees as soon as its last frame is consumed
            ch = key = None
        # shutdown: every channel closes (waking any blocked worker)
        for ch in list(self._conns.values()):
            self._close_channel(ch)
        for sock in (self._lsock, self._wake_r, self._wake_w):
            try:
                sock.close()
            except OSError:
                pass
        self._sel.close()

    def _accept_ready(self) -> None:
        while True:
            try:
                sock, _ = self._lsock.accept()
            except (BlockingIOError, OSError):
                return
            ch = ChannelConnection(sock, self)
            self._conns[ch.fd] = ch
            self._sel.register(sock, _READ, ch)
            self.connections_accepted += 1

    def _loop_writable(self, ch: ChannelConnection) -> None:
        with ch._out_cv:
            try:
                if ch._pump_out_locked():
                    ch._want_write = False
            except ConnectionError:
                pass  # _pump_out_locked already posted the close

    def _apply_interest(self, ch: ChannelConnection) -> None:
        if ch._fd_closed:
            return
        events = (0 if ch._paused else _READ) | (_WRITE if ch._want_write else 0)
        if events == ch._events:
            return
        try:
            if ch._events and events:
                self._sel.modify(ch.sock, events, ch)
            elif ch._events:
                self._sel.unregister(ch.sock)
            else:
                self._sel.register(ch.sock, events, ch)
        except (KeyError, ValueError, OSError):
            return
        ch._events = events

    def _close_channel(self, ch: ChannelConnection) -> None:
        # never block the loop on a lock a worker is holding mid-sendmsg
        # (GIL priority inversion): re-post and serve other channels instead
        if not ch._out_cv.acquire(blocking=False):
            self._post("close", ch)
            return
        try:
            if ch._fd_closed:
                return
            ch._fd_closed = True
            ch.closed = True
            try:
                # close first: the kernel drops the epoll registration with
                # the fd, and selectors' unregister tolerates the dead fd —
                # one epoll_ctl saved per connection
                ch.sock.close()
            except OSError:
                pass
            if ch._events:
                try:
                    # by fd, not socket object: the closed socket's
                    # fileno() is -1, which would force a linear key scan
                    self._sel.unregister(ch.fd)
                except (KeyError, ValueError, OSError):
                    pass
                ch._events = 0
            ch._outq.clear()
            ch._out_bytes = 0
            ch._out_cv.notify_all()
        finally:
            ch._out_cv.release()
        with ch._in_cv:
            ch._in_cv.notify_all()
        self._conns.pop(ch.fd, None)

    def _run_cmds(self) -> None:
        while True:
            try:
                op, ch = self._cmds.popleft()
            except IndexError:
                return
            if op == "stop":
                self._stopping = True
            elif op == "dropconns":
                # fault injection: sever every live connection (listener
                # stays up, so clients see a reset — not a refused dial)
                for c in list(self._conns.values()):
                    self._close_channel(c)
            elif ch is None or ch._fd_closed:
                continue
            elif op == "close":
                self._close_channel(ch)
            else:  # "write" arm / "resume" reads: recompute interest
                self._apply_interest(ch)

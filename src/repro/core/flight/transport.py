"""Wire transports for Flight RPC.

Two transports with one frame model:

* ``SocketTransport`` — real TCP.  Frames go out via ``sendmsg`` scatter/
  gather straight from the columnar buffers (zero copies on the send side);
  the receive side reads the body into one aligned allocation and decodes
  RecordBatches as **views** into it (zero deserialization).
* in-proc — handled one level up (client holds a server reference and moves
  ``RecordBatch`` objects by reference; models same-host shared memory).

Frame layout::

    <I magic><B kind><I meta_len><Q body_len> | meta | body

``kind``: 0 = control (JSON), 1 = data (IPC message).  gRPC's HTTP/2 framing
is replaced by this minimal equivalent (see DESIGN.md §2 non-transferable).
"""
from __future__ import annotations

import json
import socket
import struct
import threading
from typing import Callable, Iterable

import numpy as np

from ..buffer import Buffer
from ..ipc import EncodedMessage, parse_metadata
from .protocol import FlightError

FRAME = struct.Struct("<IBIQ")
FRAME_MAGIC = 0xF117A77C
KIND_CTRL, KIND_DATA = 0, 1

# Default socket options tuned for bulk transfer (paper §3: Flight wins on
# large messages; we keep buffers big and Nagle off for the small control frames).
SOCK_BUF = 4 << 20


class FrameConnection:
    """A framed, bidirectional byte-stream connection over a socket."""

    def __init__(self, sock: socket.socket):
        self.sock = sock
        self.sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        for opt in (socket.SO_SNDBUF, socket.SO_RCVBUF):
            try:
                self.sock.setsockopt(socket.SOL_SOCKET, opt, SOCK_BUF)
            except OSError:
                pass
        self._send_lock = threading.Lock()
        self.bytes_sent = 0
        self.bytes_received = 0

    # ------------------------------------------------------------- send --
    def send_ctrl(self, obj: dict) -> None:
        meta = json.dumps(obj).encode()
        self._sendv(KIND_CTRL, meta, [], 0)

    def send_data(self, msg: EncodedMessage) -> None:
        self._sendv(KIND_DATA, msg.metadata, msg.body_parts, msg.body_len)

    def _sendv(self, kind: int, meta: bytes, body_parts: list[np.ndarray], body_len: int) -> None:
        header = FRAME.pack(FRAME_MAGIC, kind, len(meta), body_len)
        parts: list[memoryview | bytes] = [header, meta]
        parts += [memoryview(p).cast("B") if isinstance(p, np.ndarray) else p for p in body_parts]
        total = len(header) + len(meta) + body_len
        with self._send_lock:
            self._sendall_vectored(parts, total)
        self.bytes_sent += total

    def _sendall_vectored(self, parts: list, total: int) -> None:
        """sendmsg with continuation — zero-copy gather from columnar buffers."""
        sent = self.sock.sendmsg(parts)
        while sent < total:
            # find resume point
            remaining: list[memoryview] = []
            acc = 0
            for p in parts:
                mv = memoryview(p).cast("B") if not isinstance(p, memoryview) else p
                if acc + len(mv) <= sent:
                    acc += len(mv)
                    continue
                start = max(0, sent - acc)
                remaining.append(mv[start:])
                acc += len(mv)
            parts = remaining
            sent += self.sock.sendmsg(parts)

    # ------------------------------------------------------------- recv --
    def _recv_exact_into(self, view: memoryview) -> None:
        got = 0
        while got < len(view):
            n = self.sock.recv_into(view[got:], len(view) - got)
            if n == 0:
                raise ConnectionError("peer closed")
            got += n

    def recv_frame(self) -> tuple[int, dict, Buffer | None]:
        head = bytearray(FRAME.size)
        self._recv_exact_into(memoryview(head))
        magic, kind, meta_len, body_len = FRAME.unpack(head)
        if magic != FRAME_MAGIC:
            raise FlightError(f"bad frame magic {magic:#x}")
        meta_raw = bytearray(meta_len)
        self._recv_exact_into(memoryview(meta_raw))
        body = None
        if body_len:
            body = Buffer.allocate(body_len)
            self._recv_exact_into(memoryview(body.data))
        self.bytes_received += FRAME.size + meta_len + body_len
        meta = parse_metadata(bytes(meta_raw)) if kind == KIND_DATA else json.loads(meta_raw)
        return kind, meta, body

    def recv_ctrl(self) -> dict:
        kind, meta, _ = self.recv_frame()
        if kind != KIND_CTRL:
            raise FlightError(f"expected ctrl frame, got kind={kind}")
        if meta.get("error"):
            raise FlightError(meta["error"])
        return meta

    def close(self) -> None:
        try:
            self.sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        self.sock.close()


def dial(host: str, port: int, timeout: float | None = 30.0) -> FrameConnection:
    sock = socket.create_connection((host, port), timeout=timeout)
    sock.settimeout(None)
    return FrameConnection(sock)


class SocketListener:
    """Accept loop running handler-per-connection threads (the server side)."""

    def __init__(self, handler: Callable[[FrameConnection], None], host: str = "127.0.0.1", port: int = 0):
        self._handler = handler
        self._sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._sock.bind((host, port))
        self._sock.listen(128)
        self.host, self.port = self._sock.getsockname()
        self._threads: list[threading.Thread] = []
        self._accept_thread: threading.Thread | None = None
        self._closing = threading.Event()

    def start(self) -> "SocketListener":
        self._accept_thread = threading.Thread(target=self._accept_loop, daemon=True)
        self._accept_thread.start()
        return self

    def _accept_loop(self) -> None:
        while not self._closing.is_set():
            try:
                sock, _ = self._sock.accept()
            except OSError:
                return
            conn = FrameConnection(sock)
            t = threading.Thread(target=self._safe_handle, args=(conn,), daemon=True)
            t.start()
            self._threads.append(t)

    def _safe_handle(self, conn: FrameConnection) -> None:
        try:
            self._handler(conn)
        except (ConnectionError, OSError):
            pass
        except FlightError as e:  # report to peer if still possible
            try:
                conn.send_ctrl({"error": str(e)})
            except OSError:
                pass
        finally:
            conn.close()

    def stop(self) -> None:
        self._closing.set()
        try:
            self._sock.close()
        except OSError:
            pass

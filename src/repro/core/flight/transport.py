"""Wire transports for Flight RPC.

Two transports with one frame model:

* ``SocketTransport`` — real TCP.  Frames go out via ``sendmsg`` scatter/
  gather straight from the columnar buffers (zero copies on the send side);
  the receive side reads the body into one aligned allocation and decodes
  RecordBatches as **views** into it (zero deserialization).
* in-proc — handled one level up (client holds a server reference and moves
  ``RecordBatch`` objects by reference; models same-host shared memory).

Frame layout::

    <I magic><B kind><I meta_len><Q body_len> | meta | body

``kind``: 0 = control (JSON), 1 = data (IPC message; metadata is the binary
codec of ipc.py by default, JSON-compatible by first byte).  gRPC's HTTP/2
framing is replaced by this minimal equivalent (see DESIGN.md §2
non-transferable).

Syscall discipline — the small-message regime is syscall bound, so:

* **coalesced send** — ``send_data_many`` packs multiple data frames into
  single ``sendmsg`` calls under a byte budget (``COALESCE_BYTES``) and the
  platform ``IOV_MAX``; a DoGet of 1 KiB batches goes from one syscall per
  frame to one per ~megabyte.  ``_sendall_vectored`` additionally chunks any
  part list to ``IOV_MAX`` iovecs (wide batches + pad views can exceed it —
  the kernel would fail with EMSGSIZE).
* **buffered receive** — frame header + metadata (and any small bodies
  already in flight) are consumed from one buffered ``recv`` instead of one
  syscall each; large bodies are still received directly into their
  destination (zero copies past the socket buffer).
* **pooled bodies** — receive bodies come from a ``BufferPool`` recycling
  aligned slabs across frames instead of a fresh allocation per body.
"""
from __future__ import annotations

import json
import os
import select
import socket
import struct
import threading
import time
from typing import Callable, Iterable

import numpy as np

from ..buffer import Buffer, BufferPool
from ..ipc import EncodedMessage, parse_metadata
from .errors import FlightError, error_from_wire

FRAME = struct.Struct("<IBIQ")
FRAME_MAGIC = 0xF117A77C
KIND_CTRL, KIND_DATA = 0, 1

# Default socket options tuned for bulk transfer (paper §3: Flight wins on
# large messages; we keep buffers big and Nagle off for the small control frames).
SOCK_BUF = 4 << 20
COALESCE_BYTES = 1 << 20  # coalesced-send flush budget
RECV_CHUNK = 256 << 10  # buffered-receive read size (small-frame streams)
RECV_CHUNK_BULK = 4 << 10  # read size once bodies are large (see _fill)
LARGE_BODY = 16 << 10  # body size that flips the connection to bulk reads

try:
    IOV_MAX = os.sysconf("SC_IOV_MAX")
except (AttributeError, OSError, ValueError):  # pragma: no cover
    IOV_MAX = 1024
if IOV_MAX <= 0:  # sysconf may report "indeterminate"
    IOV_MAX = 1024


class FrameConnection:
    """A framed, bidirectional byte-stream connection over a socket."""

    def __init__(self, sock: socket.socket, pool: BufferPool | None = None):
        self.sock = sock
        try:
            self.sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        except OSError:  # e.g. AF_UNIX socketpair in tests
            pass
        for opt in (socket.SO_SNDBUF, socket.SO_RCVBUF):
            try:
                self.sock.setsockopt(socket.SOL_SOCKET, opt, SOCK_BUF)
            except OSError:
                pass
        self._send_lock = threading.Lock()
        self.pool = pool or BufferPool()
        self._poll = None  # persistent readiness poller (receive_ready)
        self._rbuf = bytearray()  # buffered-receive leftover bytes
        self._rpos = 0
        self._fill_chunk = RECV_CHUNK  # adapted per observed body sizes
        self.bytes_sent = 0
        self.bytes_received = 0
        self.sendmsg_calls = 0
        self.recv_calls = 0

    # ------------------------------------------------------------- send --
    def send_ctrl(self, obj: dict) -> None:
        meta = json.dumps(obj).encode()
        self._sendv(KIND_CTRL, meta, [], 0)

    def send_data(self, msg: EncodedMessage) -> None:
        self._sendv(KIND_DATA, msg.metadata, msg.body_parts, msg.body_len)

    def send_data_many(self, msgs: Iterable[EncodedMessage], budget: int = COALESCE_BYTES) -> None:
        """Send data frames coalesced: many frames per ``sendmsg``.

        Frames are appended to one iovec list and flushed when the byte
        budget or ``IOV_MAX`` would be exceeded — the syscall count scales
        with bytes, not with frame count."""
        parts: list[memoryview] = []
        total = 0
        for msg in msgs:
            fparts, flen = self._frame_parts(KIND_DATA, msg.metadata, msg.body_parts, msg.body_len)
            if parts and (total + flen > budget or len(parts) + len(fparts) > IOV_MAX):
                self._flush(parts, total)
                parts, total = [], 0
            parts += fparts
            total += flen
        if parts:
            self._flush(parts, total)

    @staticmethod
    def _frame_parts(
        kind: int, meta: bytes, body_parts: list[np.ndarray], body_len: int
    ) -> tuple[list[memoryview], int]:
        header = FRAME.pack(FRAME_MAGIC, kind, len(meta), body_len)
        parts = [memoryview(header), memoryview(meta)]
        for p in body_parts:
            parts.append(memoryview(p).cast("B") if isinstance(p, np.ndarray) else memoryview(p))
        return parts, len(header) + len(meta) + body_len

    def _sendv(self, kind: int, meta: bytes, body_parts: list[np.ndarray], body_len: int) -> None:
        parts, total = self._frame_parts(kind, meta, body_parts, body_len)
        self._flush(parts, total)

    def _flush(self, parts: list[memoryview], total: int) -> None:
        with self._send_lock:
            self._sendall_vectored(parts, total)
        self.bytes_sent += total

    def _sendall_vectored(self, parts: list[memoryview], total: int) -> None:
        """sendmsg with continuation — zero-copy gather from columnar buffers.

        Consumes ``parts`` in windows of ``IOV_MAX`` iovecs (the kernel limit)
        and resumes after short writes.  Mutates the list in place."""
        i, n = 0, len(parts)
        while i < n:
            window = parts[i : i + IOV_MAX]
            sent = self.sock.sendmsg(window)
            self.sendmsg_calls += 1
            for mv in window:
                if sent >= len(mv):
                    sent -= len(mv)
                    i += 1
                else:
                    parts[i] = mv[sent:]
                    break

    # ------------------------------------------------------------- recv --
    def _recv_exact_into(self, view: memoryview) -> None:
        got = 0
        while got < len(view):
            n = self.sock.recv_into(view[got:], len(view) - got)
            self.recv_calls += 1
            if n == 0:
                raise ConnectionError("peer closed")
            got += n

    def _buffered(self) -> int:
        return len(self._rbuf) - self._rpos

    def _fill(self, n: int) -> None:
        """Ensure ≥ n unread buffered bytes; one recv drains many small frames.

        The read size adapts: small-frame streams use wide reads so one
        syscall covers dozens of header+metadata(+body) sequences; once a
        large body is seen the reads shrink so bodies stay on the direct
        ``recv_into``-the-slab path instead of being double-copied through
        this buffer."""
        if self._rpos and (self._rpos == len(self._rbuf) or self._rpos > RECV_CHUNK):
            del self._rbuf[: self._rpos]
            self._rpos = 0
        while self._buffered() < n:
            chunk = self.sock.recv(max(self._fill_chunk, n - self._buffered()))
            self.recv_calls += 1
            if not chunk:
                raise ConnectionError("peer closed")
            self._rbuf += chunk

    def _take(self, n: int) -> bytes:
        self._fill(n)
        out = bytes(self._rbuf[self._rpos : self._rpos + n])
        self._rpos += n
        return out

    def receive_ready(self) -> bool:
        """True when ``recv_frame`` has bytes to consume without blocking
        (user-space buffer or kernel socket buffer).  Lets callers flush
        pending output exactly when a read is about to block — the
        streaming-exchange coalescing heuristic (exchange.py).

        The kernel probe goes through a poll object registered once per
        connection instead of a fresh ``select`` fd-set per call — the
        probe runs once per streamed batch, so its setup cost is hot-path
        cost.  Event-loop channels override this entirely (readiness is
        already known from the last epoll event; zero syscalls)."""
        if self._buffered():
            return True
        try:
            if self._poll is None:
                if not hasattr(select, "poll"):  # pragma: no cover — non-Linux
                    r, _, _ = select.select([self.sock], [], [], 0)
                    return bool(r)
                self._poll = select.poll()
                self._poll.register(self.sock, select.POLLIN)
            return bool(self._poll.poll(0))
        except (OSError, ValueError):  # closed socket
            return True  # let recv_frame surface the real error

    def recv_frame(self) -> tuple[int, dict, Buffer | None]:
        head = self._take(FRAME.size)
        magic, kind, meta_len, body_len = FRAME.unpack(head)
        if magic != FRAME_MAGIC:
            raise FlightError(f"bad frame magic {magic:#x}")
        meta_raw = self._take(meta_len)
        self._fill_chunk = RECV_CHUNK_BULK if body_len >= LARGE_BODY else RECV_CHUNK
        body = None
        if body_len:
            body = self.pool.acquire(body_len)
            view = memoryview(body.data)
            have = min(self._buffered(), body_len)
            if have:  # body head over-read by the buffered metadata recv
                view[:have] = memoryview(self._rbuf)[self._rpos : self._rpos + have]
                self._rpos += have
            if have < body_len:
                self._recv_exact_into(view[have:])
        self.bytes_received += FRAME.size + meta_len + body_len
        meta = parse_metadata(meta_raw) if kind == KIND_DATA else json.loads(meta_raw)
        return kind, meta, body

    def recv_ctrl(self) -> dict:
        kind, meta, _ = self.recv_frame()
        if kind != KIND_CTRL:
            raise FlightError(f"expected ctrl frame, got kind={kind}")
        if meta.get("error"):
            raise error_from_wire(meta)  # typed FlightError subclass round-trip
        return meta

    def close(self) -> None:
        try:
            self.sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        self.sock.close()


DIAL_ATTEMPTS = 3       # bounded connect retries on ECONNREFUSED
DIAL_BACKOFF = 0.05     # first retry delay; doubles per attempt


def dial(host: str, port: int, timeout: float | None = 30.0,
         attempts: int = DIAL_ATTEMPTS, backoff: float = DIAL_BACKOFF) -> FrameConnection:
    """Connect with bounded retry-with-backoff on ``ConnectionRefusedError``.

    A refused connect usually means the server process is mid-startup (the
    subprocess-server benchmarks and cluster restart tests race the bind);
    anything else — unreachable host, timeout — fails immediately.  Total
    added wait is ``backoff * (2^(attempts-1) - 1)`` ≈ 0.15 s at defaults."""
    attempts = max(1, attempts)
    for attempt in range(attempts):
        try:
            sock = socket.create_connection((host, port), timeout=timeout)
        except ConnectionRefusedError:
            if attempt == attempts - 1:
                raise
            time.sleep(backoff * (1 << attempt))
        else:
            sock.settimeout(None)
            return FrameConnection(sock)
    raise ConnectionRefusedError  # pragma: no cover — loop always returns/raises


class SocketListener:
    """Accept loop running handler-per-connection threads (the server side).

    The thread-per-connection model: simple, but thread count is O(live
    clients) and the GIL convoy grows with them — see eventloop.py for the
    selector core that replaces it (``ServerConfig(io_mode=...)`` picks;
    this listener is retained one release for bisection)."""

    MAX_TRACKED = 64  # retained Thread objects (diagnostics only), hard cap

    def __init__(self, handler: Callable[[FrameConnection], None], host: str = "127.0.0.1", port: int = 0):
        self._handler = handler
        self._sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._sock.bind((host, port))
        self._sock.listen(128)
        self.host, self.port = self._sock.getsockname()
        self._threads: list[threading.Thread] = []
        self._conns: set[FrameConnection] = set()
        self._accept_thread: threading.Thread | None = None
        self._closing = threading.Event()

    def start(self) -> "SocketListener":
        self._accept_thread = threading.Thread(target=self._accept_loop, daemon=True)
        self._accept_thread.start()
        return self

    def _accept_loop(self) -> None:
        while not self._closing.is_set():
            try:
                sock, _ = self._sock.accept()
            except OSError:
                return
            conn = FrameConnection(sock)
            self._conns.add(conn)
            t = threading.Thread(target=self._safe_handle, args=(conn,), daemon=True)
            t.start()
            # reap finished handlers on every accept AND cap the retained
            # list: a connection storm between reaps must not accrete one
            # Thread object per connection ever accepted (the list is
            # diagnostic — dropping a reference never kills a live handler)
            alive = [x for x in self._threads if x.is_alive()]
            alive.append(t)
            self._threads = alive[-self.MAX_TRACKED:]

    def _safe_handle(self, conn: FrameConnection) -> None:
        try:
            self._handler(conn)
        except (ConnectionError, OSError):
            pass
        except FlightError as e:  # report to peer if still possible
            try:
                conn.send_ctrl(e.to_wire())
            except OSError:
                pass
        finally:
            conn.close()
            self._conns.discard(conn)

    def drop_connections(self) -> int:
        """Sever every live connection (fault injection / admin drain);
        the accept loop keeps running."""
        conns = list(self._conns)
        for c in conns:
            try:
                c.close()
            except OSError:
                pass
        return len(conns)

    def open_connections(self) -> int:
        """Live handler threads (== live connections, up to ``MAX_TRACKED``)."""
        return sum(1 for t in self._threads if t.is_alive())

    def stats(self) -> dict:
        open_conns = self.open_connections()
        return {"io_mode": "threads",
                "open_connections": open_conns,
                "open_fds": open_conns + 1,  # handler sockets + listener
                "worker_queue_depth": 0,     # thread-per-conn: no shared queue
                "workers": None}

    def stop(self) -> None:
        self._closing.set()
        try:
            self._sock.close()
        except OSError:
            pass

"""Telemetry plane: distributed tracing + latency histograms, exported as Arrow.

The paper's headline claim — >80% of data-access time lost to ser/de,
recovered by Flight — is an *attribution* claim, and attribution needs
per-stage accounting: where did one DoGet spend its time (accept queue,
worker handoff, encode, sendmsg), and which hop of a client → head → shard
fan-out was the slow one?  This module supplies the three primitives and the
export path; the wiring lives in middleware.py / server.py / eventloop.py /
cluster.py.

**Distributed tracing.**  A ``TraceContext`` (trace id, span id, parent span)
rides ``CallOptions.headers`` (client → server) and endpoint
``app_metadata["trace"]`` (planner → scheduler → shard), so one trace
stitches every hop of a distributed read, a 2PC commit, or a chained
exchange pipeline.  Tracing is **sampled by the caller**: servers only
record spans for requests that arrive carrying trace headers — untraced
traffic pays one dict lookup per RPC and nothing else.  Each recorded
``Span`` carries per-stage timings (queue-wait, handler, encode, flush,
backpressure stalls) filled in by the server and event loop via the
thread-local ``add_stage`` hook.

**Latency histograms.**  ``LogHistogram`` is a fixed-size log2-bucket
histogram (one integer increment per observation, no allocation, no lock —
the count bumps are GIL-atomic and deliberately approximate, like the event
loop's diagnostics counters).  Bucket ``i`` holds observations whose
microsecond value has bit-length ``i``, i.e. upper bound ``2**i µs`` — 40
buckets span sub-µs to ~9 minutes.  Percentiles are read as the upper bound
of the bucket where the cumulative count crosses the rank: an upper-bound
estimate with ≤2x resolution error, which is what p99 dashboards need.

**Arrow-native export.**  ``spans_to_batch`` / ``metrics_to_batch`` render
snapshots as ``RecordBatch``es; the ``server-trace`` / ``server-metrics``
actions (``telemetry_action``) return them as one-batch Arrow IPC streams in
the action body, and the cluster head's ``cluster-trace`` /
``cluster-metrics`` scrape fans out to every shard and merges one
epoch-stamped cluster-wide batch.  The telemetry plane's wire format *is*
the data plane's wire format.
"""
from __future__ import annotations

import json
import os
import threading
import time
from collections import deque
from contextlib import contextmanager
from dataclasses import dataclass, field

from ..recordbatch import RecordBatch
from ..ipc import read_stream_with_schema, write_stream

# Trace headers (CallOptions.headers / endpoint app_metadata["trace"] keys).
HDR_TRACE = "x-trace-id"
HDR_SPAN = "x-span-id"
HDR_PARENT = "x-parent-span"

MAX_SPANS = 2048      # bounded per-server span buffer (drop-oldest)
MAX_BUCKETS = 40      # log2 µs buckets: 2**39 µs ≈ 9.1 min ceiling


def _new_id() -> str:
    return os.urandom(8).hex()


# --------------------------------------------------------------------------
# trace context + spans
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class TraceContext:
    """One hop's identity within a trace: who am I, who called me."""

    trace_id: str
    span_id: str
    parent_id: str | None = None

    @classmethod
    def new(cls) -> "TraceContext":
        return cls(trace_id=_new_id(), span_id=_new_id())

    def child(self) -> "TraceContext":
        return TraceContext(self.trace_id, _new_id(), self.span_id)

    def to_headers(self) -> dict:
        h = {HDR_TRACE: self.trace_id, HDR_SPAN: self.span_id}
        if self.parent_id:
            h[HDR_PARENT] = self.parent_id
        return h

    @classmethod
    def from_headers(cls, headers: dict | None) -> "TraceContext | None":
        if not headers:
            return None
        tid = headers.get(HDR_TRACE)
        sid = headers.get(HDR_SPAN)
        if not tid or not sid:
            return None
        return cls(tid, sid, headers.get(HDR_PARENT) or None)


@dataclass
class Span:
    """One timed operation within a trace, with per-stage breakdown."""

    trace_id: str
    span_id: str
    parent_id: str | None
    name: str
    service: str = "?"
    shard: int = -1
    start_s: float = 0.0
    duration_s: float = 0.0
    status: str = "ok"        # "ok" or the FlightError wire code
    stages: dict = field(default_factory=dict)  # stage name -> seconds

    def context(self) -> TraceContext:
        return TraceContext(self.trace_id, self.span_id, self.parent_id)


class SpanRecorder:
    """Bounded, thread-safe span sink (drop-oldest ring)."""

    def __init__(self, maxlen: int = MAX_SPANS):
        self._lock = threading.Lock()
        self._spans: deque[Span] = deque(maxlen=maxlen)
        self.recorded = 0

    def record(self, span: Span) -> None:
        with self._lock:
            self._spans.append(span)
            self.recorded += 1

    def snapshot(self, clear: bool = False) -> list[Span]:
        with self._lock:
            out = list(self._spans)
            if clear:
                self._spans.clear()
            return out

    def __len__(self) -> int:
        with self._lock:
            return len(self._spans)


# --------------------------------------------------------------------------
# log2 histograms
# --------------------------------------------------------------------------


class LogHistogram:
    """Fixed log2-bucket histogram: one int increment per observe, no lock.

    ``scale`` maps observed values to the bucketed integer domain —
    ``1e6`` (default) buckets seconds by microsecond bit-length; ``1``
    buckets raw counts (queue depths).  Bucket ``i``'s upper bound is
    ``2**i / scale``."""

    __slots__ = ("counts", "count", "total", "scale")

    def __init__(self, scale: float = 1e6):
        self.counts = [0] * MAX_BUCKETS
        self.count = 0
        self.total = 0.0
        self.scale = scale

    def observe(self, value: float) -> None:
        # GIL-atomic-ish bumps, same contract as the event loop's
        # "approximate: bumped without dedicated locks" diagnostics
        idx = int(value * self.scale).bit_length()
        if idx >= MAX_BUCKETS:
            idx = MAX_BUCKETS - 1
        self.counts[idx] += 1
        self.count += 1
        self.total += value

    def bucket_upper(self, idx: int) -> float:
        return (1 << idx) / self.scale

    def percentile(self, q: float) -> float:
        """Upper-bound estimate of the q-quantile (0 < q <= 1)."""
        n = self.count
        if n == 0:
            return 0.0
        rank = q * n
        cum = 0
        for i, c in enumerate(self.counts):
            cum += c
            if cum >= rank:
                return self.bucket_upper(i)
        return self.bucket_upper(MAX_BUCKETS - 1)

    def merge(self, other: "LogHistogram") -> None:
        for i, c in enumerate(other.counts):
            self.counts[i] += c
        self.count += other.count
        self.total += other.total

    def snapshot(self) -> dict:
        return {
            "count": self.count,
            "sum": round(self.total, 6),
            "p50": self.percentile(0.50),
            "p95": self.percentile(0.95),
            "p99": self.percentile(0.99),
            "buckets": {i: c for i, c in enumerate(self.counts) if c},
        }


# --------------------------------------------------------------------------
# thread-local active span (the stage-timing hook)
# --------------------------------------------------------------------------

_tls = threading.local()


def current_span() -> Span | None:
    return getattr(_tls, "span", None)


def current_context() -> TraceContext | None:
    span = getattr(_tls, "span", None)
    return span.context() if span is not None else None


def propagation_headers() -> dict | None:
    """Headers a downstream hop should carry to parent under the active
    span; ``None`` when no trace is active (the common case)."""
    span = getattr(_tls, "span", None)
    if span is None:
        return None
    return {HDR_TRACE: span.trace_id, HDR_SPAN: span.span_id}


def add_stage(name: str, seconds: float) -> None:
    """Attribute ``seconds`` to a named stage of the active span.

    No-op (one thread-local read) when the request is untraced, so hot
    paths may call it unconditionally."""
    span = getattr(_tls, "span", None)
    if span is not None:
        span.stages[name] = span.stages.get(name, 0.0) + seconds


def _push_span(span: Span) -> Span | None:
    prev = getattr(_tls, "span", None)
    _tls.span = span
    return prev


def _pop_span(prev: Span | None) -> None:
    _tls.span = prev


# --------------------------------------------------------------------------
# per-server telemetry bundle
# --------------------------------------------------------------------------


class ServerTelemetry:
    """What one server owns: mode, identity, and the span sink.

    ``mode`` gates cost: ``"off"`` (no histograms, no spans), ``"metrics"``
    (histograms only), ``"full"`` (histograms + caller-sampled spans)."""

    def __init__(self, mode: str = "full", service: str = "?",
                 shard: int | None = None):
        if mode not in ("off", "metrics", "full"):
            raise ValueError(f"telemetry mode {mode!r} (off|metrics|full)")
        self.mode = mode
        self.service = service
        self.shard = -1 if shard is None else shard
        self.spans = SpanRecorder()

    @property
    def metrics_enabled(self) -> bool:
        return self.mode != "off"

    @property
    def trace_enabled(self) -> bool:
        return self.mode == "full"

    def begin_span(self, name: str, parent: TraceContext) -> tuple[Span, Span | None]:
        """Open a server span as a child of the caller's context and make
        it the thread's active span; returns ``(span, previous)`` for the
        matching ``end_span``."""
        span = Span(
            trace_id=parent.trace_id, span_id=_new_id(),
            parent_id=parent.span_id, name=name,
            service=self.service, shard=self.shard, start_s=time.time())
        return span, _push_span(span)

    def end_span(self, span: Span, prev: Span | None, duration_s: float,
                 error: Exception | None = None) -> None:
        span.duration_s = duration_s
        if error is not None:
            span.status = getattr(error, "code", None) or type(error).__name__
        span.stages.setdefault("handler", duration_s)
        _pop_span(prev)
        self.spans.record(span)

    @contextmanager
    def span(self, name: str, parent: TraceContext | None = None):
        """Record an explicit sub-span (e.g. a 2PC sub-txn run in-proc,
        bypassing middleware).  Parent defaults to the thread's active
        span; with no parent and no active trace this is a no-op."""
        if not self.trace_enabled:
            yield None
            return
        parent = parent or current_context()
        if parent is None:
            yield None
            return
        span, prev = self.begin_span(name, parent)
        t0 = time.perf_counter()
        try:
            yield span
        except Exception as e:
            self.end_span(span, prev, time.perf_counter() - t0, e)
            raise
        else:
            self.end_span(span, prev, time.perf_counter() - t0)


class Tracer:
    """Client-side trace root: opens the span every server hop stitches to.

    >>> tracer = Tracer(service="client")
    >>> with tracer.trace("read") as ctx:
    ...     opts = CallOptions(headers=ctx.to_headers())   # doctest: +SKIP
    """

    def __init__(self, service: str = "client"):
        self.service = service
        self.spans = SpanRecorder()

    @contextmanager
    def trace(self, name: str):
        ctx = TraceContext.new()
        span = Span(trace_id=ctx.trace_id, span_id=ctx.span_id,
                    parent_id=None, name=name, service=self.service,
                    start_s=time.time())
        prev = _push_span(span)
        t0 = time.perf_counter()
        try:
            yield ctx
        except Exception as e:
            span.status = getattr(e, "code", None) or type(e).__name__
            raise
        finally:
            span.duration_s = time.perf_counter() - t0
            _pop_span(prev)
            self.spans.record(span)


# --------------------------------------------------------------------------
# Arrow export
# --------------------------------------------------------------------------


def spans_to_batch(spans: list[Span]) -> RecordBatch:
    """Render spans as one RecordBatch (variable stages ride as JSON)."""
    return RecordBatch.from_pydict({
        "trace_id": [s.trace_id for s in spans],
        "span_id": [s.span_id for s in spans],
        "parent_id": [s.parent_id or "" for s in spans],
        "name": [s.name for s in spans],
        "service": [s.service for s in spans],
        "shard": [int(s.shard) for s in spans],
        "start_s": [float(s.start_s) for s in spans],
        "duration_s": [float(s.duration_s) for s in spans],
        "status": [s.status for s in spans],
        "stages": [json.dumps({k: round(v, 9) for k, v in s.stages.items()})
                   for s in spans],
    } if spans else _EMPTY_SPANS)


_EMPTY_SPANS = {
    "trace_id": [], "span_id": [], "parent_id": [], "name": [],
    "service": [], "shard": [], "start_s": [], "duration_s": [],
    "status": [], "stages": [],
}


def batch_to_spans(batch: RecordBatch) -> list[dict]:
    """Decode a span batch into row dicts (stages JSON rehydrated)."""
    cols = batch.to_pydict()
    rows = []
    for i in range(batch.num_rows):
        row = {k: v[i] for k, v in cols.items()}
        row["stages"] = json.loads(row.get("stages") or "{}")
        rows.append(row)
    return rows


def metrics_rows(scope: str, hists: dict) -> list[dict]:
    """Flatten ``{name: LogHistogram | snapshot-dict}`` into export rows."""
    rows = []
    for name, h in sorted(hists.items()):
        snap = h.snapshot() if isinstance(h, LogHistogram) else h
        rows.append({
            "scope": scope, "name": name,
            "count": int(snap.get("count", 0)),
            "sum_s": float(snap.get("sum", 0.0)),
            "p50_s": float(snap.get("p50", 0.0)),
            "p95_s": float(snap.get("p95", 0.0)),
            "p99_s": float(snap.get("p99", 0.0)),
            "buckets": json.dumps(snap.get("buckets", {})),
        })
    return rows


def metrics_to_batch(rows: list[dict], shard: int = -1,
                     epoch: int = -1) -> RecordBatch:
    return RecordBatch.from_pydict({
        "scope": [r["scope"] for r in rows],
        "name": [r["name"] for r in rows],
        "count": [int(r["count"]) for r in rows],
        "sum_s": [float(r["sum_s"]) for r in rows],
        "p50_s": [float(r["p50_s"]) for r in rows],
        "p95_s": [float(r["p95_s"]) for r in rows],
        "p99_s": [float(r["p99_s"]) for r in rows],
        "buckets": [r["buckets"] for r in rows],
        "shard": [int(r.get("shard", shard)) for r in rows],
        "epoch": [int(r.get("epoch", epoch)) for r in rows],
    } if rows else {k: [] for k in (
        "scope", "name", "count", "sum_s", "p50_s", "p95_s", "p99_s",
        "buckets", "shard", "epoch")})


def batch_to_rows(batch: RecordBatch) -> list[dict]:
    cols = batch.to_pydict()
    return [{k: v[i] for k, v in cols.items()} for i in range(batch.num_rows)]


def encode_telemetry_batch(batch: RecordBatch) -> bytes:
    """One-batch Arrow IPC stream — the ``server-trace``/``server-metrics``
    action body format (decode with ``decode_telemetry_batch``)."""
    return write_stream([batch], schema=batch.schema)


def decode_telemetry_batch(body: bytes) -> RecordBatch:
    schema, batches = read_stream_with_schema(bytes(body))
    if not batches:
        return RecordBatch.from_pydict({f.name: [] for f in schema.fields}, schema)
    return batches[0]


def merge_telemetry_batches(batches: list[tuple[int, RecordBatch]],
                            epoch: int) -> RecordBatch:
    """Head-side scrape merge: concatenate per-shard batches into one
    cluster-wide batch, stamping ``shard`` and ``epoch`` per row."""
    merged: dict[str, list] = {}
    template: RecordBatch | None = None
    for shard, b in batches:
        if template is None:
            template = b
            merged = {k: [] for k in b.to_pydict()}
        cols = b.to_pydict()
        n = b.num_rows
        for k in merged:
            vals = cols.get(k, [None] * n)
            if k == "shard":
                vals = [shard if v in (None, -1) else v for v in vals]
            elif k == "epoch":
                vals = [epoch] * n
            merged[k].extend(vals)
    if template is None:
        return metrics_to_batch([])
    return RecordBatch.from_pydict(merged)


# --------------------------------------------------------------------------
# the server-trace / server-metrics actions (shared by server + cluster head)
# --------------------------------------------------------------------------


def server_metrics_rows(server) -> list[dict]:
    """Every histogram scope one server exposes, flattened to export rows."""
    rows: list[dict] = []
    metrics = getattr(server, "metrics", None)
    if metrics is not None:
        rows += metrics_rows("verb", getattr(metrics, "latency", {}))
        rows += metrics_rows(
            "exchange",
            {k: v["hist"] for k, v in getattr(metrics, "exchanges", {}).items()
             if isinstance(v, dict) and isinstance(v.get("hist"), LogHistogram)})
        for verb, codes in getattr(metrics, "error_codes", {}).items():
            for code, n in sorted(codes.items()):
                rows.append({
                    "scope": "errors", "name": f"{verb}:{code}", "count": n,
                    "sum_s": 0.0, "p50_s": 0.0, "p95_s": 0.0, "p99_s": 0.0,
                    "buckets": "{}"})
    listener = getattr(server, "_listener", None)
    if listener is not None:
        rows += metrics_rows("io", getattr(listener, "histograms", lambda: {})())
    # monotone serve counters (no histogram): scrape deltas give rates
    rows.append({
        "scope": "serve", "name": "rows_served",
        "count": int(getattr(server, "rows_served", 0)),
        "sum_s": 0.0, "p50_s": 0.0, "p95_s": 0.0, "p99_s": 0.0,
        "buckets": "{}"})
    tel = getattr(server, "telemetry", None)
    shard = tel.shard if tel is not None else -1
    for r in rows:
        r.setdefault("shard", shard)
    return rows


def telemetry_action(server, action) -> "list | None":
    """Serve ``server-trace`` / ``server-metrics`` for one server; returns
    ``None`` for any other action type (caller falls through)."""
    from .protocol import ActionResult  # lazy: protocol imports stay light

    if action.type == "server-metrics":
        batch = metrics_to_batch(server_metrics_rows(server))
        return [ActionResult(encode_telemetry_batch(batch))]
    if action.type == "server-trace":
        opts = json.loads(action.body) if action.body else {}
        tel = getattr(server, "telemetry", None)
        spans = tel.spans.snapshot(clear=bool(opts.get("clear"))) if tel else []
        return [ActionResult(encode_telemetry_batch(spans_to_batch(spans)))]
    return None

"""Exchange transform services — the microservice side of DoExchange.

The paper's third pillar treats Flight not just as a transport but as the
substrate for *data microservices*: a client streams RecordBatches in, the
service streams transformed RecordBatches back, and both directions run
concurrently.  This module supplies the server-side plumbing for that
pattern (the streaming wire protocol itself lives in exchange.py / server.py):

* ``ExchangeService`` — one named transform.  ``out_schema`` declares the
  output schema from the input schema *before any batch arrives* (the wire
  protocol sends the output schema up front, so a downstream consumer —
  including the next server in a chained ``Pipeline`` — can open its own
  stream immediately), and ``transform`` is a generator over the input
  batches, so services are free to be non-1:1 (filter drops, repartition
  re-chunks).
* ``ExchangeServiceRegistry`` — name → service, the fal-teller provider
  pattern: a ``DoExchange`` descriptor carrying ``ExchangeCommand(name,
  params)`` (protocol.py, 0xC2 type 4) routes the stream through the
  registered service.  Unknown names are a typed ``FlightNotFound`` refused
  before the stream opens.
* stock services — ``echo``, ``filter`` (query-engine ``Expr`` predicate),
  ``project`` (column subset), ``repartition`` (re-chunk to a row target);
  plus ``MapBatchesService``/``ScoreService`` wrappers for server-side
  callables (a scoring model can't ride the wire, so those are registered
  at server construction, not named in params).

Every service sees only ``(in_schema, batches, params)`` — no transport,
no connection — so the same instance serves TCP and in-proc exchanges and
can be unit-tested with plain lists.
"""
from __future__ import annotations

from typing import Callable, Iterator

from ..recordbatch import RecordBatch, Table
from ..schema import Schema
from .errors import FlightError, FlightInvalidArgument, FlightNotFound


class ExchangeService:
    """One named bidirectional transform.  Subclass and register.

    ``transform`` runs with the input stream still arriving — yield early,
    yield often: every batch yielded before the input EOS overlaps with the
    client still writing (that concurrency is the paper's "half the cores"
    claim for DoExchange microservices)."""

    name = "?"

    def check_params(self, params: dict) -> None:
        """Validate schema-independent params; raise ``FlightInvalidArgument``.

        Runs *before the stream opens* on every transport (TCP refuses
        before the ok frame, keeping the channel clean and poolable), so
        malformed params never cost a torn-down connection.  Checks that
        need the input schema (e.g. project's unknown-column check) belong
        in ``out_schema`` and surface as typed mid-stream errors."""

    def out_schema(self, in_schema: Schema, params: dict) -> Schema | None:
        """Output schema, declared before any batch arrives (sent up-front).

        Return ``None`` when the schema genuinely cannot be known until the
        first output batch exists — the serve loop then defers the schema
        frame to that batch (chained consumers stall until it lands)."""
        return in_schema

    def transform(
        self, in_schema: Schema, batches: Iterator[RecordBatch], params: dict
    ) -> Iterator[RecordBatch]:
        raise NotImplementedError


class EchoService(ExchangeService):
    """Identity — the wire-speed baseline every benchmark measures against."""

    name = "echo"

    def transform(self, in_schema, batches, params):
        yield from batches


class FilterService(ExchangeService):
    """Row filter by a query-engine predicate.

    ``params = {"predicate": Expr.to_json()}`` — the same expression tree
    the QueryCommand pushdown path executes, so a filter exchange and a
    filtered DoGet select identical rows.  Batches with no surviving rows
    are dropped (non-1:1: the ack channel, not output count, drives the
    sender's window)."""

    name = "filter"

    def _predicate(self, params: dict):
        from ...query.expr import Expr  # lazy: query imports flight's service layer

        if "predicate" not in params:
            raise FlightInvalidArgument("filter service needs a 'predicate' param")
        return Expr.from_json(params["predicate"])

    def check_params(self, params):
        self._predicate(params)

    def out_schema(self, in_schema, params):
        return in_schema

    def transform(self, in_schema, batches, params):
        from ...query.expr import evaluate

        pred = self._predicate(params)
        for b in batches:
            mask = evaluate(pred, b)
            if mask.any():
                yield b if mask.all() else b.filter(mask)


class ProjectService(ExchangeService):
    """Column subset: ``params = {"columns": [...]}`` (zero-copy select)."""

    name = "project"

    def _columns(self, params: dict) -> list[str]:
        cols = params.get("columns")
        if not cols or not isinstance(cols, list):
            raise FlightInvalidArgument("project service needs a 'columns' list param")
        return cols

    def check_params(self, params):
        self._columns(params)

    def out_schema(self, in_schema, params):
        cols = self._columns(params)
        missing = [c for c in cols if c not in in_schema.names]
        if missing:
            raise FlightInvalidArgument(f"project: unknown column(s) {missing}",
                                        detail={"missing": missing})
        return in_schema.select(cols)

    def transform(self, in_schema, batches, params):
        cols = self._columns(params)
        for b in batches:
            yield b.select(cols)


class RepartitionService(ExchangeService):
    """Re-chunk or key-partition the stream — the shuffle plane's transform.

    Two modes, selected by params:

    * ``{"rows": N}`` — historical re-chunking to N rows per output batch.
      Deliberately non-1:1 in both directions (N small inputs → one output,
      one large input → N outputs): the regression test for the windowed
      sender never deadlocking on a consumer that buffers before emitting.
    * ``{"key": [cols], "num_partitions": N, "partition": p}`` — keyed
      partitioning: emit only the rows whose key-tuple hash buckets to
      partition ``p`` of ``N`` (shuffle.row_partitions — the same stable
      hash as ``HashPlacement``).  A shuffle source drives one exchange per
      destination partition over its local batches; the union of the N
      partition streams is exactly the input, key-disjoint."""

    name = "repartition"

    def _rows(self, params: dict) -> int:
        rows = params.get("rows")
        if not isinstance(rows, int) or rows < 1:
            raise FlightInvalidArgument("repartition service needs a positive 'rows' param")
        return rows

    def _keyed(self, params: dict) -> tuple[list[str], int, int]:
        keys = params.get("key")
        if isinstance(keys, str):
            keys = [keys]
        if (not isinstance(keys, list) or not keys
                or not all(isinstance(k, str) for k in keys)):
            raise FlightInvalidArgument(
                "keyed repartition needs a 'key' column name or list")
        n = params.get("num_partitions")
        p = params.get("partition")
        if not isinstance(n, int) or n < 1:
            raise FlightInvalidArgument(
                "keyed repartition needs a positive 'num_partitions' param")
        if not isinstance(p, int) or not 0 <= p < n:
            raise FlightInvalidArgument(
                f"keyed repartition needs a 'partition' in [0, {n})")
        return keys, n, p

    def check_params(self, params):
        if "key" in params:
            self._keyed(params)
        else:
            self._rows(params)

    def out_schema(self, in_schema, params):
        return in_schema

    def transform(self, in_schema, batches, params):
        if "key" in params:
            from .shuffle import row_partitions

            keys, n, p = self._keyed(params)
            for b in batches:
                ids = row_partitions(b, keys, n)
                sub = b.filter(ids == p)
                if sub.num_rows:
                    yield sub
            return
        rows = self._rows(params)
        held: list[RecordBatch] = []
        held_rows = 0
        for b in batches:
            held.append(b)
            held_rows += b.num_rows
            while held_rows >= rows:
                merged = held[0] if len(held) == 1 else Table(held).combine()
                yield merged.slice(0, rows)
                rest = merged.slice(rows)
                held = [rest] if rest.num_rows else []
                held_rows = rest.num_rows
        if held_rows:
            yield held[0] if len(held) == 1 else Table(held).combine()


class MapBatchesService(ExchangeService):
    """Wrap a server-side callable as a named 1:1 service.

    ``fn(batch) -> batch``; pass ``out_schema_fn(in_schema) -> Schema`` so
    the output schema can be declared (and sent) up front — without it the
    schema is *deferred* to the first output batch, which still works but
    stalls a chained consumer until the first output.  The callable lives
    on the server — only its *name* rides the ``ExchangeCommand``."""

    def __init__(self, name: str, fn: Callable[[RecordBatch], RecordBatch],
                 out_schema_fn: Callable[[Schema], Schema] | None = None):
        self.name = name
        self._fn = fn
        self._out_schema_fn = out_schema_fn

    def out_schema(self, in_schema, params):
        return self._out_schema_fn(in_schema) if self._out_schema_fn else None

    def transform(self, in_schema, batches, params):
        for b in batches:
            yield self._fn(b)


class ScoreService(MapBatchesService):
    """The scoring-microservice shape: ``score_fn(batch) -> scores batch``."""

    def __init__(self, score_fn: Callable[[RecordBatch], RecordBatch],
                 out_schema_fn: Callable[[Schema], Schema] | None = None,
                 name: str = "score"):
        super().__init__(name, score_fn, out_schema_fn)


def drive_exchange(service: ExchangeService, in_schema: Schema, params: dict,
                   inputs: Iterator[RecordBatch], declare, emit,
                   state: dict) -> None:
    """Drive one exchange service against transport callbacks.

    The single implementation of the serve loop's invariants — declared
    output schema sent up front and enforced per batch, deferred schema
    riding the first output, output batch/row counting into ``state``,
    unread input drained so an early-stopping service never wedges the
    writer — shared by the TCP server (``_run_exchange``) and the in-proc
    stream so the two transports cannot drift.  ``declare(schema)`` is
    called at most once, always before the first ``emit(batch)``."""
    declared = service.out_schema(in_schema, params)
    sent_schema = declared is not None
    if sent_schema:  # schema up front: chained consumers open now
        declare(declared)
    for ob in service.transform(in_schema, inputs, params):
        if declared is not None and ob.schema != declared:
            raise FlightError(
                f"service {service.name!r} emitted a batch not matching "
                f"its declared schema")
        if not sent_schema:  # deferred schema rides the first output
            declare(ob.schema)
            sent_schema = True
        state["out"] += 1
        state["rows_out"] += ob.num_rows
        emit(ob)
    for _ in inputs:  # drain unread input (early-stopping services)
        pass
    if not sent_schema:  # zero outputs from a deferred-schema service
        declare(in_schema)


class ExchangeServiceRegistry:
    """Name → ``ExchangeService`` (the fal-teller provider-registry shape).

    Servers own one (``FlightServerBase.services``); a cluster shares a
    single registry object across head and shards so one ``register`` call
    makes a service reachable on every endpoint."""

    def __init__(self, include_stock: bool = True):
        self._services: dict[str, ExchangeService] = {}
        if include_stock:
            for svc in (EchoService(), FilterService(), ProjectService(),
                        RepartitionService()):
                self.register(svc)

    def register(self, service: ExchangeService) -> ExchangeService:
        if not service.name or service.name == "?":
            raise FlightInvalidArgument("exchange service needs a name")
        self._services[service.name] = service
        return service

    def get(self, name: str) -> ExchangeService:
        svc = self._services.get(name)
        if svc is None:
            raise FlightNotFound(
                f"no such exchange service: {name!r}",
                detail={"service": name, "registered": sorted(self._services)})
        return svc

    def names(self) -> list[str]:
        return sorted(self._services)

    def __contains__(self, name: str) -> bool:
        return name in self._services

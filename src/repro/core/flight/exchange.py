"""Streaming bidirectional DoExchange — the pipelined microservice plane.

The old exchange verb was lockstep: one batch up, wait, one batch back —
every round trip idle in both directions.  This module replaces it with a
*pipelined* stream modeled on the scheduler's window semantics:

* **decoupled writer/reader** — a receive thread drains the connection
  continuously (output batches into a bounded buffer, acks into the send
  window), so writing and reading overlap instead of alternating;
* **bounded in-flight window** — the writer blocks once ``window`` input
  batches are unacknowledged.  The server acks batches as its service
  *consumes* them (not as they hit the socket), so ``window=1`` degenerates
  to the old lockstep behavior and larger windows keep both directions of
  the pipe full without unbounded buffering anywhere;
* **schema up front** — registry services declare their output schema from
  the input schema, and the server sends it before any batch, so a
  downstream consumer (the next server in a ``Pipeline``) can open its own
  stream immediately.  Legacy per-batch handlers defer it to the first
  output batch;
* **typed mid-stream errors** — a server-side failure after the stream
  opened arrives as a structured error control frame *inside* the data
  stream; the receive thread rehydrates the typed ``FlightError`` and every
  blocked writer/reader raises it.  The connection is torn down on both
  sides (frames may be in flight in either direction), so an exchange error
  never bleeds into a later RPC.

Wire sequence (framing details in docs/wire-format.md, "DoExchange
framing")::

    client                                server
    ctrl {method: DoExchange, ...}  →
                                    ←  ctrl {ok}            (or typed refusal)
    data SCHEMA                     →
                                    ←  data SCHEMA          (declared services)
    data BATCH *                    →
                                    ←  ctrl {ack: n} *      (consumption acks)
                                    ←  data BATCH *         (outputs, interleaved)
    data EOS                        →
                                    ←  data EOS
                                    ←  ctrl {ok, stats}

``Pipeline`` chains exchange streams across servers Mallard-style: stage
N's output iterator feeds stage N+1's writer on a relay thread, so batches
flow A→transform→B bounded by each link's window with no client-side
materialization.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from collections import deque
from typing import Iterable, Iterator

from ..ipc import decode_message, encode_batch, encode_eos, encode_schema
from ..recordbatch import RecordBatch, Table
from ..schema import Schema
from .errors import (
    FlightError,
    FlightTimedOut,
    FlightUnavailable,
    error_from_wire,
)
from .protocol import CallOptions, ExchangeCommand, FlightDescriptor
from .services import drive_exchange
from .telemetry import HDR_TRACE, propagation_headers
from .transport import KIND_CTRL

DEFAULT_WINDOW = 16  # in-flight input batches per exchange stream


def ack_interval(window: int) -> int:
    """How many consumed batches between acks.  Must stay ≤ the window (a
    blocked writer must always have a releasing ack on the way); half the
    window halves the control-frame overhead while keeping the writer at
    most half-drained before permits replenish."""
    return max(1, window // 2)


def resolve_window(options: CallOptions | None) -> int:
    if options is not None and options.read_window:
        return max(1, options.read_window)
    return DEFAULT_WINDOW


def as_exchange_descriptor(command) -> FlightDescriptor:
    """Normalize a service name / ``ExchangeCommand`` / descriptor."""
    if isinstance(command, FlightDescriptor):
        return command
    if isinstance(command, str):
        command = ExchangeCommand(command)
    return FlightDescriptor.for_command(command)


_EOS = object()


class ExchangeStreamBase:
    """Shared reader/buffer/lifecycle machinery of both transports.

    Public surface (both ``FlightExchangeStream`` and
    ``InprocExchangeStream``): ``write_batch`` / ``write_batches`` /
    ``done_writing`` feed the input side; iterating yields output batches;
    ``feed(batches)`` runs the whole input side on a relay thread;
    ``out_schema`` blocks until the server's schema frame arrives; ``stats``
    holds the server's summary after the stream completes.

    A stream is a resource: end it by iterating to completion, ``close()``,
    ``abort()``, or a ``with`` block — an abandoned stream leaks its
    connection (TCP) or worker thread (in-proc), like any unclosed file."""

    def __init__(self, in_schema: Schema, window: int):
        self.in_schema = in_schema
        self.window = max(1, window)
        self._cond = threading.Condition()
        self._buf: deque = deque()
        self._cap = max(2, self.window)
        self._out_schema: Schema | None = None
        self._err: Exception | None = None
        self._eos_written = False
        self._finished = False
        self._disposed = False
        self.stats: dict | None = None
        self._feeder: threading.Thread | None = None

    # -- input side ------------------------------------------------------- #
    def write_batch(self, batch: RecordBatch) -> None:
        raise NotImplementedError

    def write_batches(self, batches: Iterable[RecordBatch]) -> None:
        for b in batches:
            self.write_batch(b)

    def done_writing(self) -> None:
        raise NotImplementedError

    def __enter__(self) -> "ExchangeStreamBase":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        # a stream must always end via close()/abort()/full iteration —
        # abandoning one leaks its connection (TCP) or worker thread
        # (in-proc), like any unclosed resource
        if exc_type is not None:
            self.abort(exc)
        else:
            self.close()
        return False

    def feed(self, batches: Iterable[RecordBatch]) -> "ExchangeStreamBase":
        """Write every batch then EOS on a background thread (the decoupled
        writer), leaving the calling thread free to iterate outputs.  A
        feeder failure aborts the stream, so the reader raises instead of
        waiting forever."""

        def run() -> None:
            try:
                self.write_batches(batches)
                self.done_writing()
            except Exception as e:  # noqa: BLE001 — relayed to the reader
                self.abort(e)

        self._feeder = threading.Thread(
            target=run, daemon=True, name="flight-exchange-feed")
        self._feeder.start()
        return self

    # -- output side ------------------------------------------------------ #
    @property
    def out_schema(self) -> Schema:
        """The service's output schema; blocks until the schema frame lands
        (immediately for registry services — it is sent up front)."""
        with self._cond:
            while (self._out_schema is None and self._err is None
                   and not self._finished):
                self._cond.wait(0.05)
            if self._out_schema is not None:
                return self._out_schema
            if self._err is not None:
                raise self._err
            return self.in_schema  # legacy stream with zero outputs

    schema = out_schema  # FlightStreamReader-compatible alias

    def __iter__(self) -> Iterator[RecordBatch]:
        while True:
            item = self._next()
            if item is _EOS:
                self._wait_finished()
                return
            yield item

    def _next(self):
        with self._cond:
            while True:
                if self._buf:
                    item = self._buf.popleft()
                    self._cond.notify_all()
                    return item
                if self._err is not None:
                    err = self._err
                    break
                if self._finished:
                    return _EOS  # already drained (re-iteration safe)
                self._cond.wait(0.05)
        self._dispose()
        raise err

    def read_all(self) -> Table:
        return Table(list(self))

    def close(self) -> dict:
        """Finish the call: drain remaining output, release the connection,
        return the server's stats.  With an active ``feed`` thread the
        feeder owns the input side — draining keeps acks flowing so it can
        finish, and racing it with our own EOS would abort the stream."""
        if self._feeder is not None:
            for _ in self:
                pass
            self._feeder.join(timeout=5.0)
            return self.stats or {}
        if self._err is None and not self._eos_written:
            self.done_writing()
        for _ in self:
            pass
        return self.stats or {}

    def abort(self, exc: Exception | None = None) -> None:
        """Tear the stream down (feeder failure, consumer giving up)."""
        if exc is None:
            exc = FlightError("exchange aborted")
        elif not isinstance(exc, FlightError):
            exc = FlightError(f"exchange aborted: {exc}")
        self._fail(exc)
        self._dispose()

    # -- internals -------------------------------------------------------- #
    def _emit(self, item) -> None:
        with self._cond:
            while (len(self._buf) >= self._cap and self._err is None
                   and not self._disposed):
                self._cond.wait(0.05)
            if self._err is not None or self._disposed:
                return  # stream failed: drop, the error wins
            self._buf.append(item)
            self._cond.notify_all()

    def _fail(self, exc: Exception) -> None:
        with self._cond:
            if self._err is None and not self._finished:
                self._err = exc
            self._cond.notify_all()

    def _raise_if_failed(self) -> None:
        with self._cond:
            if self._err is not None:
                raise self._err

    def _wait_finished(self) -> None:
        with self._cond:
            while not self._finished and self._err is None:
                self._cond.wait(0.05)
            err = self._err
        if err is not None:
            self._dispose()
            raise err
        self._dispose()

    def _dispose(self) -> None:
        """Release transport resources exactly once (subclass hook)."""
        with self._cond:
            if self._disposed:
                return
            self._disposed = True
            clean = self._finished and self._err is None
            self._cond.notify_all()
        self._release(clean)

    def _release(self, clean: bool) -> None:
        pass


class FlightExchangeStream(ExchangeStreamBase):
    """One pipelined DoExchange call over a TCP ``FrameConnection``.

    Constructed by ``FlightClient.do_exchange_stream`` after the server's
    ``ok`` frame; sends the input schema immediately.  The connection is
    *pumped inline by whichever thread reads* (iterating the stream, or
    blocking on ``out_schema``): each pump processes one incoming frame —
    output batches, acks replenishing the writer's window, the up-front
    schema, mid-stream typed errors, the trailing stats — so the hot read
    path pays zero cross-thread handoffs (decoupling comes from running the
    *writer* on the ``feed`` thread).  Consequence: a writer blocked on the
    window is released by acks only while some thread reads — use ``feed``
    + iterate (or the lockstep write/read alternation), never
    write-everything-then-read with a window smaller than the input.
    ``max_in_flight`` records the high-water mark of unacked input batches —
    the window property tests pin it."""

    def __init__(self, client, conn, in_schema: Schema,
                 options: CallOptions | None):
        super().__init__(in_schema, resolve_window(options))
        self._client = client
        self._conn = conn
        self._options = options
        self._sent = 0
        self._acked = 0
        self._recv_lock = threading.Lock()
        self._pending: deque = deque()  # batches pumped by a non-reader thread
        self._eos_seen = False
        self.max_in_flight = 0
        try:
            conn.send_data(encode_schema(in_schema))
        except (ConnectionError, OSError) as e:
            conn.close()
            raise FlightUnavailable(f"exchange open failed: {e}") from e

    # -- inline pump: the reader side of the connection -------------------- #
    def _pump_one(self) -> None:
        """Process exactly one incoming frame (caller holds ``_recv_lock``)."""
        kind, meta, body = self._conn.recv_frame()
        if kind == KIND_CTRL:
            if meta.get("error"):
                raise error_from_wire(meta)  # typed mid-stream error
            if "ack" in meta:
                with self._cond:
                    self._acked = max(self._acked, int(meta["ack"]))
                    self._cond.notify_all()
                return
            if meta.get("ok"):  # trailing stats: stream complete
                with self._cond:
                    self.stats = meta.get("stats", {})
                    self._finished = True
                    self._acked = self._sent
                    self._cond.notify_all()
                return
            return  # unknown control frame: ignore (forward compat)
        msg = decode_message(meta, body)
        if msg.kind == "schema":
            with self._cond:
                self._out_schema = msg.schema
                self._cond.notify_all()
            return
        if msg.kind == "eos":
            self._eos_seen = True
            return
        if self._out_schema is None:
            raise FlightError("exchange: output batch before schema")
        self._pending.append(msg.batch(self._out_schema))

    def _pump_until(self, ready) -> None:
        """Pump frames until ``ready()`` holds; any failure wakes writers."""
        while not ready():
            self._raise_if_failed()
            with self._recv_lock:
                if ready():  # another thread pumped it meanwhile
                    return
                try:
                    self._pump_one()
                except TimeoutError as e:
                    err = FlightTimedOut(
                        f"exchange stalled past the call timeout: {e}")
                    self._fail(err)
                except (ConnectionError, OSError) as e:
                    self._fail(FlightUnavailable(f"exchange stream died: {e}"))
                except FlightError as e:
                    self._fail(e)
            self._raise_if_failed()

    def _next(self):
        while True:
            if self._pending:
                return self._pending.popleft()
            if self._eos_seen:
                return _EOS
            try:
                self._pump_until(
                    lambda: self._pending or self._eos_seen or self._finished)
            except FlightError:
                self._dispose()
                raise
            if self._finished and not self._pending:
                return _EOS

    @property
    def out_schema(self) -> Schema:
        try:
            self._pump_until(
                lambda: self._out_schema is not None or self._finished)
        except FlightError:
            self._dispose()
            raise
        with self._cond:
            if self._out_schema is not None:
                return self._out_schema
            return self.in_schema  # legacy stream with zero outputs

    schema = out_schema

    def _wait_finished(self) -> None:
        try:
            self._pump_until(lambda: self._finished)
        except FlightError:
            self._dispose()
            raise
        self._dispose()

    # -- windowed writer --------------------------------------------------- #
    def _reserve(self, want: int) -> int:
        """Block until ≥1 window permit is free; take up to ``want``."""
        with self._cond:
            while True:
                if self._err is not None:
                    raise self._err
                if self._eos_written:
                    raise FlightError("exchange input stream already closed")
                free = self.window - (self._sent - self._acked)
                if free >= 1:
                    k = min(want, free)
                    self._sent += k
                    return k
                self._cond.wait(0.05)

    def _unreserve(self, k: int) -> None:
        if k:
            with self._cond:
                self._sent -= k
                self._cond.notify_all()

    def _note_in_flight(self) -> None:
        with self._cond:
            self.max_in_flight = max(self.max_in_flight, self._sent - self._acked)

    def write_batch(self, batch: RecordBatch) -> None:
        if batch.schema != self.in_schema:
            raise FlightError("batch schema mismatch on DoExchange stream")
        self._reserve(1)
        self._note_in_flight()
        try:
            self._conn.send_data(encode_batch(batch))
        except TimeoutError as e:  # socket.timeout subclasses OSError: first
            self._fail(FlightTimedOut(f"exchange send exceeded the call timeout: {e}"))
            self._raise_if_failed()
        except (ConnectionError, OSError) as e:
            self._fail(FlightUnavailable(f"exchange send failed: {e}"))
            self._raise_if_failed()

    def write_batches(self, batches: Iterable[RecordBatch]) -> None:
        """Windowed *and* coalesced: grab the free permits, send that many
        frames in one ``sendmsg`` burst."""
        it = iter(batches)
        while True:
            first = next(it, None)
            if first is None:
                return
            k = self._reserve(self.window)
            chunk = [first]
            while len(chunk) < k:
                nxt = next(it, None)
                if nxt is None:
                    break
                chunk.append(nxt)
            self._unreserve(k - len(chunk))  # iterator ran dry mid-grant
            self._note_in_flight()
            for b in chunk:
                if b.schema != self.in_schema:
                    self._unreserve(len(chunk))
                    raise FlightError("batch schema mismatch on DoExchange stream")
            try:
                self._conn.send_data_many(encode_batch(b) for b in chunk)
            except TimeoutError as e:
                self._fail(FlightTimedOut(f"exchange send exceeded the call timeout: {e}"))
                self._raise_if_failed()
            except (ConnectionError, OSError) as e:
                self._fail(FlightUnavailable(f"exchange send failed: {e}"))
                self._raise_if_failed()

    def done_writing(self) -> None:
        with self._cond:
            if self._eos_written:
                return
            self._eos_written = True
        try:
            self._conn.send_data(encode_eos())
        except TimeoutError as e:
            self._fail(FlightTimedOut(f"exchange send exceeded the call timeout: {e}"))
            self._raise_if_failed()
        except (ConnectionError, OSError) as e:
            self._fail(FlightUnavailable(f"exchange send failed: {e}"))
            self._raise_if_failed()

    def _release(self, clean: bool) -> None:
        if clean:
            # stream completed in protocol order: the channel is reusable
            self._client._reset_deadline(self._conn, self._options)
            self._client._checkin(self._conn)
        else:
            # frames may be in flight in either direction: never pool
            self._conn.close()


class InprocExchangeStream(ExchangeStreamBase):
    """The in-proc twin: a worker thread stands in for the peer server.

    Runs through the *same* middleware stack and service registry as the
    TCP path (auth middleware guards in-proc exchanges too, metrics count
    them), with bounded queues standing in for the socket — the input
    queue's bound is the window, so backpressure semantics match."""

    def __init__(self, server, descriptor: FlightDescriptor, in_schema: Schema,
                 token: str | None = None, options: CallOptions | None = None):
        super().__init__(in_schema, resolve_window(options))
        self._server = server
        self._descriptor = descriptor
        self._token = token
        self._options = options
        self._inq: queue.Queue = queue.Queue(maxsize=self.window)
        self.max_in_flight = 0
        self._ready = threading.Event()
        self._worker = threading.Thread(
            target=self._run, daemon=True, name="flight-exchange-inproc")
        self._worker.start()
        # TCP parity: auth/resolution failures refuse at open, not mid-read
        self._ready.wait()
        self._raise_if_failed()

    def _run(self) -> None:
        srv = self._server
        req = {
            "method": "DoExchange",
            "descriptor": self._descriptor.to_json(),
            "token": self._token,
            "options": self._options.to_json() if self._options else {},
        }
        state = {"in": 0, "rows_in": 0, "out": 0, "rows_out": 0}

        def inputs() -> Iterator[RecordBatch]:
            while True:
                try:
                    item = self._inq.get(timeout=0.1)
                except queue.Empty:
                    # backstop against an abandoned stream: once the client
                    # disposed (or failed) and the queue drained, no _EOS is
                    # coming — exit instead of leaking this thread forever
                    if self._disposed or self._err is not None:
                        return
                    continue
                if item is _EOS:
                    return
                state["in"] += 1
                state["rows_in"] += item.num_rows
                yield item

        def declare(s: Schema) -> None:
            with self._cond:
                self._out_schema = s
                self._cond.notify_all()

        try:
            with srv.middleware.wrap(srv._call_context("DoExchange", req)):
                service, params = srv.resolve_exchange(self._descriptor)
                service.check_params(params)  # pre-open refusal, like TCP
                self._ready.set()
                drive_exchange(service, self.in_schema, params, inputs(),
                               declare=declare, emit=self._emit, state=state)
            with self._cond:
                self.stats = {
                    "service": service.name,
                    "batches_in": state["in"],
                    "rows_in": state["rows_in"],
                    "batches_out": state["out"],
                    "rows_out": state["rows_out"],
                }
                self._finished = True
                self._cond.notify_all()
            self._emit(_EOS)
        except FlightError as e:
            self._fail(e)
        except Exception as e:  # service bug: surface as a typed error
            self._fail(FlightError(f"exchange failed: {e}"))
        finally:
            self._ready.set()

    def write_batch(self, batch: RecordBatch) -> None:
        if batch.schema != self.in_schema:
            raise FlightError("batch schema mismatch on DoExchange stream")
        self._put(batch)

    def done_writing(self) -> None:
        with self._cond:
            if self._eos_written:
                return
            self._eos_written = True
        self._put(_EOS)

    def _put(self, item) -> None:
        while True:
            self._raise_if_failed()
            try:
                self._inq.put(item, timeout=0.05)
                self.max_in_flight = max(self.max_in_flight, self._inq.qsize())
                return
            except queue.Full:
                continue

    def _release(self, clean: bool) -> None:
        if not clean:
            # wake a worker blocked on input it will never receive: drop
            # whatever the feeder queued, then deliver the poison pill (the
            # worker's own 0.1 s disposal poll is the backstop if a racing
            # feeder put lands after this drain)
            while True:
                try:
                    self._inq.get_nowait()
                except queue.Empty:
                    break
            try:
                self._inq.put_nowait(_EOS)
            except queue.Full:
                pass


def open_exchange(client, command, schema: Schema,
                  batches: Iterable[RecordBatch] | None = None,
                  options: CallOptions | None = None):
    """One-call exchange: open the stream for ``command`` (a service name,
    ``ExchangeCommand`` or descriptor) and, when ``batches`` is given, feed
    them on a relay thread.  Iterate the returned stream for the outputs."""
    stream = client.do_exchange_stream(
        as_exchange_descriptor(command), schema, options=options)
    if batches is not None:
        stream.feed(batches)
    return stream


class Pipeline:
    """Chained cross-server exchanges (Mallard's server→server pipelines).

    ``stages`` is a list of ``(client, command)`` pairs — each client a
    ``FlightClient`` (TCP or in-proc), each command a service name,
    ``ExchangeCommand`` or full descriptor.  ``run`` opens stage 1, feeds it
    from the source iterator on a relay thread, and as soon as its output
    schema frame arrives opens stage 2 fed by stage 1's output iterator,
    and so on: batches flow A→transform→B link by link, each link bounded
    by its own in-flight window — the pipeline never materializes a
    dataset client-side.  A failure anywhere aborts every downstream link
    and the final reader raises the original typed error."""

    def __init__(self, stages, options: CallOptions | None = None):
        if not stages:
            raise FlightError("pipeline needs at least one stage")
        self._stages = [(client, as_exchange_descriptor(cmd))
                        for client, cmd in stages]
        self._options = options
        self.streams: list[ExchangeStreamBase] = []

    def _stage_options(self) -> CallOptions | None:
        """Per-run CallOptions with the active trace context attached, so a
        traced caller's pipeline stitches one span per exchange stage (each
        server's middleware parents its ``DoExchange:<service>`` span here).
        Explicit trace headers in the pipeline's own options win."""
        trace = propagation_headers()
        if trace is None:
            return self._options
        base = self._options
        hdrs = dict(base.headers) if base is not None and base.headers else {}
        if HDR_TRACE in hdrs:
            return base
        hdrs.update(trace)
        if base is None:
            return CallOptions(headers=hdrs)
        return dataclasses.replace(base, headers=hdrs)

    def run(self, schema: Schema, batches: Iterable[RecordBatch]):
        """Start every link; returns the last stage's stream (iterate it)."""
        self.streams = []
        it: Iterable[RecordBatch] = batches
        cur_schema = schema
        options = self._stage_options()
        for client, desc in self._stages:
            stream = client.do_exchange_stream(desc, cur_schema,
                                               options=options)
            stream.feed(it)
            self.streams.append(stream)
            cur_schema = stream.out_schema  # blocks until the frame lands
            it = iter(stream)
        return self.streams[-1]

    def run_all(self, schema: Schema, batches: Iterable[RecordBatch]) -> Table:
        return self.run(schema, batches).read_all()

    def stats(self) -> list[dict]:
        """Per-stage server stats (available once the run completes)."""
        return [s.stats or {} for s in self.streams]

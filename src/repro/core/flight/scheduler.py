"""Parallel stream scheduler — the client-side engine behind the paper's
``GetFlightInfo → parallel DoGet`` topology (Figs 2/3) and its DoPut dual.

One ``FlightInfo`` names N endpoints; the scheduler opens one connection per
endpoint ``Location`` (clients are cached per location), pulls the streams
concurrently on a thread pool capped at ``max_streams``, and reassembles
RecordBatches either in endpoint order (``ordered=True``, deterministic) or
as they arrive (lowest latency).  A bounded per-stream window provides
backpressure: a fast producer blocks after ``window`` undrained batches
instead of buffering the dataset.

Fault handling exploits tickets being idempotent range reads:

* **failover** — a location that cannot be resolved or dies mid-stream is
  retried on the endpoint's next location, skipping already-emitted batches
  (resume, not duplicate);
* **hedging** — with ``hedge_after`` seconds and no completion, the same
  ticket is re-issued against replica locations and the first finisher wins
  (straggler mitigation, paper §4.2.2's InMemoryStore re-reads).  Note:
  racing two streams requires buffering each contender per endpoint, so
  hedged mode trades the bounded window for whole-endpoint buffers — size
  endpoints accordingly when enabling it.

The scheduler never imports the client module: anything satisfying
``FlightClientProtocol`` — verb methods that uniformly accept
``options: CallOptions | None = None`` — works, supplied through
``client_factory(location) -> client``.
"""
from __future__ import annotations

import queue
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, replace
from typing import Callable, Iterable, Iterator, Protocol, runtime_checkable

from ..recordbatch import RecordBatch, Table
from ..schema import Schema
from .protocol import (
    CallOptions,
    FlightDescriptor,
    FlightEndpoint,
    FlightError,
    FlightInfo,
    FlightTimedOut,
    FlightUnavailable,
    FlightUnavailableError,
    Location,
)


@runtime_checkable
class FlightClientProtocol(Protocol):
    """The formal client call contract the scheduler programs against.

    Every verb method accepts ``options: CallOptions | None = None`` —
    uniformly, by keyword — so the scheduler forwards its ``call_options``
    on every call instead of probing each client's signature.  Anything
    structurally matching works: ``FlightClient``, a test fake, a wrapper.
    ``do_exchange_stream`` is optional (checked explicitly at the exchange
    call site) so read/write-only clients stay valid scheduler targets.
    """

    def do_get(self, ticket, options: CallOptions | None = None) -> Iterable:
        ...

    def do_put(self, descriptor, schema, options: CallOptions | None = None):
        ...


@dataclass
class TransferStats:
    rows: int = 0
    bytes: int = 0
    seconds: float = 0.0
    streams: int = 1
    retries: int = 0  # location failovers taken
    hedges: int = 0   # hedge timers that fired

    @property
    def mb_per_s(self) -> float:
        return self.bytes / max(self.seconds, 1e-12) / 1e6


_EOS = object()


def _empty_batch(schema: Schema) -> RecordBatch:
    from ..array import Array

    return RecordBatch(schema, [Array.from_pylist([], f.type) for f in schema.fields])


class _Cancelled(Exception):
    pass


class ParallelStreamScheduler:
    def __init__(
        self,
        client_factory: Callable[[Location | None], object],
        max_streams: int = 8,
        ordered: bool = True,
        window: int = 4,
        hedge_after: float | None = None,
        hedge_factory: Callable[[Location], object] | None = None,
        call_options: CallOptions | None = None,
        put_retries: int = 0,
    ):
        self._factory = client_factory
        self._hedge_factory = hedge_factory
        self.max_streams = max(1, max_streams)
        self.ordered = ordered
        self.call_options = call_options
        if call_options is not None and call_options.read_window is not None:
            window = call_options.read_window
        self.window = max(1, window)
        self.hedge_after = hedge_after
        self.put_retries = max(0, put_retries)
        self._clients: dict[str, FlightClientProtocol] = {}
        self._client_lock = threading.Lock()
        self._stat_lock = threading.Lock()
        self.retries = 0
        self.hedges = 0

    def _do_get(self, client: FlightClientProtocol, ticket,
                options: CallOptions | None = None):
        """Issue DoGet.  ``FlightClientProtocol`` makes ``options`` part of
        the contract, so it is always forwarded — no signature probing."""
        return client.do_get(
            ticket, options=options if options is not None else self.call_options)

    def _endpoint_options(self, ep: FlightEndpoint) -> CallOptions | None:
        """Base CallOptions plus the trace context the planner stamped into
        the endpoint's ``app_metadata["trace"]`` (telemetry.py) — so every
        shard fetch stitches under the planning server's span instead of
        arriving untraced.  Explicit caller headers win on key collisions."""
        md = getattr(ep, "app_metadata", None)
        trace = md.get("trace") if isinstance(md, dict) else None
        if not isinstance(trace, dict):
            return self.call_options
        base = self.call_options
        if base is None:
            return CallOptions(headers=dict(trace))
        return replace(base, headers={**trace, **(base.headers or {})})

    def _do_put(self, client: FlightClientProtocol, descriptor, schema):
        """Open a DoPut stream, forwarding CallOptions unconditionally."""
        return client.do_put(descriptor, schema, options=self.call_options)

    def _do_exchange(self, client, descriptor, schema):
        """Open a streaming exchange.  ``do_exchange_stream`` is the one
        optional protocol method (read/write-only clients are still valid),
        so its absence is a typed refusal rather than an AttributeError."""
        opener = getattr(client, "do_exchange_stream", None)
        if opener is None:
            raise FlightError(
                f"client {type(client).__name__} does not support streaming exchange")
        return opener(descriptor, schema, options=self.call_options)

    def _bump(self, counter: str, n: int = 1) -> None:
        with self._stat_lock:
            setattr(self, counter, getattr(self, counter) + n)

    # -- connection cache -------------------------------------------------- #
    def _client(self, loc: Location | None, factory=None):
        key = loc.uri if loc is not None else "@default"
        factory = factory or self._factory
        if factory is not self._factory:
            key = "hedge:" + key
        with self._client_lock:
            if key not in self._clients:
                self._clients[key] = factory(loc)
            return self._clients[key]

    # -- one endpoint ------------------------------------------------------ #
    def _stream_endpoint(self, ep: FlightEndpoint, emit) -> None:
        """Emit the endpoint's batches once, failing over across locations.

        On a mid-stream failure the ticket is re-issued on the next location
        and the first ``emitted`` batches are skipped — range tickets make the
        re-read idempotent, so this is a resume."""
        locs: list[Location | None] = list(ep.locations) or [None]
        emitted = 0
        attempted = False
        last_err: Exception | None = None
        # attempt plan: every location through the primary factory, then —
        # when a separate factory exists — every location again through it,
        # so a failover can cross hosts even off a single-location endpoint
        plan: list[tuple[Location | None, object]] = [(loc, None) for loc in locs]
        if self._hedge_factory is not None and self._hedge_factory != self._factory:
            plan += [(loc, self._hedge_factory) for loc in locs]
        for loc, factory in plan:
            try:
                client = self._client(loc, factory=factory)
            except (FlightError, ConnectionError, OSError) as e:
                last_err = e  # unresolvable location (e.g. inproc seen remotely)
                continue
            if attempted:
                self._bump("retries")
            attempted = True
            try:
                reader = self._do_get(client, ep.ticket,
                                      self._endpoint_options(ep))
                seen = 0
                for b in reader:
                    seen += 1
                    if seen > emitted:
                        emit(b)
                        emitted += 1
                return
            except (FlightError, ConnectionError, OSError) as e:
                last_err = e
                continue
        raise FlightUnavailableError(
            f"endpoint exhausted {len(plan)} attempt(s) over "
            f"{len(locs)} location(s): {last_err}"
        )

    def _hedged_fetch(self, ep: FlightEndpoint) -> list[RecordBatch]:
        """Buffered endpoint read racing a primary against replica hedges."""
        locs: list[Location | None] = list(ep.locations) or [None]
        done = threading.Event()
        winner: list[list[RecordBatch]] = []
        ep_options = self._endpoint_options(ep)

        def attempt(client) -> list[RecordBatch]:
            return list(self._do_get(client, ep.ticket, ep_options))

        primary_client = None
        primary_loc: Location | None = None
        for loc in locs:  # first constructible location is the primary
            try:
                primary_client = self._client(loc)
                primary_loc = loc
                break
            except (FlightError, ConnectionError, OSError):
                continue

        def primary() -> None:
            if primary_client is None:
                return
            try:
                out = attempt(primary_client)
                if not done.is_set():
                    winner.append(out)
                    done.set()
            except (FlightError, ConnectionError, OSError):
                pass

        pt = threading.Thread(target=primary, daemon=True)
        pt.start()
        if not done.wait(self.hedge_after):
            self._bump("hedges")
            # replicas first — hedging exists to escape the primary's server;
            # its own location is only a last resort (fresh connection, same
            # host) when no replica is reachable
            hedge_order = [l for l in locs if l is not primary_loc]
            if primary_loc is not None:
                hedge_order.append(primary_loc)
            for loc in hedge_order:
                try:
                    client = self._client(loc, factory=self._hedge_factory)
                    out = attempt(client)
                    if not done.is_set():
                        winner.append(out)
                        done.set()
                    break
                except (FlightError, ConnectionError, OSError):
                    continue
            if not winner:
                # every hedge failed: the still-running primary is the only
                # remaining hope — wait for it to finish, not forever
                pt.join()
        if not winner:
            raise FlightUnavailableError("endpoint failed on primary and all hedges")
        return winner[0]

    # -- DoGet fan-in ------------------------------------------------------ #
    def stream(self, info: FlightInfo) -> Iterator[RecordBatch]:
        """Backpressured iterator over all endpoints' batches."""
        endpoints = list(info.endpoints)
        if not endpoints:
            return
        cancel = threading.Event()
        if self.ordered:
            queues = [queue.Queue(self.window) for _ in endpoints]
        else:
            shared: queue.Queue = queue.Queue(self.window * len(endpoints))
        errors: list[Exception] = []

        def emit_to(q):
            def emit(item):
                while True:
                    if cancel.is_set():
                        raise _Cancelled
                    try:
                        q.put(item, timeout=0.05)
                        return
                    except queue.Full:
                        continue

            return emit

        def worker(i: int, ep: FlightEndpoint) -> None:
            q = queues[i] if self.ordered else shared
            emit = emit_to(q)
            try:
                if self.hedge_after is None:
                    self._stream_endpoint(ep, emit)
                else:
                    for b in self._hedged_fetch(ep):
                        emit(b)
            except _Cancelled:
                return
            except Exception as e:  # surfaced to the consumer after drain
                errors.append(e)
            finally:
                try:
                    emit(_EOS)
                except _Cancelled:
                    pass

        pool = ThreadPoolExecutor(
            max_workers=min(self.max_streams, len(endpoints)),
            thread_name_prefix="flight-stream",
        )
        try:
            for i, ep in enumerate(endpoints):
                pool.submit(worker, i, ep)
            if self.ordered:
                for q in queues:
                    while True:
                        item = q.get()
                        if item is _EOS:
                            break
                        yield item
            else:
                open_streams = len(endpoints)
                while open_streams:
                    item = shared.get()
                    if item is _EOS:
                        open_streams -= 1
                    else:
                        yield item
            if errors:
                raise errors[0]
        finally:
            cancel.set()
            pool.shutdown(wait=False)

    def fetch(self, info: FlightInfo) -> tuple[Table, TransferStats]:
        r0, h0 = self.retries, self.hedges  # report this fetch's deltas only
        t0 = time.perf_counter()
        batches = list(self.stream(info))
        dt = time.perf_counter() - t0
        if not batches:
            batches = [_empty_batch(info.schema)]  # empty dataset, not an error
        table = Table(batches)
        return table, TransferStats(
            table.num_rows,
            table.nbytes(),
            dt,
            streams=min(self.max_streams, max(len(info.endpoints), 1)),
            retries=self.retries - r0,
            hedges=self.hedges - h0,
        )

    # -- DoPut fan-out ------------------------------------------------------ #
    def put(
        self,
        descriptor: FlightDescriptor | None,
        schema: Schema,
        assignments: list,
    ) -> TransferStats:
        """Write each (location, batches) shard on its own DoPut stream.

        Transient failures (``FlightUnavailable``, ``FlightTimedOut``, socket
        errors) are retried up to ``put_retries`` times per stream.  A retry
        may re-send a payload the server already committed, so retries
        default to 0: only enable them against servers with the content-hash
        dedup guard (``InMemoryFlightServer.dedup_puts``), which drops the
        duplicate and makes the retry idempotent.  Staged-put streams
        (descriptors carrying ``StagedPutCommand``) get the same protection
        from in-txn content-hash dedup — which is likewise gated on the
        server's ``dedup_puts`` flag, so against ``dedup_puts=False``
        servers a stage-leg retry can duplicate rows inside the txn just as
        a plain-put retry would.

        An assignment is ``(location, batches)`` or ``(location, batches,
        descriptor)`` — the 3-tuple form lets one fan-out write different
        datasets per stream (a replicated writer targets each slice's own
        storage key), in which case the top-level ``descriptor`` may be
        ``None``."""
        assignments = [
            (a[0], a[1], a[2] if len(a) > 2 else descriptor)
            for a in assignments if a[1]
        ]
        if not assignments:
            return TransferStats(streams=0)
        t0 = time.perf_counter()

        def write_once(loc: Location | None, shard: list[RecordBatch],
                       desc: FlightDescriptor) -> None:
            w = self._do_put(self._client(loc), desc, schema)
            # the scheduler's writer contract is write_batch/close (see module
            # docstring: any client works); write_batches is an optional
            # extension for coalesced frames
            write_many = getattr(w, "write_batches", None)
            if write_many is not None:
                write_many(shard)
            else:
                for b in shard:
                    w.write_batch(b)
            w.close()

        def write(loc: Location | None, shard: list[RecordBatch],
                  desc: FlightDescriptor) -> None:
            for attempt in range(self.put_retries + 1):
                try:
                    write_once(loc, shard, desc)
                    return
                except (FlightUnavailable, FlightTimedOut, ConnectionError, OSError):
                    if attempt == self.put_retries:
                        raise
                    self._bump("retries")

        with ThreadPoolExecutor(
            max_workers=min(self.max_streams, len(assignments)),
            thread_name_prefix="flight-put",
        ) as pool:
            futs = [pool.submit(write, loc, bs, d) for loc, bs, d in assignments]
            for f in futs:
                f.result()
        dt = time.perf_counter() - t0
        all_batches = [b for _, bs, _ in assignments for b in bs]
        return TransferStats(
            sum(b.num_rows for b in all_batches),
            sum(b.nbytes() for b in all_batches),
            dt,
            streams=len(assignments),
        )

    # -- DoExchange fan-out -------------------------------------------------- #
    def exchange(
        self,
        descriptor: FlightDescriptor,
        schema: Schema,
        assignments: list[tuple[Location | None, list[RecordBatch]]],
    ) -> tuple[Schema | None, list[RecordBatch], TransferStats]:
        """Run one bidirectional exchange per (location, batches) assignment
        in parallel — the paper's parallel-stream recipe applied to the
        microservice verb.  Each stream feeds its slice on a relay thread
        while this side collects the transformed output; results come back
        in assignment order.  Returns ``(out_schema, batches, stats)`` with
        ``stats.bytes`` counting BOTH directions (the bidirectional figure
        of merit) and ``stats.rows`` counting the transformed output."""
        assignments = [(loc, bs) for loc, bs in assignments if bs]
        if not assignments:
            return None, [], TransferStats(streams=0)
        t0 = time.perf_counter()
        results: list[list[RecordBatch] | None] = [None] * len(assignments)
        schemas: list[Schema | None] = [None] * len(assignments)

        def work(i: int, loc: Location | None, shard: list[RecordBatch]) -> None:
            stream = self._do_exchange(self._client(loc), descriptor, schema)
            stream.feed(shard)
            results[i] = list(stream)
            schemas[i] = stream.out_schema

        with ThreadPoolExecutor(
            max_workers=min(self.max_streams, len(assignments)),
            thread_name_prefix="flight-exchange",
        ) as pool:
            futs = [pool.submit(work, i, loc, bs)
                    for i, (loc, bs) in enumerate(assignments)]
            for f in futs:
                f.result()
        dt = time.perf_counter() - t0
        out = [b for r in results if r for b in r]
        bytes_in = sum(b.nbytes() for _, bs in assignments for b in bs)
        bytes_out = sum(b.nbytes() for b in out)
        return schemas[0], out, TransferStats(
            sum(b.num_rows for b in out),
            bytes_in + bytes_out,
            dt,
            streams=len(assignments),
        )

"""Arrow-Flight-style RPC: protocol, transports, server, client, netsim."""
from .client import FlightClient, FlightExchange, FlightStreamReader, TransferStats  # noqa: F401
from .protocol import (  # noqa: F401
    Action,
    ActionResult,
    FlightDescriptor,
    FlightEndpoint,
    FlightError,
    FlightInfo,
    FlightUnavailableError,
    Location,
    Ticket,
)
from .server import FlightServerBase, InMemoryFlightServer  # noqa: F401

"""Arrow-Flight-style RPC: protocol, transports, server, client, scheduler,
cluster, netsim."""
from .client import FlightClient, FlightExchange, FlightStreamReader  # noqa: F401
from .cluster import (  # noqa: F401
    FlightClusterClient,
    FlightClusterServer,
    HashPlacement,
    Placement,
    RoundRobinPlacement,
    make_placement,
)
from .protocol import (  # noqa: F401
    Action,
    ActionResult,
    FlightDescriptor,
    FlightEndpoint,
    FlightError,
    FlightInfo,
    FlightUnavailableError,
    Location,
    ShardSpec,
    Ticket,
)
from .scheduler import ParallelStreamScheduler, TransferStats  # noqa: F401
from .server import FlightServerBase, InMemoryFlightServer  # noqa: F401

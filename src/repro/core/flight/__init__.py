"""Arrow-Flight-style RPC: protocol, transports, server, client, scheduler,
cluster, membership/replication, fault injection, middleware, typed errors,
streaming exchange services, netsim."""
from .client import FlightClient, FlightExchange, FlightStreamReader  # noqa: F401
from .faultsim import FaultInjector  # noqa: F401
from .membership import (  # noqa: F401
    ClusterMembership,
    ClusterView,
    MembershipProber,
    ShardState,
)
from .replication import (  # noqa: F401
    DatasetLayout,
    ReplicatedPlacement,
    SliceInfo,
    parse_slice_key,
    plan_layout,
    recover_layouts,
    slice_key,
    subtxn_id,
)
from .exchange import (  # noqa: F401
    FlightExchangeStream,
    InprocExchangeStream,
    Pipeline,
    as_exchange_descriptor,
    open_exchange,
)
from .cluster import (  # noqa: F401
    FlightClusterClient,
    FlightClusterServer,
    HashPlacement,
    Placement,
    RoundRobinPlacement,
    make_placement,
)
from .errors import (  # noqa: F401
    FlightError,
    FlightInvalidArgument,
    FlightNotFound,
    FlightTimedOut,
    FlightUnauthenticated,
    FlightUnavailable,
    FlightUnavailableError,
    error_from_wire,
)
from .middleware import (  # noqa: F401
    AuthTokenMiddleware,
    CallContext,
    LoggingMiddleware,
    MetricsMiddleware,
    MiddlewareStack,
    ServerMiddleware,
)
from .protocol import (  # noqa: F401
    Action,
    ActionResult,
    CallOptions,
    Command,
    ExchangeCommand,
    FlightDescriptor,
    FlightEndpoint,
    FlightInfo,
    Location,
    QueryCommand,
    RangeReadCommand,
    ShardSpec,
    StagedPutCommand,
    Ticket,
    parse_command,
)
from .scheduler import (  # noqa: F401
    FlightClientProtocol,
    ParallelStreamScheduler,
    TransferStats,
)
from .server import (  # noqa: F401
    FlightServerBase,
    InMemoryFlightServer,
    ServerConfig,
    parse_txn_body,
)
from .telemetry import (  # noqa: F401
    HDR_PARENT,
    HDR_SPAN,
    HDR_TRACE,
    LogHistogram,
    ServerTelemetry,
    Span,
    TraceContext,
    Tracer,
    batch_to_rows,
    batch_to_spans,
    decode_telemetry_batch,
    metrics_to_batch,
    spans_to_batch,
)
from .storage import (  # noqa: F401
    DiskStorageProvider,
    MemoryStorageProvider,
    RemoteFlightProvider,
    StagedEntry,
    StorageProvider,
    make_provider,
)
from .services import (  # noqa: F401
    EchoService,
    ExchangeService,
    ExchangeServiceRegistry,
    FilterService,
    MapBatchesService,
    ProjectService,
    RepartitionService,
    ScoreService,
)

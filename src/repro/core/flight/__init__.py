"""Arrow-Flight-style RPC: protocol, transports, server, client, scheduler,
cluster, middleware, typed errors, netsim."""
from .client import FlightClient, FlightExchange, FlightStreamReader  # noqa: F401
from .cluster import (  # noqa: F401
    FlightClusterClient,
    FlightClusterServer,
    HashPlacement,
    Placement,
    RoundRobinPlacement,
    make_placement,
)
from .errors import (  # noqa: F401
    FlightError,
    FlightInvalidArgument,
    FlightNotFound,
    FlightTimedOut,
    FlightUnauthenticated,
    FlightUnavailable,
    FlightUnavailableError,
    error_from_wire,
)
from .middleware import (  # noqa: F401
    AuthTokenMiddleware,
    CallContext,
    LoggingMiddleware,
    MetricsMiddleware,
    MiddlewareStack,
    ServerMiddleware,
)
from .protocol import (  # noqa: F401
    Action,
    ActionResult,
    CallOptions,
    Command,
    FlightDescriptor,
    FlightEndpoint,
    FlightInfo,
    Location,
    QueryCommand,
    RangeReadCommand,
    ShardSpec,
    StagedPutCommand,
    Ticket,
    parse_command,
)
from .scheduler import ParallelStreamScheduler, TransferStats  # noqa: F401
from .server import (  # noqa: F401
    FlightServerBase,
    InMemoryFlightServer,
    parse_txn_body,
)

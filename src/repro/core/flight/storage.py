"""Storage provider plane — pluggable dataset backends behind the server.

``InMemoryFlightServer`` used to *be* its store: datasets lived in dicts, a
restart lost the world, and PR 4's transactional staging was RAM-only.  This
module splits storage out behind a ``StorageProvider`` interface (the
fal-teller provider pattern: a small ``read/write/append/drop/info/list``
contract plus staging hooks), so the serving layer — verbs, middleware,
encode-once cache, the 2PC protocol — is backend-agnostic:

* ``MemoryStorageProvider`` — the historical behavior: dataset name ->
  ``list[RecordBatch]``, zero-copy, nothing survives the process.
* ``DiskStorageProvider``   — datasets spill to Arrow-IPC stream files (the
  0xB1 binary codec from ``core/ipc.py``) and re-serve **mmap-backed**:
  decoded batches are views into the page cache, so feeding the server's
  encode-once cache never materializes a second copy of value data.
  Transactional stages land as files under ``.staging/<txn>/`` and commit
  is an ``os.rename`` into the dataset directory — which is what makes the
  two-phase put *durable*: a server recreated on the same root recovers
  both committed datasets and prepared-but-uncommitted stages.
* ``RemoteFlightProvider``  — forwards every call to another Flight
  endpoint (tiered serving: a front server whose "store" is a remote
  cluster; reads proxy DoGet, writes proxy DoPut, staging proxies the
  staged-put/txn actions).

Concurrency contract: providers are driven by exactly one server, which
holds its store lock across every mutating call — providers need no
internal locking beyond what their own lazily-built caches require.

On-disk layout (``DiskStorageProvider(root)``)::

    root/
      datasets/<quoted-name>/part-00000000-<nonce>.arrow   # IPC stream files
      .staging/<quoted-txn>/meta.json                      # {dataset, prepared}
      .staging/<quoted-txn>/part-00000000-<nonce>.arrow    # staged streams
      .tmp/                                                # write-then-rename

Every part file is a complete IPC stream (schema + batches + EOS); a
dataset's batch order is its part files in name order, each part's batches
in stream order.  Writes go to ``.tmp`` first and ``os.rename`` into place,
so a reader (or a crash) never observes a half-written part.  Committing a
txn renames its staged part files into the dataset directory — data is
never re-copied on commit.  See docs/providers.md.
"""
from __future__ import annotations

import json
import os
import shutil
import time
import uuid
from dataclasses import dataclass, field
from typing import Iterable
from urllib.parse import quote, unquote

import numpy as np

from ..buffer import Buffer
from ..ipc import read_stream_with_schema, write_stream
from ..recordbatch import RecordBatch
from ..schema import Schema
from .errors import FlightInvalidArgument, FlightNotFound

_PART_FMT = "part-{seq:08d}-{nonce}.arrow"


@dataclass
class StagedEntry:
    """One recovered/live staged transaction as a provider reports it."""

    dataset: str
    schema: Schema
    batches: int = 0
    rows: int = 0
    nbytes: int = 0
    prepared: bool = False


class StorageProvider:
    """Backend contract for a Flight server's dataset store.

    All methods are called under the owning server's store lock (see module
    docstring).  ``name`` is an opaque dataset key; providers must accept
    any string.  Unknown datasets raise ``FlightNotFound`` from the read
    side (``schema``/``read_batches``/``info``); ``drop`` is idempotent.
    """

    kind = "?"

    # -- catalog ---------------------------------------------------------- #
    def list(self) -> list[str]:
        raise NotImplementedError

    def exists(self, name: str) -> bool:
        raise NotImplementedError

    def schema(self, name: str) -> Schema:
        raise NotImplementedError

    def info(self, name: str) -> dict:
        """``{"batches", "rows", "bytes"}`` for one dataset."""
        raise NotImplementedError

    # -- data ------------------------------------------------------------- #
    def read_batches(self, name: str, start: int = 0,
                     stop: int | None = None) -> list[RecordBatch]:
        raise NotImplementedError

    def append(self, name: str, schema: Schema,
               batches: Iterable[RecordBatch]) -> None:
        raise NotImplementedError

    def replace(self, name: str, schema: Schema,
                batches: Iterable[RecordBatch]) -> None:
        """``add_dataset`` semantics: drop whatever exists, then append."""
        self.drop(name)
        self.append(name, schema, batches)

    def drop(self, name: str) -> None:
        raise NotImplementedError

    # -- durable transactional staging ------------------------------------ #
    # The *protocol* (votes, idempotency windows, TTL GC) lives in the
    # server; providers supply the durability primitives underneath it.
    def stage(self, txn_id: str, dataset: str, schema: Schema,
              batches: list[RecordBatch]) -> None:
        raise NotImplementedError

    def commit_stage(self, txn_id: str) -> None:
        """Make the txn's staged payload part of its dataset (atomically for
        single-stream stages on disk: one ``os.rename``)."""
        raise NotImplementedError

    def discard_stage(self, txn_id: str) -> None:
        raise NotImplementedError

    def mark_prepared(self, txn_id: str) -> None:
        """Durably record a phase-1 yes vote (no-op for volatile backends)."""

    def staged_txns(self) -> dict[str, StagedEntry]:
        """Stages this provider holds — including ones recovered from a
        previous process for durable backends."""
        return {}

    # -- introspection ----------------------------------------------------- #
    def stats(self) -> dict:
        """Provider-kind block surfaced under ``server-stats["storage"]``."""
        return {"kind": self.kind, "datasets": len(self.list())}

    def close(self) -> None:
        """Release backend handles (sockets, mmaps).  Idempotent."""


# --------------------------------------------------------------------------
# memory
# --------------------------------------------------------------------------


class MemoryStorageProvider(StorageProvider):
    """The historical in-process store: ``name -> list[RecordBatch]``."""

    kind = "memory"

    def __init__(self):
        self._store: dict[str, list[RecordBatch]] = {}
        self._schemas: dict[str, Schema] = {}
        self._staged: dict[str, tuple[str, Schema, list[RecordBatch]]] = {}

    def list(self) -> list[str]:
        return list(self._store)

    def exists(self, name: str) -> bool:
        return name in self._store

    def _require(self, name: str) -> list[RecordBatch]:
        if name not in self._store:
            raise FlightNotFound(f"no such dataset: {name}", detail={"dataset": name})
        return self._store[name]

    def schema(self, name: str) -> Schema:
        self._require(name)
        return self._schemas[name]

    def info(self, name: str) -> dict:
        bs = self._require(name)
        return {"batches": len(bs), "rows": sum(b.num_rows for b in bs),
                "bytes": sum(b.nbytes() for b in bs)}

    def read_batches(self, name, start=0, stop=None):
        return self._require(name)[start:stop]

    def append(self, name, schema, batches) -> None:
        self._store.setdefault(name, []).extend(batches)
        self._schemas.setdefault(name, schema)

    def replace(self, name, schema, batches) -> None:
        self._store[name] = list(batches)
        self._schemas[name] = schema

    def drop(self, name) -> None:
        self._store.pop(name, None)
        self._schemas.pop(name, None)

    def stage(self, txn_id, dataset, schema, batches) -> None:
        entry = self._staged.get(txn_id)
        if entry is None:
            self._staged[txn_id] = (dataset, schema, list(batches))
        else:
            entry[2].extend(batches)

    def commit_stage(self, txn_id) -> None:
        if txn_id not in self._staged:
            raise FlightNotFound(f"no staged txn {txn_id!r}",
                                 detail={"txn_id": txn_id})
        dataset, schema, batches = self._staged.pop(txn_id)
        self.append(dataset, schema, batches)

    def discard_stage(self, txn_id) -> None:
        self._staged.pop(txn_id, None)

    def staged_txns(self) -> dict[str, StagedEntry]:
        return {
            t: StagedEntry(ds, sch, len(bs), sum(b.num_rows for b in bs),
                           sum(b.nbytes() for b in bs))
            for t, (ds, sch, bs) in self._staged.items()
        }


# --------------------------------------------------------------------------
# disk
# --------------------------------------------------------------------------


@dataclass
class _DiskDataset:
    """Decoded view of one on-disk dataset (batches are mmap-backed)."""

    schema: Schema
    batches: list[RecordBatch] = field(default_factory=list)


class DiskStorageProvider(StorageProvider):
    """Arrow-IPC part files under ``root`` — spill on write, mmap on read.

    Writes are write-to-``.tmp``-then-rename, so parts are all-or-nothing.
    Reads mmap each part once and keep the *decoded* batches cached: their
    buffers are zero-copy views into the mapping, so the cache costs
    metadata, not data — the page cache owns the bytes, and datasets larger
    than RAM page in on demand.  Counters: ``spills``/``spill_bytes``
    (part files written), ``mmap_reads`` (part files mapped).
    """

    kind = "disk"

    def __init__(self, root: str | os.PathLike):
        self.root = os.fspath(root)
        self._datasets_dir = os.path.join(self.root, "datasets")
        self._staging_dir = os.path.join(self.root, ".staging")
        self._tmp_dir = os.path.join(self.root, ".tmp")
        for d in (self._datasets_dir, self._staging_dir, self._tmp_dir):
            os.makedirs(d, exist_ok=True)
        # decoded mmap-backed batches per dataset, dropped on any mutation
        self._decoded: dict[str, _DiskDataset] = {}
        self._mmaps: list[np.memmap] = []  # keep mappings alive explicitly
        self.spills = 0
        self.spill_bytes = 0
        self.mmap_reads = 0
        self.recovered_datasets = len(self.list())
        self.recovered_stages = len(self._stage_dirs())

    # -- paths ------------------------------------------------------------- #
    def _dataset_dir(self, name: str) -> str:
        return os.path.join(self._datasets_dir, quote(name, safe=""))

    def _parts(self, d: str) -> list[str]:
        if not os.path.isdir(d):
            return []
        return sorted(f for f in os.listdir(d) if f.endswith(".arrow"))

    def _next_seq(self, d: str) -> int:
        parts = self._parts(d)
        return int(parts[-1].split("-")[1]) + 1 if parts else 0

    def _write_part(self, dest_dir: str, seq: int, schema: Schema,
                    batches: list[RecordBatch]) -> str:
        payload = write_stream(batches, schema=schema)
        tmp = os.path.join(self._tmp_dir, uuid.uuid4().hex)
        with open(tmp, "wb") as f:
            f.write(payload)
        os.makedirs(dest_dir, exist_ok=True)
        dest = os.path.join(
            dest_dir, _PART_FMT.format(seq=seq, nonce=uuid.uuid4().hex[:6]))
        os.rename(tmp, dest)
        self.spills += 1
        self.spill_bytes += len(payload)
        return dest

    def _load(self, name: str) -> _DiskDataset:
        entry = self._decoded.get(name)
        if entry is not None:
            return entry
        d = self._dataset_dir(name)
        parts = self._parts(d)
        if not parts:
            raise FlightNotFound(f"no such dataset: {name}", detail={"dataset": name})
        schema, batches = None, []
        for p in parts:
            s, bs = self._mmap_stream(os.path.join(d, p))
            schema = schema or s
            batches.extend(bs)
        entry = _DiskDataset(schema, batches)
        self._decoded[name] = entry
        return entry

    def _mmap_stream(self, path: str) -> tuple[Schema, list[RecordBatch]]:
        mm = np.memmap(path, dtype=np.uint8, mode="r")
        self._mmaps.append(mm)
        self.mmap_reads += 1
        return read_stream_with_schema(Buffer(mm))

    # -- catalog ----------------------------------------------------------- #
    def list(self) -> list[str]:
        return sorted(
            unquote(n) for n in os.listdir(self._datasets_dir)
            if self._parts(os.path.join(self._datasets_dir, n))
        )

    def exists(self, name: str) -> bool:
        return bool(self._parts(self._dataset_dir(name)))

    def schema(self, name: str) -> Schema:
        return self._load(name).schema

    def info(self, name: str) -> dict:
        bs = self._load(name).batches
        return {"batches": len(bs), "rows": sum(b.num_rows for b in bs),
                "bytes": sum(b.nbytes() for b in bs)}

    # -- data --------------------------------------------------------------- #
    def read_batches(self, name, start=0, stop=None):
        return self._load(name).batches[start:stop]

    def append(self, name, schema, batches) -> None:
        d = self._dataset_dir(name)
        self._write_part(d, self._next_seq(d), schema, list(batches))
        self._decoded.pop(name, None)

    def replace(self, name, schema, batches) -> None:
        self.drop(name)
        self.append(name, schema, batches)

    def drop(self, name) -> None:
        d = self._dataset_dir(name)
        if os.path.isdir(d):
            shutil.rmtree(d)
        self._decoded.pop(name, None)

    # -- staging ------------------------------------------------------------ #
    def _txn_dir(self, txn_id: str) -> str:
        return os.path.join(self._staging_dir, quote(txn_id, safe=""))

    def _stage_dirs(self) -> list[str]:
        return sorted(
            os.path.join(self._staging_dir, n)
            for n in os.listdir(self._staging_dir)
            if os.path.isdir(os.path.join(self._staging_dir, n))
        )

    def _meta(self, txn_dir: str) -> dict:
        try:
            with open(os.path.join(txn_dir, "meta.json")) as f:
                return json.load(f)
        except (OSError, json.JSONDecodeError):
            return {}

    def stage(self, txn_id, dataset, schema, batches) -> None:
        d = self._txn_dir(txn_id)
        os.makedirs(d, exist_ok=True)
        meta_path = os.path.join(d, "meta.json")
        if not os.path.exists(meta_path):
            with open(meta_path, "w") as f:
                json.dump({"dataset": dataset, "prepared": False}, f)
        self._write_part(d, self._next_seq(d), schema, list(batches))

    def mark_prepared(self, txn_id) -> None:
        d = self._txn_dir(txn_id)
        meta = self._meta(d)
        if meta:
            meta["prepared"] = True
            with open(os.path.join(d, "meta.json"), "w") as f:
                json.dump(meta, f)

    def commit_stage(self, txn_id) -> None:
        """Rename staged part files into the dataset directory — the commit
        never re-reads or re-writes payload bytes.  A single-stream stage
        (one part file) is one atomic ``os.rename``."""
        d = self._txn_dir(txn_id)
        meta = self._meta(d)
        if "dataset" not in meta:
            raise FlightNotFound(f"no staged txn {txn_id!r} on disk",
                                 detail={"txn_id": txn_id})
        dest = self._dataset_dir(meta["dataset"])
        os.makedirs(dest, exist_ok=True)
        seq = self._next_seq(dest)
        for p in self._parts(d):
            os.rename(os.path.join(d, p),
                      os.path.join(dest, _PART_FMT.format(
                          seq=seq, nonce=uuid.uuid4().hex[:6])))
            seq += 1
        shutil.rmtree(d)
        self._decoded.pop(meta["dataset"], None)

    def discard_stage(self, txn_id) -> None:
        d = self._txn_dir(txn_id)
        if os.path.isdir(d):
            shutil.rmtree(d)

    def staged_txns(self) -> dict[str, StagedEntry]:
        out: dict[str, StagedEntry] = {}
        for d in self._stage_dirs():
            meta = self._meta(d)
            parts = self._parts(d)
            if "dataset" not in meta or not parts:
                continue
            schema, batches = None, []
            for p in parts:
                s, bs = self._mmap_stream(os.path.join(d, p))
                schema = schema or s
                batches.extend(bs)
            out[unquote(os.path.basename(d))] = StagedEntry(
                meta["dataset"], schema, len(batches),
                sum(b.num_rows for b in batches),
                sum(b.nbytes() for b in batches),
                prepared=bool(meta.get("prepared")),
            )
        return out

    # -- introspection ------------------------------------------------------- #
    def disk_bytes(self) -> int:
        total = 0
        for base in (self._datasets_dir, self._staging_dir):
            for dirpath, _dirs, files in os.walk(base):
                for f in files:
                    try:
                        total += os.path.getsize(os.path.join(dirpath, f))
                    except OSError:
                        pass
        return total

    def stats(self) -> dict:
        return {
            "kind": self.kind,
            "root": self.root,
            "datasets": len(self.list()),
            "disk_bytes": self.disk_bytes(),
            "spills": self.spills,
            "spill_bytes": self.spill_bytes,
            "mmap_reads": self.mmap_reads,
            "staged_txns_on_disk": len(self._stage_dirs()),
            "recovered_datasets": self.recovered_datasets,
            "recovered_stages": self.recovered_stages,
        }

    def close(self) -> None:
        self._decoded.clear()
        self._mmaps.clear()


# --------------------------------------------------------------------------
# remote Flight proxy
# --------------------------------------------------------------------------


class RemoteFlightProvider(StorageProvider):
    """A provider whose backend is *another Flight endpoint* (tiered serving).

    Reads redeem range tickets against the remote, writes open DoPut
    streams, and the staging hooks forward the staged-put/txn protocol —
    so a front server can serve (and transactionally ingest into) a
    dataset that physically lives on a remote server or cluster.  Staging
    durability is the remote's concern: ``staged_txns`` reports nothing,
    because recovery belongs to the endpoint that owns the bytes.

    Unreachability always surfaces as the *typed* ``FlightUnavailable``
    (never a raw ``ConnectionError``/``OSError``, whatever client object
    backs the provider), so callers can catch one error for "the tier
    behind me is down".  ``retries`` bounds transparent re-dials of
    transient failures — each retry backs off ``retry_backoff * 2**attempt``
    seconds.  The default is 0: non-idempotent writes should not silently
    re-send unless the operator opted in against a dedup-guarded remote.
    """

    kind = "remote"

    def __init__(self, target, token: str | None = None,
                 retries: int = 0, retry_backoff: float = 0.05):
        # lazy import: client.py imports server.py which imports storage.py
        from .client import FlightClient

        self.target = getattr(target, "uri", target)
        self._client = (target if isinstance(target, FlightClient)
                        else FlightClient(target, token=token))
        self._txn_datasets: dict[str, str] = {}
        self.retries = retries
        self.retry_backoff = retry_backoff
        self.retried_calls = 0
        self.proxied_reads = 0
        self.proxied_writes = 0

    def _call(self, fn):
        """Run one remote interaction under the retry/typing policy."""
        from .protocol import FlightTimedOut, FlightUnavailable

        for attempt in range(self.retries + 1):
            try:
                return fn()
            except (FlightUnavailable, FlightTimedOut, ConnectionError, OSError) as e:
                if attempt == self.retries:
                    if isinstance(e, (FlightUnavailable, FlightTimedOut)):
                        raise
                    # belt-and-braces: a non-mapping client object leaked a
                    # raw socket error — type it at the provider boundary
                    raise FlightUnavailable(
                        f"remote tier {self.target!r} unreachable: {e}",
                        detail={"target": str(self.target)}) from e
                self.retried_calls += 1
                time.sleep(self.retry_backoff * (2 ** attempt))

    # -- catalog ----------------------------------------------------------- #
    def list(self) -> list[str]:
        from .protocol import Action

        names = self._call(
            lambda: self._client.do_action(Action("list-names")))[0].body.decode()
        return [n for n in names.split(",") if n]

    def exists(self, name: str) -> bool:
        return name in self.list()

    def schema(self, name: str) -> Schema:
        from .protocol import FlightDescriptor

        return self._call(lambda: self._client.get_flight_info(
            FlightDescriptor.for_path(name))).schema

    def info(self, name: str) -> dict:
        from .protocol import Action

        stats = json.loads(self._call(
            lambda: self._client.do_action(Action("stats")))[0].body)
        if name not in stats:
            raise FlightNotFound(f"no such dataset: {name}", detail={"dataset": name})
        return stats[name]

    # -- data --------------------------------------------------------------- #
    def read_batches(self, name, start=0, stop=None):
        from .protocol import Ticket

        self.proxied_reads += 1
        stop_ix = -1 if stop is None else stop
        return self._call(
            lambda: list(self._client.do_get(Ticket.for_range(name, start, stop_ix))))

    def _put(self, descriptor, schema, batches) -> None:
        payload = list(batches)

        def put_once():
            w = self._client.do_put(descriptor, schema)
            w.write_batches(payload)
            w.close()

        # NB: a retried plain put re-sends the payload — idempotent only
        # against a dedup-guarded remote (retries default to 0 for a reason)
        self._call(put_once)
        self.proxied_writes += 1

    def append(self, name, schema, batches) -> None:
        from .protocol import FlightDescriptor

        self._put(FlightDescriptor.for_path(name), schema, batches)

    def replace(self, name, schema, batches) -> None:
        self.drop(name)
        self.append(name, schema, batches)

    def drop(self, name) -> None:
        from .protocol import Action

        self._call(lambda: self._client.do_action(Action("drop", name.encode())))

    # -- staging ------------------------------------------------------------ #
    def stage(self, txn_id, dataset, schema, batches) -> None:
        from .protocol import FlightDescriptor, StagedPutCommand

        self._txn_datasets[txn_id] = dataset
        self._put(FlightDescriptor.for_command(
            StagedPutCommand(dataset, txn_id, "stage")), schema, batches)

    def _txn_action(self, verb: str, txn_id: str) -> None:
        from .protocol import Action

        body = json.dumps({
            "txn_id": txn_id,
            "dataset": self._txn_datasets.get(txn_id, ""),
        }).encode()
        self._call(lambda: self._client.do_action(Action(verb, body)))

    def mark_prepared(self, txn_id) -> None:
        self._txn_action("txn-prepare", txn_id)

    def commit_stage(self, txn_id) -> None:
        self._txn_action("txn-commit", txn_id)
        self._txn_datasets.pop(txn_id, None)

    def discard_stage(self, txn_id) -> None:
        self._txn_action("txn-abort", txn_id)
        self._txn_datasets.pop(txn_id, None)

    # -- introspection ------------------------------------------------------- #
    def stats(self) -> dict:
        return {
            "kind": self.kind,
            "target": str(self.target),
            "datasets": len(self.list()),
            "proxied_reads": self.proxied_reads,
            "proxied_writes": self.proxied_writes,
            "retries": self.retries,
            "retried_calls": self.retried_calls,
        }


# --------------------------------------------------------------------------
# construction
# --------------------------------------------------------------------------


def make_provider(storage) -> StorageProvider:
    """Resolve a ``ServerConfig.storage`` value into a provider.

    * ``None`` / ``"memory"``  -> ``MemoryStorageProvider``
    * ``"disk:<root>"``        -> ``DiskStorageProvider(root)``
    * ``"remote:<uri>"``       -> ``RemoteFlightProvider(uri)``
    * a ``StorageProvider``    -> returned as-is
    """
    if storage is None or storage == "memory":
        return MemoryStorageProvider()
    if isinstance(storage, StorageProvider):
        return storage
    if isinstance(storage, str):
        if storage.startswith("disk:"):
            return DiskStorageProvider(storage[len("disk:"):])
        if storage.startswith("remote:"):
            return RemoteFlightProvider(storage[len("remote:"):])
        raise FlightInvalidArgument(
            f"unknown storage spec {storage!r} "
            f"(want 'memory', 'disk:<root>', 'remote:<uri>', or a provider)")
    raise FlightInvalidArgument(f"cannot build a storage provider from {storage!r}")

"""Server middleware: a composable interception chain around verb dispatch.

Arrow Flight lets servers install middleware that observes/steers every RPC
(auth, tracing, metrics) without touching handlers; this is our equivalent.
``FlightServerBase`` runs each incoming RPC through a ``MiddlewareStack``:

* ``on_call(ctx)`` runs front-to-back *before* the verb handler; raising a
  ``FlightError`` short-circuits the call (later middleware and the handler
  never run) and the typed error goes back over the wire.
* ``on_complete(ctx, error)`` runs back-to-front *after* the handler (or the
  short-circuit) for every middleware whose ``on_call`` was invoked —
  ``error`` is ``None`` on success.

``CallContext.state`` is a per-call scratch dict middleware can use to pass
data between its two hooks (e.g. a start timestamp) or to later middleware.

The hard-coded ``_check_auth`` of earlier revisions is now just
``AuthTokenMiddleware`` installed by the server when ``auth_token`` is set.
"""
from __future__ import annotations

import threading
import time
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Callable

from .errors import FlightError, FlightUnauthenticated
from .telemetry import LogHistogram, ServerTelemetry, TraceContext


def _exchange_service_label(request: dict) -> str:
    """Which exchange service a DoExchange request names (metrics key).

    Best-effort: a label must never fail the call, so malformed descriptors
    degrade to ``"?"`` (the serve path rejects them with a typed error)."""
    d = request.get("descriptor") or {}
    path = d.get("path")
    if path:
        return "path:" + "/".join(path)
    raw = d.get("command")
    if raw:
        from .protocol import ExchangeCommand, parse_command  # lazy: keeps import light

        try:
            cmd = parse_command(raw.encode("latin1") if isinstance(raw, str) else raw)
        except Exception:
            return "?"
        return cmd.service if isinstance(cmd, ExchangeCommand) else type(cmd).__name__
    return "?"


@dataclass
class CallContext:
    """What middleware sees about one RPC."""

    method: str                      # verb name: "DoGet", "DoPut", ...
    headers: dict = field(default_factory=dict)   # token + CallOptions headers
    request: dict = field(default_factory=dict)   # raw control-frame payload
    state: dict = field(default_factory=dict)     # per-call middleware scratch


class ServerMiddleware:
    """Override one or both hooks; the defaults are no-ops."""

    def on_call(self, ctx: CallContext) -> None:  # raise FlightError to reject
        pass

    def on_complete(self, ctx: CallContext, error: Exception | None) -> None:
        pass


class MiddlewareStack:
    def __init__(self, items: list[ServerMiddleware] | None = None):
        self.items: list[ServerMiddleware] = list(items or [])

    @contextmanager
    def wrap(self, ctx: CallContext):
        """Run the chain around one dispatched verb (see module docstring)."""
        started: list[ServerMiddleware] = []
        error: Exception | None = None
        try:
            for m in self.items:
                started.append(m)
                m.on_call(ctx)
            yield
        except Exception as e:
            error = e
            raise
        finally:
            for m in reversed(started):
                try:
                    m.on_complete(ctx, error)
                except Exception:
                    pass  # completion hooks never mask the real outcome


# --------------------------------------------------------------------------
# stock middleware
# --------------------------------------------------------------------------


class AuthTokenMiddleware(ServerMiddleware):
    """Shared-token auth — the typed replacement for ``_check_auth``."""

    def __init__(self, token: str):
        self.token = token

    def on_call(self, ctx: CallContext) -> None:
        if ctx.headers.get("token") != self.token:
            raise FlightUnauthenticated(
                "bad or missing token", detail={"method": ctx.method}
            )


class MetricsMiddleware(ServerMiddleware):
    """Per-verb call/error/latency accounting (surfaced by ``server-stats``).

    Latency is a ``LogHistogram`` per verb (and per exchange service), so
    ``server-metrics`` exports p50/p95/p99 instead of one scalar sum; the
    legacy ``seconds`` sums stay for back-compat.  Errors count per verb
    *and* per ``FlightError`` wire code (``error_codes``) — a dashboard can
    tell ``not_found`` noise from an ``unavailable`` incident.

    When constructed with a ``ServerTelemetry`` in ``"full"`` mode this is
    also the server-side tracer: a request arriving with trace headers gets
    a child ``Span`` opened in ``on_call`` (installed as the thread-local
    active span so handlers can ``add_stage``) and recorded in
    ``on_complete`` with queue-wait and handler stage timings.  Untraced
    requests pay one header lookup.  Everything here is non-blocking and
    allocation-light on purpose: this middleware lives in the
    ``MiddlewareStack`` module, so it must keep the event loop's inline
    fast-path certificate valid (see ``FlightServerBase._rpc_inline_ok``).

    Locked where it matters: each TCP connection runs on its own handler
    thread, so concurrent RPCs hit the dict read-modify-writes
    simultaneously; histogram bumps are deliberately lock-free."""

    def __init__(self, telemetry: ServerTelemetry | None = None):
        self.telemetry = telemetry
        self.calls: dict[str, int] = {}
        self.errors: dict[str, int] = {}
        self.error_codes: dict[str, dict[str, int]] = {}
        self.seconds: dict[str, float] = {}
        self.latency: dict[str, LogHistogram] = {}  # per-verb log2 buckets
        self.actions: dict[str, int] = {}  # DoAction broken out by type
        # DoExchange broken out by service: call/error/latency per transform
        self.exchanges: dict[str, dict] = {}
        self._lock = threading.Lock()

    def _exchange_entry(self, label: str) -> dict:
        return self.exchanges.setdefault(
            label, {"calls": 0, "errors": 0, "seconds": 0.0,
                    "hist": LogHistogram()})

    def on_call(self, ctx: CallContext) -> None:
        ctx.state["metrics_t0"] = time.perf_counter()
        with self._lock:
            self.calls[ctx.method] = self.calls.get(ctx.method, 0) + 1
            if ctx.method == "DoAction":
                kind = (ctx.request.get("action") or {}).get("type", "?")
                self.actions[kind] = self.actions.get(kind, 0) + 1
            elif ctx.method == "DoExchange":
                label = _exchange_service_label(ctx.request)
                ctx.state["metrics_exchange"] = label
                self._exchange_entry(label)["calls"] += 1
        tel = self.telemetry
        if tel is not None and tel.trace_enabled:
            parent = TraceContext.from_headers(ctx.headers)
            if parent is not None:  # caller-sampled: only traced requests pay
                name = ctx.method
                if name == "DoAction":
                    name = f"DoAction:{(ctx.request.get('action') or {}).get('type', '?')}"
                elif name == "DoExchange":
                    name = f"DoExchange:{ctx.state.get('metrics_exchange', '?')}"
                span, prev = tel.begin_span(name, parent)
                qw = ctx.state.get("queue_wait_s")
                if qw:
                    span.stages["queue"] = qw
                ctx.state["telemetry_span"] = (span, prev)

    def on_complete(self, ctx: CallContext, error: Exception | None) -> None:
        dt = time.perf_counter() - ctx.state.get("metrics_t0", time.perf_counter())
        tel = self.telemetry
        if tel is None or tel.metrics_enabled:
            hist = self.latency.get(ctx.method)
            if hist is None:  # racy setdefault is fine: worst case one resets
                hist = self.latency[ctx.method] = LogHistogram()
            hist.observe(dt)
        with self._lock:
            self.seconds[ctx.method] = self.seconds.get(ctx.method, 0.0) + dt
            if error is not None:
                self.errors[ctx.method] = self.errors.get(ctx.method, 0) + 1
                code = getattr(error, "code", None) or type(error).__name__
                by_code = self.error_codes.setdefault(ctx.method, {})
                by_code[code] = by_code.get(code, 0) + 1
            label = ctx.state.get("metrics_exchange")
            if label is not None:
                e = self._exchange_entry(label)
                e["seconds"] += dt
                if error is not None:
                    e["errors"] += 1
        if label is not None and (tel is None or tel.metrics_enabled):
            self.exchanges[label]["hist"].observe(dt)
        traced = ctx.state.pop("telemetry_span", None)
        if traced is not None:
            span, prev = traced
            # handler time excludes the pre-dispatch queue wait, which
            # happened before this span's clock started
            span.stages.setdefault("handler", dt)
            tel.end_span(span, prev, dt, error)

    def snapshot(self) -> dict:
        with self._lock:
            return {
                "calls": dict(self.calls),
                "errors": dict(self.errors),
                "error_codes": {k: dict(v) for k, v in self.error_codes.items()},
                "seconds": {k: round(v, 6) for k, v in self.seconds.items()},
                "latency": {k: h.snapshot() for k, h in self.latency.items()},
                "actions": dict(self.actions),
                "exchanges": {
                    k: {**{kk: vv for kk, vv in v.items() if kk != "hist"},
                        "seconds": round(v["seconds"], 6),
                        "latency": v["hist"].snapshot()}
                    for k, v in self.exchanges.items()
                },
            }


class LoggingMiddleware(ServerMiddleware):
    """Calls ``log(line)`` per completed RPC; defaults to collecting lines."""

    def __init__(self, log: Callable[[str], None] | None = None):
        self.lines: list[str] = []
        self._log = log if log is not None else self.lines.append

    def on_complete(self, ctx: CallContext, error: Exception | None) -> None:
        status = "ok" if error is None else f"error:{getattr(error, 'code', 'exception')}"
        self._log(f"{ctx.method} {status}")

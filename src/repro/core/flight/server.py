"""Flight server: RPC dispatch + an in-memory store implementation.

``FlightServerBase`` defines the six verbs (GetFlightInfo, ListFlights,
DoGet, DoPut, DoAction, DoExchange) against abstract handlers; it can be
used in-process (zero-copy object handoff) or served over TCP via
``serve_tcp``.  TCP serving runs on the event-loop core by default
(``io_mode="eventloop"``: one selector dispatch thread + a small worker
pool, eventloop.py — server threads stay O(worker pool), not O(clients));
``io_mode="threads"`` keeps the historical thread-per-connection listener
one release for bisection.  Both modes speak the identical framed wire
format and run the identical ``_dispatch_rpc``.

Every RPC is dispatched through a **middleware stack** (see middleware.py):
auth is just ``AuthTokenMiddleware`` (installed automatically when
``auth_token`` is set), a ``MetricsMiddleware`` counts per-verb calls/errors
/latency (surfaced via ``server-stats``), and servers can prepend their own
interceptors.  Failures raise the typed ``FlightError`` hierarchy
(errors.py) and round-trip to clients as structured control frames.

``InMemoryFlightServer`` is the paper's "simple data producer with an
InMemoryStore" (§4.2.2) — datasets are lists of RecordBatches keyed by
descriptor path.  Tickets carry typed ``Command``s (protocol.py):

* ``RangeReadCommand`` — idempotent (dataset, start, stop) range reads, so
  any batch range can be re-fetched (hedged reads / resume);
* ``QueryCommand`` — executed **natively** via ``query.engine.execute``
  (predicate/projection/limit pushdown), no ``do_get_impl`` monkeypatching.
  Pass-through queries (no predicate, full projection, no limit) serve from
  the encode-once cache like plain range reads; filtered queries encode
  per-request and never poison the cache.

Data-plane fast paths (the wire-speed work):

* **encode-once cache** — datasets are pre-encoded to ``EncodedMessage``s on
  first DoGet and every later DoGet serves from the cache (zero
  ``encode_batch`` calls — asserted via the ``server-stats`` counters).  The
  cache is invalidated on DoPut / ``add_dataset`` / ``drop``, and bypassed
  whenever ``do_get_impl`` is overridden (paced shards, test monkeypatches)
  so behavior-modifying subclasses keep their semantics.
* **frame coalescing** — DoGet streams go out via
  ``FrameConnection.send_data_many`` (many frames per ``sendmsg``) unless
  disabled; ``CallOptions.coalesce`` overrides per call.
* ``wire_codec`` selects the IPC metadata codec (binary default; json kept
  for comparison benchmarks); ``CallOptions.wire_codec`` overrides per call
  (bypassing the cache, which holds server-codec messages).
* **DoPut dedup guard** — recently committed put payloads are content-hashed
  per dataset; an identical re-append within the window (a retried parallel
  put after partial failure) is dropped instead of duplicating rows.

Transactional staged DoPut (the two-phase cluster write protocol):

* a DoPut whose descriptor carries ``StagedPutCommand(dataset, txn_id,
  "stage")`` lands in a **staging store** keyed by txn id — invisible to
  every DoGet/query until committed, and never touching the encode-once
  cache (invalidation happens on *commit*, not stage);
* ``txn-prepare`` / ``txn-commit`` / ``txn-abort`` DoActions drive the
  commit round (commit flips all of a txn's staged batches into the visible
  dataset under one lock acquisition — a concurrent reader sees none or all
  of them; abort discards them).  Commit and abort are idempotent within a
  recent-transactions window, so a retried coordinator round is safe;
* a TTL **GC reaper** (daemon thread, started when the first stage arrives)
  discards stages whose writer went away — an orphaned txn is never
  readable and stops holding memory after ``stage_ttl`` seconds;
* ``server-stats`` surfaces ``staged_bytes`` / ``staged_txns`` /
  ``txn_commits`` / ``txn_aborts`` / ``txn_gc_reaped``.

Streaming DoExchange (the microservice plane — exchange.py / services.py):

* descriptors carrying an ``ExchangeCommand`` route the bidirectional
  stream through the server's ``ExchangeServiceRegistry`` (``services``
  attr; stock echo/filter/project/repartition plus registered callables);
  path descriptors keep the legacy per-batch ``do_exchange_impl`` hook;
* the serve loop (``_run_exchange``) is pipelined: output frames buffer
  and **flush when a read would block** (coalesced sendmsg bursts without
  starving a lockstep peer), and consumption acks ride the output
  direction so the client's bounded in-flight window provides
  backpressure — see docs/wire-format.md ("DoExchange framing");
* mid-stream failures are sent as typed error control frames the client
  rehydrates, then the connection is torn down (frames may be in flight
  in both directions — an exchange error never bleeds into a later RPC).
"""
from __future__ import annotations

import hashlib
import json
import threading
import time
from collections import OrderedDict
from collections.abc import Mapping
from dataclasses import dataclass, field, replace
from itertools import chain
from typing import Iterable, Iterator

from ..ipc import (
    CODEC_BINARY,
    CODEC_JSON,
    DEFAULT_CODEC,
    EncodedMessage,
    decode_message,
    encode_batch,
    encode_eos,
    encode_schema,
)
from ..recordbatch import RecordBatch
from ..schema import Schema
from .errors import (
    FlightError,
    FlightInvalidArgument,
    FlightNotFound,
    FlightUnauthenticated,
)
from .middleware import (
    AuthTokenMiddleware,
    CallContext,
    MetricsMiddleware,
    MiddlewareStack,
    ServerMiddleware,
)
from .protocol import (
    Action,
    ActionResult,
    ExchangeCommand,
    FlightDescriptor,
    FlightEndpoint,
    FlightInfo,
    Location,
    QueryCommand,
    RangeReadCommand,
    StagedPutCommand,
    Ticket,
    parse_command,
)
from .eventloop import EventLoopListener
from .exchange import DEFAULT_WINDOW, ack_interval
from .services import ExchangeService, ExchangeServiceRegistry, drive_exchange
from .storage import StorageProvider, make_provider
from .telemetry import (
    ServerTelemetry,
    add_stage,
    current_span,
    propagation_headers,
    telemetry_action,
)
from .transport import (
    COALESCE_BYTES,
    KIND_CTRL,
    KIND_DATA,
    FrameConnection,
    SocketListener,
)

_PUT_DEDUP_WINDOW = 32   # recent content hashes remembered per dataset
_TXN_FINISH_WINDOW = 64  # recent committed/aborted txn ids (idempotency)

_UNSET = object()  # legacy-kwarg sentinel: distinguishes "not passed" from a value


@dataclass(frozen=True)
class ServerConfig:
    """One bundle for ``InMemoryFlightServer``'s construction knobs.

    Replaces the sprawling per-kwarg signature: build a config once and hand
    it to many servers (cluster shards, benchmark sweeps).  The legacy
    keyword arguments are still accepted for one release and route through
    this dataclass — an explicitly passed kwarg overrides the same field of
    a ``config`` also given.

    ``storage`` selects the dataset backend (storage.py): ``None``/
    ``"memory"``, ``"disk:<root>"``, ``"remote:<uri>"``, or a ready
    ``StorageProvider`` instance.

    ``io_mode`` selects the TCP serving core: ``"eventloop"`` (default —
    one selector dispatch thread + a small worker pool, eventloop.py) or
    ``"threads"`` (the historical thread-per-connection ``SocketListener``,
    retained one release for bisection).  ``io_workers`` sizes the event
    loop's worker pool (0 = auto: half the cores, floor 2, cap 8).
    """

    auth_token: str | None = None
    wire_codec: str = DEFAULT_CODEC
    coalesce: bool = True
    cache_encoded: bool = True
    batches_per_endpoint: int = 0
    endpoints_per_query: int = 4
    dedup_puts: bool = True
    stage_ttl: float = 60.0
    storage: "str | StorageProvider | None" = None
    io_mode: str = "eventloop"
    io_workers: int = 0
    # telemetry plane (telemetry.py): "off" | "metrics" (histograms only) |
    # "full" (histograms + caller-sampled distributed tracing, the default —
    # untraced traffic pays one header lookup per RPC)
    telemetry: str = "full"


class _ProviderMapping(Mapping):
    """Read-only dict-shaped view over a provider (``_store``/``_schemas``
    back-compat: external code historically peeked at those dicts)."""

    def __init__(self, provider: StorageProvider, getter):
        self._provider = provider
        self._getter = getter

    def __getitem__(self, name):
        if not self._provider.exists(name):
            raise KeyError(name)
        return self._getter(name)

    def __contains__(self, name):
        return self._provider.exists(name)

    def __iter__(self):
        return iter(self._provider.list())

    def __len__(self):
        return len(self._provider.list())


def parse_txn_body(raw: bytes) -> dict:
    """Decode a txn action body: ``StagedPutCommand`` bytes or a JSON dict.

    Returns ``{"txn_id", "dataset", ...}`` — JSON bodies may carry extra
    coordinator fields (e.g. ``expect_shards``)."""
    if not raw:
        raise FlightInvalidArgument("empty transaction body")
    if raw[0] == 0xC2:
        cmd = parse_command(raw)
        if not isinstance(cmd, StagedPutCommand):
            raise FlightInvalidArgument(
                f"txn action body must be a StagedPutCommand, got {type(cmd).__name__}")
        return {"txn_id": cmd.txn_id, "dataset": cmd.dataset}
    try:
        o = json.loads(raw.decode())
    except (UnicodeDecodeError, json.JSONDecodeError) as e:
        raise FlightInvalidArgument(f"unparseable txn body: {e}") from e
    if not isinstance(o, dict) or "txn_id" not in o:
        raise FlightInvalidArgument("txn body JSON must name a txn_id")
    return o


@dataclass
class _StagedTxn:
    """Bookkeeping for one staged-but-invisible transaction.

    The payload itself lives in the storage provider (durably, for the disk
    backend); the server only tracks counters, the in-txn dedup digests,
    and the TTL/prepared state that drive the 2PC protocol."""

    dataset: str
    schema: Schema
    batches: int = 0
    rows: int = 0
    nbytes: int = 0
    digests: set = field(default_factory=set)  # in-txn stream dedup (retries)
    expires_at: float = 0.0
    prepared: bool = False


class _LegacyExchangeService(ExchangeService):
    """Adapter: path exchange descriptors run ``do_exchange_impl`` per batch.

    The output schema is whatever the handler returns, so it cannot be
    declared up front — ``out_schema`` returns ``None`` and the serve loop
    defers the schema frame to the first output batch."""

    def __init__(self, server: "FlightServerBase", descriptor: FlightDescriptor):
        self._server = server
        self._descriptor = descriptor
        self.name = descriptor.key

    def out_schema(self, in_schema, params):
        return None  # deferred: sent with the first output batch

    def transform(self, in_schema, batches, params):
        for b in batches:
            yield self._server.do_exchange_impl(self._descriptor, in_schema, b)


class FlightServerBase:
    """Override the ``*_impl`` handlers to build a service."""

    def __init__(
        self,
        location_name: str = "local",
        auth_token: str | None = None,
        *,
        wire_codec: str = DEFAULT_CODEC,
        coalesce: bool = True,
        io_mode: str = "eventloop",
        io_workers: int = 0,
        telemetry: str = "full",
        middleware: Iterable[ServerMiddleware] | None = None,
        services: ExchangeServiceRegistry | None = None,
    ):
        self.location_name = location_name
        self.auth_token = auth_token
        self.wire_codec = wire_codec
        self.coalesce = coalesce
        self.io_mode = io_mode
        self.io_workers = io_workers
        self.encode_calls = 0  # encode_batch invocations on the DoGet path
        self.rows_served = 0  # rows shipped by DoGet (cached + uncached paths)
        # named streaming-exchange transforms (services.py); a shared
        # registry object makes one `register` visible on many servers
        self.services = services if services is not None else ExchangeServiceRegistry()
        self._listener: SocketListener | EventLoopListener | None = None
        self.telemetry = ServerTelemetry(telemetry, service=location_name)
        stack: list[ServerMiddleware] = list(middleware or [])
        if auth_token is not None and not any(
            isinstance(m, AuthTokenMiddleware) for m in stack
        ):
            stack.insert(0, AuthTokenMiddleware(auth_token))
        # first: counts rejected calls too; also the server-side tracer
        self.metrics = MetricsMiddleware(telemetry=self.telemetry)
        self.middleware = MiddlewareStack([self.metrics, *stack])

    # -- handlers to override ------------------------------------------- #
    def list_flights_impl(self) -> list[FlightInfo]:
        raise NotImplementedError

    def get_flight_info_impl(self, descriptor: FlightDescriptor) -> FlightInfo:
        raise NotImplementedError

    def do_get_impl(self, ticket: Ticket) -> tuple[Schema, Iterator[RecordBatch]]:
        raise NotImplementedError

    def do_get_encoded(
        self, ticket: Ticket
    ) -> tuple[EncodedMessage, list[EncodedMessage]] | None:
        """Optional fast path: pre-encoded ``(schema msg, batch msgs)``.

        Return ``None`` (the default) to serve through ``do_get_impl`` +
        per-request encoding."""
        return None

    def do_put_impl(
        self, descriptor: FlightDescriptor, schema: Schema, batches: Iterator[RecordBatch]
    ) -> dict:
        raise NotImplementedError

    def do_action_impl(self, action: Action) -> list[ActionResult]:
        raise NotImplementedError

    def do_exchange_impl(
        self, descriptor: FlightDescriptor, schema: Schema, batch: RecordBatch
    ) -> RecordBatch:
        """Per-batch handler for *path* exchange descriptors (the original
        scoring-microservice hook).  Command descriptors carrying an
        ``ExchangeCommand`` route through ``self.services`` instead — see
        ``resolve_exchange``."""
        raise NotImplementedError

    # -- locations -------------------------------------------------------- #
    def locations(self) -> tuple[Location, ...]:
        locs: list[Location] = [Location.inproc(self.location_name)]
        if self._listener is not None:
            locs.append(Location.for_tcp(self._listener.host, self._listener.port))
        return tuple(locs)

    # -- TCP serving ------------------------------------------------------ #
    def serve_tcp(self, host: str = "127.0.0.1", port: int = 0) -> "FlightServerBase":
        if self.io_mode == "eventloop":
            self._listener = EventLoopListener(
                self._dispatch_rpc, host, port,
                workers=self.io_workers or None,
                inline_ok=self._rpc_inline_ok,
                telemetry=self.telemetry.metrics_enabled).start()
        elif self.io_mode == "threads":
            self._listener = SocketListener(self._handle_connection, host, port).start()
        else:
            raise FlightInvalidArgument(
                f"unknown io_mode {self.io_mode!r} (eventloop|threads)",
                detail={"io_mode": self.io_mode})
        return self

    @property
    def port(self) -> int:
        assert self._listener is not None, "serve_tcp() first"
        return self._listener.port

    def shutdown(self) -> None:
        if self._listener is not None:
            self._listener.stop()
            self._listener = None

    def _rpc_inline_ok(self, req: dict) -> bool:
        """Certify a request for loop-thread dispatch (eventloop.py).

        Inline RPCs run on the event loop's one dispatch thread, so the
        contract is strict: never read another frame, never block, cheap.
        The base server can only vouch for ``Handshake``; subclasses widen
        this where they can *prove* the fast path (see
        ``InMemoryFlightServer``).  User middleware voids the certificate —
        its hooks run inside the dispatch and may block."""
        if any(type(m).__module__ != MiddlewareStack.__module__
               for m in self.middleware.items):
            return False
        return req.get("method") == "Handshake"

    # -- dispatch ---------------------------------------------------------- #
    def _check_auth(self, req: dict) -> None:
        """Deprecated — auth now runs as ``AuthTokenMiddleware``."""
        if self.auth_token is not None and req.get("token") != self.auth_token:
            raise FlightUnauthenticated("bad or missing token")

    def _call_context(self, method: str, req: dict) -> CallContext:
        opts = req.get("options") or {}
        headers = {"token": req.get("token")}
        headers.update(opts.get("headers") or {})
        return CallContext(method=method, headers=headers, request=req)

    def _handle_connection(self, conn: FrameConnection) -> None:
        """One connection = a sequence of RPCs (like an HTTP/2 channel).

        The blocking serve loop of the thread-per-connection listener; the
        event-loop listener instead calls ``_dispatch_rpc`` per opening
        frame from its worker pool.  Both run the same dispatch."""
        while True:
            try:
                kind, req, _ = conn.recv_frame()
            except (ConnectionError, OSError):
                return
            self._dispatch_rpc(conn, kind, req)

    def _dispatch_rpc(self, conn: FrameConnection, kind: int, req: dict) -> None:
        """Serve one RPC whose opening frame has already been read.

        Raises ``FlightError`` for protocol violations that must kill the
        connection (non-control opening frame); RPC-level failures are
        reported to the peer as typed error frames and the channel stays
        usable."""
        if kind != KIND_CTRL:
            raise FlightError("expected control frame opening an RPC")
        method = req.get("method")
        opts = req.get("options") or {}
        ctx = self._call_context(method or "?", req)
        # event-loop channels stamp how long the opening frame sat parsed in
        # the inbox before a worker picked it up; traced spans surface it as
        # the "queue" stage (inline dispatch never queues — no attribute)
        queue_wait = getattr(conn, "last_queue_wait_s", 0.0)
        if queue_wait:
            ctx.state["queue_wait_s"] = queue_wait
        try:
            # unary verbs buffer their reply and send it *after* the
            # middleware chain unwinds: once the client holds the answer,
            # every on_complete hook (metrics, logging) has already fired
            reply: dict | None = None
            with self.middleware.wrap(ctx):
                if method == "GetFlightInfo":
                    info = self.get_flight_info_impl(
                        FlightDescriptor.from_json(req["descriptor"]))
                    reply = {"info": info.to_json()}
                elif method == "ListFlights":
                    infos = self.list_flights_impl()
                    reply = {"infos": [i.to_json() for i in infos]}
                elif method == "DoAction":
                    results = self.do_action_impl(Action.from_json(req["action"]))
                    reply = {"results": [r.to_json() for r in results]}
                elif method == "DoGet":
                    self._serve_do_get(conn, Ticket.from_json(req["ticket"]), opts)
                elif method == "DoPut":
                    self._serve_do_put(conn, FlightDescriptor.from_json(req["descriptor"]))
                elif method == "DoExchange":
                    self._serve_do_exchange(
                        conn, FlightDescriptor.from_json(req["descriptor"]), opts)
                elif method == "Handshake":
                    reply = {"ok": True}
                else:
                    raise FlightInvalidArgument(f"unknown method {method!r}")
            if reply is not None:
                conn.send_ctrl(reply)
        except FlightError as e:
            conn.send_ctrl(e.to_wire())

    def _send_stream(
        self, conn: FrameConnection, msgs: Iterable[EncodedMessage], coalesce: bool | None = None
    ) -> None:
        if self.coalesce if coalesce is None else coalesce:
            conn.send_data_many(msgs)
        else:
            for m in msgs:
                conn.send_data(m)

    def _serve_do_get(self, conn: FrameConnection, ticket: Ticket, opts: dict | None = None) -> None:
        opts = opts or {}
        codec = opts.get("wire_codec") or self.wire_codec
        if codec not in (CODEC_BINARY, CODEC_JSON):
            # reject before the ok frame: an unknown codec must be a typed
            # refusal, not a ValueError killing the handler mid-stream
            raise FlightInvalidArgument(f"unknown wire codec {codec!r}",
                                        detail={"wire_codec": codec})
        coalesce = opts.get("coalesce")
        # stage timing is sampled: only a traced request (active span set by
        # MetricsMiddleware) pays the perf_counter pairs on this hot path
        traced = current_span() is not None
        pre = self.do_get_encoded(ticket) if codec == self.wire_codec else None
        if pre is not None:  # encode-once cache: no per-request encoding
            schema_msg, batch_msgs = pre
            conn.send_ctrl({"ok": True})
            t0 = time.perf_counter() if traced else 0.0
            self._send_stream(
                conn, chain((schema_msg,), batch_msgs, (encode_eos(codec),)), coalesce
            )
            if traced:
                add_stage("flush", time.perf_counter() - t0)
            return
        schema, batches = self.do_get_impl(ticket)
        conn.send_ctrl({"ok": True})

        def frames() -> Iterator[EncodedMessage]:
            yield encode_schema(schema)
            for b in batches:
                self.encode_calls += 1
                self.rows_served += b.num_rows
                if traced:
                    te = time.perf_counter()
                    msg = encode_batch(b, codec)
                    add_stage("encode", time.perf_counter() - te)
                    yield msg
                else:
                    yield encode_batch(b, codec)
            yield encode_eos(codec)

        t0 = time.perf_counter() if traced else 0.0
        self._send_stream(conn, frames(), coalesce)
        if traced:
            # the walltime of the send loop minus encode = queueing/sendmsg
            add_stage("flush", max(time.perf_counter() - t0
                                   - (current_span().stages.get("encode", 0.0)), 0.0))

    def _recv_stream(self, conn: FrameConnection) -> tuple[Schema, Iterator[RecordBatch]]:
        kind, meta, body = conn.recv_frame()
        if kind != KIND_DATA:
            raise FlightError("expected schema message")
        msg = decode_message(meta, body)
        if msg.kind != "schema":
            raise FlightError(f"expected schema, got {msg.kind}")
        schema = msg.schema

        def gen() -> Iterator[RecordBatch]:
            while True:
                k, m, b = conn.recv_frame()
                if k != KIND_DATA:
                    raise FlightError("expected data frame in stream")
                dm = decode_message(m, b)
                if dm.kind == "eos":
                    return
                yield dm.batch(schema)

        return schema, gen()

    def _serve_do_put(self, conn: FrameConnection, descriptor: FlightDescriptor) -> None:
        conn.send_ctrl({"ok": True})
        schema, batches = self._recv_stream(conn)
        stats = self.do_put_impl(descriptor, schema, batches)
        conn.send_ctrl({"ok": True, "stats": stats})

    # -- streaming DoExchange (the microservice plane; see exchange.py) ---- #
    def resolve_exchange(self, descriptor: FlightDescriptor) -> tuple[ExchangeService, dict]:
        """Which transform serves this exchange descriptor.

        ``ExchangeCommand`` descriptors route through the ``services``
        registry (unknown names are a typed ``FlightNotFound`` refused
        before the stream opens); path descriptors keep the legacy
        per-batch ``do_exchange_impl`` semantics via an adapter."""
        if descriptor.command is not None:
            cmd = descriptor.parsed_command()
            if isinstance(cmd, ExchangeCommand):
                return self.services.get(cmd.service), cmd.params
            raise FlightInvalidArgument(
                f"DoExchange takes an ExchangeCommand or path descriptor, "
                f"not {type(cmd).__name__}")
        return _LegacyExchangeService(self, descriptor), {}

    def _serve_do_exchange(self, conn: FrameConnection, descriptor: FlightDescriptor,
                           opts: dict | None = None) -> None:
        opts = opts or {}
        codec = opts.get("wire_codec") or self.wire_codec
        if codec not in (CODEC_BINARY, CODEC_JSON):
            raise FlightInvalidArgument(f"unknown wire codec {codec!r}",
                                        detail={"wire_codec": codec})
        coalesce = self.coalesce if opts.get("coalesce") is None else opts["coalesce"]
        window = max(1, int(opts.get("read_window") or DEFAULT_WINDOW))
        # service resolution and param validation failures (unknown name,
        # malformed command, malformed params) refuse *before* the ok frame:
        # the client has not started streaming and the channel stays clean.
        # Schema-dependent validation (project's unknown-column check) needs
        # the input schema and surfaces as a typed mid-stream error instead
        service, params = self.resolve_exchange(descriptor)
        service.check_params(params)
        conn.send_ctrl({"ok": True})
        try:
            self._run_exchange(conn, service, params, codec, coalesce, window)
        except (ConnectionError, OSError):
            raise  # peer died: nothing to report, nobody to report it to
        except Exception as e:
            # mid-stream failure: input frames may still be in flight, so
            # the channel cannot be reused — send the typed error as a
            # control frame (the client rehydrates it mid-read) and tear
            # the connection down.  Non-Flight exceptions (a service
            # callable bug) surface as the base typed error, matching the
            # inproc path, instead of killing the handler thread raw
            err = e if isinstance(e, FlightError) else FlightError(f"exchange failed: {e}")
            try:
                conn.send_ctrl(err.to_wire())
            except (ConnectionError, OSError):
                pass
            conn.close()
            raise ConnectionError(f"exchange aborted: {err}") from e

    def _run_exchange(self, conn: FrameConnection, service: ExchangeService,
                      params: dict, codec: str, coalesce: bool, window: int) -> None:
        """The pipelined exchange loop, single-threaded by design.

        The serve thread alternates between pulling input frames (as the
        service consumes them) and emitting output frames; pipelining comes
        from *buffering with flush-before-block*: encoded output frames
        accumulate while more input is already waiting (one coalesced
        ``sendmsg`` per ~budget), and flush the moment a read would block —
        so a lockstep (window=1) peer always sees its response before the
        server waits for its next batch, while a windowed peer gets
        syscall-amortized bursts.  Backpressure is the client-side window:
        the server acks batches as the service consumes them (``{"ack": n}``
        control frames riding the output direction), and the client writer
        blocks once ``window`` batches are unacked — so at most ``window``
        batches are ever queued in the socket, and a serial server never
        needs its own input queue."""
        kind, meta, body = conn.recv_frame()
        if kind != KIND_DATA:
            raise FlightInvalidArgument("exchange: expected a schema data frame first")
        msg = decode_message(meta, body)
        if msg.kind != "schema":
            raise FlightInvalidArgument(
                f"exchange: expected schema first, got {msg.kind!r}")
        in_schema = msg.schema
        state = {"in": 0, "acked": 0, "rows_in": 0, "out": 0, "rows_out": 0}
        every = ack_interval(window)
        pending: list[EncodedMessage] = []
        pending_bytes = 0

        def flush() -> None:
            nonlocal pending, pending_bytes
            if not pending:
                return
            if coalesce and len(pending) > 1:
                conn.send_data_many(pending)
            else:
                for f in pending:
                    conn.send_data(f)
            pending = []
            pending_bytes = 0

        def emit(frame: EncodedMessage) -> None:
            nonlocal pending_bytes
            pending.append(frame)
            pending_bytes += frame.nbytes()
            if not coalesce or pending_bytes >= COALESCE_BYTES:
                flush()

        def inputs() -> Iterator[RecordBatch]:
            while True:
                if not conn.receive_ready():
                    flush()  # about to block on the peer: let it see progress
                k, m, b = conn.recv_frame()
                if k != KIND_DATA:
                    raise FlightInvalidArgument(
                        "exchange: unexpected control frame in the input stream")
                dm = decode_message(m, b)
                if dm.kind == "eos":
                    if state["acked"] != state["in"]:  # final ack frees the writer
                        conn.send_ctrl({"ack": state["in"]})
                        state["acked"] = state["in"]
                    return
                if dm.kind == "schema":
                    raise FlightInvalidArgument("exchange: duplicate schema mid-stream")
                state["in"] += 1
                state["rows_in"] += dm.batch_meta.rows
                if state["in"] - state["acked"] >= every:
                    conn.send_ctrl({"ack": state["in"]})
                    state["acked"] = state["in"]
                yield dm.batch(in_schema)

        # `declare` sends directly: it only ever runs with nothing pending
        # (up front, or immediately before the first output batch), so the
        # schema frame is never held back by the coalescing buffer
        drive_exchange(
            service, in_schema, params, inputs(),
            declare=lambda s: conn.send_data(encode_schema(s)),
            emit=lambda ob: emit(encode_batch(ob, codec)),
            state=state,
        )
        emit(encode_eos(codec))
        flush()
        conn.send_ctrl({"ok": True, "stats": {
            "service": service.name,
            "batches_in": state["in"], "rows_in": state["rows_in"],
            "batches_out": state["out"], "rows_out": state["rows_out"],
        }})


def _query_out_schema(plan, schema: Schema) -> Schema:
    """Schema a QueryCommand's DoGet stream carries.

    Aggregating plans stream per-group *state* batches (the partial half of
    the operator split), so the planned FlightInfo schema is the state
    schema — which also makes empty shards merge cleanly (the scheduler
    materializes an empty state batch from it).  Plain plans stream rows in
    the projected schema.  ``group_by`` without aggregations is refused:
    the plane has no distinct-rows operator."""
    from ...query.engine import partial_schema  # lazy: engine imports this layer

    if plan.group_by and not plan.aggregations:
        raise FlightInvalidArgument(
            "QueryPlan.group_by requires at least one aggregation")
    if plan.aggregations:
        return partial_schema(plan, schema)
    return schema.select(plan.projection) if plan.projection else schema


def _content_digest(schema: Schema, batches: list[RecordBatch]) -> str:
    """Stable content hash of a put payload (dedup key for retried puts).

    Hashes the IPC frame *views* (metadata + zero-copy buffer slices) rather
    than materializing each message, so the cost is one pass over the bytes
    with no per-batch body copy."""
    h = hashlib.blake2b(digest_size=16)
    h.update(json.dumps(schema.to_json(), sort_keys=True).encode())
    for b in batches:
        for part in encode_batch(b).frame_parts():
            h.update(part)
    return h.hexdigest()


class InMemoryFlightServer(FlightServerBase):
    """Dataset store: descriptor path[0] -> list[RecordBatch].

    The store itself lives behind a pluggable ``StorageProvider``
    (storage.py) — memory (default, the historical behavior), ``disk:<root>``
    (Arrow-IPC spill files, mmap-backed re-serve, durable staging +
    restart recovery), or ``remote:<uri>`` (forward to another Flight
    endpoint).  The serving layer — verbs, encode-once cache, the 2PC
    staging protocol — is identical across backends."""

    def __init__(
        self,
        location_name: str = "local",
        auth_token=_UNSET,
        batches_per_endpoint=_UNSET,
        shard_id: int | None = None,
        *,
        config: ServerConfig | None = None,
        wire_codec=_UNSET,
        coalesce=_UNSET,
        cache_encoded=_UNSET,
        endpoints_per_query=_UNSET,
        dedup_puts=_UNSET,
        stage_ttl=_UNSET,
        storage=_UNSET,
        io_mode=_UNSET,
        io_workers=_UNSET,
        telemetry=_UNSET,
        middleware: Iterable[ServerMiddleware] | None = None,
        services: ExchangeServiceRegistry | None = None,
    ):
        # legacy kwargs (accepted for one release) route through ServerConfig;
        # an explicitly passed kwarg wins over the same field of `config`
        cfg = config if config is not None else ServerConfig()
        overrides = {
            k: v for k, v in {
                "auth_token": auth_token,
                "batches_per_endpoint": batches_per_endpoint,
                "wire_codec": wire_codec,
                "coalesce": coalesce,
                "cache_encoded": cache_encoded,
                "endpoints_per_query": endpoints_per_query,
                "dedup_puts": dedup_puts,
                "stage_ttl": stage_ttl,
                "storage": storage,
                "io_mode": io_mode,
                "io_workers": io_workers,
                "telemetry": telemetry,
            }.items() if v is not _UNSET
        }
        if overrides:
            cfg = replace(cfg, **overrides)
        self.config = cfg
        super().__init__(location_name, cfg.auth_token, wire_codec=cfg.wire_codec,
                         coalesce=cfg.coalesce, io_mode=cfg.io_mode,
                         io_workers=cfg.io_workers, telemetry=cfg.telemetry,
                         middleware=middleware, services=services)
        self._provider = make_provider(cfg.storage)
        self._lock = threading.Lock()
        self.batches_per_endpoint = cfg.batches_per_endpoint  # 0 = single endpoint
        self.shard_id = shard_id  # set by cluster.py: stamped into tickets
        if shard_id is not None:
            self.telemetry.shard = shard_id  # spans carry shard identity
        self.endpoints_per_query = cfg.endpoints_per_query  # GetFlightInfo(QueryCommand) fan-out
        # encode-once cache: dataset -> (schema msg, per-batch msgs), built on
        # first DoGet, invalidated whenever the dataset changes
        self.cache_encoded = cfg.cache_encoded
        self._encoded: dict[
            str, tuple[EncodedMessage, tuple[EncodedMessage, ...], tuple[int, ...]]
        ] = {}
        self._versions: dict[str, int] = {}  # bumped on every dataset mutation
        self.cache_hits = 0
        self.cache_misses = 0
        # query pushdown counters (per-shard evidence that filtering ran here)
        self.queries_executed = 0
        self.query_rows_in = 0
        self.query_rows_out = 0
        self.partial_aggs_executed = 0  # DoGet served per-group state, not rows
        self.joins_executed = 0         # local-join actions run on this shard
        # DoPut dedup guard: dataset -> recent payload content hashes
        self.dedup_puts = cfg.dedup_puts
        self._recent_puts: dict[str, OrderedDict[str, dict]] = {}
        self.put_dedup_hits = 0
        # transactional staged puts: txn_id -> staged payload, plus a window
        # of finished txns so duplicate commit/abort rounds are idempotent
        self.stage_ttl = cfg.stage_ttl
        self._staged: dict[str, _StagedTxn] = {}
        self._finished_txns: OrderedDict[str, tuple[str, dict]] = {}
        self._reaper: threading.Thread | None = None
        self._reaper_stop = threading.Event()
        self.txn_commits = 0
        self.txn_aborts = 0
        self.txn_gc_reaped = 0
        # restart recovery: a durable provider hands back the stages a
        # previous process left behind — prepared ones stay GC-exempt and
        # commit/abort from the coordinator finishes the interrupted 2PC
        for txn_id, e in self._provider.staged_txns().items():
            self._staged[txn_id] = _StagedTxn(
                e.dataset, e.schema, e.batches, e.rows, e.nbytes,
                expires_at=time.monotonic() + self.stage_ttl,
                prepared=e.prepared)
        if self._staged:
            with self._lock:
                self._ensure_reaper()

    @property
    def storage(self) -> StorageProvider:
        return self._provider

    # back-compat read views: external code (and a long tail of tests)
    # historically peeked at the server's store/schema dicts
    @property
    def _store(self) -> Mapping:
        return _ProviderMapping(self._provider, self._provider.read_batches)

    @property
    def _schemas(self) -> Mapping:
        return _ProviderMapping(self._provider, self._provider.schema)

    # -- direct (in-proc) API ------------------------------------------- #
    def add_dataset(
        self, name: str, batches: list[RecordBatch], schema: Schema | None = None
    ) -> None:
        """``schema`` allows registering an empty shard of a known dataset."""
        if schema is None:
            schema = batches[0].schema
        with self._lock:
            self._provider.replace(name, schema, list(batches))
            self._encoded.pop(name, None)
            self._recent_puts.pop(name, None)
            self._versions[name] = self._versions.get(name, 0) + 1

    def dataset(self, name: str) -> list[RecordBatch]:
        return self._provider.read_batches(name)

    # -- handlers ---------------------------------------------------------- #
    def _info_for(self, name: str) -> FlightInfo:
        info = self._provider.info(name)
        n = info["batches"]
        per = self.batches_per_endpoint or n or 1
        extra = {} if self.shard_id is None else {"shard": self.shard_id}
        # a traced planning call stamps its span into the endpoints, so the
        # scheduler's later DoGets stitch to this GetFlightInfo's trace
        md = dict(extra)
        trace = propagation_headers()
        if trace is not None:
            md["trace"] = trace
        endpoints = [
            FlightEndpoint(
                Ticket.for_range(name, i, min(i + per, n), **extra),
                self.locations(),
                app_metadata=md or None,
            )
            for i in range(0, max(n, 1), per)
        ]
        return FlightInfo(
            self._provider.schema(name),
            FlightDescriptor.for_path(name),
            endpoints,
            total_records=info["rows"],
            total_bytes=info["bytes"],
        )

    def _plan_query_info(self, cmd: QueryCommand, descriptor: FlightDescriptor) -> FlightInfo:
        """Plan ``GetFlightInfo(QueryCommand)``: per-range query endpoints.

        The command's own ``[start, stop)`` scope (if any) bounds the planned
        ranges, so a ranged query descriptor only ever touches its slice."""
        plan = cmd.plan
        with self._lock:
            if not self._provider.exists(plan.dataset):
                raise FlightNotFound(f"no such dataset: {plan.dataset}",
                                     detail={"dataset": plan.dataset})
            n = self._provider.info(plan.dataset)["batches"]
            schema = self._provider.schema(plan.dataset)
        out_schema = _query_out_schema(plan, schema)
        lo = min(max(cmd.start, 0), n)
        hi = n if cmd.stop < 0 else min(cmd.stop, n)
        span = max(hi - lo, 0)
        per = max(1, -(-span // self.endpoints_per_query))
        extra = {} if self.shard_id is None else {"shard": self.shard_id}
        trace = propagation_headers()
        if trace is not None:
            extra = {**extra, "trace": trace}
        endpoints = [
            FlightEndpoint(
                Ticket.for_command(
                    QueryCommand(cmd.plan_bytes, i, min(i + per, hi), self.shard_id)),
                self.locations(),
                app_metadata=extra or None,
            )
            for i in range(lo, max(hi, lo + 1), per)
        ]
        return FlightInfo(out_schema, descriptor, endpoints,
                          total_records=-1, total_bytes=-1)

    def list_flights_impl(self) -> list[FlightInfo]:
        with self._lock:
            return [self._info_for(name) for name in self._provider.list()]

    def get_flight_info_impl(self, descriptor: FlightDescriptor) -> FlightInfo:
        if descriptor.path is None:
            cmd = descriptor.parsed_command()
            if isinstance(cmd, QueryCommand):
                return self._plan_query_info(cmd, descriptor)
            raise FlightInvalidArgument(
                f"in-memory store plans path or query descriptors, not "
                f"{type(cmd).__name__}")
        name = descriptor.path[0]
        with self._lock:
            if not self._provider.exists(name):
                raise FlightNotFound(f"no such flight: {name}", detail={"dataset": name})
            return self._info_for(name)

    def _execute_query(self, cmd: QueryCommand) -> tuple[Schema, Iterator[RecordBatch]]:
        """Native QueryCommand execution: filter/project where the data lives.

        A plan carrying aggregations runs the *partial* half of the operator
        split instead: the stream is one per-group state batch (per-group
        sums/counts/extrema — see ``query.engine.partial_schema``), not rows.
        The caller (cluster head or client) merges state batches from every
        shard with ``merge_partials`` — only group-sized state crosses the
        wire, never the surviving rows."""
        from ...query.engine import execute, partial_aggregate

        plan = cmd.plan
        with self._lock:
            if not self._provider.exists(plan.dataset):
                raise FlightNotFound(f"no such dataset: {plan.dataset}",
                                     detail={"dataset": plan.dataset})
            stop = cmd.stop if cmd.stop >= 0 else None
            batches = self._provider.read_batches(plan.dataset, cmd.start, stop)
            schema = self._provider.schema(plan.dataset)
        out_schema = _query_out_schema(plan, schema)
        if plan.aggregations:
            state = partial_aggregate(plan, batches, schema)
            with self._lock:
                self.queries_executed += 1
                self.partial_aggs_executed += 1
                self.query_rows_in += sum(b.num_rows for b in batches)
                self.query_rows_out += state.num_rows
            return out_schema, iter([state])
        results = list(execute(plan, batches))
        with self._lock:
            self.queries_executed += 1
            self.query_rows_in += sum(b.num_rows for b in batches)
            self.query_rows_out += sum(b.num_rows for b in results)
        return out_schema, iter(results)

    def do_get_impl(self, ticket: Ticket) -> tuple[Schema, Iterator[RecordBatch]]:
        cmd = ticket.command()
        if isinstance(cmd, QueryCommand):
            return self._execute_query(cmd)
        if isinstance(cmd, (StagedPutCommand, ExchangeCommand)):
            raise FlightInvalidArgument(
                f"{type(cmd).__name__} tickets are not redeemable via DoGet")
        name = cmd.dataset
        with self._lock:
            if not self._provider.exists(name):
                raise FlightNotFound(f"no such flight: {name}", detail={"dataset": name})
            stop = cmd.stop if cmd.stop >= 0 else None
            batches = self._provider.read_batches(name, cmd.start, stop)
            schema = self._provider.schema(name)
        return schema, iter(batches)

    def do_get_encoded(
        self, ticket: Ticket
    ) -> tuple[EncodedMessage, list[EncodedMessage]] | None:
        # A subclass or monkeypatch that changes do_get_impl (pacing, fault
        # injection) must keep serving through it.
        if (
            not self.cache_encoded
            or type(self).do_get_impl is not InMemoryFlightServer.do_get_impl
            or "do_get_impl" in self.__dict__
        ):
            return None
        cmd = ticket.command()
        if isinstance(cmd, QueryCommand):
            # pass-through queries (no predicate, full projection, no limit)
            # are range reads in disguise: serve them from the cache.  Real
            # pushdown queries return per-request results and must never
            # enter (or poison) the cache.
            plan = cmd.plan
            with self._lock:
                schema = (self._provider.schema(plan.dataset)
                          if self._provider.exists(plan.dataset) else None)
            if schema is None or not plan.is_passthrough(schema.names):
                return None
            name, start, stop = plan.dataset, cmd.start, cmd.stop
        elif isinstance(cmd, RangeReadCommand):
            name, start, stop = cmd.dataset, cmd.start, cmd.stop
        else:
            return None
        stop_ix = stop if stop >= 0 else None
        with self._lock:
            if not self._provider.exists(name):
                raise FlightNotFound(f"no such flight: {name}", detail={"dataset": name})
            entry = self._encoded.get(name)
            if entry is not None:
                self.cache_hits += 1
                self.rows_served += sum(entry[2][start:stop_ix])
                return entry[0], list(entry[1][start:stop_ix])
            self.cache_misses += 1
            batches = self._provider.read_batches(name)
            schema = self._provider.schema(name)
            version = self._versions.get(name, 0)
        # encode outside the lock: a multi-GB first build must not stall
        # every other RPC on this server.  For the disk provider the batches
        # are mmap-backed views, so this pass is the only value-data read.
        schema_msg = encode_schema(schema)
        msgs = []
        for b in batches:
            self.encode_calls += 1
            msgs.append(encode_batch(b, self.wire_codec))
        entry = (schema_msg, tuple(msgs), tuple(b.num_rows for b in batches))
        with self._lock:
            # cache only if the dataset didn't change while we encoded; the
            # stale-but-consistent snapshot still serves this request
            if self._versions.get(name, 0) == version and self._provider.exists(name):
                self._encoded[name] = entry
            self.rows_served += sum(entry[2][start:stop_ix])
        return entry[0], list(entry[1][start:stop_ix])

    def _rpc_inline_ok(self, req: dict) -> bool:
        """Widen the base certificate: a cache-warm ``DoGet`` is pure
        memoryview queueing (no encode, no user code, no blocking), so the
        event loop may serve it on the dispatch thread.  A cold cache, an
        overridden ``do_get_impl``, a real pushdown query, or a foreign
        codec all fall back to the worker pool — first request per dataset
        warms the cache through a worker, the rest inline."""
        if req.get("method") == "DoGet":
            if any(type(m).__module__ != MiddlewareStack.__module__
                   for m in self.middleware.items):
                return False
            opts = req.get("options") or {}
            if (opts.get("wire_codec") or self.wire_codec) != self.wire_codec:
                return False
            if (
                not self.cache_encoded
                or type(self).do_get_impl is not InMemoryFlightServer.do_get_impl
                or "do_get_impl" in self.__dict__
            ):
                return False
            try:
                cmd = Ticket.from_json(req["ticket"]).command()
            except Exception:
                return False
            if isinstance(cmd, RangeReadCommand):
                name = cmd.dataset
            elif isinstance(cmd, QueryCommand):
                name = cmd.plan.dataset
                with self._lock:
                    schema = (self._provider.schema(name)
                              if self._provider.exists(name) else None)
                if schema is None or not cmd.plan.is_passthrough(schema.names):
                    return False
            else:
                return False
            with self._lock:
                return name in self._encoded
        return super()._rpc_inline_ok(req)

    # -- transactional staged puts -------------------------------------- #
    def _ensure_reaper(self) -> None:
        """Start the GC reaper lazily (under ``self._lock``); it exits when
        the staging store drains and restarts on the next stage."""
        if self._reaper is not None and self._reaper.is_alive():
            return
        self._reaper_stop = threading.Event()
        self._reaper = threading.Thread(
            target=self._reap_loop, daemon=True,
            name=f"stage-gc-{self.location_name}")
        self._reaper.start()

    def _reap_loop(self) -> None:
        interval = min(max(self.stage_ttl / 4.0, 0.02), 30.0)
        stop = self._reaper_stop
        while not stop.wait(interval):
            self._gc_staged()
            with self._lock:
                if not self._staged:  # idle: exit; _ensure_reaper restarts us
                    self._reaper = None
                    return

    def _gc_staged(self) -> None:
        """Discard expired stages — an orphaned writer's payload is never
        readable, and stops holding memory after ``stage_ttl`` seconds.

        *Prepared* stages are exempt: after a yes vote the txn's fate
        belongs to the coordinator, and reaping it here could land between
        a sibling shard's commit and ours — a half-visible txn.  The cost
        is the classic 2PC in-doubt window: a coordinator that dies after
        prepare leaves the stage pinned until an explicit txn-abort."""
        now = time.monotonic()
        with self._lock:
            expired = [t for t, s in self._staged.items()
                       if s.expires_at <= now and not s.prepared]
            for txn_id in expired:
                self._staged.pop(txn_id)
                self._provider.discard_stage(txn_id)
                self._finish_txn(txn_id, "expired", {})
                self.txn_gc_reaped += 1

    def _finish_txn(self, txn_id: str, outcome: str, stats: dict) -> None:
        """Record a txn's fate (idempotency window). Caller holds the lock."""
        self._finished_txns[txn_id] = (outcome, stats)
        while len(self._finished_txns) > _TXN_FINISH_WINDOW:
            self._finished_txns.popitem(last=False)

    def _stage_put(self, cmd: StagedPutCommand, schema: Schema,
                   received: list[RecordBatch]) -> dict:
        """The stage leg: payload lands keyed by txn id, invisible to reads.

        Stages never touch ``_store`` or the encode-once cache — cache
        invalidation happens on commit, when the data becomes visible.
        Re-staged streams (scheduler put retries) are deduplicated by
        content hash *within the txn*, so a retry cannot double rows.
        Like the plain-put guard this is gated on ``dedup_puts`` and shares
        its trade-off: byte-identical parallel streams in one txn are
        indistinguishable from retries and collapse to one — stage distinct
        payloads, or construct the server with ``dedup_puts=False`` (which
        also makes stage-leg retries unsafe, exactly as for plain puts)."""
        digest = _content_digest(schema, received) if self.dedup_puts else None
        nbytes = sum(b.nbytes() for b in received)
        rows = sum(b.num_rows for b in received)
        with self._lock:
            outcome = self._finished_txns.get(cmd.txn_id)
            if outcome is not None:
                raise FlightInvalidArgument(
                    f"txn {cmd.txn_id!r} already {outcome[0]}: cannot stage",
                    detail={"txn_id": cmd.txn_id, "outcome": outcome[0]})
            txn = self._staged.get(cmd.txn_id)
            if txn is None:
                txn = self._staged[cmd.txn_id] = _StagedTxn(cmd.dataset, schema)
                self._ensure_reaper()
            elif txn.dataset != cmd.dataset:
                raise FlightInvalidArgument(
                    f"txn {cmd.txn_id!r} is bound to dataset {txn.dataset!r}",
                    detail={"txn_id": cmd.txn_id, "dataset": txn.dataset})
            elif txn.schema != schema:
                raise FlightInvalidArgument(
                    f"schema mismatch on staged stream of txn {cmd.txn_id!r}")
            txn.expires_at = time.monotonic() + self.stage_ttl
            if digest is not None:
                if digest in txn.digests:  # retried stage stream: idempotent
                    self.put_dedup_hits += 1
                    return {"staged": True, "txn_id": cmd.txn_id, "deduped": True,
                            "batches": len(received), "rows": rows,
                            "bytes": nbytes}
                txn.digests.add(digest)
            # payload lands in the provider (durably, for the disk backend)
            self._provider.stage(cmd.txn_id, cmd.dataset, schema, received)
            txn.batches += len(received)
            txn.rows += rows
            txn.nbytes += nbytes
        return {"staged": True, "txn_id": cmd.txn_id, "batches": len(received),
                "rows": rows, "bytes": nbytes}

    def _txn_prepare(self, o: dict) -> dict:
        """Phase-1 vote: is this txn's stage present and healthy here?

        Never raises for an unknown txn — the coordinator uses ``staged``
        to tell participants from bystanders.  Preparing refreshes the TTL
        so GC cannot race the commit that immediately follows."""
        self._gc_staged()
        txn_id = o["txn_id"]
        with self._lock:
            outcome = self._finished_txns.get(txn_id)
            if outcome is not None and outcome[0] == "committed":
                return {"txn_id": txn_id, "staged": True, "committed": True,
                        **outcome[1]}
            if outcome is not None and outcome[0] == "expired":
                # the stage was here but the reaper ate it: the coordinator
                # must abort the whole txn, not commit the surviving shards
                return {"txn_id": txn_id, "staged": False, "expired": True}
            txn = self._staged.get(txn_id)
            if txn is None or outcome is not None:
                return {"txn_id": txn_id, "staged": False}
            txn.prepared = True
            txn.expires_at = time.monotonic() + self.stage_ttl
            # durable backends persist the yes vote: a prepared stage must
            # survive a restart and stay GC-exempt in the next process too
            self._provider.mark_prepared(txn_id)
            return {"txn_id": txn_id, "staged": True,
                    "batches": txn.batches,
                    "rows": txn.rows,
                    "bytes": txn.nbytes}

    def _txn_commit(self, o: dict) -> dict:
        """Flip a txn's staged batches into the visible dataset atomically.

        The flip happens under one ``self._lock`` acquisition — the same
        lock every DoGet/query snapshot takes — so a concurrent reader sees
        either none or all of the txn's batches, never a torn prefix."""
        self._gc_staged()
        txn_id = o["txn_id"]
        with self._lock:
            outcome = self._finished_txns.get(txn_id)
            if outcome is not None:
                if outcome[0] == "committed":  # duplicate commit: idempotent
                    return {**outcome[1], "committed": True, "duplicate": True}
                if outcome[0] == "aborted":
                    raise FlightInvalidArgument(
                        f"txn {txn_id!r} was aborted: cannot commit",
                        detail={"txn_id": txn_id, "outcome": outcome[0]})
            txn = self._staged.pop(txn_id, None)
            if txn is None:
                raise FlightNotFound(
                    f"no staged txn {txn_id!r} (never staged, or GC'd after "
                    f"{self.stage_ttl}s)", detail={"txn_id": txn_id})
            name = txn.dataset
            # the provider makes the staged payload part of the dataset —
            # on disk, an atomic rename of the staged part files
            self._provider.commit_stage(txn_id)
            self._encoded.pop(name, None)  # visibility flip invalidates cache
            self._versions[name] = self._versions.get(name, 0) + 1
            stats = {
                "txn_id": txn_id,
                "dataset": name,
                "batches": txn.batches,
                "rows": txn.rows,
                "bytes": txn.nbytes,
            }
            self._finish_txn(txn_id, "committed", stats)
            self.txn_commits += 1
        return {**stats, "committed": True}

    def _txn_abort(self, o: dict) -> dict:
        """Discard a txn's staged batches.  Unknown/expired txns are a
        no-op (idempotent — the coordinator aborts broadly on failure);
        aborting a *committed* txn is a protocol error and surfaces."""
        self._gc_staged()
        txn_id = o["txn_id"]
        with self._lock:
            outcome = self._finished_txns.get(txn_id)
            if outcome is not None:
                if outcome[0] == "committed":
                    raise FlightInvalidArgument(
                        f"txn {txn_id!r} already committed: cannot abort",
                        detail={"txn_id": txn_id})
                if outcome[0] == "aborted":  # duplicate abort: idempotent
                    return {"txn_id": txn_id, "aborted": True, "duplicate": True}
                return {"txn_id": txn_id, "aborted": False, "expired": True}
            txn = self._staged.pop(txn_id, None)
            if txn is None:
                return {"txn_id": txn_id, "aborted": False}
            self._provider.discard_stage(txn_id)
            self._finish_txn(txn_id, "aborted", {"dataset": txn.dataset})
            self.txn_aborts += 1
        return {"txn_id": txn_id, "aborted": True}

    def do_put_impl(self, descriptor, schema, batches) -> dict:
        if descriptor.path is None and descriptor.command is not None:
            cmd = descriptor.parsed_command()
            if isinstance(cmd, StagedPutCommand):
                if cmd.phase != "stage":
                    raise FlightInvalidArgument(
                        f"DoPut takes the stage leg only; {cmd.phase!r} rides "
                        f"the txn-{cmd.phase} action",
                        detail={"phase": cmd.phase})
                return self._stage_put(cmd, schema, list(batches))
        name = descriptor.path[0] if descriptor.path else descriptor.key
        received = list(batches)
        digest = _content_digest(schema, received) if self.dedup_puts else None
        with self._lock:
            if digest is not None:
                recent = self._recent_puts.setdefault(name, OrderedDict())
                if digest in recent:
                    # retried put of an already-committed payload: idempotent
                    self.put_dedup_hits += 1
                    return {**recent[digest], "deduped": True}
            self._provider.append(name, schema, received)
            self._encoded.pop(name, None)
            self._versions[name] = self._versions.get(name, 0) + 1
            stats = {
                "batches": len(received),
                "rows": sum(b.num_rows for b in received),
                "bytes": sum(b.nbytes() for b in received),
            }
            if digest is not None:
                recent[digest] = stats
                while len(recent) > _PUT_DEDUP_WINDOW:
                    recent.popitem(last=False)
        return stats

    def shutdown(self) -> None:
        self._reaper_stop.set()
        self._provider.close()
        super().shutdown()

    def do_action_impl(self, action: Action) -> list[ActionResult]:
        # telemetry export: spans / histogram snapshots as Arrow IPC bodies
        told = telemetry_action(self, action)
        if told is not None:
            return told
        if action.type == "txn-prepare":
            return [ActionResult(json.dumps(
                self._txn_prepare(parse_txn_body(action.body))).encode())]
        if action.type == "txn-commit":
            return [ActionResult(json.dumps(
                self._txn_commit(parse_txn_body(action.body))).encode())]
        if action.type == "txn-abort":
            return [ActionResult(json.dumps(
                self._txn_abort(parse_txn_body(action.body))).encode())]
        if action.type == "drop":
            name = action.body.decode()
            with self._lock:
                self._provider.drop(name)
                self._encoded.pop(name, None)
                self._recent_puts.pop(name, None)
                self._versions[name] = self._versions.get(name, 0) + 1
            return [ActionResult(b"dropped")]
        if action.type == "list-names":
            with self._lock:
                names = ",".join(self._provider.list())
            return [ActionResult(names.encode())]
        if action.type == "aggregate":
            # filtered aggregation where the data lives — only scalars (or,
            # for grouped plans, per-group result columns) cross the wire
            from ...query.engine import QueryPlan, aggregate  # lazy import cycle

            plan = QueryPlan.deserialize(action.body)
            with self._lock:
                if not self._provider.exists(plan.dataset):
                    raise FlightNotFound(f"no such dataset: {plan.dataset}",
                                         detail={"dataset": plan.dataset})
                batches = self._provider.read_batches(plan.dataset)
                schema = self._provider.schema(plan.dataset)
            res = aggregate(plan, batches, schema)
            if isinstance(res, RecordBatch):  # grouped → columnar JSON
                res = {"group_by": plan.group_by, "columns": res.to_pydict()}
            return [ActionResult(json.dumps(res).encode())]
        if action.type == "local-join":
            # inner equi-join of two datasets living on this server; the
            # result lands as a new local dataset (the per-shard leg of the
            # cluster's shuffled join — key-aligned inputs, local output)
            from ...query.engine import hash_join

            spec = json.loads(action.body.decode())
            on = spec["on"] if isinstance(spec["on"], list) else [spec["on"]]
            with self._lock:
                for name in (spec["left"], spec["right"]):
                    if not self._provider.exists(name):
                        raise FlightNotFound(f"no such dataset: {name}",
                                             detail={"dataset": name})
                lb = self._provider.read_batches(spec["left"])
                rb = self._provider.read_batches(spec["right"])
                ls = self._provider.schema(spec["left"])
                rs = self._provider.schema(spec["right"])
            joined = hash_join(lb, rb, on, ls, rs)
            self.add_dataset(spec["into"], [joined], joined.schema)
            with self._lock:
                self.joins_executed += 1
            return [ActionResult(json.dumps(
                {"dataset": spec["into"], "rows": joined.num_rows}).encode())]
        if action.type == "health":
            return [ActionResult(b"ok")]
        if action.type == "heartbeat":
            # a liveness ping that also tells the caller who answered —
            # cluster probers feed this into their membership registry
            return [ActionResult(json.dumps(
                {"ok": True, "shard": self.shard_id}).encode())]
        if action.type == "server-stats":
            with self._lock:
                stats = {
                    "encode_calls": self.encode_calls,
                    "encode_cache_hits": self.cache_hits,
                    "encode_cache_misses": self.cache_misses,
                    "encode_cache_datasets": len(self._encoded),
                    "rows_served": self.rows_served,
                    "wire_codec": self.wire_codec,
                    "coalesce": self.coalesce,
                    "queries_executed": self.queries_executed,
                    "query_rows_in": self.query_rows_in,
                    "query_rows_out": self.query_rows_out,
                    "partial_aggs_executed": self.partial_aggs_executed,
                    "joins_executed": self.joins_executed,
                    "put_dedup_hits": self.put_dedup_hits,
                    "staged_txns": len(self._staged),
                    "staged_bytes": sum(t.nbytes for t in self._staged.values()),
                    "txn_commits": self.txn_commits,
                    "txn_aborts": self.txn_aborts,
                    "txn_gc_reaped": self.txn_gc_reaped,
                    "storage": self._provider.stats(),
                    "io": (self._listener.stats()
                           if self._listener is not None else None),
                    "verbs": self.metrics.snapshot(),
                }
            return [ActionResult(json.dumps(stats).encode())]
        if action.type == "stats":
            with self._lock:
                stats = {name: self._provider.info(name)
                         for name in self._provider.list()}
            return [ActionResult(json.dumps(stats).encode())]
        raise FlightError(f"unknown action {action.type!r}")

    def do_exchange_impl(self, descriptor, schema, batch) -> RecordBatch:
        return batch  # echo; scoring services override

"""Flight server: RPC dispatch + an in-memory store implementation.

``FlightServerBase`` defines the six verbs (GetFlightInfo, ListFlights,
DoGet, DoPut, DoAction, DoExchange) against abstract handlers; it can be
used in-process (zero-copy object handoff) or served over TCP via
``serve_tcp`` (thread per connection, streaming IPC frames).

``InMemoryFlightServer`` is the paper's "simple data producer with an
InMemoryStore" (§4.2.2) — datasets are lists of RecordBatches keyed by
descriptor path; tickets are idempotent (dataset, start, stop) range reads,
so any batch range can be re-fetched (hedged reads / resume).

Data-plane fast paths (the wire-speed work):

* **encode-once cache** — ``InMemoryFlightServer`` pre-encodes each stored
  dataset to ``EncodedMessage``s on first DoGet and serves every later DoGet
  from the cache (zero ``encode_batch`` calls — asserted via the
  ``server-stats`` action counters).  The cache is invalidated on DoPut /
  ``add_dataset`` / ``drop``, and bypassed whenever ``do_get_impl`` is
  overridden (query pushdown, paced shards, test monkeypatches) so
  behavior-modifying subclasses keep their semantics.
* **frame coalescing** — DoGet streams go out via
  ``FrameConnection.send_data_many`` (many frames per ``sendmsg``) unless
  ``coalesce=False``.
* ``wire_codec`` selects the IPC metadata codec (binary default; json kept
  for comparison benchmarks).
"""
from __future__ import annotations

import json
import threading
from itertools import chain
from typing import Callable, Iterable, Iterator

from ..ipc import DEFAULT_CODEC, EncodedMessage, decode_message, encode_batch, encode_eos, encode_schema
from ..recordbatch import RecordBatch
from ..schema import Schema
from .protocol import (
    Action,
    ActionResult,
    FlightDescriptor,
    FlightEndpoint,
    FlightError,
    FlightInfo,
    Location,
    Ticket,
)
from .transport import KIND_CTRL, KIND_DATA, FrameConnection, SocketListener


class FlightServerBase:
    """Override the ``*_impl`` handlers to build a service."""

    def __init__(
        self,
        location_name: str = "local",
        auth_token: str | None = None,
        *,
        wire_codec: str = DEFAULT_CODEC,
        coalesce: bool = True,
    ):
        self.location_name = location_name
        self.auth_token = auth_token
        self.wire_codec = wire_codec
        self.coalesce = coalesce
        self.encode_calls = 0  # encode_batch invocations on the DoGet path
        self._listener: SocketListener | None = None

    # -- handlers to override ------------------------------------------- #
    def list_flights_impl(self) -> list[FlightInfo]:
        raise NotImplementedError

    def get_flight_info_impl(self, descriptor: FlightDescriptor) -> FlightInfo:
        raise NotImplementedError

    def do_get_impl(self, ticket: Ticket) -> tuple[Schema, Iterator[RecordBatch]]:
        raise NotImplementedError

    def do_get_encoded(
        self, ticket: Ticket
    ) -> tuple[EncodedMessage, list[EncodedMessage]] | None:
        """Optional fast path: pre-encoded ``(schema msg, batch msgs)``.

        Return ``None`` (the default) to serve through ``do_get_impl`` +
        per-request encoding."""
        return None

    def do_put_impl(
        self, descriptor: FlightDescriptor, schema: Schema, batches: Iterator[RecordBatch]
    ) -> dict:
        raise NotImplementedError

    def do_action_impl(self, action: Action) -> list[ActionResult]:
        raise NotImplementedError

    def do_exchange_impl(
        self, descriptor: FlightDescriptor, schema: Schema, batch: RecordBatch
    ) -> RecordBatch:
        """Per-batch bidirectional handler (scoring microservice pattern)."""
        raise NotImplementedError

    # -- locations -------------------------------------------------------- #
    def locations(self) -> tuple[Location, ...]:
        locs: list[Location] = [Location.inproc(self.location_name)]
        if self._listener is not None:
            locs.append(Location.for_tcp(self._listener.host, self._listener.port))
        return tuple(locs)

    # -- TCP serving ------------------------------------------------------ #
    def serve_tcp(self, host: str = "127.0.0.1", port: int = 0) -> "FlightServerBase":
        self._listener = SocketListener(self._handle_connection, host, port).start()
        return self

    @property
    def port(self) -> int:
        assert self._listener is not None, "serve_tcp() first"
        return self._listener.port

    def shutdown(self) -> None:
        if self._listener is not None:
            self._listener.stop()
            self._listener = None

    # -- dispatch ---------------------------------------------------------- #
    def _check_auth(self, req: dict) -> None:
        if self.auth_token is not None and req.get("token") != self.auth_token:
            raise FlightError("unauthenticated: bad or missing token")

    def _handle_connection(self, conn: FrameConnection) -> None:
        """One connection = a sequence of RPCs (like an HTTP/2 channel)."""
        while True:
            try:
                kind, req, _ = conn.recv_frame()
            except (ConnectionError, OSError):
                return
            if kind != KIND_CTRL:
                raise FlightError("expected control frame opening an RPC")
            method = req.get("method")
            try:
                self._check_auth(req)
                if method == "GetFlightInfo":
                    info = self.get_flight_info_impl(FlightDescriptor.from_json(req["descriptor"]))
                    conn.send_ctrl({"info": info.to_json()})
                elif method == "ListFlights":
                    infos = self.list_flights_impl()
                    conn.send_ctrl({"infos": [i.to_json() for i in infos]})
                elif method == "DoAction":
                    results = self.do_action_impl(Action.from_json(req["action"]))
                    conn.send_ctrl({"results": [r.to_json() for r in results]})
                elif method == "DoGet":
                    self._serve_do_get(conn, Ticket.from_json(req["ticket"]))
                elif method == "DoPut":
                    self._serve_do_put(conn, FlightDescriptor.from_json(req["descriptor"]))
                elif method == "DoExchange":
                    self._serve_do_exchange(conn, FlightDescriptor.from_json(req["descriptor"]))
                elif method == "Handshake":
                    conn.send_ctrl({"ok": True})
                else:
                    raise FlightError(f"unknown method {method!r}")
            except FlightError as e:
                conn.send_ctrl({"error": str(e)})

    def _send_stream(self, conn: FrameConnection, msgs: Iterable[EncodedMessage]) -> None:
        if self.coalesce:
            conn.send_data_many(msgs)
        else:
            for m in msgs:
                conn.send_data(m)

    def _serve_do_get(self, conn: FrameConnection, ticket: Ticket) -> None:
        pre = self.do_get_encoded(ticket)
        if pre is not None:  # encode-once cache: no per-request encoding
            schema_msg, batch_msgs = pre
            conn.send_ctrl({"ok": True})
            self._send_stream(
                conn, chain((schema_msg,), batch_msgs, (encode_eos(self.wire_codec),))
            )
            return
        schema, batches = self.do_get_impl(ticket)
        conn.send_ctrl({"ok": True})

        def frames() -> Iterator[EncodedMessage]:
            yield encode_schema(schema)
            for b in batches:
                self.encode_calls += 1
                yield encode_batch(b, self.wire_codec)
            yield encode_eos(self.wire_codec)

        self._send_stream(conn, frames())

    def _recv_stream(self, conn: FrameConnection) -> tuple[Schema, Iterator[RecordBatch]]:
        kind, meta, body = conn.recv_frame()
        if kind != KIND_DATA:
            raise FlightError("expected schema message")
        msg = decode_message(meta, body)
        if msg.kind != "schema":
            raise FlightError(f"expected schema, got {msg.kind}")
        schema = msg.schema

        def gen() -> Iterator[RecordBatch]:
            while True:
                k, m, b = conn.recv_frame()
                if k != KIND_DATA:
                    raise FlightError("expected data frame in stream")
                dm = decode_message(m, b)
                if dm.kind == "eos":
                    return
                yield dm.batch(schema)

        return schema, gen()

    def _serve_do_put(self, conn: FrameConnection, descriptor: FlightDescriptor) -> None:
        conn.send_ctrl({"ok": True})
        schema, batches = self._recv_stream(conn)
        stats = self.do_put_impl(descriptor, schema, batches)
        conn.send_ctrl({"ok": True, "stats": stats})

    def _serve_do_exchange(self, conn: FrameConnection, descriptor: FlightDescriptor) -> None:
        conn.send_ctrl({"ok": True})
        kind, meta, body = conn.recv_frame()
        msg = decode_message(meta, body)
        if msg.kind != "schema":
            raise FlightError("exchange: expected schema first")
        in_schema = msg.schema
        out_schema_sent = False
        while True:
            k, m, b = conn.recv_frame()
            dm = decode_message(m, b)
            if dm.kind == "eos":
                conn.send_data(encode_eos(self.wire_codec))
                return
            out = self.do_exchange_impl(descriptor, in_schema, dm.batch(in_schema))
            if not out_schema_sent:
                conn.send_data(encode_schema(out.schema))
                out_schema_sent = True
            conn.send_data(encode_batch(out, self.wire_codec))


class InMemoryFlightServer(FlightServerBase):
    """Dataset store: descriptor path[0] -> list[RecordBatch]."""

    def __init__(
        self,
        location_name: str = "local",
        auth_token: str | None = None,
        batches_per_endpoint: int = 0,
        shard_id: int | None = None,
        *,
        wire_codec: str = DEFAULT_CODEC,
        coalesce: bool = True,
        cache_encoded: bool = True,
    ):
        super().__init__(location_name, auth_token, wire_codec=wire_codec, coalesce=coalesce)
        self._store: dict[str, list[RecordBatch]] = {}
        self._schemas: dict[str, Schema] = {}
        self._lock = threading.Lock()
        self.batches_per_endpoint = batches_per_endpoint  # 0 = single endpoint
        self.shard_id = shard_id  # set by cluster.py: stamped into tickets
        # encode-once cache: dataset -> (schema msg, per-batch msgs), built on
        # first DoGet, invalidated whenever the dataset changes
        self.cache_encoded = cache_encoded
        self._encoded: dict[str, tuple[EncodedMessage, tuple[EncodedMessage, ...]]] = {}
        self._versions: dict[str, int] = {}  # bumped on every dataset mutation
        self.cache_hits = 0
        self.cache_misses = 0

    # -- direct (in-proc) API ------------------------------------------- #
    def add_dataset(
        self, name: str, batches: list[RecordBatch], schema: Schema | None = None
    ) -> None:
        """``schema`` allows registering an empty shard of a known dataset."""
        if schema is None:
            schema = batches[0].schema
        with self._lock:
            self._store[name] = list(batches)
            self._schemas[name] = schema
            self._encoded.pop(name, None)
            self._versions[name] = self._versions.get(name, 0) + 1

    def dataset(self, name: str) -> list[RecordBatch]:
        return self._store[name]

    # -- handlers ---------------------------------------------------------- #
    def _info_for(self, name: str) -> FlightInfo:
        batches = self._store[name]
        n = len(batches)
        per = self.batches_per_endpoint or n or 1
        extra = {} if self.shard_id is None else {"shard": self.shard_id}
        endpoints = [
            FlightEndpoint(
                Ticket.for_range(name, i, min(i + per, n), **extra),
                self.locations(),
                app_metadata=extra or None,
            )
            for i in range(0, max(n, 1), per)
        ]
        return FlightInfo(
            self._schemas[name],
            FlightDescriptor.for_path(name),
            endpoints,
            total_records=sum(b.num_rows for b in batches),
            total_bytes=sum(b.nbytes() for b in batches),
        )

    def list_flights_impl(self) -> list[FlightInfo]:
        with self._lock:
            return [self._info_for(name) for name in self._store]

    def get_flight_info_impl(self, descriptor: FlightDescriptor) -> FlightInfo:
        if descriptor.path is None:
            raise FlightError("in-memory store resolves path descriptors only")
        name = descriptor.path[0]
        with self._lock:
            if name not in self._store:
                raise FlightError(f"no such flight: {name}")
            return self._info_for(name)

    def do_get_impl(self, ticket: Ticket) -> tuple[Schema, Iterator[RecordBatch]]:
        r = ticket.range()
        name = r["dataset"]
        with self._lock:
            if name not in self._store:
                raise FlightError(f"no such flight: {name}")
            batches = self._store[name][r["start"] : r["stop"]]
            schema = self._schemas[name]
        return schema, iter(batches)

    def do_get_encoded(
        self, ticket: Ticket
    ) -> tuple[EncodedMessage, list[EncodedMessage]] | None:
        # A subclass or monkeypatch that changes do_get_impl (query pushdown,
        # paced streams, fault injection) must keep serving through it.
        if (
            not self.cache_encoded
            or type(self).do_get_impl is not InMemoryFlightServer.do_get_impl
            or "do_get_impl" in self.__dict__
        ):
            return None
        r = ticket.range()
        name = r["dataset"]
        with self._lock:
            if name not in self._store:
                raise FlightError(f"no such flight: {name}")
            entry = self._encoded.get(name)
            if entry is not None:
                self.cache_hits += 1
                return entry[0], list(entry[1][r["start"] : r["stop"]])
            self.cache_misses += 1
            batches = list(self._store[name])
            schema = self._schemas[name]
            version = self._versions.get(name, 0)
        # encode outside the lock: a multi-GB first build must not stall
        # every other RPC on this server
        schema_msg = encode_schema(schema)
        msgs = []
        for b in batches:
            self.encode_calls += 1
            msgs.append(encode_batch(b, self.wire_codec))
        entry = (schema_msg, tuple(msgs))
        with self._lock:
            # cache only if the dataset didn't change while we encoded; the
            # stale-but-consistent snapshot still serves this request
            if self._versions.get(name, 0) == version and name in self._store:
                self._encoded[name] = entry
        return entry[0], list(entry[1][r["start"] : r["stop"]])

    def do_put_impl(self, descriptor, schema, batches) -> dict:
        name = descriptor.path[0] if descriptor.path else descriptor.key
        received = list(batches)
        with self._lock:
            self._store.setdefault(name, [])
            self._store[name].extend(received)
            self._schemas.setdefault(name, schema)
            self._encoded.pop(name, None)
            self._versions[name] = self._versions.get(name, 0) + 1
        return {
            "batches": len(received),
            "rows": sum(b.num_rows for b in received),
            "bytes": sum(b.nbytes() for b in received),
        }

    def do_action_impl(self, action: Action) -> list[ActionResult]:
        if action.type == "drop":
            name = action.body.decode()
            with self._lock:
                self._store.pop(name, None)
                self._encoded.pop(name, None)
                self._versions[name] = self._versions.get(name, 0) + 1
            return [ActionResult(b"dropped")]
        if action.type == "list-names":
            with self._lock:
                names = ",".join(self._store)
            return [ActionResult(names.encode())]
        if action.type == "health":
            return [ActionResult(b"ok")]
        if action.type == "server-stats":
            with self._lock:
                stats = {
                    "encode_calls": self.encode_calls,
                    "encode_cache_hits": self.cache_hits,
                    "encode_cache_misses": self.cache_misses,
                    "encode_cache_datasets": len(self._encoded),
                    "wire_codec": self.wire_codec,
                    "coalesce": self.coalesce,
                }
            return [ActionResult(json.dumps(stats).encode())]
        if action.type == "stats":
            with self._lock:
                stats = {
                    name: {
                        "batches": len(bs),
                        "rows": sum(b.num_rows for b in bs),
                        "bytes": sum(b.nbytes() for b in bs),
                    }
                    for name, bs in self._store.items()
                }
            return [ActionResult(json.dumps(stats).encode())]
        raise FlightError(f"unknown action {action.type!r}")

    def do_exchange_impl(self, descriptor, schema, batch) -> RecordBatch:
        return batch  # echo; scoring services override

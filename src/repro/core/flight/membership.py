"""Cluster membership: heartbeat registry, failure detection, epoch views.

The head node's authoritative picture of which shard endpoints are alive —
the precondition for everything the paper's parallel-stream topology (§3,
Fig 2) assumes for free.  The detector is the timeout-plus-grace design of
``repro.distributed.fault.FailureDetector`` (phi-accrual-lite) re-grounded
in shard ids and Flight locations, with one addition the data plane needs:
an **epoch-versioned cluster view**.

* ``ClusterMembership`` — per-shard state machine HEALTHY → SUSPECT → DEAD
  driven by ``heartbeat()`` / ``sweep()``.  Every *view change* (a shard
  joins, leaves, dies, or revives — anything that alters which shards a
  planner may route to) bumps a monotonically increasing **epoch**.  Plans
  (``FlightInfo``) are stamped with the epoch they were computed under, so
  a client holding endpoints from epoch E can detect that the world has
  moved on and re-plan instead of burning failover attempts on tombstones.
  SUSPECT transitions do *not* bump the epoch: a suspect shard is still
  routable (it gets demoted in replica orderings), so no plan is invalid.
* ``MembershipProber`` — the head's active prober: calls each registered
  shard's ``health`` probe on an interval, feeding successes to
  ``heartbeat()`` and then ``sweep()``-ing.  Shards may also push
  heartbeats through the head's ``heartbeat`` action; both paths meet in
  the same registry.

The registry never forgets a dead shard (its id stays tombstoned) — shard
ids index into the cluster's shard table, and resurrecting an id with
different data would violate every outstanding ticket.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from enum import Enum
from typing import Callable, Iterable


class ShardState(str, Enum):
    HEALTHY = "healthy"
    SUSPECT = "suspect"    # missed heartbeats, still routable (last resort)
    DEAD = "dead"          # failure detector gave up, or explicitly killed
    REMOVED = "removed"    # gracefully drained + deregistered


@dataclass
class ShardEntry:
    shard_id: int
    locations: tuple[str, ...] = ()
    state: ShardState = ShardState.HEALTHY
    last_heartbeat: float = field(default_factory=time.monotonic)
    joined_epoch: int = 0
    heartbeats: int = 0


@dataclass(frozen=True)
class ClusterView:
    """An immutable snapshot of membership at one epoch."""

    epoch: int
    shards: tuple[tuple[int, str, tuple[str, ...]], ...]  # (id, state, locations)

    def alive(self) -> list[int]:
        return [sid for sid, state, _ in self.shards
                if state in (ShardState.HEALTHY.value, ShardState.SUSPECT.value)]

    def to_json(self) -> dict:
        return {
            "epoch": self.epoch,
            "shards": [
                {"shard": sid, "state": state, "locations": list(locs)}
                for sid, state, locs in self.shards
            ],
        }


class ClusterMembership:
    """Heartbeat registry + failure detector with an epoch-versioned view.

    ``suspect_after`` / ``dead_after`` are seconds without a heartbeat
    before a HEALTHY shard turns SUSPECT / a shard is declared DEAD —
    the same two-threshold ladder as the training-plane detector this
    adapts (``distributed/fault.py``), just on a data-plane timescale.
    """

    def __init__(self, suspect_after: float = 1.0, dead_after: float = 3.0):
        if dead_after <= suspect_after:
            raise ValueError("dead_after must exceed suspect_after")
        self.suspect_after = suspect_after
        self.dead_after = dead_after
        self._shards: dict[int, ShardEntry] = {}
        self._epoch = 0
        self._lock = threading.Lock()

    # -- epoch ------------------------------------------------------------- #
    @property
    def epoch(self) -> int:
        return self._epoch

    def bump(self) -> int:
        """Advance the epoch for an external view change (layout cutover)."""
        with self._lock:
            self._epoch += 1
            return self._epoch

    # -- registry ---------------------------------------------------------- #
    def register(self, shard_id: int, locations: Iterable[str] = ()) -> int:
        """Add (or re-announce) a shard; joining is a view change."""
        with self._lock:
            e = self._shards.get(shard_id)
            if e is not None and e.state not in (ShardState.DEAD, ShardState.REMOVED):
                e.locations = tuple(locations) or e.locations
                return self._epoch
            self._epoch += 1
            self._shards[shard_id] = ShardEntry(
                shard_id, tuple(locations), joined_epoch=self._epoch)
            return self._epoch

    def deregister(self, shard_id: int) -> int:
        """Graceful removal (drained by a rebalance): a view change."""
        with self._lock:
            e = self._shards.get(shard_id)
            if e is None or e.state == ShardState.REMOVED:
                return self._epoch
            e.state = ShardState.REMOVED
            self._epoch += 1
            return self._epoch

    def update_locations(self, shard_id: int, locations: Iterable[str]) -> None:
        with self._lock:
            if shard_id in self._shards:
                self._shards[shard_id].locations = tuple(locations)

    # -- liveness ---------------------------------------------------------- #
    def heartbeat(self, shard_id: int, now: float | None = None) -> None:
        """Record proof of life.  Reviving a DEAD shard is a view change
        (plans may route to it again); REMOVED shards stay removed — a
        drained shard no longer holds data, so late heartbeats are noise."""
        now = time.monotonic() if now is None else now
        with self._lock:
            e = self._shards.get(shard_id)
            if e is None or e.state == ShardState.REMOVED:
                return
            e.last_heartbeat = now
            e.heartbeats += 1
            if e.state == ShardState.DEAD:
                e.state = ShardState.HEALTHY
                self._epoch += 1
            elif e.state == ShardState.SUSPECT:
                e.state = ShardState.HEALTHY

    def sweep(self, now: float | None = None) -> list[int]:
        """Advance the state ladder; returns newly-DEAD shard ids.  Each
        death bumps the epoch once — a plan from before the death must be
        recognizably stale."""
        now = time.monotonic() if now is None else now
        newly_dead: list[int] = []
        with self._lock:
            for e in self._shards.values():
                if e.state in (ShardState.DEAD, ShardState.REMOVED):
                    continue
                dt = now - e.last_heartbeat
                if dt > self.dead_after:
                    e.state = ShardState.DEAD
                    self._epoch += 1
                    newly_dead.append(e.shard_id)
                elif dt > self.suspect_after and e.state == ShardState.HEALTHY:
                    e.state = ShardState.SUSPECT
        return newly_dead

    def mark_dead(self, shard_id: int) -> int:
        """Out-of-band death report (connection refused, fault injection)."""
        with self._lock:
            e = self._shards.get(shard_id)
            if e is None or e.state in (ShardState.DEAD, ShardState.REMOVED):
                return self._epoch
            e.state = ShardState.DEAD
            self._epoch += 1
            return self._epoch

    # -- queries ------------------------------------------------------------ #
    def state(self, shard_id: int) -> ShardState | None:
        with self._lock:
            e = self._shards.get(shard_id)
            return e.state if e is not None else None

    def is_routable(self, shard_id: int) -> bool:
        return self.state(shard_id) in (ShardState.HEALTHY, ShardState.SUSPECT)

    def alive(self) -> list[int]:
        """Routable shard ids in id order (SUSPECT included: still serving)."""
        with self._lock:
            return sorted(
                e.shard_id for e in self._shards.values()
                if e.state in (ShardState.HEALTHY, ShardState.SUSPECT))

    def healthy(self) -> list[int]:
        with self._lock:
            return sorted(e.shard_id for e in self._shards.values()
                          if e.state == ShardState.HEALTHY)

    def view(self) -> ClusterView:
        with self._lock:
            return ClusterView(
                self._epoch,
                tuple(sorted(
                    (e.shard_id, e.state.value, e.locations)
                    for e in self._shards.values())),
            )


class MembershipProber:
    """Active health prober: drives ``ClusterMembership`` from a probe
    callable.  ``probe(shard_id) -> bool`` returns liveness (exceptions
    count as failures); on each tick every non-removed shard is probed and
    the registry swept.  ``on_dead`` (optional) fires once per newly-dead
    shard — the cluster hooks repair/rebalance here."""

    def __init__(
        self,
        membership: ClusterMembership,
        probe: Callable[[int], bool],
        interval: float = 0.25,
        on_dead: Callable[[list[int]], None] | None = None,
    ):
        self.membership = membership
        self.probe = probe
        self.interval = interval
        self.on_dead = on_dead
        self.probes = 0
        self.probe_failures = 0
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None

    def tick(self) -> list[int]:
        """One probe round + sweep (also the manual-clock test hook)."""
        view = self.membership.view()
        for sid, state, _ in view.shards:
            if state == ShardState.REMOVED.value:
                continue
            self.probes += 1
            try:
                ok = bool(self.probe(sid))
            except Exception:
                ok = False
            if ok:
                self.membership.heartbeat(sid)
            else:
                self.probe_failures += 1
        newly_dead = self.membership.sweep()
        if newly_dead and self.on_dead is not None:
            try:
                self.on_dead(newly_dead)
            except Exception:
                pass  # repair hooks must not kill the prober
        return newly_dead

    def start(self) -> "MembershipProber":
        if self._thread is not None:
            return self
        self._stop.clear()

        def loop() -> None:
            while not self._stop.wait(self.interval):
                self.tick()

        self._thread = threading.Thread(
            target=loop, daemon=True, name="flight-membership-prober")
        self._thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        t, self._thread = self._thread, None
        if t is not None:
            t.join(timeout=2.0)

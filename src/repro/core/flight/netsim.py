"""Analytical link/protocol models — the "what would this do on real wires" layer.

The container has one CPU core and a loopback device, so paper Figs 3/5/6
(56 Gbit/s InfiniBand client-server) cannot be *measured* here.  This module
models them the way the roofline models TPU time: a transfer is

    T(bytes, streams) = T_setup + ceil(bytes / msg) * ov_msg / streams_eff
                        + bytes / (BW_link * util(streams))

with per-protocol constants calibrated to the paper's published endpoints
(Fig 2/3/5/6) and, for TPU meshes, to v5e ICI/DCN link rates.  Benchmarks use
it to produce the paper's curve shapes next to our measured loopback numbers;
EXPERIMENTS.md labels which is which.
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass
from typing import Iterable, Iterator


@dataclass(frozen=True)
class LinkModel:
    name: str
    bandwidth: float          # B/s raw wire rate
    setup_s: float            # per-transfer handshake
    per_msg_s: float          # protocol overhead per message/frame
    msg_bytes: int            # framing unit (gRPC message / TCP chunk)
    max_util: float           # fraction of wire the protocol ever reaches
    stream_scaling: float     # 0..1: how well N streams add up (1 = linear)
    single_stream_cap: float | None = None  # B/s cap of one stream, if any

    def transfer_seconds(self, nbytes: int, streams: int = 1) -> float:
        streams = max(1, streams)
        per_stream = nbytes / streams
        msgs = max(1, math.ceil(per_stream / self.msg_bytes))
        # message overheads pipeline across streams but serialize per stream
        t_protocol = self.setup_s + msgs * self.per_msg_s
        bw = self.bandwidth * self._util(streams)
        if self.single_stream_cap is not None:
            bw = min(bw, self.single_stream_cap * streams)
        t_wire = nbytes / bw
        return t_protocol + t_wire

    def _util(self, streams: int) -> float:
        # saturating curve: u(1)=base (one stream's share), u(inf)=max_util
        base = min(self.max_util, (self.single_stream_cap or self.max_util * self.bandwidth) / self.bandwidth)
        gain = 1 - math.exp(-(streams - 1) * self.stream_scaling)
        return min(self.max_util, base + (self.max_util - base) * gain)

    def throughput(self, nbytes: int, streams: int = 1) -> float:
        return nbytes / self.transfer_seconds(nbytes, streams)


# ---------------------------------------------------------------------------
# Calibrated models.  Targets from the paper:
#   Fig 3: Flight-o-IB DoGet 1.5->2.0 GB/s (1->16 streams); DoPut 1.2->1.65
#   Fig 5: TCP-o-IB  ~2 GB/s, streams do NOT help (congestion)
#   Fig 6: RDMA 6.2 GB/s flat from small sizes; Flight overtakes TCP >1KB,
#          hits ~95% of RDMA >= 2.6 GB transfers.  Wire max ~7 GB/s (4xFDR).
# ---------------------------------------------------------------------------

FDR_IB_WIRE = 7.0e9  # 56 Gbit/s minus encoding => ~7 GB/s usable

RDMA_O_IB = LinkModel(
    name="rdma-o-ib", bandwidth=FDR_IB_WIRE, setup_s=2e-6, per_msg_s=1e-6,
    msg_bytes=1 << 22, max_util=0.886, stream_scaling=1.0,  # 6.2/7.0
)
TCP_O_IB = LinkModel(
    name="tcp-o-ib", bandwidth=FDR_IB_WIRE, setup_s=150e-6, per_msg_s=12e-6,
    msg_bytes=64 << 10, max_util=0.30, stream_scaling=0.9,
    single_stream_cap=2.1e9,
)
FLIGHT_O_IB_GET = LinkModel(
    name="flight-o-ib-doget", bandwidth=FDR_IB_WIRE, setup_s=900e-6, per_msg_s=35e-6,
    msg_bytes=4 << 20, max_util=0.286, stream_scaling=0.18,  # 2.0/7.0 at 16 streams
    single_stream_cap=1.5e9,
)
FLIGHT_O_IB_PUT = LinkModel(
    name="flight-o-ib-doput", bandwidth=FDR_IB_WIRE, setup_s=900e-6, per_msg_s=40e-6,
    msg_bytes=4 << 20, max_util=0.236, stream_scaling=0.18,  # 1.65/7.0
    single_stream_cap=1.2e9,
)

# Large-transfer regime of Fig 6 (Flight asymptotically ~95% of RDMA): the
# endpoint-parallel bulk path, distinct from the modest per-stream Fig 3 rates.
FLIGHT_O_IB_BULK = LinkModel(
    name="flight-o-ib-bulk", bandwidth=FDR_IB_WIRE, setup_s=900e-6, per_msg_s=35e-6,
    msg_bytes=4 << 20, max_util=0.84, stream_scaling=0.35,  # 0.95 * 0.886
)

# TPU fabric models (the adaptation targets; §Roofline uses the same constants)
ICI_LINK = LinkModel(
    name="tpu-ici", bandwidth=50e9, setup_s=1e-6, per_msg_s=0.5e-6,
    msg_bytes=1 << 20, max_util=0.95, stream_scaling=1.0,
)
DCN_LINK = LinkModel(
    name="tpu-dcn", bandwidth=25e9 / 8, setup_s=50e-6, per_msg_s=5e-6,
    msg_bytes=1 << 20, max_util=0.8, stream_scaling=0.7,
)

ALL_LINKS = {m.name: m for m in
             [RDMA_O_IB, TCP_O_IB, FLIGHT_O_IB_GET, FLIGHT_O_IB_PUT, FLIGHT_O_IB_BULK,
              ICI_LINK, DCN_LINK]}


def paced_stream(batches: Iterable, link: LinkModel) -> Iterator:
    """Re-yield a RecordBatch stream at the modeled per-stream wire rate.

    Each batch is delayed by its modeled transfer time on ``link``.  The delay
    is a sleep, which releases the GIL — so N shard streams paced this way
    genuinely overlap, and a parallel client measures the paper's
    stream-scaling curve even on a small-core container where CPU-bound
    loopback streams would serialize (see bench_cluster.py)."""
    for b in batches:
        time.sleep(link.transfer_seconds(b.nbytes(), 1))
        yield b

"""Flight client: single-stream RPCs + the parallel/hedged stream manager.

Two connection modes, chosen by ``Location``:

* ``inproc://`` — the client holds the server object; ``DoGet`` moves
  ``RecordBatch`` references (zero-copy, models shared memory on one host).
* ``tcp://host:port`` — framed IPC over a socket (see transport.py).

``read_all_parallel`` implements the paper's throughput recipe: one worker
per endpoint, ``max_streams`` concurrent connections (paper Fig 2: scale
streams up to ~half the cores).  Because tickets are idempotent range reads,
the same worker loop also provides **straggler mitigation**: a configurable
hedge timer re-issues a slow endpoint's ticket against a replica location and
takes whichever stream finishes first.
"""
from __future__ import annotations

import queue
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Iterator

from ..ipc import decode_message, encode_batch, encode_eos, encode_schema
from ..recordbatch import RecordBatch, Table
from ..schema import Schema
from .protocol import (
    Action,
    ActionResult,
    FlightDescriptor,
    FlightEndpoint,
    FlightError,
    FlightInfo,
    FlightUnavailableError,
    Location,
    Ticket,
)
from .server import FlightServerBase
from .transport import KIND_CTRL, KIND_DATA, FrameConnection, dial


# --------------------------------------------------------------------------
# stream reader/writer handles
# --------------------------------------------------------------------------


class FlightStreamReader:
    """Iterates RecordBatches of one DoGet stream."""

    def __init__(self, schema: Schema, batches: Iterator[RecordBatch], on_done=None):
        self.schema = schema
        self._batches = batches
        self._on_done = on_done

    def __iter__(self) -> Iterator[RecordBatch]:
        for b in self._batches:
            yield b
        if self._on_done:
            self._on_done()

    def read_all(self) -> Table:
        return Table(list(self))


class FlightStreamWriter:
    """Feeds one DoPut stream; ``close()`` returns the server's stats ack."""

    def __init__(self, schema: Schema, conn: FrameConnection | None, server: FlightServerBase | None,
                 descriptor: FlightDescriptor):
        self._schema = schema
        self._conn = conn
        self._queue: list[RecordBatch] = []
        self._server = server
        self._descriptor = descriptor
        if conn is not None:
            conn.send_data(encode_schema(schema))

    def write_batch(self, batch: RecordBatch) -> None:
        if batch.schema != self._schema:
            raise FlightError("batch schema mismatch on DoPut stream")
        if self._conn is not None:
            self._conn.send_data(encode_batch(batch))
        else:
            self._queue.append(batch)

    def close(self) -> dict:
        if self._conn is not None:
            self._conn.send_data(encode_eos())
            ack = self._conn.recv_ctrl()
            return ack.get("stats", {})
        return self._server.do_put_impl(self._descriptor, self._schema, iter(self._queue))


# --------------------------------------------------------------------------
# client
# --------------------------------------------------------------------------


@dataclass
class TransferStats:
    rows: int = 0
    bytes: int = 0
    seconds: float = 0.0
    streams: int = 1

    @property
    def mb_per_s(self) -> float:
        return self.bytes / max(self.seconds, 1e-12) / 1e6


class FlightClient:
    def __init__(self, target: FlightServerBase | Location | str, token: str | None = None):
        self._server: FlightServerBase | None = None
        self._addr: tuple[str, int] | None = None
        self.token = token
        if isinstance(target, FlightServerBase):
            self._server = target
        else:
            uri = target.uri if isinstance(target, Location) else target
            if uri.startswith("inproc://"):
                raise FlightError("inproc location needs the server object")
            if not uri.startswith("tcp://"):
                raise FlightError(f"unsupported location {uri!r}")
            host, port = uri[len("tcp://") :].rsplit(":", 1)
            self._addr = (host, int(port))
        self._conn_pool: queue.SimpleQueue[FrameConnection] = queue.SimpleQueue()

    # -- connection management ------------------------------------------- #
    @property
    def is_inproc(self) -> bool:
        return self._server is not None

    def _checkout(self) -> FrameConnection:
        try:
            return self._conn_pool.get_nowait()
        except queue.Empty:
            try:
                return dial(*self._addr)
            except OSError as e:
                raise FlightUnavailableError(f"dial {self._addr}: {e}") from e

    def _checkin(self, conn: FrameConnection) -> None:
        self._conn_pool.put(conn)

    def _request(self, payload: dict) -> dict:
        payload.setdefault("token", self.token)
        conn = self._checkout()
        try:
            conn.send_ctrl(payload)
            resp = conn.recv_ctrl()
        except (ConnectionError, OSError) as e:
            conn.close()
            raise FlightUnavailableError(str(e)) from e
        self._checkin(conn)
        return resp

    # -- control plane ------------------------------------------------------ #
    def get_flight_info(self, descriptor: FlightDescriptor) -> FlightInfo:
        if self._server is not None:
            return self._server.get_flight_info_impl(descriptor)
        return FlightInfo.from_json(self._request(
            {"method": "GetFlightInfo", "descriptor": descriptor.to_json()})["info"])

    def list_flights(self) -> list[FlightInfo]:
        if self._server is not None:
            return self._server.list_flights_impl()
        return [FlightInfo.from_json(o) for o in self._request({"method": "ListFlights"})["infos"]]

    def do_action(self, action: Action | str) -> list[ActionResult]:
        if isinstance(action, str):
            action = Action(action)
        if self._server is not None:
            return self._server.do_action_impl(action)
        return [ActionResult.from_json(o)
                for o in self._request({"method": "DoAction", "action": action.to_json()})["results"]]

    # -- data plane ----------------------------------------------------------- #
    def do_get(self, ticket: Ticket) -> FlightStreamReader:
        if self._server is not None:
            schema, batches = self._server.do_get_impl(ticket)
            return FlightStreamReader(schema, batches)
        conn = self._checkout()
        try:
            conn.send_ctrl({"method": "DoGet", "ticket": ticket.to_json(), "token": self.token})
            conn.recv_ctrl()  # ok / error
            kind, meta, body = conn.recv_frame()
            msg = decode_message(meta, body)
            if msg.kind != "schema":
                raise FlightError("DoGet: expected schema message")
        except (ConnectionError, OSError) as e:
            conn.close()
            raise FlightUnavailableError(str(e)) from e
        schema = msg.schema

        def gen() -> Iterator[RecordBatch]:
            while True:
                k, m, b = conn.recv_frame()
                dm = decode_message(m, b)
                if dm.kind == "eos":
                    return
                yield dm.batch(schema)

        return FlightStreamReader(schema, gen(), on_done=lambda: self._checkin(conn))

    def do_put(self, descriptor: FlightDescriptor, schema: Schema) -> FlightStreamWriter:
        if self._server is not None:
            return FlightStreamWriter(schema, None, self._server, descriptor)
        conn = self._checkout()
        conn.send_ctrl({"method": "DoPut", "descriptor": descriptor.to_json(), "token": self.token})
        conn.recv_ctrl()
        return FlightStreamWriter(schema, conn, None, descriptor)

    def do_exchange(self, descriptor: FlightDescriptor, schema: Schema) -> "FlightExchange":
        return FlightExchange(self, descriptor, schema)

    # -- parallel stream manager (the paper's Fig 2/3 engine) ---------------- #
    def read_all_parallel(
        self,
        info: FlightInfo,
        max_streams: int = 8,
        hedge_after: float | None = None,
        client_factory=None,
    ) -> tuple[Table, TransferStats]:
        """Pull every endpoint of ``info`` with up to ``max_streams`` parallel
        DoGet streams.  ``hedge_after`` seconds without completion re-issues
        the ticket on a replica location (straggler mitigation).
        ``client_factory(location) -> FlightClient`` lets hedges cross hosts.
        """
        endpoints = list(info.endpoints)
        results: list[list[RecordBatch] | None] = [None] * len(endpoints)
        t0 = time.perf_counter()

        def fetch(i: int, ep: FlightEndpoint) -> None:
            def attempt(client: "FlightClient") -> list[RecordBatch]:
                return list(client.do_get(ep.ticket))

            if hedge_after is None:
                results[i] = attempt(self)
                return
            done = threading.Event()
            winner: list[list[RecordBatch]] = []

            def primary():
                try:
                    out = attempt(self)
                    if not done.is_set():
                        winner.append(out)
                        done.set()
                except FlightError:
                    pass

            pt = threading.Thread(target=primary, daemon=True)
            pt.start()
            if not done.wait(hedge_after):
                # hedge on a replica (or retry same server if no factory)
                for loc in ep.locations:
                    try:
                        client = client_factory(loc) if client_factory else self
                        out = attempt(client)
                        if not done.is_set():
                            winner.append(out)
                            done.set()
                        break
                    except FlightError:
                        continue
                done.wait()
            results[i] = winner[0]

        with ThreadPoolExecutor(max_workers=max_streams) as pool:
            list(pool.map(lambda args: fetch(*args), enumerate(endpoints)))

        batches = [b for r in results for b in (r or [])]
        dt = time.perf_counter() - t0
        table = Table(batches)
        return table, TransferStats(table.num_rows, table.nbytes(), dt, min(max_streams, len(endpoints)))

    def write_parallel(
        self,
        descriptor: FlightDescriptor,
        batches: list[RecordBatch],
        max_streams: int = 8,
    ) -> TransferStats:
        """DoPut the batches over N parallel streams (round-robin)."""
        schema = batches[0].schema
        shards = [batches[i::max_streams] for i in range(max_streams)]
        shards = [s for s in shards if s]
        t0 = time.perf_counter()

        def put(shard: list[RecordBatch]) -> None:
            w = self.do_put(descriptor, schema)
            for b in shard:
                w.write_batch(b)
            w.close()

        with ThreadPoolExecutor(max_workers=len(shards)) as pool:
            list(pool.map(put, shards))
        dt = time.perf_counter() - t0
        return TransferStats(
            sum(b.num_rows for b in batches), sum(b.nbytes() for b in batches), dt, len(shards)
        )


class FlightExchange:
    """Bidirectional per-batch exchange (the scoring-microservice verb)."""

    def __init__(self, client: FlightClient, descriptor: FlightDescriptor, schema: Schema):
        self._client = client
        self._schema = schema
        self._descriptor = descriptor
        self._out_schema: Schema | None = None
        if client.is_inproc:
            self._conn = None
        else:
            self._conn = client._checkout()
            self._conn.send_ctrl(
                {"method": "DoExchange", "descriptor": descriptor.to_json(), "token": client.token}
            )
            self._conn.recv_ctrl()
            self._conn.send_data(encode_schema(schema))

    def exchange(self, batch: RecordBatch) -> RecordBatch:
        if self._conn is None:
            return self._client._server.do_exchange_impl(self._descriptor, self._schema, batch)
        self._conn.send_data(encode_batch(batch))
        kind, meta, body = self._conn.recv_frame()
        msg = decode_message(meta, body)
        if msg.kind == "schema":
            self._out_schema = msg.schema
            kind, meta, body = self._conn.recv_frame()
            msg = decode_message(meta, body)
        return msg.batch(self._out_schema or self._schema)

    def close(self) -> None:
        if self._conn is not None:
            self._conn.send_data(encode_eos())
            kind, meta, body = self._conn.recv_frame()  # server EOS
            self._client._checkin(self._conn)
            self._conn = None

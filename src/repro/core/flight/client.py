"""Flight client: single-stream RPCs + the parallel/hedged stream manager.

Two connection modes, chosen by ``Location``:

* ``inproc://`` — the client holds the server object; ``DoGet`` moves
  ``RecordBatch`` references (zero-copy, models shared memory on one host).
* ``tcp://host:port`` — framed IPC over a socket (see transport.py).

Every verb accepts a ``CallOptions`` (protocol.py): ``timeout`` turns a
stalled RPC into a typed ``FlightTimedOut`` (the connection is discarded,
not pooled — a late reply must never bleed into the next call);
``wire_codec``/``coalesce`` ask the server to reshape this call's data
stream; ``headers`` surface to server middleware.  Failures arrive as the
typed ``FlightError`` hierarchy (``FlightNotFound``, ``FlightUnauthenticated``,
``FlightUnavailable``, ...) rebuilt from structured error frames.

``read_all_parallel`` implements the paper's throughput recipe: one worker
per endpoint, ``max_streams`` concurrent connections (paper Fig 2: scale
streams up to ~half the cores).  It is a thin wrapper over
``scheduler.ParallelStreamScheduler``, which also provides backpressure,
ordered/unordered reassembly, location failover, and hedged (straggler-
mitigating) re-reads — see scheduler.py; multi-endpoint *cluster* topologies
live in cluster.py.
"""
from __future__ import annotations

import json
import queue
import uuid
from dataclasses import replace
from typing import Iterator

from ..ipc import decode_message, encode_batch, encode_eos, encode_schema
from ..recordbatch import RecordBatch, Table
from ..schema import Schema
from .exchange import FlightExchangeStream, InprocExchangeStream
from .protocol import (
    Action,
    ActionResult,
    CallOptions,
    FlightDescriptor,
    FlightError,
    FlightInfo,
    FlightTimedOut,
    FlightUnavailable,
    Location,
    QueryCommand,
    Ticket,
)
from .protocol import StagedPutCommand
from .scheduler import ParallelStreamScheduler, TransferStats
from .server import FlightServerBase
from .telemetry import HDR_TRACE, propagation_headers
from .transport import FrameConnection, dial


def run_staged_put(
    scheduler: ParallelStreamScheduler,
    do_action,
    dataset: str,
    schema: Schema,
    assignments: list,
    txn_id: str,
    commit_body: bytes,
) -> TransferStats:
    """The client side of the two-phase put, shared by single-server
    ``write_parallel`` and cluster ``write``: stage every assignment under
    one txn id, then commit via the ``txn-commit`` action.  Any failure
    triggers a best-effort ``txn-abort`` (the server's TTL reaper covers
    whatever the abort cannot reach) and re-raises."""
    descriptor = FlightDescriptor.for_command(
        StagedPutCommand(dataset, txn_id, "stage"))
    try:
        stats = scheduler.put(descriptor, schema, assignments)
        do_action(Action("txn-commit", commit_body))
    except Exception:  # any failure, not just Flight ones: free the stage now
        try:
            do_action(Action("txn-abort", json.dumps(
                {"txn_id": txn_id, "dataset": dataset}).encode()))
        except FlightError:
            pass
        raise
    return stats


# --------------------------------------------------------------------------
# stream reader/writer handles
# --------------------------------------------------------------------------


class FlightStreamReader:
    """Iterates RecordBatches of one DoGet stream."""

    def __init__(self, schema: Schema, batches: Iterator[RecordBatch], on_done=None):
        self.schema = schema
        self._batches = batches
        self._on_done = on_done

    def __iter__(self) -> Iterator[RecordBatch]:
        for b in self._batches:
            yield b
        if self._on_done:
            self._on_done()

    def read_all(self) -> Table:
        return Table(list(self))


class FlightStreamWriter:
    """Feeds one DoPut stream; ``close()`` returns the server's stats ack."""

    def __init__(self, schema: Schema, conn: FrameConnection | None, server: FlightServerBase | None,
                 descriptor: FlightDescriptor):
        self._schema = schema
        self._conn = conn
        self._queue: list[RecordBatch] = []
        self._server = server
        self._descriptor = descriptor
        if conn is not None:
            conn.send_data(encode_schema(schema))

    def write_batch(self, batch: RecordBatch) -> None:
        if batch.schema != self._schema:
            raise FlightError("batch schema mismatch on DoPut stream")
        if self._conn is not None:
            self._conn.send_data(encode_batch(batch))
        else:
            self._queue.append(batch)

    def write_batches(self, batches: "Iterator[RecordBatch] | list[RecordBatch]") -> None:
        """Write many batches with coalesced frames (one sendmsg per ~MiB)."""
        if self._conn is None:
            for b in batches:
                self.write_batch(b)
            return

        def frames():
            for b in batches:
                if b.schema != self._schema:
                    raise FlightError("batch schema mismatch on DoPut stream")
                yield encode_batch(b)

        self._conn.send_data_many(frames())

    def close(self) -> dict:
        if self._conn is not None:
            self._conn.send_data(encode_eos())
            ack = self._conn.recv_ctrl()
            return ack.get("stats", {})
        return self._server.do_put_impl(self._descriptor, self._schema, iter(self._queue))


# --------------------------------------------------------------------------
# client
# --------------------------------------------------------------------------


class FlightClient:
    def __init__(self, target: FlightServerBase | Location | str, token: str | None = None,
                 options: CallOptions | None = None):
        self._server: FlightServerBase | None = None
        self._addr: tuple[str, int] | None = None
        self.token = token
        self.options = options  # default CallOptions; per-call ones override
        if isinstance(target, FlightServerBase):
            self._server = target
        else:
            uri = target.uri if isinstance(target, Location) else target
            if uri.startswith("inproc://"):
                raise FlightError("inproc location needs the server object")
            if not uri.startswith("tcp://"):
                raise FlightError(f"unsupported location {uri!r}")
            host, port = uri[len("tcp://") :].rsplit(":", 1)
            self._addr = (host, int(port))
        self._conn_pool: queue.SimpleQueue[FrameConnection] = queue.SimpleQueue()

    # -- connection management ------------------------------------------- #
    @property
    def is_inproc(self) -> bool:
        return self._server is not None

    def _checkout(self) -> FrameConnection:
        try:
            return self._conn_pool.get_nowait()
        except queue.Empty:
            try:
                return dial(*self._addr)
            except OSError as e:
                raise FlightUnavailable(f"dial {self._addr}: {e}") from e

    def _checkin(self, conn: FrameConnection) -> None:
        self._conn_pool.put(conn)

    def _options(self, options: CallOptions | None) -> CallOptions | None:
        return options if options is not None else self.options

    def _prepare(self, payload: dict, conn: FrameConnection,
                 options: CallOptions | None) -> None:
        payload.setdefault("token", self.token)
        opt_json: dict = {}
        if options is not None:
            opt_json = options.to_json()
            if options.timeout is not None:
                conn.sock.settimeout(options.timeout)
        # ambient trace propagation: when this thread has an active span (a
        # client Tracer, or a traced server handler making downstream calls)
        # its context rides every outgoing RPC, unless the caller already
        # pinned explicit trace headers (scheduler endpoint fetches do)
        trace = propagation_headers()
        if trace is not None:
            hdrs = opt_json.get("headers")
            if not hdrs or HDR_TRACE not in hdrs:
                opt_json = {**opt_json, "headers": {**trace, **(hdrs or {})}}
        if opt_json:
            payload["options"] = opt_json

    def _reset_deadline(self, conn: FrameConnection, options: CallOptions | None) -> None:
        if options is not None and options.timeout is not None:
            try:
                conn.sock.settimeout(None)
            except OSError:
                pass

    def _timed_out(self, conn: FrameConnection, options: CallOptions | None,
                   exc: Exception) -> FlightTimedOut:
        conn.close()  # a late reply must not bleed into the next RPC
        t = options.timeout if options is not None else None
        return FlightTimedOut(f"call exceeded {t}s", detail={"timeout": t})

    def _request(self, payload: dict, options: CallOptions | None = None) -> dict:
        options = self._options(options)
        conn = self._checkout()
        try:
            self._prepare(payload, conn, options)
            conn.send_ctrl(payload)
            resp = conn.recv_ctrl()
        except FlightError:
            # server declined at the RPC boundary: the channel is still clean
            self._reset_deadline(conn, options)
            self._checkin(conn)
            raise
        except TimeoutError as e:
            raise self._timed_out(conn, options, e) from e
        except (ConnectionError, OSError) as e:
            conn.close()
            raise FlightUnavailable(str(e)) from e
        self._reset_deadline(conn, options)
        self._checkin(conn)
        return resp

    # -- control plane ------------------------------------------------------ #
    def get_flight_info(self, descriptor: FlightDescriptor,
                        options: CallOptions | None = None) -> FlightInfo:
        if self._server is not None:
            return self._server.get_flight_info_impl(descriptor)
        return FlightInfo.from_json(self._request(
            {"method": "GetFlightInfo", "descriptor": descriptor.to_json()}, options)["info"])

    def list_flights(self, options: CallOptions | None = None) -> list[FlightInfo]:
        if self._server is not None:
            return self._server.list_flights_impl()
        return [FlightInfo.from_json(o)
                for o in self._request({"method": "ListFlights"}, options)["infos"]]

    def do_action(self, action: Action | str,
                  options: CallOptions | None = None) -> list[ActionResult]:
        if isinstance(action, str):
            action = Action(action)
        if self._server is not None:
            return self._server.do_action_impl(action)
        return [ActionResult.from_json(o)
                for o in self._request(
                    {"method": "DoAction", "action": action.to_json()}, options)["results"]]

    # -- data plane ----------------------------------------------------------- #
    def do_get(self, ticket: Ticket, options: CallOptions | None = None) -> FlightStreamReader:
        options = self._options(options)
        if self._server is not None:
            schema, batches = self._server.do_get_impl(ticket)
            return FlightStreamReader(schema, batches)
        conn = self._checkout()
        try:
            payload = {"method": "DoGet", "ticket": ticket.to_json()}
            self._prepare(payload, conn, options)
            conn.send_ctrl(payload)
            try:
                conn.recv_ctrl()  # ok / error
            except FlightError:
                self._reset_deadline(conn, options)
                self._checkin(conn)  # refused before the stream: channel clean
                raise
            kind, meta, body = conn.recv_frame()
            msg = decode_message(meta, body)
            if msg.kind != "schema":
                conn.close()  # mid-stream protocol mismatch: channel dirty
                raise FlightError("DoGet: expected schema message")
        except TimeoutError as e:
            raise self._timed_out(conn, options, e) from e
        except (ConnectionError, OSError) as e:
            conn.close()
            raise FlightUnavailable(str(e)) from e
        schema = msg.schema

        def gen() -> Iterator[RecordBatch]:
            try:
                while True:
                    k, m, b = conn.recv_frame()
                    dm = decode_message(m, b)
                    if dm.kind == "eos":
                        return
                    yield dm.batch(schema)
            except TimeoutError as e:
                raise self._timed_out(conn, options, e) from e
            except (ConnectionError, OSError) as e:
                conn.close()
                raise FlightUnavailable(str(e)) from e

        def done() -> None:
            self._reset_deadline(conn, options)
            self._checkin(conn)

        return FlightStreamReader(schema, gen(), on_done=done)

    def do_get_query(self, plan, options: CallOptions | None = None) -> FlightStreamReader:
        """DoGet a typed ``QueryCommand`` executing ``plan`` server-side."""
        return self.do_get(Ticket.for_command(QueryCommand.for_plan(plan)), options)

    def do_put(self, descriptor: FlightDescriptor, schema: Schema,
               options: CallOptions | None = None) -> FlightStreamWriter:
        options = self._options(options)
        if self._server is not None:
            return FlightStreamWriter(schema, None, self._server, descriptor)
        conn = self._checkout()
        try:
            payload = {"method": "DoPut", "descriptor": descriptor.to_json()}
            self._prepare(payload, conn, options)
            conn.send_ctrl(payload)
            conn.recv_ctrl()
        except FlightError:
            self._reset_deadline(conn, options)
            self._checkin(conn)
            raise
        except TimeoutError as e:
            raise self._timed_out(conn, options, e) from e
        except (ConnectionError, OSError) as e:
            conn.close()
            raise FlightUnavailable(str(e)) from e
        return FlightStreamWriter(schema, conn, None, descriptor)

    def do_exchange_stream(self, descriptor: FlightDescriptor, schema: Schema,
                           options: CallOptions | None = None):
        """Open a pipelined bidirectional DoExchange stream (exchange.py).

        The returned stream decouples writing and reading: feed input
        batches (``write_batch``/``write_batches``/``feed``) while iterating
        the transformed output, with a bounded in-flight window
        (``CallOptions.read_window``) providing backpressure.  The
        descriptor may carry an ``ExchangeCommand`` naming a registered
        transform service, or a path for the legacy per-batch handler."""
        options = self._options(options)
        if self._server is not None:
            return InprocExchangeStream(self._server, descriptor, schema,
                                        token=self.token, options=options)
        conn = self._checkout()
        try:
            payload = {"method": "DoExchange", "descriptor": descriptor.to_json()}
            self._prepare(payload, conn, options)
            conn.send_ctrl(payload)
            conn.recv_ctrl()  # ok / typed refusal
        except FlightError:
            self._reset_deadline(conn, options)
            self._checkin(conn)  # refused before the stream: channel clean
            raise
        except TimeoutError as e:
            raise self._timed_out(conn, options, e) from e
        except (ConnectionError, OSError) as e:
            conn.close()
            raise FlightUnavailable(str(e)) from e
        return FlightExchangeStream(self, conn, schema, options)

    def do_exchange(self, descriptor: FlightDescriptor, schema: Schema) -> "FlightExchange":
        """Deprecated lockstep exchange — use ``do_exchange_stream``."""
        return FlightExchange(self, descriptor, schema)

    # -- parallel stream manager (the paper's Fig 2/3 engine) ---------------- #
    def scheduler(
        self,
        max_streams: int = 8,
        hedge_after: float | None = None,
        client_factory=None,
        ordered: bool = True,
        window: int = 4,
        call_options: CallOptions | None = None,
    ) -> ParallelStreamScheduler:
        """A ParallelStreamScheduler whose primary connection is this client.

        ``client_factory(location) -> FlightClient`` lets hedges *and*
        location failovers cross hosts (the scheduler routes every attempt
        after the first through it); without it every attempt re-uses this
        client (retry the same server).
        """
        return ParallelStreamScheduler(
            client_factory=lambda loc: self,
            hedge_factory=client_factory,
            max_streams=max_streams,
            hedge_after=hedge_after,
            ordered=ordered,
            window=window,
            call_options=call_options if call_options is not None else self.options,
        )

    def read_all_parallel(
        self,
        info: FlightInfo,
        max_streams: int = 8,
        hedge_after: float | None = None,
        client_factory=None,
        ordered: bool = True,
        call_options: CallOptions | None = None,
    ) -> tuple[Table, TransferStats]:
        """Pull every endpoint of ``info`` with up to ``max_streams`` parallel
        DoGet streams.  ``hedge_after`` seconds without completion re-issues
        the ticket on a replica location (straggler mitigation).
        ``client_factory(location) -> FlightClient`` lets hedges cross hosts.
        """
        return self.scheduler(
            max_streams=max_streams, hedge_after=hedge_after,
            client_factory=client_factory, ordered=ordered,
            call_options=call_options,
        ).fetch(info)

    def write_parallel(
        self,
        descriptor: FlightDescriptor,
        batches: list[RecordBatch],
        max_streams: int = 8,
        transactional: bool = False,
        txn_id: str | None = None,
    ) -> TransferStats:
        """DoPut the batches over N parallel streams (round-robin).

        ``transactional=True`` stages the N streams under one txn id
        (``StagedPutCommand`` stage leg — nothing is visible while streams
        are in flight) and then commits via the ``txn-commit`` action: a
        reader sees either none of the payload or all of it.  If any stream
        fails the txn is aborted (best-effort; the server's TTL reaper GCs
        whatever an abort cannot reach) and the failure re-raises.  Note
        that against a ``dedup_puts`` server (the default), byte-identical
        streams within the txn collapse to one — the same trade-off as the
        plain-put dedup guard (see ``InMemoryFlightServer``)."""
        schema = batches[0].schema
        shards = [batches[i::max_streams] for i in range(max_streams)]
        if not transactional:
            return self.scheduler(max_streams=max_streams).put(
                descriptor, schema, [(None, s) for s in shards]
            )
        dataset = descriptor.path[0] if descriptor.path else descriptor.key
        txn_id = txn_id or uuid.uuid4().hex
        return run_staged_put(
            self.scheduler(max_streams=max_streams), self.do_action,
            dataset, schema, [(None, s) for s in shards], txn_id,
            StagedPutCommand(dataset, txn_id, "commit").to_bytes())


class FlightExchange:
    """Deprecated single-batch ping-pong view over the streaming exchange.

    Kept as a shim (the ``Ticket.range()`` deprecation pattern): each
    ``exchange(batch)`` writes one batch and blocks for one response —
    lockstep, ``window=1`` — so legacy 1:1 scoring services keep working
    unchanged.  Strictly for **1:1** services: against a dropping or
    re-chunking transform (filter, repartition) the blocking read waits for
    a response that may never come (set ``CallOptions.timeout`` on the
    client to bound it, or — better — don't use this shim).  New code
    should use ``FlightClient.do_exchange_stream`` /
    ``core.flight.exchange.open_exchange`` (pipelined, windowed, routed to
    named ``ExchangeCommand`` services, safe for non-1:1 transforms); the
    streaming wire protocol is specified in docs/wire-format.md
    ("DoExchange framing")."""

    def __init__(self, client: FlightClient, descriptor: FlightDescriptor, schema: Schema):
        import warnings

        warnings.warn(
            "FlightExchange (and FlightClient.do_exchange) is deprecated; "
            "use FlightClient.do_exchange_stream for pipelined, windowed "
            "bidirectional exchange",
            DeprecationWarning, stacklevel=3)
        opts = client._options(None)
        opts = replace(opts, read_window=1) if opts is not None else CallOptions(read_window=1)
        self._stream = client.do_exchange_stream(descriptor, schema, options=opts)
        self._iter = iter(self._stream)

    def exchange(self, batch: RecordBatch) -> RecordBatch:
        self._stream.write_batch(batch)
        out = next(self._iter, None)
        if out is None:
            raise FlightError("exchange stream ended before a response batch")
        return out

    def close(self) -> None:
        self._stream.close()

"""Stable keyed row partitioning — the hash plane under shuffles and placement.

One hashing discipline serves both layers: ``HashPlacement`` (rows → shards
at ingest) and the keyed ``repartition`` exchange (rows → partitions during a
shuffle) delegate here, so a dataset ingested with ``HashPlacement("k")`` on
N shards is *already* shuffle-aligned for a group-by or join on ``k`` across
N partitions — the shuffle becomes a no-op move.  Hashes are salt-free and
PYTHONHASHSEED-independent: equal keys map to equal partitions across
processes, runs, and machines.

Per-column u64 lanes: integers multiply by the Fibonacci constant; floats
canonicalize ``-0.0 → 0.0`` and NaN payloads first, then hash their bits;
varlen (and other non-numpy) columns fall back to ``crc32(repr(value))``
(masked entries surface as ``None`` → one deterministic null lane).
Multi-key tuples fold lanes with xor-multiply before bucketing.
"""
from __future__ import annotations

import zlib

import numpy as np

from ..recordbatch import RecordBatch

_MIX = np.uint64(0x9E3779B97F4A7C15)  # Fibonacci hashing constant
_SHIFT = np.uint64(33)


def column_lane(arr) -> np.ndarray:
    """Per-row u64 hash lane for one column (pre-bucketing)."""
    try:
        vals = arr.to_numpy()
    except TypeError:
        vals = None
    if vals is not None and vals.dtype == np.dtype(bool):
        vals = vals.astype(np.uint64)
    if vals is not None and np.issubdtype(vals.dtype, np.integer):
        return vals.astype(np.uint64) * _MIX
    if vals is not None and np.issubdtype(vals.dtype, np.floating):
        f = vals.astype(np.float64)
        f = np.where(f == 0.0, 0.0, f)            # -0.0 == 0.0 → same bucket
        f = np.where(np.isnan(f), np.nan, f)      # canonical NaN payload
        return f.view(np.uint64) * _MIX
    return np.array(
        [zlib.crc32(repr(v).encode()) for v in arr.to_pylist()], dtype=np.uint64
    ) * _MIX


def row_partitions(batch: RecordBatch, keys: list[str], num_partitions: int) -> np.ndarray:
    """Partition id per row: stable hash of the key tuple, mod ``num_partitions``.

    The single-key path reproduces ``HashPlacement.row_shards`` bucket-for-
    bucket (the placement delegates here), which is what makes hash-placed
    datasets shuffle-free for same-key aggregation and joins."""
    if not keys:
        raise ValueError("row_partitions needs at least one key column")
    n = np.uint64(num_partitions)
    if len(keys) == 1:
        # exact replica of the historical HashPlacement.row_shards buckets:
        # int/float columns via the MIX lane, everything else (varlen, bool)
        # via raw crc32 % n — existing hash-placed layouts must not move.
        arr = batch.column(keys[0])
        try:
            vals = arr.to_numpy()
        except TypeError:
            vals = None
        if vals is not None and (np.issubdtype(vals.dtype, np.integer)
                                 or np.issubdtype(vals.dtype, np.floating)):
            return ((column_lane(arr) >> _SHIFT) % n).astype(np.int64)
        return np.array(
            [zlib.crc32(repr(v).encode()) % num_partitions
             for v in arr.to_pylist()],
            dtype=np.int64,
        )
    h = np.full(batch.num_rows, _MIX, dtype=np.uint64)
    for k in keys:
        h = (h ^ column_lane(batch.column(k))) * _MIX
    return ((h >> _SHIFT) % n).astype(np.int64)


def partition_batch(
    batch: RecordBatch, keys: list[str], num_partitions: int
) -> list[RecordBatch]:
    """Split one batch into ``num_partitions`` key-disjoint sub-batches
    (index ``p`` holds every row whose key tuple hashes to ``p``; empty
    partitions are zero-row batches, kept so callers can zip by index)."""
    ids = row_partitions(batch, keys, num_partitions)
    return [batch.filter(ids == p) for p in range(num_partitions)]

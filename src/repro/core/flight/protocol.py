"""Flight control-plane messages (Fig 1 of the paper).

Semantics mirror Arrow Flight RPC: a client asks ``GetFlightInfo(descriptor)``
and receives a ``FlightInfo`` whose ``endpoints`` carry ``Ticket``s — opaque,
idempotent handles to streams of RecordBatches, each with one or more
``locations`` (replicas).  ``DoGet(ticket)`` pulls a stream; ``DoPut``
pushes one.

Since the typed-command redesign, descriptors' ``command`` bytes and
tickets' ``raw`` bytes carry a **Command** — a versioned, binary-serialized
control message (magic ``0xC2``, alongside the ``0xB1`` binary IPC codec one
layer down):

* ``RangeReadCommand`` — the idempotent ``(dataset, start, stop[, shard])``
  range read that makes parallel streams, resume, and hedged reads trivial;
* ``QueryCommand``      — a ``QueryPlan`` (predicate/projection pushdown)
  plus an optional batch range and shard, so query execution composes with
  the sharded-cluster and parallel-stream machinery;
* ``StagedPutCommand``  — the two-phase transactional cluster DoPut control
  message: the ``stage`` phase rides a DoPut descriptor (payload lands
  staged, invisible to readers), while ``commit``/``abort`` bytes are the
  bodies of the ``txn-commit``/``txn-abort`` DoAction verbs that flip all
  staged data visible atomically or discard it (see docs/wire-format.md);
* ``ExchangeCommand``   — names a registered streaming-exchange transform
  service (``core/flight/services.py``) plus its per-call params; carried by
  a DoExchange descriptor, it routes the bidirectional stream through the
  server's ``ExchangeServiceRegistry``.

``parse_command`` also accepts the two legacy JSON encodings (range-ticket
dicts and bare ``QueryPlan`` JSON) so pre-redesign tickets keep redeeming;
``Ticket.range()`` remains as a deprecated dict view over the parsed
command.

``CallOptions`` is the per-call knob bundle (timeout, wire codec, frame
coalescing, read window) that clients propagate with each RPC instead of
freezing behavior at server construction.
"""
from __future__ import annotations

import json
import struct
from dataclasses import dataclass, field
from typing import Any, Union

from ..schema import Schema
from .errors import (  # noqa: F401  (re-exported: historical home of the errors)
    FlightError,
    FlightInvalidArgument,
    FlightNotFound,
    FlightTimedOut,
    FlightUnauthenticated,
    FlightUnavailable,
    FlightUnavailableError,
    error_from_wire,
)

# ---------------------------------------------------------------------------
# typed commands
# ---------------------------------------------------------------------------

COMMAND_MAGIC = 0xC2  # first byte of every binary command (JSON starts with '{')
COMMAND_VERSION = 1

_CMD_RANGE, _CMD_QUERY, _CMD_STAGED_PUT, _CMD_EXCHANGE = 1, 2, 3, 4
_HEAD = struct.Struct("<BBB")        # magic, version, type
_U16, _U32 = struct.Struct("<H"), struct.Struct("<I")
_RANGE_TAIL = struct.Struct("<qqi")  # start, stop, shard (-1 = none)


def _pack_str(s: str) -> bytes:
    b = s.encode()
    return _U16.pack(len(b)) + b


def _unpack_str(raw: bytes, pos: int) -> tuple[str, int]:
    (n,) = _U16.unpack_from(raw, pos)
    pos += _U16.size
    if pos + n > len(raw):  # slicing would silently truncate the string
        raise FlightInvalidArgument("truncated command: string runs past buffer")
    return raw[pos : pos + n].decode(), pos + n


@dataclass(frozen=True)
class RangeReadCommand:
    """Idempotent batch-range read — the workhorse DoGet ticket."""

    dataset: str
    start: int
    stop: int                      # exclusive; -1 = to end
    shard: int | None = None
    extra: tuple = ()              # legacy JSON extras, kept for the shim

    def to_bytes(self) -> bytes:
        if self.extra:  # extras have no binary slot: stay on the JSON shim
            return json.dumps(self.to_dict()).encode()
        return (
            _HEAD.pack(COMMAND_MAGIC, COMMAND_VERSION, _CMD_RANGE)
            + _pack_str(self.dataset)
            + _RANGE_TAIL.pack(self.start, self.stop, -1 if self.shard is None else self.shard)
        )

    def to_dict(self) -> dict:
        o = {"dataset": self.dataset, "start": self.start, "stop": self.stop}
        if self.shard is not None:
            o["shard"] = self.shard
        o.update(dict(self.extra))
        return o


@dataclass(frozen=True)
class QueryCommand:
    """A serialized ``QueryPlan`` + optional batch range/shard scope.

    ``plan_bytes`` is ``QueryPlan.serialize()`` output; the ``plan`` property
    decodes lazily so this module never imports the query engine at import
    time (the engine imports Flight for its service layer).

    The plan JSON is opaque at this layer — extending the plan (e.g. the
    ``group_by`` key added for grouped partial aggregation) changes neither
    the 0xC2 command layout nor these bytes' framing, and plans serialized
    before the extension still parse (missing keys default empty).  A
    command whose plan carries aggregations is redeemed as a *partial
    aggregate*: its DoGet stream is per-group state batches, not rows
    (see ``query.engine.partial_schema``)."""

    plan_bytes: bytes
    start: int = 0
    stop: int = -1                 # -1 = all stored batches
    shard: int | None = None

    @classmethod
    def for_plan(cls, plan, start: int = 0, stop: int = -1,
                 shard: int | None = None) -> "QueryCommand":
        return cls(plan.serialize(), start, stop, shard)

    @property
    def plan(self):
        from ...query.engine import QueryPlan  # lazy: avoids an import cycle

        return QueryPlan.deserialize(self.plan_bytes)

    def to_bytes(self) -> bytes:
        return (
            _HEAD.pack(COMMAND_MAGIC, COMMAND_VERSION, _CMD_QUERY)
            + _RANGE_TAIL.pack(self.start, self.stop, -1 if self.shard is None else self.shard)
            + _U32.pack(len(self.plan_bytes))
            + self.plan_bytes
        )

    def to_dict(self) -> dict:
        o = {
            "dataset": self.plan.dataset,
            "start": self.start,
            "stop": self.stop,
            "plan": self.plan_bytes.decode(),
        }
        if self.shard is not None:
            o["shard"] = self.shard
        return o


_STAGED_PHASES = ("stage", "commit", "abort")  # wire phase byte = tuple index


@dataclass(frozen=True)
class StagedPutCommand:
    """Two-phase transactional DoPut control message.

    ``phase`` selects the leg of the protocol:

    * ``"stage"``  — carried by a DoPut descriptor: the streamed batches land
      in the server's staging store keyed by ``txn_id``, invisible to every
      reader until committed;
    * ``"commit"`` — body of the ``txn-commit`` DoAction: atomically flips
      the txn's staged batches into the visible dataset;
    * ``"abort"``  — body of the ``txn-abort`` DoAction: discards them.

    The serialization was pinned one PR ahead of the protocol (phase byte
    0/1/2 in ``_STAGED_PHASES`` order), so staged tickets from the stub era
    still parse."""

    dataset: str
    txn_id: str
    phase: str = "stage"

    def to_bytes(self) -> bytes:
        if self.phase not in _STAGED_PHASES:
            raise FlightInvalidArgument(f"unknown staged-put phase {self.phase!r}")
        return (
            _HEAD.pack(COMMAND_MAGIC, COMMAND_VERSION, _CMD_STAGED_PUT)
            + _pack_str(self.dataset)
            + _pack_str(self.txn_id)
            + bytes([_STAGED_PHASES.index(self.phase)])
        )

    def to_dict(self) -> dict:
        return {"dataset": self.dataset, "txn_id": self.txn_id, "phase": self.phase}


@dataclass(frozen=True)
class ExchangeCommand:
    """Names a streaming-exchange transform service + its per-call params.

    Carried by a ``DoExchange`` descriptor; the server resolves ``service``
    in its ``ExchangeServiceRegistry`` (services.py) and runs the
    bidirectional stream through it.  ``params_bytes`` is a JSON object
    (``b""`` = no params) — kept as bytes so the command round-trips
    byte-exact and params stay opaque to the control plane."""

    service: str
    params_bytes: bytes = b""

    @classmethod
    def for_service(cls, service: str, **params: Any) -> "ExchangeCommand":
        return cls(service,
                   json.dumps(params, sort_keys=True).encode() if params else b"")

    @property
    def params(self) -> dict:
        if not self.params_bytes:
            return {}
        try:
            o = json.loads(self.params_bytes.decode())
        except (UnicodeDecodeError, json.JSONDecodeError) as e:
            raise FlightInvalidArgument(f"unparseable exchange params: {e}") from e
        if not isinstance(o, dict):
            raise FlightInvalidArgument("exchange params must be a JSON object")
        return o

    def to_bytes(self) -> bytes:
        return (
            _HEAD.pack(COMMAND_MAGIC, COMMAND_VERSION, _CMD_EXCHANGE)
            + _pack_str(self.service)
            + _U32.pack(len(self.params_bytes))
            + self.params_bytes
        )

    def to_dict(self) -> dict:
        return {"service": self.service, "params": self.params}


Command = Union[RangeReadCommand, QueryCommand, StagedPutCommand, ExchangeCommand]


def parse_command(raw: bytes) -> Command:
    """Decode binary commands; fall back to the two legacy JSON encodings."""
    if not raw:
        raise FlightInvalidArgument("empty command")
    if raw[0] == COMMAND_MAGIC:
        try:
            magic, version, kind = _HEAD.unpack_from(raw, 0)
            if version != COMMAND_VERSION:
                raise FlightInvalidArgument(
                    f"unsupported command version {version}",
                    detail={"version": version, "supported": COMMAND_VERSION},
                )
            pos = _HEAD.size
            if kind == _CMD_RANGE:
                dataset, pos = _unpack_str(raw, pos)
                start, stop, shard = _RANGE_TAIL.unpack_from(raw, pos)
                return RangeReadCommand(dataset, start, stop, None if shard < 0 else shard)
            if kind == _CMD_QUERY:
                start, stop, shard = _RANGE_TAIL.unpack_from(raw, pos)
                pos += _RANGE_TAIL.size
                (n,) = _U32.unpack_from(raw, pos)
                pos += _U32.size
                if pos + n > len(raw):
                    raise FlightInvalidArgument("truncated command: plan runs past buffer")
                return QueryCommand(raw[pos : pos + n], start, stop,
                                    None if shard < 0 else shard)
            if kind == _CMD_STAGED_PUT:
                dataset, pos = _unpack_str(raw, pos)
                txn_id, pos = _unpack_str(raw, pos)
                phase_byte = raw[pos]
                if phase_byte >= len(_STAGED_PHASES):
                    raise FlightInvalidArgument(
                        f"unknown staged-put phase byte {phase_byte}",
                        detail={"phase": phase_byte})
                return StagedPutCommand(dataset, txn_id, _STAGED_PHASES[phase_byte])
            if kind == _CMD_EXCHANGE:
                service, pos = _unpack_str(raw, pos)
                (n,) = _U32.unpack_from(raw, pos)
                pos += _U32.size
                if pos + n > len(raw):
                    raise FlightInvalidArgument("truncated command: params run past buffer")
                return ExchangeCommand(service, raw[pos : pos + n])
            raise FlightInvalidArgument(f"unknown command type {kind}", detail={"type": kind})
        except (struct.error, IndexError, UnicodeDecodeError) as e:
            # truncated/garbled binary must surface as a typed refusal, not
            # an unhandled exception killing the server's handler thread
            raise FlightInvalidArgument(f"malformed binary command: {e}") from e
    try:
        o = json.loads(raw.decode())
    except (UnicodeDecodeError, json.JSONDecodeError) as e:
        raise FlightInvalidArgument(f"unparseable command: {e}") from e
    if not isinstance(o, dict) or "dataset" not in o:
        raise FlightInvalidArgument("command JSON must name a dataset")
    if "start" in o and "stop" in o:  # legacy range-ticket dict
        if "plan" in o:
            return QueryCommand(o["plan"].encode(), o["start"], o["stop"], o.get("shard"))
        extra = tuple(sorted(
            (k, v) for k, v in o.items()
            if k not in ("dataset", "start", "stop", "shard")
        ))
        return RangeReadCommand(o["dataset"], o["start"], o["stop"], o.get("shard"), extra)
    # bare QueryPlan JSON (pre-redesign FlightDescriptor.for_command payload)
    return QueryCommand(raw)


# ---------------------------------------------------------------------------
# per-call options
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class CallOptions:
    """Per-RPC knobs, propagated with the call instead of frozen server-side.

    * ``timeout``     — seconds before the client abandons the RPC with a
      ``FlightTimedOut`` (TCP transport only; in-proc calls cannot be
      interrupted).
    * ``wire_codec``  — IPC metadata codec for this call's data stream
      ("binary"/"json"); the server re-encodes instead of using its default.
    * ``coalesce``    — override the server's frame-coalescing choice.
    * ``read_window`` — per-stream backpressure window: scheduler reads use
      it client-side, and streaming DoExchange sends it to the server too
      (bounding the server's input queue and ack granularity — exchange.py).
    * ``headers``     — opaque key/values surfaced to server middleware.
    """

    timeout: float | None = None
    wire_codec: str | None = None
    coalesce: bool | None = None
    read_window: int | None = None
    headers: dict | None = None

    def to_json(self) -> dict:
        o: dict = {}
        if self.wire_codec is not None:
            o["wire_codec"] = self.wire_codec
        if self.coalesce is not None:
            o["coalesce"] = self.coalesce
        if self.read_window is not None:
            o["read_window"] = self.read_window
        if self.headers:
            o["headers"] = dict(self.headers)
        return o


# ---------------------------------------------------------------------------
# descriptors / tickets / endpoints
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class FlightDescriptor:
    """Names a dataset: a path (storage) or a command (query plan)."""

    path: tuple[str, ...] | None = None
    command: bytes | None = None

    @classmethod
    def for_path(cls, *path: str) -> "FlightDescriptor":
        return cls(path=tuple(path))

    @classmethod
    def for_command(cls, command: "bytes | str | Command") -> "FlightDescriptor":
        if hasattr(command, "to_bytes"):
            command = command.to_bytes()
        elif isinstance(command, str):
            command = command.encode()
        return cls(command=command)

    @classmethod
    def for_query(cls, plan, start: int = 0, stop: int = -1) -> "FlightDescriptor":
        """Descriptor carrying a typed ``QueryCommand`` for ``plan``."""
        return cls.for_command(QueryCommand.for_plan(plan, start, stop))

    def parsed_command(self) -> Command:
        if self.command is None:
            raise FlightInvalidArgument("descriptor carries no command")
        return parse_command(self.command)

    @property
    def key(self) -> str:
        if self.path is not None:
            return "path:" + "/".join(self.path)
        return "cmd:" + (self.command or b"").decode("latin1")

    def to_json(self) -> dict:
        return {
            "path": list(self.path) if self.path is not None else None,
            "command": self.command.decode("latin1") if self.command is not None else None,
        }

    @classmethod
    def from_json(cls, o: dict) -> "FlightDescriptor":
        return cls(
            path=tuple(o["path"]) if o.get("path") is not None else None,
            command=o["command"].encode("latin1") if o.get("command") is not None else None,
        )


@dataclass(frozen=True)
class Ticket:
    """Opaque stream handle — the bytes of a serialized ``Command``."""

    raw: bytes

    @classmethod
    def for_command(cls, cmd: Command) -> "Ticket":
        return cls(cmd.to_bytes())

    @classmethod
    def for_range(cls, dataset: str, start: int, stop: int, **extra: Any) -> "Ticket":
        shard = extra.pop("shard", None)
        if "plan" in extra and not extra.keys() - {"plan"}:
            return cls(QueryCommand(extra["plan"].encode(), start, stop, shard).to_bytes())
        return cls(
            RangeReadCommand(dataset, start, stop, shard,
                             tuple(sorted(extra.items()))).to_bytes()
        )

    def command(self) -> Command:
        return parse_command(self.raw)

    def range(self) -> dict:
        """Deprecated dict view of the parsed command.

        Use ``command()`` and the typed ``Command`` union instead — the
        binary layouts and their JSON fallbacks are specified in
        docs/wire-format.md ("0xC2 — the Command union")."""
        import warnings

        warnings.warn(
            "Ticket.range() is deprecated; use Ticket.command() and the "
            "typed Command union instead",
            DeprecationWarning, stacklevel=2)
        return self.command().to_dict()

    def to_json(self) -> dict:
        return {"raw": self.raw.decode("latin1")}

    @classmethod
    def from_json(cls, o: dict) -> "Ticket":
        return cls(o["raw"].encode("latin1"))


@dataclass(frozen=True)
class Location:
    """Where a ticket can be redeemed.  ``inproc:`` or ``tcp://host:port``."""

    uri: str

    @classmethod
    def for_tcp(cls, host: str, port: int) -> "Location":
        return cls(f"tcp://{host}:{port}")

    @classmethod
    def inproc(cls, name: str = "local") -> "Location":
        return cls(f"inproc://{name}")


@dataclass(frozen=True)
class FlightEndpoint:
    ticket: Ticket
    locations: tuple[Location, ...] = ()
    app_metadata: dict | None = None  # e.g. {"shard": 2} on cluster endpoints

    def __hash__(self):  # dict field breaks the generated hash
        return hash(
            (self.ticket, self.locations, tuple(sorted((self.app_metadata or {}).items())))
        )

    def to_json(self) -> dict:
        o = {"ticket": self.ticket.to_json(), "locations": [l.uri for l in self.locations]}
        if self.app_metadata:
            o["app_metadata"] = self.app_metadata
        return o

    @classmethod
    def from_json(cls, o: dict) -> "FlightEndpoint":
        return cls(
            Ticket.from_json(o["ticket"]),
            tuple(Location(u) for u in o["locations"]),
            o.get("app_metadata"),
        )

    @property
    def shard(self) -> int | None:
        return (self.app_metadata or {}).get("shard")


@dataclass(frozen=True)
class ShardSpec:
    """How a dataset is laid out across a cluster's shard endpoints."""

    scheme: str  # "round_robin" | "hash"
    num_shards: int
    key: str | None = None  # partition column for scheme == "hash"
    replicas: int = 1  # copies of each partition (1 = unreplicated)

    def to_json(self) -> dict:
        o = {"scheme": self.scheme, "num_shards": self.num_shards, "key": self.key}
        if self.replicas != 1:
            o["replicas"] = self.replicas
        return o

    @classmethod
    def from_json(cls, o: dict) -> "ShardSpec":
        return cls(o["scheme"], o["num_shards"], o.get("key"),
                   o.get("replicas", 1))


@dataclass
class FlightInfo:
    schema: Schema
    descriptor: FlightDescriptor
    endpoints: list[FlightEndpoint]
    total_records: int = -1
    total_bytes: int = -1
    shard_spec: ShardSpec | None = None  # present when served by a cluster
    # cluster-view epoch this info was planned under: a client can detect a
    # stale plan (post-rebalance, post-death) by comparing against the
    # head's current `membership` view and re-plan instead of failing over
    epoch: int | None = None

    def to_json(self) -> dict:
        o = {
            "schema": self.schema.to_json(),
            "descriptor": self.descriptor.to_json(),
            "endpoints": [e.to_json() for e in self.endpoints],
            "total_records": self.total_records,
            "total_bytes": self.total_bytes,
        }
        if self.shard_spec is not None:
            o["shard_spec"] = self.shard_spec.to_json()
        if self.epoch is not None:
            o["epoch"] = self.epoch
        return o

    @classmethod
    def from_json(cls, o: dict) -> "FlightInfo":
        return cls(
            Schema.from_json(o["schema"]),
            FlightDescriptor.from_json(o["descriptor"]),
            [FlightEndpoint.from_json(e) for e in o["endpoints"]],
            o["total_records"],
            o["total_bytes"],
            ShardSpec.from_json(o["shard_spec"]) if o.get("shard_spec") else None,
            o.get("epoch"),
        )


@dataclass(frozen=True)
class Action:
    type: str
    body: bytes = b""

    def to_json(self) -> dict:
        return {"type": self.type, "body": self.body.decode("latin1")}

    @classmethod
    def from_json(cls, o: dict) -> "Action":
        return cls(o["type"], o["body"].encode("latin1"))


@dataclass(frozen=True)
class ActionResult:
    body: bytes

    def to_json(self) -> dict:
        return {"body": self.body.decode("latin1")}

    @classmethod
    def from_json(cls, o: dict) -> "ActionResult":
        return cls(o["body"].encode("latin1"))

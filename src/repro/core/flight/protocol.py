"""Flight control-plane messages (Fig 1 of the paper).

Semantics mirror Arrow Flight RPC: a client asks ``GetFlightInfo(descriptor)``
and receives a ``FlightInfo`` whose ``endpoints`` carry ``Ticket``s — opaque,
idempotent handles to streams of RecordBatches, each with one or more
``locations`` (replicas).  ``DoGet(ticket)`` pulls a stream; ``DoPut``
pushes one.  Tickets being *range reads* (dataset, start, stop) is what makes
parallel streams, resumable loaders, and hedged (straggler-mitigating) reads
trivial — the property the data plane exploits.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Any

from ..schema import Schema

# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class FlightDescriptor:
    """Names a dataset: a path (storage) or a command (query plan)."""

    path: tuple[str, ...] | None = None
    command: bytes | None = None

    @classmethod
    def for_path(cls, *path: str) -> "FlightDescriptor":
        return cls(path=tuple(path))

    @classmethod
    def for_command(cls, command: bytes | str) -> "FlightDescriptor":
        if isinstance(command, str):
            command = command.encode()
        return cls(command=command)

    @property
    def key(self) -> str:
        if self.path is not None:
            return "path:" + "/".join(self.path)
        return "cmd:" + (self.command or b"").decode("utf-8", "replace")

    def to_json(self) -> dict:
        return {
            "path": list(self.path) if self.path is not None else None,
            "command": self.command.decode("latin1") if self.command is not None else None,
        }

    @classmethod
    def from_json(cls, o: dict) -> "FlightDescriptor":
        return cls(
            path=tuple(o["path"]) if o.get("path") is not None else None,
            command=o["command"].encode("latin1") if o.get("command") is not None else None,
        )


@dataclass(frozen=True)
class Ticket:
    """Opaque stream handle.  We structure ours as an idempotent range read."""

    raw: bytes

    @classmethod
    def for_range(cls, dataset: str, start: int, stop: int, **extra: Any) -> "Ticket":
        return cls(json.dumps({"dataset": dataset, "start": start, "stop": stop, **extra}).encode())

    def range(self) -> dict:
        return json.loads(self.raw.decode())

    def to_json(self) -> dict:
        return {"raw": self.raw.decode("latin1")}

    @classmethod
    def from_json(cls, o: dict) -> "Ticket":
        return cls(o["raw"].encode("latin1"))


@dataclass(frozen=True)
class Location:
    """Where a ticket can be redeemed.  ``inproc:`` or ``tcp://host:port``."""

    uri: str

    @classmethod
    def for_tcp(cls, host: str, port: int) -> "Location":
        return cls(f"tcp://{host}:{port}")

    @classmethod
    def inproc(cls, name: str = "local") -> "Location":
        return cls(f"inproc://{name}")


@dataclass(frozen=True)
class FlightEndpoint:
    ticket: Ticket
    locations: tuple[Location, ...] = ()
    app_metadata: dict | None = None  # e.g. {"shard": 2} on cluster endpoints

    def __hash__(self):  # dict field breaks the generated hash
        return hash(
            (self.ticket, self.locations, tuple(sorted((self.app_metadata or {}).items())))
        )

    def to_json(self) -> dict:
        o = {"ticket": self.ticket.to_json(), "locations": [l.uri for l in self.locations]}
        if self.app_metadata:
            o["app_metadata"] = self.app_metadata
        return o

    @classmethod
    def from_json(cls, o: dict) -> "FlightEndpoint":
        return cls(
            Ticket.from_json(o["ticket"]),
            tuple(Location(u) for u in o["locations"]),
            o.get("app_metadata"),
        )

    @property
    def shard(self) -> int | None:
        return (self.app_metadata or {}).get("shard")


@dataclass(frozen=True)
class ShardSpec:
    """How a dataset is laid out across a cluster's shard endpoints."""

    scheme: str  # "round_robin" | "hash"
    num_shards: int
    key: str | None = None  # partition column for scheme == "hash"

    def to_json(self) -> dict:
        return {"scheme": self.scheme, "num_shards": self.num_shards, "key": self.key}

    @classmethod
    def from_json(cls, o: dict) -> "ShardSpec":
        return cls(o["scheme"], o["num_shards"], o.get("key"))


@dataclass
class FlightInfo:
    schema: Schema
    descriptor: FlightDescriptor
    endpoints: list[FlightEndpoint]
    total_records: int = -1
    total_bytes: int = -1
    shard_spec: ShardSpec | None = None  # present when served by a cluster

    def to_json(self) -> dict:
        o = {
            "schema": self.schema.to_json(),
            "descriptor": self.descriptor.to_json(),
            "endpoints": [e.to_json() for e in self.endpoints],
            "total_records": self.total_records,
            "total_bytes": self.total_bytes,
        }
        if self.shard_spec is not None:
            o["shard_spec"] = self.shard_spec.to_json()
        return o

    @classmethod
    def from_json(cls, o: dict) -> "FlightInfo":
        return cls(
            Schema.from_json(o["schema"]),
            FlightDescriptor.from_json(o["descriptor"]),
            [FlightEndpoint.from_json(e) for e in o["endpoints"]],
            o["total_records"],
            o["total_bytes"],
            ShardSpec.from_json(o["shard_spec"]) if o.get("shard_spec") else None,
        )


@dataclass(frozen=True)
class Action:
    type: str
    body: bytes = b""

    def to_json(self) -> dict:
        return {"type": self.type, "body": self.body.decode("latin1")}

    @classmethod
    def from_json(cls, o: dict) -> "Action":
        return cls(o["type"], o["body"].encode("latin1"))


@dataclass(frozen=True)
class ActionResult:
    body: bytes

    def to_json(self) -> dict:
        return {"body": self.body.decode("latin1")}

    @classmethod
    def from_json(cls, o: dict) -> "ActionResult":
        return cls(o["body"].encode("latin1"))


class FlightError(RuntimeError):
    pass


class FlightUnavailableError(FlightError):
    """Endpoint unreachable — callers may fail over to a replica location."""

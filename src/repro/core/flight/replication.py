"""Replicated placement: each partition on R shards, Arrow-plane rebalance.

The unit of replication is the **slice** — one placement bucket of a
dataset, stored verbatim (same batches, same order) on ``R`` holder
shards.  A slice's storage key embeds the dataset name, the layout
generation, and the slice index::

    users@@g3s1      slice 1 of "users", layout generation 3

which buys three properties at once:

* **Tickets transfer between replicas.**  Every holder serves the slice
  under the same key with identical batch boundaries, so a plain range
  ticket (``RangeReadCommand(key, 0, n)``) redeemed on *any* holder yields
  byte-identical frames — the scheduler's existing mid-stream failover
  (resume-skip) and hedged reads work against replicas with **zero
  scheduler changes**; the head only has to list every holder's Location
  on the endpoint.
* **Rebalancing is transactional.**  A new layout generation stages under
  fresh keys (``@@g4s*``) while generation 3 keeps serving; the cutover is
  one layout-pointer swap after the staged 2PC commits, and the epoch bump
  tells clients their old plan is stale.  Old and new generations never
  collide in the store.
* **Recovery is listing.**  Slice keys parse back to (dataset, gen,
  slice), so a restarted head rebuilds every layout — including which
  shard holds which replica — from the shards' own catalogs.

``ReplicatedPlacement`` wraps a base placement (round-robin or hash) and
adds the replica fan-out: slice ``j`` lands on holders ``targets[j],
targets[j+1], ... targets[j+R-1]`` (mod the target count) — the classic
chained-rotation layout, so losing any single shard leaves every slice
with R-1 live holders and the load of the dead shard spreads evenly.

``move_slice`` is the rebalance data path: source batches stream through
the *destination shard's* ``repartition`` exchange service (re-chunking to
a uniform batch size in flight), then stage as a transactional put — the
move happens on the Arrow plane with the same verbs any client uses, not
through a private side channel.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, replace as dc_replace

from ..recordbatch import RecordBatch
from .protocol import (
    ExchangeCommand,
    FlightDescriptor,
    FlightInvalidArgument,
    ShardSpec,
    StagedPutCommand,
)

SLICE_SEP = "@@"
_KEY_RE = re.compile(r"^(?P<name>.+)@@g(?P<gen>\d+)s(?P<idx>\d+)$", re.DOTALL)


def slice_key(name: str, gen: int, index: int) -> str:
    """Storage key for slice ``index`` of ``name`` at layout ``gen``."""
    if SLICE_SEP in name:
        raise FlightInvalidArgument(
            f"dataset name {name!r} may not contain {SLICE_SEP!r} "
            f"(reserved for replica slice keys)")
    return f"{name}{SLICE_SEP}g{gen}s{index}"


def parse_slice_key(key: str) -> tuple[str, int, int] | None:
    """Inverse of ``slice_key``; None for plain (unreplicated) names."""
    m = _KEY_RE.match(key)
    if m is None:
        return None
    return m.group("name"), int(m.group("gen")), int(m.group("idx"))


def subtxn_id(txn_id: str, index: int) -> str:
    """Per-slice transaction id under one logical txn.

    Each slice stages on its holders as an independent server-level txn
    (a server txn binds to exactly one dataset); the head's coordinator
    prepares and commits *all* of a logical txn's sub-txns as one round,
    so atomicity is preserved across the fan-out."""
    return f"{txn_id}/s{index}"


@dataclass(frozen=True)
class SliceInfo:
    """One placement bucket: where its replicas live."""

    index: int
    key: str
    holders: tuple[int, ...]  # shard ids, primary first

    def to_json(self) -> dict:
        return {"index": self.index, "key": self.key, "holders": list(self.holders)}

    @classmethod
    def from_json(cls, o: dict) -> "SliceInfo":
        return cls(o["index"], o["key"], tuple(o["holders"]))


@dataclass(frozen=True)
class DatasetLayout:
    """A dataset's slice → holders map at one layout generation."""

    name: str
    gen: int
    slices: tuple[SliceInfo, ...]

    @property
    def num_slices(self) -> int:
        return len(self.slices)

    def holders_of(self, index: int) -> tuple[int, ...]:
        return self.slices[index].holders

    def keys(self) -> list[str]:
        return [s.key for s in self.slices]

    def to_json(self) -> dict:
        return {"name": self.name, "gen": self.gen,
                "slices": [s.to_json() for s in self.slices]}

    @classmethod
    def from_json(cls, o: dict) -> "DatasetLayout":
        return cls(o["name"], o["gen"],
                   tuple(SliceInfo.from_json(s) for s in o["slices"]))


def plan_layout(name: str, gen: int, targets: list[int], replicas: int) -> DatasetLayout:
    """Chained-rotation layout: slice ``j`` on ``targets[j..j+R-1]`` (mod)."""
    if not targets:
        raise FlightInvalidArgument("cannot plan a layout over zero shards")
    r = min(replicas, len(targets))
    slices = tuple(
        SliceInfo(
            j,
            slice_key(name, gen, j),
            tuple(targets[(j + k) % len(targets)] for k in range(r)),
        )
        for j in range(len(targets))
    )
    return DatasetLayout(name, gen, slices)


def recover_layouts(listings: dict[int, list[str]]) -> dict[str, DatasetLayout]:
    """Rebuild layouts from per-shard catalog listings (restart recovery).

    For each dataset the highest generation with at least one holder per
    present slice wins; stale generations are ignored (the cutover that
    superseded them also scheduled their deletion, which may not have
    finished before the crash)."""
    # (name, gen) -> {index -> [holder ids]}
    gens: dict[tuple[str, int], dict[int, list[int]]] = {}
    for sid, keys in listings.items():
        for key in keys:
            parsed = parse_slice_key(key)
            if parsed is None:
                continue
            name, gen, idx = parsed
            gens.setdefault((name, gen), {}).setdefault(idx, []).append(sid)
    out: dict[str, DatasetLayout] = {}
    for (name, gen), slices in sorted(gens.items()):
        if name in out and out[name].gen >= gen:
            continue
        indices = sorted(slices)
        if indices != list(range(len(indices))):
            continue  # holes: an interrupted stage, not a committed layout
        out[name] = DatasetLayout(name, gen, tuple(
            SliceInfo(i, slice_key(name, gen, i), tuple(sorted(slices[i])))
            for i in indices))
    return out


class ReplicatedPlacement:
    """A base placement (round-robin / hash) plus an R-way replica fan-out.

    ``assign`` delegates to the base policy — replication changes *where
    copies go*, never *which rows form a slice* — and ``holders`` adds the
    rotation.  Exposes the base's ``scheme``/``key`` so control-plane
    consumers (``shard-locations``, client-side writers) keep working."""

    def __init__(self, base, replicas: int):
        if replicas < 1:
            raise FlightInvalidArgument("replicas must be >= 1")
        self.base = base
        self.replicas = replicas

    @property
    def scheme(self) -> str:
        return self.base.scheme

    def __getattr__(self, name: str):
        return getattr(self.base, name)  # e.g. HashPlacement.key / row_shards

    def assign(self, batches: list[RecordBatch], num_slices: int) -> list[list[RecordBatch]]:
        return self.base.assign(batches, num_slices)

    def holders(self, index: int, targets: list[int]) -> tuple[int, ...]:
        r = min(self.replicas, len(targets))
        return tuple(targets[(index + k) % len(targets)] for k in range(r))

    def spec(self, num_shards: int) -> ShardSpec:
        return dc_replace(self.base.spec(num_shards), replicas=self.replicas)


# --------------------------------------------------------------------------
# rebalance data path
# --------------------------------------------------------------------------


def repartition_rows(batches: list[RecordBatch]) -> int:
    """Uniform batch size for a moved slice: the source's largest batch."""
    return max((b.num_rows for b in batches), default=1) or 1


def move_slice(
    dest_client,
    key: str,
    txn_id: str,
    schema,
    batches: list[RecordBatch],
    rows: int | None = None,
) -> list[RecordBatch]:
    """Stream one slice to a destination shard on the Arrow plane.

    The batches flow through the destination's ``repartition`` exchange
    service (re-chunked to ``rows`` per batch in flight) and the transformed
    stream stages there under ``txn_id`` — invisible until the coordinator's
    commit round.  Returns the re-chunked batches so the caller can stage
    the *identical* payload on the slice's other holders (replicas must be
    byte-identical for tickets to transfer)."""
    if not batches:
        return []
    rows = rows or repartition_rows(batches)
    stream = dest_client.do_exchange_stream(
        FlightDescriptor.for_command(
            ExchangeCommand.for_service("repartition", rows=rows)),
        schema)
    stream.feed(batches)
    moved = list(stream)
    stage_slice(dest_client, key, txn_id, schema, moved)
    return moved


def stage_slice(client, key: str, txn_id: str, schema, batches: list[RecordBatch]) -> None:
    """Stage a slice payload on one holder (DoPut, stage leg only)."""
    w = client.do_put(
        FlightDescriptor.for_command(StagedPutCommand(key, txn_id, "stage")),
        schema)
    w.write_batches(list(batches))
    w.close()

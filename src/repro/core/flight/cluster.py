"""Sharded Flight cluster — the paper's multi-endpoint topology (§3, Fig 2).

The paper's headline DoGet rates come from *parallel* RecordBatch streams:
``GetFlightInfo`` returns a ``FlightInfo`` whose endpoints live on different
server processes, and the client pulls them concurrently.  This module
supplies the server side of that topology:

* ``FlightClusterServer`` — a head node that partitions each dataset across
  N ``InMemoryFlightServer`` shard endpoints.  ``GetFlightInfo`` answers with
  one ``(Location, Ticket)`` endpoint per shard slice, so any scheduler-aware
  client saturates all shards at once.  The head itself still serves every
  verb (DoGet proxies/gathers, DoPut re-partitions), so legacy single-stream
  clients keep working.
* placements — ``RoundRobinPlacement`` (batch-granular, balanced bytes) and
  ``HashPlacement`` (row-granular, hash-by-column; co-locates equal keys on
  one shard, the layout a distributed join/aggregate wants).  Hashes are
  salt-free and stable across processes, so two clusters loaded with the
  same data place rows identically.
* ``FlightClusterClient`` — convenience wrapper bundling a head connection
  with a ``ParallelStreamScheduler``: ``read()`` fans in all shard endpoints,
  ``write()`` partitions client-side and DoPuts straight to the shards in
  parallel (never funneling bytes through the head).

Transactional writes (``write(..., transactional=True)``) keep shard ingest
at wire speed while the head coordinates atomic visibility: batches stream
to shards as *staged* payloads keyed by a txn id (``StagedPutCommand``'s
stage leg on each DoPut descriptor), then one ``txn-commit`` action at the
head drives a two-phase round — prepare votes on every expected shard, then
commit fan-out flips all staged data visible; any missing/failed vote
aborts the txn on every shard, so a crashed writer's partial stage is never
readable (and the shards' TTL reaper GCs it).
"""
from __future__ import annotations

import dataclasses
import json
import os
import threading
import uuid
from collections import OrderedDict

import numpy as np

from ..recordbatch import RecordBatch, Table
from ..schema import Schema
from .client import FlightClient, run_staged_put
from .exchange import as_exchange_descriptor
from .membership import ClusterMembership, MembershipProber, ShardState
from .replication import (
    DatasetLayout,
    ReplicatedPlacement,
    move_slice,
    parse_slice_key,
    plan_layout,
    recover_layouts,
    stage_slice,
    subtxn_id,
)
from .protocol import (
    Action,
    ActionResult,
    CallOptions,
    ExchangeCommand,
    FlightDescriptor,
    FlightEndpoint,
    FlightError,
    FlightInfo,
    FlightInvalidArgument,
    FlightNotFound,
    FlightUnavailable,
    Location,
    QueryCommand,
    ShardSpec,
    StagedPutCommand,
    Ticket,
)
from .scheduler import ParallelStreamScheduler, TransferStats
from .shuffle import row_partitions
from .telemetry import (
    decode_telemetry_batch,
    encode_telemetry_batch,
    merge_telemetry_batches,
    propagation_headers,
    telemetry_action,
)
from .server import (
    FlightServerBase,
    InMemoryFlightServer,
    ServerConfig,
    _query_out_schema,
    parse_txn_body,
)


def _shard_storage(storage, shard_id: int):
    """Resolve a cluster-level storage spec into one shard's spec.

    A callable gets the shard id (full control); a ``disk:<root>`` string
    becomes ``disk:<root>/shard-<i>`` so every shard owns a disjoint subtree
    of one cluster root — which is also what makes cluster restart recovery
    line up shard-for-shard.  Anything else passes through unchanged."""
    if callable(storage):
        return storage(shard_id)
    if isinstance(storage, str) and storage.startswith("disk:"):
        root = storage[len("disk:"):]
        return "disk:" + os.path.join(root, f"shard-{shard_id}")
    return storage


# --------------------------------------------------------------------------
# placement policies
# --------------------------------------------------------------------------


class Placement:
    """Maps a list of RecordBatches onto ``num_shards`` buckets."""

    scheme = "?"

    def assign(self, batches: list[RecordBatch], num_shards: int) -> list[list[RecordBatch]]:
        raise NotImplementedError

    def spec(self, num_shards: int) -> ShardSpec:
        return ShardSpec(self.scheme, num_shards)


class RoundRobinPlacement(Placement):
    """Batch ``i`` goes to shard ``i % N`` — balanced, zero-copy."""

    scheme = "round_robin"

    def assign(self, batches, num_shards):
        shards: list[list[RecordBatch]] = [[] for _ in range(num_shards)]
        for i, b in enumerate(batches):
            shards[i % num_shards].append(b)
        return shards


class HashPlacement(Placement):
    """Row-granular placement by a stable hash of one column.

    Equal key values always land on the same shard (and the same shard id
    across runs/processes — no PYTHONHASHSEED dependence), which is what
    shard-local joins and aggregations require."""

    scheme = "hash"

    def __init__(self, key: str):
        self.key = key

    def spec(self, num_shards: int) -> ShardSpec:
        return ShardSpec(self.scheme, num_shards, key=self.key)

    def row_shards(self, batch: RecordBatch, num_shards: int) -> np.ndarray:
        # one hash discipline for placement AND shuffle: a dataset placed by
        # HashPlacement("k") on N shards is already partition-aligned for a
        # same-key shuffle into N partitions (shuffle.py owns the buckets)
        return row_partitions(batch, [self.key], num_shards)

    def assign(self, batches, num_shards):
        shards: list[list[RecordBatch]] = [[] for _ in range(num_shards)]
        for b in batches:
            ids = self.row_shards(b, num_shards)
            for s in range(num_shards):
                sub = b.filter(ids == s)
                if sub.num_rows:
                    shards[s].append(sub)
        return shards


def make_placement(placement: str | Placement, key: str | None = None) -> Placement:
    if isinstance(placement, Placement):
        return placement
    if placement == "round_robin":
        return RoundRobinPlacement()
    if placement == "hash":
        if not key:
            raise ValueError("hash placement needs a key column")
        return HashPlacement(key)
    raise ValueError(f"unknown placement {placement!r}")


# --------------------------------------------------------------------------
# head node
# --------------------------------------------------------------------------


class FlightClusterServer(FlightServerBase):
    """Head node of an N-shard Flight cluster.

    ``add_dataset``/``DoPut`` partition via the placement policy;
    ``GetFlightInfo`` exposes per-shard endpoints whose tickets carry the
    owning shard id, so hedged re-reads and head-side proxying both route
    without a lookup."""

    def __init__(
        self,
        num_shards: int = 2,
        placement: str | Placement = "round_robin",
        hash_key: str | None = None,
        location_name: str = "cluster",
        auth_token: str | None = None,
        batches_per_endpoint: int = 0,
        shard_factory=None,
        shard_config: ServerConfig | None = None,
        storage=None,
        replicas: int = 1,
        heartbeat_interval: float = 0.0,
        suspect_after: float = 0.75,
        dead_after: float = 2.0,
        auto_rebalance: bool = False,
        rebalance_grace: float = 0.0,
    ):
        super().__init__(location_name, auth_token)
        if num_shards < 1:
            raise ValueError("num_shards must be >= 1")
        if not 1 <= replicas <= num_shards:
            raise FlightInvalidArgument(
                f"replicas must be in [1, num_shards]: {replicas} vs {num_shards}",
                detail={"replicas": replicas, "num_shards": num_shards})
        self.placement = make_placement(placement, hash_key)
        self.replicas = replicas
        if replicas > 1:
            # the replicated plane: slice-key layouts + per-endpoint replica
            # Locations (see replication.py); R=1 keeps the historical
            # positional per-shard layout byte-for-byte
            self.placement = ReplicatedPlacement(self.placement, replicas)
        # shard_factory(shard_id, location_name) -> InMemoryFlightServer lets
        # benchmarks/tests substitute instrumented or wire-paced shards
        if shard_factory is None:
            # `storage` wins over shard_config.storage; either way the spec
            # is re-scoped per shard (see _shard_storage) so disk-backed
            # shards never share a root
            spec = storage if storage is not None else getattr(
                shard_config, "storage", None)

            def shard_factory(i: int, loc_name: str) -> InMemoryFlightServer:
                # only forward knobs actually set at the cluster level —
                # an explicit kwarg would override the same shard_config field
                extra = {}
                if spec is not None:
                    extra["storage"] = _shard_storage(spec, i)
                if auth_token is not None:
                    extra["auth_token"] = auth_token
                if batches_per_endpoint:
                    extra["batches_per_endpoint"] = batches_per_endpoint
                return InMemoryFlightServer(
                    location_name=loc_name,
                    shard_id=i,
                    config=shard_config,
                    # head and shards share one exchange-service registry, so
                    # registering a transform once makes it reachable on
                    # every endpoint a fanned-out exchange lands on
                    services=self.services,
                    **extra,
                )
        self._shard_factory = shard_factory  # kept: add_shard builds with it
        self.shards = [
            shard_factory(i, f"{location_name}-shard{i}") for i in range(num_shards)
        ]
        for i, s in enumerate(self.shards):
            s.shard_id = i
        self._datasets: dict[str, Schema] = {}
        self._dlock = threading.Lock()
        # membership: every shard starts HEALTHY; the prober (when enabled)
        # or explicit heartbeat/sweep calls advance the state machine, and
        # every view change bumps the epoch stamped into FlightInfo plans
        self.membership = ClusterMembership(suspect_after, dead_after)
        for i, s in enumerate(self.shards):
            self.membership.register(i, [l.uri for l in s.locations()])
        self.auto_rebalance = auto_rebalance
        self.rebalance_grace = rebalance_grace
        self.heartbeat_interval = heartbeat_interval
        self.prober = MembershipProber(
            self.membership, self._probe_shard,
            interval=heartbeat_interval or 0.25,
            on_dead=self._on_shards_dead)
        # replicated layouts: dataset -> slice/holder map at a generation
        self._layouts: dict[str, DatasetLayout] = {}
        self._gen = 0
        self._pending_txns: OrderedDict[str, tuple[str, list[tuple[int, str]]]] = OrderedDict()
        self._rebalance_lock = threading.Lock()
        self._rebalance_thread: threading.Thread | None = None
        self.last_rebalance_error: Exception | None = None
        self.rebalances = 0
        self._tcp_host: str | None = None
        # catalog recovery: durable shard backends (disk roots) re-surface
        # their datasets at construction — fold their union into the head's
        # catalog so a restarted cluster answers GetFlightInfo immediately.
        # Replica slice keys parse back to (dataset, gen, slice), so the
        # layouts — including which shard holds which replica — rebuild too.
        listings = {i: s.storage.list() for i, s in enumerate(self.shards)}
        if replicas > 1:
            self._layouts = recover_layouts(listings)
            for name, lay in self._layouts.items():
                self._gen = max(self._gen, lay.gen)
                for sl in lay.slices:
                    holder = next((h for h in sl.holders if h < len(self.shards)
                                   and self.shards[h].storage.exists(sl.key)), None)
                    if holder is not None:
                        self._datasets.setdefault(
                            name, self.shards[holder].storage.schema(sl.key))
                        break
        for i, s in enumerate(self.shards):
            for name in listings[i]:
                if parse_slice_key(name) is None:
                    self._datasets.setdefault(name, s.storage.schema(name))
        if heartbeat_interval > 0:
            self.prober.start()

    @property
    def num_shards(self) -> int:
        return len(self.shards)

    # -- lifecycle --------------------------------------------------------- #
    def serve_tcp(self, host: str = "127.0.0.1", port: int = 0) -> "FlightClusterServer":
        super().serve_tcp(host, port)
        self._tcp_host = host
        for i, s in enumerate(self.shards):
            s.serve_tcp(host, 0)
            self.membership.update_locations(i, [l.uri for l in s.locations()])
        return self

    def shutdown(self) -> None:
        self.prober.stop()
        t = self._rebalance_thread
        if t is not None:
            t.join(timeout=5.0)
        super().shutdown()
        for s in self.shards:
            s.shutdown()

    # -- membership -------------------------------------------------------- #
    def _probe_shard(self, sid: int) -> bool:
        """Health probe for the prober: the shard's ``health`` action.

        In-proc this is a direct call (the head owns the shard objects), so
        an injected fault (faultsim patches the verb impls) fails the probe
        exactly like a dead process would fail a TCP one."""
        s = self.shards[sid]
        return s.do_action_impl(Action("health"))[0].body == b"ok"

    def _on_shards_dead(self, newly_dead: list[int]) -> None:
        if self.auto_rebalance and self.replicas > 1:
            self._start_rebalance(wait=False)

    # -- loading ----------------------------------------------------------- #
    def add_dataset(self, name: str, batches: list[RecordBatch]) -> None:
        schema = batches[0].schema
        if self.replicas == 1:
            parts = self.placement.assign(batches, self.num_shards)
            for shard, part in zip(self.shards, parts):
                shard.add_dataset(name, part, schema=schema)
            with self._dlock:
                self._datasets[name] = schema
            return
        # replicated load: slice with the base policy, store each slice
        # verbatim on all of its holders (identical batch boundaries are
        # what make one slice's ticket redeemable on any replica)
        targets = self.membership.alive()
        if len(targets) < self.replicas:
            raise FlightUnavailable(
                f"{len(targets)} live shard(s) cannot host {self.replicas} replicas",
                detail={"alive": targets, "replicas": self.replicas})
        lay = plan_layout(name, self._next_gen(), targets, self.replicas)
        parts = self.placement.assign(batches, lay.num_slices)
        for sl, part in zip(lay.slices, parts):
            for h in sl.holders:
                self.shards[h].add_dataset(sl.key, part, schema=schema)
        with self._dlock:
            old = self._layouts.get(name)
            self._layouts[name] = lay
            self._datasets[name] = schema
        if old is not None:
            # a replaced dataset invalidates plans against the old layout
            self.membership.bump()
            self._drop_layout_keys(old, keep=frozenset(lay.keys()))

    def dataset(self, name: str) -> list[RecordBatch]:
        """All batches in slice/shard order (the head DoGet gather order)."""
        lay = self._layout(name)
        if lay is None:
            return [b for s in self.shards if s.storage.exists(name)
                    for b in s.dataset(name)]
        return [b for sl in lay.slices for b in self._slice_batches(sl)]

    # -- replicated-layout helpers ----------------------------------------- #
    def _layout(self, name: str) -> DatasetLayout | None:
        with self._dlock:
            return self._layouts.get(name)

    def _next_gen(self) -> int:
        with self._dlock:
            self._gen += 1
            return self._gen

    def _holders_alive(self, sl) -> list[int]:
        """A slice's routable holders, HEALTHY before SUSPECT (stable within
        each class, so the rotation's primary stays primary while healthy)."""
        hs = [h for h in sl.holders if self.membership.is_routable(h)]
        if not hs:
            raise FlightUnavailable(
                f"slice {sl.index} ({sl.key!r}) has no live replica",
                detail={"slice": sl.index, "holders": list(sl.holders)})
        hs.sort(key=lambda h: 0 if self.membership.state(h) == ShardState.HEALTHY else 1)
        return hs

    def _slice_batches(self, sl) -> list[RecordBatch]:
        for h in self._holders_alive(sl):
            if self.shards[h].storage.exists(sl.key):
                return self.shards[h].dataset(sl.key)
        return []  # slice never received batches (fewer batches than slices)

    def _ensure_layout(self, name: str, schema: Schema | None = None) -> DatasetLayout:
        """Pin a layout for ``name``, planning one over the live shards if
        it does not exist yet.  Pinning is separate from visibility: the
        dataset only enters the catalog when data commits (register-dataset
        or a txn-commit round), so concurrent writers share one plan."""
        with self._dlock:
            lay = self._layouts.get(name)
        if lay is not None:
            return lay
        targets = self.membership.alive()
        if len(targets) < self.replicas:
            raise FlightUnavailable(
                f"{len(targets)} live shard(s) cannot host {self.replicas} replicas",
                detail={"alive": targets, "replicas": self.replicas})
        lay = plan_layout(name, self._next_gen(), targets, self.replicas)
        with self._dlock:
            return self._layouts.setdefault(name, lay)

    def _drop_layout_keys(self, lay: DatasetLayout, keep: frozenset = frozenset()) -> None:
        """Best-effort removal of a superseded generation's slice keys.

        With ``rebalance_grace > 0`` the drop is deferred, so reads planned
        against the old generation can drain mid-cutover."""
        def drop() -> None:
            for sl in lay.slices:
                if sl.key in keep:
                    continue
                for h in set(sl.holders):
                    if not 0 <= h < len(self.shards):
                        continue
                    try:
                        self.shards[h].do_action_impl(Action("drop", sl.key.encode()))
                    except Exception:
                        continue  # dead holder: its copy died with it

        if self.rebalance_grace > 0:
            t = threading.Timer(self.rebalance_grace, drop)
            t.daemon = True
            t.start()
        else:
            drop()

    # -- elastic membership: add/remove shards, rebalance ------------------- #
    def add_shard(self, wait: bool = True) -> int:
        """Grow the cluster by one shard and rebalance every layout onto it.

        The new shard is built with the same factory as the originals (and
        serves TCP when the cluster does); it becomes a replica holder once
        the background rebalance's cutover commits."""
        if self.replicas == 1:
            raise FlightInvalidArgument(
                "add_shard requires a replicated cluster (replicas > 1); "
                "positional R=1 layouts cannot absorb new shards")
        sid = len(self.shards)
        s = self._shard_factory(sid, f"{self.location_name}-shard{sid}")
        s.shard_id = sid
        if self._tcp_host is not None:
            s.serve_tcp(self._tcp_host, 0)
        self.shards.append(s)
        self.membership.register(sid, [l.uri for l in s.locations()])
        self._start_rebalance(wait=wait)
        return sid

    def remove_shard(self, shard_id: int, wait: bool = True) -> None:
        """Gracefully drain a shard: rebalance every layout off it, then
        deregister + shut it down.  The shard object stays in the table as a
        tombstone — shard ids are indices, and outstanding tickets stamped
        with other ids must keep resolving."""
        if self.replicas == 1:
            raise FlightInvalidArgument(
                "remove_shard requires a replicated cluster (replicas > 1)")
        if not 0 <= shard_id < len(self.shards):
            raise FlightNotFound(f"no such shard: {shard_id}",
                                 detail={"shard": shard_id})

        def drained() -> None:
            self.membership.deregister(shard_id)
            try:
                self.shards[shard_id].shutdown()
            except Exception:
                pass  # tombstone anyway; the data already moved

        self._start_rebalance(wait=wait, exclude=(shard_id,), after=drained)

    def wait_rebalanced(self, timeout: float | None = None) -> None:
        """Join an in-flight background rebalance; re-raise its failure."""
        t = self._rebalance_thread
        if t is not None:
            t.join(timeout)
        err, self.last_rebalance_error = self.last_rebalance_error, None
        if err is not None:
            raise err

    def _start_rebalance(self, wait: bool = True, exclude: tuple = (),
                         after=None) -> None:
        if wait:
            self._rebalance(exclude)
            if after is not None:
                after()
            return

        def run() -> None:
            try:
                self._rebalance(exclude)
                if after is not None:
                    after()
            except Exception as e:
                self.last_rebalance_error = e

        t = threading.Thread(target=run, daemon=True, name="flight-rebalance")
        self._rebalance_thread = t
        t.start()

    def _rebalance(self, exclude: tuple = ()) -> None:
        """Re-plan every replicated layout over the live shards (minus
        ``exclude``) and move the data — all on the Arrow plane, all under a
        transactional cutover.  Old layouts keep serving until their
        replacement commits; a failure aborts the staged generation and
        leaves the old one untouched."""
        with self._rebalance_lock:
            targets = [s for s in self.membership.alive() if s not in exclude]
            if len(targets) < self.replicas:
                raise FlightUnavailable(
                    f"{len(targets)} live shard(s) cannot host "
                    f"{self.replicas} replicas",
                    detail={"alive": targets, "replicas": self.replicas})
            with self._dlock:
                names = list(self._layouts)
            for name in names:
                self._rebalance_dataset(name, targets)
            self.rebalances += 1

    def _rebalance_dataset(self, name: str, targets: list[int]) -> bool:
        old = self._layout(name)
        if old is None:
            return False
        trial = plan_layout(name, old.gen, targets, self.replicas)
        if [sl.holders for sl in old.slices] == [sl.holders for sl in trial.slices]:
            return False  # already balanced over exactly these shards
        with self._dlock:
            schema = self._datasets.get(name)
        # gather in slice order from whichever replicas are alive, then
        # re-slice with the base policy for the new target count
        src = [b for sl in old.slices for b in self._slice_batches(sl)]
        new = plan_layout(name, self._next_gen(), targets, self.replicas)
        parts = self.placement.assign(src, new.num_slices)
        txn = f"rebalance-{uuid.uuid4().hex}"
        subs: list[tuple[int, str]] = []
        try:
            for sl, part in zip(new.slices, parts):
                if not part:
                    continue
                sch = schema if schema is not None else part[0].schema
                stxn = subtxn_id(txn, sl.index)
                # the move streams through the destination's `repartition`
                # exchange (re-chunking in flight) and stages there; the
                # re-chunked payload then stages verbatim on the remaining
                # holders so every replica is byte-identical
                moved = move_slice(
                    FlightClient(self.shards[sl.holders[0]], token=self.auth_token),
                    sl.key, stxn, sch, part)
                for h in sl.holders[1:]:
                    stage_slice(
                        FlightClient(self.shards[h], token=self.auth_token),
                        sl.key, stxn, sch, moved)
                subs += [(h, stxn) for h in sl.holders]
            if subs:
                self._coordinate_commit_replicated(
                    {"txn_id": txn, "dataset": name}, subs)
        except Exception:
            self._abort_subtxns(txn, subs)
            raise
        with self._dlock:
            cur = self._layouts.get(name)
            self._layouts[name] = new
        self.membership.bump()  # the cutover is a view change: plans re-plan
        if cur is not None:
            self._drop_layout_keys(cur, keep=frozenset(new.keys()))
        return True

    # -- handlers ----------------------------------------------------------- #
    def _info_for(self, name: str) -> FlightInfo:
        with self._dlock:
            if name not in self._datasets:
                raise FlightNotFound(f"no such flight: {name}", detail={"dataset": name})
            schema = self._datasets[name]
            lay = self._layouts.get(name)
        if lay is not None:
            return self._replicated_info(name, schema, lay)
        endpoints, records, nbytes = [], 0, 0
        for shard in self.shards:
            try:
                info = shard.get_flight_info_impl(FlightDescriptor.for_path(name))
            except FlightError:
                continue
            if info.total_records <= 0 and not any(
                c.stop > c.start for c in (e.ticket.command() for e in info.endpoints)
            ):
                continue  # empty shard: nothing to stream
            endpoints += info.endpoints
            records += max(info.total_records, 0)
            nbytes += max(info.total_bytes, 0)
        return FlightInfo(
            schema,
            FlightDescriptor.for_path(name),
            endpoints,
            total_records=records,
            total_bytes=nbytes,
            shard_spec=self.placement.spec(self.num_shards),
            epoch=self.membership.epoch,
        )

    def _replicated_info(self, name: str, schema: Schema, lay: DatasetLayout) -> FlightInfo:
        """One endpoint per slice, every live holder's Locations attached.

        The ticket is a plain range read of the slice *key* — identical
        batches on every holder make it redeemable anywhere — so the
        scheduler's failover (resume-skip) and hedged reads get real
        replicas to escape to without any scheduler-side changes."""
        endpoints, records, nbytes = [], 0, 0
        # planner-side trace stamp: when this GetFlightInfo runs under a
        # traced middleware span, every endpoint carries its context so the
        # scheduler's shard fetches stitch under the head's span
        trace = propagation_headers()
        for sl in lay.slices:
            hs = self._holders_alive(sl)  # raises when a slice lost all copies
            first = next((h for h in hs if self.shards[h].storage.exists(sl.key)), None)
            if first is None:
                continue  # slice exists in the plan but never received batches
            info = self.shards[first].storage.info(sl.key)
            if not info["batches"]:
                continue
            locs = tuple(l for h in hs for l in self.shards[h].locations())
            md = {"shard": first, "slice": sl.index, "holders": hs}
            if trace is not None:
                md["trace"] = trace
            endpoints.append(FlightEndpoint(
                Ticket.for_range(sl.key, 0, info["batches"], shard=first),
                locs,
                app_metadata=md,
            ))
            records += info["rows"]
            nbytes += info["bytes"]
        return FlightInfo(
            schema,
            FlightDescriptor.for_path(name),
            endpoints,
            total_records=records,
            total_bytes=nbytes,
            shard_spec=self.placement.spec(self.num_shards),
            epoch=self.membership.epoch,
        )

    def list_flights_impl(self) -> list[FlightInfo]:
        with self._dlock:
            names = list(self._datasets)
        return [self._info_for(n) for n in names]

    def _plan_query_info(self, cmd: QueryCommand, descriptor: FlightDescriptor) -> FlightInfo:
        """Plan ``GetFlightInfo(QueryCommand)``: one query endpoint per shard.

        Each endpoint's ticket carries the *same plan* scoped to one shard,
        so a scheduler-aware client pulls N filtered/projected streams
        concurrently and every shard executes its slice of the pushdown
        where the data lives."""
        if cmd.start != 0 or cmd.stop != -1:
            # a head-level batch range has no well-defined split across
            # shard-local batch indices — scope ranges per shard instead
            raise FlightInvalidArgument(
                "cluster query planning takes an unranged QueryCommand",
                detail={"start": cmd.start, "stop": cmd.stop})
        plan = cmd.plan
        name = plan.dataset
        with self._dlock:
            if name not in self._datasets:
                raise FlightNotFound(f"no such flight: {name}", detail={"dataset": name})
            schema = self._datasets[name]
        # aggregating plans stream per-group *state* (the partial operator),
        # so the planned schema is the state schema — see server.py
        out_schema = _query_out_schema(plan, schema)
        endpoints = []
        trace = propagation_headers()  # stitch shard queries under this span
        lay = self._layout(name)
        if lay is not None:
            # replicated pushdown: each endpoint's plan is rewritten to the
            # slice key (the shard-local dataset every holder serves), and
            # all live holders' Locations ride along for failover/hedging
            for sl in lay.slices:
                hs = self._holders_alive(sl)
                first = next(
                    (h for h in hs if self.shards[h].storage.exists(sl.key)), None)
                if first is None:
                    continue
                sub = dataclasses.replace(plan, dataset=sl.key)
                locs = tuple(l for h in hs for l in self.shards[h].locations())
                md = {"shard": first, "slice": sl.index, "holders": hs}
                if trace is not None:
                    md["trace"] = trace
                endpoints.append(FlightEndpoint(
                    Ticket.for_command(
                        QueryCommand(sub.serialize(), 0, -1, shard=first)),
                    locs,
                    app_metadata=md,
                ))
            return FlightInfo(out_schema, descriptor, endpoints,
                              total_records=-1, total_bytes=-1,
                              shard_spec=self.placement.spec(self.num_shards),
                              epoch=self.membership.epoch)
        for i, shard in enumerate(self.shards):
            if not shard.storage.exists(name):
                continue  # shard never received a slice of this dataset
            md = {"shard": i}
            if trace is not None:
                md["trace"] = trace
            endpoints.append(FlightEndpoint(
                Ticket.for_command(QueryCommand(cmd.plan_bytes, 0, -1, shard=i)),
                shard.locations(),
                app_metadata=md,
            ))
        return FlightInfo(out_schema, descriptor, endpoints,
                          total_records=-1, total_bytes=-1,
                          shard_spec=self.placement.spec(self.num_shards),
                          epoch=self.membership.epoch)

    def get_flight_info_impl(self, descriptor: FlightDescriptor) -> FlightInfo:
        if descriptor.path is None:
            cmd = descriptor.parsed_command()
            if isinstance(cmd, QueryCommand):
                return self._plan_query_info(cmd, descriptor)
            raise FlightInvalidArgument(
                f"cluster plans path or query descriptors, not {type(cmd).__name__}")
        return self._info_for(descriptor.path[0])

    def _route_slice_ticket(self, cmd) -> int | None:
        """Re-route a replicated slice ticket to a live holder.

        The planned primary is stamped in the ticket, but it may have died
        after planning — head-proxied reads pick the current best holder
        instead of failing on the stale stamp."""
        ds = cmd.plan.dataset if isinstance(cmd, QueryCommand) else getattr(cmd, "dataset", None)
        parsed = parse_slice_key(ds) if ds else None
        if parsed is None:
            return None
        name, gen, idx = parsed
        lay = self._layout(name)
        if lay is None or lay.gen != gen or idx >= lay.num_slices:
            return None  # stale generation: serve verbatim if the key survives
        sl = lay.slices[idx]
        sid = getattr(cmd, "shard", None)
        if sid is not None and sid in sl.holders and self.membership.is_routable(sid):
            return sid
        return self._holders_alive(sl)[0]

    def do_get_impl(self, ticket: Ticket):
        cmd = ticket.command()
        if isinstance(cmd, (StagedPutCommand, ExchangeCommand)):
            raise FlightInvalidArgument(
                f"{type(cmd).__name__} tickets are not redeemable via DoGet")
        sid = getattr(cmd, "shard", None)
        if sid is not None:
            routed = self._route_slice_ticket(cmd)
            if routed is not None:
                sid = routed
            if not 0 <= sid < self.num_shards:
                raise FlightNotFound(f"no such shard: {sid}", detail={"shard": sid})
            return self.shards[sid].do_get_impl(ticket)
        if isinstance(cmd, QueryCommand):
            # shard-less query ticket: gather every shard's batches and
            # execute at the head (legacy single-stream clients)
            from ...query.engine import execute, partial_aggregate

            plan = cmd.plan
            with self._dlock:
                if plan.dataset not in self._datasets:
                    raise FlightNotFound(f"no such flight: {plan.dataset}",
                                         detail={"dataset": plan.dataset})
                schema = self._datasets[plan.dataset]
            out_schema = _query_out_schema(plan, schema)
            stop = cmd.stop if cmd.stop >= 0 else None
            batches = self.dataset(plan.dataset)[cmd.start : stop]
            if plan.aggregations:  # one gathered partial; merge client-side
                return out_schema, iter([partial_aggregate(plan, batches, schema)])
            return out_schema, iter(list(execute(plan, batches)))
        # shard-less range ticket: gather — a range over the shard-ordered
        # concat, so single-connection legacy clients read the whole dataset
        name = cmd.dataset
        with self._dlock:
            if name not in self._datasets:
                raise FlightNotFound(f"no such flight: {name}", detail={"dataset": name})
            schema = self._datasets[name]
        batches = self.dataset(name)[cmd.start: cmd.stop if cmd.stop >= 0 else None]
        return schema, iter(batches)

    def do_put_impl(self, descriptor, schema, batches) -> dict:
        if descriptor.path is None and descriptor.command is not None:
            cmd = descriptor.parsed_command()
            if isinstance(cmd, StagedPutCommand):
                # head-funneled stage leg (legacy single-stream writers):
                # partition and stage on the owning shards — invisible
                # everywhere until the txn-commit round
                if cmd.phase != "stage":
                    raise FlightInvalidArgument(
                        f"DoPut takes the stage leg only; {cmd.phase!r} rides "
                        f"the txn-{cmd.phase} action", detail={"phase": cmd.phase})
                received = list(batches)
                if self._is_replicated_name(cmd.dataset):
                    return self._staged_put_replicated(cmd, schema, received)
                parts = self.placement.assign(received, self.num_shards)
                per_shard = [
                    shard.do_put_impl(descriptor, schema, iter(part))
                    for shard, part in zip(self.shards, parts) if part
                ]
                # deduped acks describe payload the shard already held —
                # counting them would double-book retried streams
                fresh = [s for s in per_shard if not s.get("deduped")]
                return {
                    "staged": True,
                    "txn_id": cmd.txn_id,
                    "batches": sum(s["batches"] for s in fresh),
                    "rows": sum(s["rows"] for s in fresh),
                    "bytes": sum(s["bytes"] for s in fresh),
                    "per_shard": per_shard,
                }
        name = descriptor.path[0] if descriptor.path else descriptor.key
        received = list(batches)
        if self._is_replicated_name(name):
            return self._plain_put_replicated(name, schema, received)
        parts = self.placement.assign(received, self.num_shards)
        per_shard = []
        for shard, part in zip(self.shards, parts):
            per_shard.append(shard.do_put_impl(descriptor, schema, iter(part)))
        with self._dlock:
            self._datasets.setdefault(name, schema)
        return {
            "batches": sum(s["batches"] for s in per_shard),
            "rows": sum(s["rows"] for s in per_shard),
            "bytes": sum(s["bytes"] for s in per_shard),
            "per_shard": per_shard,
        }

    def _is_replicated_name(self, name: str) -> bool:
        """Replicated routing applies to plain dataset names on a R>1
        cluster; a slice key addressed directly (rebalance staging, replica
        repair) falls through to the positional path untouched."""
        return self.replicas > 1 and parse_slice_key(name) is None

    def _plain_put_replicated(self, name: str, schema, received: list) -> dict:
        lay = self._ensure_layout(name)
        parts = self.placement.assign(received, lay.num_slices)
        per_slice, per_shard = [], []
        for sl, part in zip(lay.slices, parts):
            if not part:
                continue
            d = FlightDescriptor.for_path(sl.key)
            acks = [self.shards[h].do_put_impl(d, schema, iter(part))
                    for h in sl.holders]
            per_slice.append(acks[0])  # logical payload counted once
            per_shard.extend(acks)
        with self._dlock:
            self._datasets.setdefault(name, schema)
        return {
            "batches": sum(s["batches"] for s in per_slice),
            "rows": sum(s["rows"] for s in per_slice),
            "bytes": sum(s["bytes"] for s in per_slice),
            "replicas": self.replicas,
            "per_shard": per_shard,
        }

    def _staged_put_replicated(self, cmd: StagedPutCommand, schema, received: list) -> dict:
        """Head-funneled replicated stage: every slice stages on all of its
        holders under a per-slice sub-txn; the mapping is remembered so the
        writer's plain ``txn-commit {txn_id}`` finds the whole fan-out."""
        lay = self._ensure_layout(cmd.dataset)
        parts = self.placement.assign(received, lay.num_slices)
        per_slice, per_shard, subs = [], [], []
        for sl, part in zip(lay.slices, parts):
            if not part:
                continue
            stxn = subtxn_id(cmd.txn_id, sl.index)
            d = FlightDescriptor.for_command(StagedPutCommand(sl.key, stxn, "stage"))
            for k, h in enumerate(sl.holders):
                ack = self.shards[h].do_put_impl(d, schema, iter(part))
                per_shard.append(ack)
                subs.append((h, stxn))
                if k == 0 and not ack.get("deduped"):
                    per_slice.append(ack)
        with self._dlock:
            self._pending_txns[cmd.txn_id] = (cmd.dataset, subs)
            while len(self._pending_txns) > 512:
                self._pending_txns.popitem(last=False)
        return {
            "staged": True,
            "txn_id": cmd.txn_id,
            "batches": sum(s["batches"] for s in per_slice),
            "rows": sum(s["rows"] for s in per_slice),
            "bytes": sum(s["bytes"] for s in per_slice),
            "replicas": self.replicas,
            "per_shard": per_shard,
        }

    # -- transaction coordination (two-phase commit across shards) -------- #
    def _shard_txn_action(self, shard: InMemoryFlightServer, verb: str,
                          body: bytes) -> dict:
        # in-proc sub-txn calls bypass middleware, so the shard-side span is
        # opened explicitly: when this coordinator runs under a traced span,
        # each prepare/commit/abort vote becomes a stitched child on the
        # shard that cast it (no-op on untraced traffic)
        with shard.telemetry.span(f"txn:{verb}"):
            return json.loads(shard.do_action_impl(Action(verb, body))[0].body)

    def _coordinate_commit(self, o: dict) -> dict:
        """Prepare→commit fan-out — the first cross-shard coordinated verb.

        Phase 1 asks every shard whether the txn's stage is present and
        healthy (``txn-prepare`` pins it against GC).  If any shard the
        caller expected (``expect_shards``, or simply *some* shard when
        unspecified) cannot vote yes, the txn is aborted everywhere and the
        failure surfaces — nothing becomes visible.  Phase 2 commits every
        staged shard; each shard's flip is atomic under its store lock."""
        txn_id = o["txn_id"]
        subs = self._resolve_subtxns(o)
        if subs is not None:
            return self._coordinate_commit_replicated(o, subs)
        body = json.dumps({"txn_id": txn_id}).encode()
        try:
            votes = [self._shard_txn_action(s, "txn-prepare", body)
                     for s in self.shards]
        except FlightError:
            self._coordinate_abort(o)
            raise
        staged_ids = [i for i, v in enumerate(votes) if v.get("staged")]
        expired = sorted(i for i, v in enumerate(votes) if v.get("expired"))
        if expired:
            # some shard *had* this txn's stage and GC'd it — committing the
            # surviving shards would tear the txn even without expect_shards
            self._coordinate_abort(o)
            raise FlightUnavailable(
                f"txn {txn_id!r} aborted: stage expired on shard(s) {expired}",
                detail={"txn_id": txn_id, "expired_shards": expired})
        expect = o.get("expect_shards")
        if expect is not None:
            missing = sorted(set(expect) - set(staged_ids))
            if missing:
                self._coordinate_abort(o)
                raise FlightUnavailable(
                    f"txn {txn_id!r} aborted: shard(s) {missing} hold no stage "
                    f"(crashed writer, or stage GC'd)",
                    detail={"txn_id": txn_id, "missing_shards": missing})
        if not staged_ids:
            raise FlightNotFound(f"no staged txn {txn_id!r} on any shard",
                                 detail={"txn_id": txn_id})
        acks = [self._shard_txn_action(self.shards[i], "txn-commit", body)
                for i in staged_ids]
        dataset = o.get("dataset") or acks[0].get("dataset")
        if dataset is not None:
            with self._dlock:
                self._datasets.setdefault(
                    dataset, self.shards[staged_ids[0]].storage.schema(dataset))
        return {
            "txn_id": txn_id,
            "committed": True,
            "dataset": dataset,
            "shards": staged_ids,
            "batches": sum(a.get("batches", 0) for a in acks),
            "rows": sum(a.get("rows", 0) for a in acks),
            "bytes": sum(a.get("bytes", 0) for a in acks),
            "duplicate": all(a.get("duplicate") for a in acks),
        }

    def _resolve_subtxns(self, o: dict) -> list[tuple[int, str]] | None:
        """Find a logical txn's replicated (shard, sub-txn) fan-out.

        The client-side replicated writer names its sub-txns in the commit
        body; head-funneled writers committed with a bare ``{txn_id}`` are
        resolved through the mapping remembered at stage time.  ``None``
        means the classic unreplicated round."""
        subs = o.get("subtxns")
        if subs is None:
            with self._dlock:
                pend = self._pending_txns.get(o["txn_id"])
            if pend is None:
                return None
            if not o.get("dataset"):
                o["dataset"] = pend[0]
            subs = pend[1]
        seen, out = set(), []
        for h, stxn in subs:
            if (int(h), stxn) not in seen:
                seen.add((int(h), stxn))
                out.append((int(h), stxn))
        return out

    def _coordinate_commit_replicated(self, o: dict, subs: list[tuple[int, str]]) -> dict:
        """Prepare→commit across every (holder, sub-txn) of a replicated
        write — same all-or-none outcome as the classic round, with the
        expectation implicit: *every* listed sub-txn must vote staged, so a
        crashed writer's partial replica fan-out can never half-commit."""
        txn_id = o["txn_id"]

        def act(h: int, verb: str, stxn: str) -> dict:
            return self._shard_txn_action(
                self.shards[h], verb, json.dumps({"txn_id": stxn}).encode())

        try:
            votes = [(h, stxn, act(h, "txn-prepare", stxn)) for h, stxn in subs]
        except FlightError:
            self._abort_subtxns(txn_id, subs)
            raise
        bad = sorted({h for h, _, v in votes if not v.get("staged")})
        if bad:
            self._abort_subtxns(txn_id, subs)
            raise FlightUnavailable(
                f"txn {txn_id!r} aborted: missing/expired stage on shard(s) {bad}",
                detail={"txn_id": txn_id, "missing_shards": bad})
        acks = [act(h, "txn-commit", stxn) for h, stxn in subs]
        dataset = o.get("dataset")
        key0 = acks[0].get("dataset")
        if dataset is None and key0:
            parsed = parse_slice_key(key0)
            dataset = parsed[0] if parsed else key0
        if dataset is not None and key0:
            with self._dlock:
                if dataset not in self._datasets:
                    self._datasets[dataset] = self.shards[subs[0][0]].storage.schema(key0)
        # logical payload counted once per slice, not once per replica copy
        counted, batches, rows, nbytes = set(), 0, 0, 0
        for (h, stxn), a in zip(subs, acks):
            if stxn in counted:
                continue
            counted.add(stxn)
            batches += a.get("batches", 0)
            rows += a.get("rows", 0)
            nbytes += a.get("bytes", 0)
        return {
            "txn_id": txn_id,
            "committed": True,
            "dataset": dataset,
            "shards": sorted({h for h, _ in subs}),
            "subtxns": len(counted),
            "batches": batches,
            "rows": rows,
            "bytes": nbytes,
            "duplicate": all(a.get("duplicate") for a in acks),
        }

    def _abort_subtxns(self, txn_id: str, subs: list[tuple[int, str]]) -> dict:
        aborted = []
        for h, stxn in subs:
            try:
                ack = self._shard_txn_action(
                    self.shards[h], "txn-abort",
                    json.dumps({"txn_id": stxn}).encode())
                if ack.get("aborted"):
                    aborted.append(h)
            except FlightError:
                continue  # best-effort: the shard's TTL reaper finishes it
        return {"txn_id": txn_id, "aborted": bool(aborted),
                "shards": sorted(set(aborted))}

    def _coordinate_abort(self, o: dict) -> dict:
        subs = self._resolve_subtxns(o)
        if subs is not None:
            return self._abort_subtxns(o["txn_id"], subs)
        body = json.dumps({"txn_id": o["txn_id"]}).encode()
        aborted = []
        for i, s in enumerate(self.shards):
            try:
                if self._shard_txn_action(s, "txn-abort", body).get("aborted"):
                    aborted.append(i)
            except FlightError:
                continue  # best-effort: committed shards surface elsewhere
        return {"txn_id": o["txn_id"], "aborted": bool(aborted), "shards": aborted}

    # -- distributed aggregation / shuffle / join --------------------------- #
    def aggregate_plan(self, plan) -> "dict | RecordBatch":
        """Head-merged aggregation: redeem every planned partial endpoint,
        merge the state batches (``query.engine.merge_partials``).

        Each shard ships only its per-group state — never the surviving
        rows.  On a replicated cluster a planned primary that died after
        planning is retried on the slice's other holders (the same ticket
        is redeemable on any replica)."""
        from ...query.engine import merge_partials
        from .scheduler import _empty_batch

        info = self._plan_query_info(QueryCommand.for_plan(plan),
                                     FlightDescriptor.for_query(plan))
        partials: list[RecordBatch] = []
        for ep in info.endpoints:
            try:
                _, it = self.do_get_impl(ep.ticket)
            except FlightError:
                it = None
                for h in (ep.app_metadata or {}).get("holders", []):
                    try:
                        _, it = self.shards[h].do_get_impl(ep.ticket)
                        break
                    except FlightError:
                        continue
                if it is None:
                    raise
            partials.extend(it)
        if not partials:
            partials = [_empty_batch(info.schema)]
        return merge_partials(plan, partials)

    def shuffle_dataset(self, name: str, key, into: str,
                        num_partitions: int | None = None) -> dict:
        """Hash-shuffle ``name`` by key column(s) into dataset ``into``:
        after this, partition ``p``'s rows live on shard ``p % N`` and equal
        key tuples are co-resident — the layout grouped aggregation and
        equi-joins want.

        Shard-affine data plane: each source shard's local batches stream
        through the keyed ``repartition`` exchange (one stream per
        destination partition, emitting only that partition's rows), and
        each partition stream lands on its destination shard as a *staged*
        DoPut under a per-(source, partition) txn id — the txn scope is what
        keeps identical partition payloads from different sources out of
        the content-dedup guard — then flips visible via per-shard
        ``txn-commit``.  Intermediates are written unreplicated even on an
        R>1 cluster (a shuffle is always reproducible from its source)."""
        keys = [key] if isinstance(key, str) else list(key)
        n = num_partitions or self.num_shards
        if n < 1:
            raise FlightInvalidArgument("num_partitions must be >= 1")
        with self._dlock:
            if name not in self._datasets:
                raise FlightNotFound(f"no such flight: {name}",
                                     detail={"dataset": name})
            schema = self._datasets[name]
        for k in keys:
            if k not in schema.names:
                raise FlightInvalidArgument(f"shuffle key {k!r} not in schema",
                                            detail={"key": k})
        # source slices: shard-local batches wherever the dataset lives
        sources: list[tuple[int, list[RecordBatch]]] = []
        lay = self._layout(name)
        if lay is None:
            for i, s in enumerate(self.shards):
                if s.storage.exists(name):
                    sources.append((i, s.dataset(name)))
        else:
            for sl in lay.slices:
                hs = self._holders_alive(sl)
                first = next(
                    (h for h in hs if self.shards[h].storage.exists(sl.key)), None)
                if first is not None:
                    sources.append((first, self.shards[first].dataset(sl.key)))
        base = uuid.uuid4().hex[:12]
        staged: list[tuple[int, str]] = []
        rows = nbytes = streams = 0
        for src, batches in sources:
            if not batches:
                continue
            src_cli = FlightClient(self.shards[src], token=self.auth_token)
            for p in range(n):
                stream = src_cli.do_exchange_stream(
                    FlightDescriptor.for_command(ExchangeCommand.for_service(
                        "repartition", key=keys, num_partitions=n, partition=p)),
                    schema)
                stream.feed(batches)
                part = list(stream)
                if not part:
                    continue
                dest = p % self.num_shards
                stxn = f"shuffle-{base}-s{src}p{p}"
                stage_slice(FlightClient(self.shards[dest], token=self.auth_token),
                            into, stxn, schema, part)
                staged.append((dest, stxn))
                rows += sum(b.num_rows for b in part)
                nbytes += sum(b.nbytes() for b in part)
                streams += 1
        # flip every staged leg visible (single-writer intermediates: plain
        # per-shard commits; client write() owns the full 2PC story)
        for dest, stxn in staged:
            self.shards[dest].do_action_impl(Action(
                "txn-commit", json.dumps({"txn_id": stxn}).encode()))
        # every shard owns its (possibly empty) partition, so downstream
        # per-shard operators (local-join) never miss a side
        for s in self.shards:
            if not s.storage.exists(into):
                s.add_dataset(into, [], schema=schema)
        with self._dlock:
            self._datasets[into] = schema
        return {"dataset": into, "partitions": n, "sources": len(sources),
                "streams": streams, "rows": rows, "bytes": nbytes}

    def join_datasets(self, left: str, right: str, on, into: str) -> dict:
        """Distributed inner equi-join: shuffle both sides by the join key,
        then join each shard's key-aligned partitions locally (the
        ``local-join`` action); the result lands sharded under ``into``.

        Correctness leans on one hash discipline end to end: both shuffles
        bucket by the same stable key hash, so every join key's rows from
        *both* datasets meet on exactly one shard and the union of the
        per-shard joins is the global join."""
        from ...query.engine import join_schema

        keys = [on] if isinstance(on, str) else list(on)
        with self._dlock:
            for nm in (left, right):
                if nm not in self._datasets:
                    raise FlightNotFound(f"no such flight: {nm}",
                                         detail={"dataset": nm})
            ls, rs = self._datasets[left], self._datasets[right]
        out_schema = join_schema(ls, rs, keys)
        base = uuid.uuid4().hex[:8]
        tl, tr = f"{into}.__l{base}", f"{into}.__r{base}"
        joins = 0
        try:
            self.shuffle_dataset(left, keys, tl)
            self.shuffle_dataset(right, keys, tr)
            body = json.dumps({"left": tl, "right": tr, "on": keys,
                               "into": into}).encode()
            for s in self.shards:
                ack = json.loads(
                    s.do_action_impl(Action("local-join", body))[0].body)
                joins += ack["rows"]
        finally:
            for s in self.shards:
                for tmp in (tl, tr):
                    try:
                        s.do_action_impl(Action("drop", tmp.encode()))
                    except FlightError:
                        pass
        with self._dlock:
            self._datasets[into] = out_schema
        return {"dataset": into, "rows": joins, "on": keys}

    def do_action_impl(self, action: Action) -> list[ActionResult]:
        told = telemetry_action(self, action)  # server-metrics / server-trace
        if told is not None:
            return told
        if action.type in ("cluster-metrics", "cluster-trace"):
            # cluster-wide scrape: the head's own snapshot plus every
            # shard's, merged into one epoch-stamped Arrow batch.  Shards
            # are scraped via ``telemetry_action`` directly, not their
            # (possibly fault-shadowed) DoAction verb: the telemetry plane
            # must stay readable while the data plane is down — a dead
            # holder's error spans are exactly what the operator is after.
            # A shard whose scrape still fails is skipped; the membership
            # view says who is missing.
            verb = "server-" + action.type[len("cluster-"):]
            parts = [(-1, decode_telemetry_batch(
                telemetry_action(self, Action(verb, action.body))[0].body))]
            for i, s in enumerate(self.shards):
                try:
                    body = telemetry_action(s, Action(verb, action.body))[0].body
                    parts.append((i, decode_telemetry_batch(body)))
                except Exception:
                    continue
            merged = merge_telemetry_batches(parts, epoch=self.membership.epoch)
            return [ActionResult(encode_telemetry_batch(merged))]
        if action.type == "health":
            return [ActionResult(b"ok")]
        if action.type == "heartbeat":
            # push path: an external shard agent announces liveness (the
            # prober is the pull path; both feed the same registry)
            o = json.loads(action.body) if action.body else {}
            sid = o.get("shard")
            if sid is not None:
                self.membership.heartbeat(int(sid))
            return [ActionResult(json.dumps(
                {"ok": True, "epoch": self.membership.epoch}).encode())]
        if action.type == "membership":
            return [ActionResult(json.dumps(self.membership.view().to_json()).encode())]
        if action.type == "server-stats":
            # head-side operator snapshot (tools/flight_top.py): the head's
            # own event-loop stats + verb counters + the membership epoch
            return [ActionResult(json.dumps({
                "epoch": self.membership.epoch,
                "io": (self._listener.stats()
                       if self._listener is not None else None),
                "verbs": self.metrics.snapshot(),
            }).encode())]
        if action.type == "txn-commit":
            out = self._coordinate_commit(parse_txn_body(action.body))
            return [ActionResult(json.dumps(out).encode())]
        if action.type == "txn-abort":
            out = self._coordinate_abort(parse_txn_body(action.body))
            return [ActionResult(json.dumps(out).encode())]
        if action.type == "list-names":
            with self._dlock:
                return [ActionResult(",".join(self._datasets).encode())]
        if action.type == "drop":
            name = action.body.decode()
            with self._dlock:
                lay = self._layouts.pop(name, None)
                self._datasets.pop(name, None)
            if lay is not None:
                self._drop_layout_keys(lay)
            else:
                for s in self.shards:
                    try:
                        s.do_action_impl(action)
                    except FlightError:
                        continue  # a dead shard's copy died with it
            return [ActionResult(b"dropped")]
        if action.type == "stats":
            shard_stats = []
            for s in self.shards:
                try:
                    shard_stats.append(
                        json.loads(s.do_action_impl(Action("stats"))[0].body))
                except Exception as e:
                    shard_stats.append({"error": f"{type(e).__name__}: {e}"})
            with self._dlock:
                layouts = {n: lay.to_json() for n, lay in self._layouts.items()}
            out = {
                "num_shards": self.num_shards,
                "scheme": self.placement.scheme,
                "replicas": self.replicas,
                "membership": self.membership.view().to_json(),
                "rebalances": self.rebalances,
                "layouts": layouts,
                "shards": shard_stats,
            }
            return [ActionResult(json.dumps(out).encode())]
        if action.type == "aggregate":
            # head-merged distributed aggregation: shards ship per-group
            # state, the head merges and returns only the final result
            from ...query.engine import QueryPlan

            plan = QueryPlan.deserialize(action.body)
            res = self.aggregate_plan(plan)
            if isinstance(res, RecordBatch):  # grouped → columnar JSON
                res = {"group_by": plan.group_by, "columns": res.to_pydict()}
            return [ActionResult(json.dumps(res).encode())]
        if action.type == "shuffle":
            o = json.loads(action.body)
            out = self.shuffle_dataset(o["dataset"], o["key"], o["into"],
                                       o.get("num_partitions"))
            return [ActionResult(json.dumps(out).encode())]
        if action.type == "join":
            o = json.loads(action.body)
            out = self.join_datasets(o["left"], o["right"], o["on"], o["into"])
            return [ActionResult(json.dumps(out).encode())]
        if action.type == "register-dataset":
            # announces a dataset written straight to the shards (the
            # client-side parallel DoPut path never funnels through the head)
            o = json.loads(action.body)
            with self._dlock:
                self._datasets.setdefault(o["name"], Schema.from_json(o["schema"]))
            return [ActionResult(b"registered")]
        if action.type == "shard-locations":
            spec = self.placement.spec(self.num_shards)
            view = self.membership.view()
            states = {sid: state for sid, state, _ in view.shards}
            out = {
                **spec.to_json(),
                "replicas": self.replicas,
                "epoch": view.epoch,
                "alive": view.alive(),
                "shards": [
                    {"shard": i, "locations": [l.uri for l in s.locations()],
                     "state": states.get(i, ShardState.HEALTHY.value)}
                    for i, s in enumerate(self.shards)
                ],
            }
            return [ActionResult(json.dumps(out).encode())]
        if action.type == "write-plan":
            # a replicated client-side writer asks where each slice's
            # replicas live (and under which keys) before fanning out
            o = json.loads(action.body)
            if self.replicas == 1:
                raise FlightInvalidArgument(
                    "write-plan applies to replicated clusters; use "
                    "shard-locations for positional writes")
            lay = self._ensure_layout(o["name"])
            holders = sorted({h for sl in lay.slices for h in sl.holders})
            out = {
                "name": lay.name,
                "gen": lay.gen,
                "scheme": self.placement.scheme,
                "key": getattr(self.placement, "key", None),
                "replicas": self.replicas,
                "epoch": self.membership.epoch,
                "slices": [sl.to_json() for sl in lay.slices],
                "locations": {
                    str(h): [l.uri for l in self.shards[h].locations()]
                    for h in holders
                },
            }
            return [ActionResult(json.dumps(out).encode())]
        raise FlightError(f"unknown action {action.type!r}")

    def do_exchange_impl(self, descriptor, schema, batch) -> RecordBatch:
        return batch

    # -- client plumbing ----------------------------------------------------- #
    def client_factory(self):
        """Location resolver for in-proc schedulers: maps each shard's
        ``inproc://`` location to a client holding that shard object."""
        by_name = {s.location_name: s for s in self.shards}
        by_name[self.location_name] = self

        def factory(loc: Location | None) -> FlightClient:
            if loc is None:
                return FlightClient(self)
            uri = loc.uri
            if uri.startswith("inproc://"):
                name = uri[len("inproc://"):]
                if name in by_name:
                    return FlightClient(by_name[name], token=self.auth_token)
                raise FlightError(f"unknown in-proc location {uri!r}")
            return FlightClient(uri, token=self.auth_token)

        return factory


# --------------------------------------------------------------------------
# cluster-aware client
# --------------------------------------------------------------------------


class FlightClusterClient:
    """Head connection + parallel scheduler, for both directions.

    ``target`` is a ``FlightClusterServer`` (in-proc) or a ``tcp://`` uri of
    one.  Reads fan in every shard endpoint; writes partition locally with
    the cluster's placement policy and DoPut directly to the shards."""

    def __init__(
        self,
        target: FlightClusterServer | Location | str,
        token: str | None = None,
        max_streams: int = 8,
        ordered: bool = True,
        window: int = 4,
        hedge_after: float | None = None,
        call_options: CallOptions | None = None,
    ):
        self.token = token
        self.call_options = call_options
        self._cluster = target if isinstance(target, FlightClusterServer) else None
        self.head = FlightClient(target, token=token, options=call_options)
        self.max_streams = max_streams
        self.ordered = ordered
        self.window = window
        self.hedge_after = hedge_after
        self._inproc_factory = self._cluster.client_factory() if self._cluster else None
        self._sched: ParallelStreamScheduler | None = None

    # -- location resolution ---------------------------------------------- #
    def _factory(self, loc: Location | None) -> FlightClient:
        if loc is None:
            return self.head
        if loc.uri.startswith("inproc://"):
            if self._inproc_factory is None:
                raise FlightError(f"cannot resolve {loc.uri!r} without the server object")
            return self._inproc_factory(loc)
        return FlightClient(loc, token=self.token)

    def scheduler(self, **overrides) -> ParallelStreamScheduler:
        # the default scheduler is cached so its per-location client (and
        # connection) cache survives across read/write calls
        if not overrides:
            if self._sched is None:
                self._sched = self._make_scheduler()
            return self._sched
        return self._make_scheduler(**overrides)

    def _make_scheduler(self, **overrides) -> ParallelStreamScheduler:
        opts = dict(
            max_streams=self.max_streams,
            ordered=self.ordered,
            window=self.window,
            hedge_after=self.hedge_after,
            call_options=self.call_options,
            # our put targets are the cluster's own shards, whose content-hash
            # dedup guard makes a retried stream idempotent
            put_retries=1,
        )
        opts.update(overrides)
        # _factory already resolves every location, so it serves as its own
        # hedge/failover tier — no separate hedge_factory needed
        return ParallelStreamScheduler(self._factory, **opts)

    # -- data plane --------------------------------------------------------- #
    def info(self, name: str) -> FlightInfo:
        return self.head.get_flight_info(FlightDescriptor.for_path(name))

    def read(self, name: str, **sched_overrides) -> tuple[Table, TransferStats]:
        return self.scheduler(**sched_overrides).fetch(self.info(name))

    def stream(self, name: str, **sched_overrides):
        return self.scheduler(**sched_overrides).stream(self.info(name))

    # -- typed query pushdown ---------------------------------------------- #
    def query_info(self, plan) -> FlightInfo:
        """Plan a ``QueryCommand`` at the head: per-shard query endpoints."""
        return self.head.get_flight_info(FlightDescriptor.for_query(plan))

    def query(self, plan, **sched_overrides) -> tuple[Table, TransferStats]:
        """Predicated/projected read executed shard-side, fanned in parallel.

        Each shard filters and projects its own slice (see
        ``FlightClusterServer._plan_query_info``); only surviving
        columns/rows cross the wire — the paper's Fig 8 pushdown win on top
        of the Fig 2 parallel-stream topology."""
        return self.scheduler(**sched_overrides).fetch(self.query_info(plan))

    def aggregate(self, plan, **sched_overrides):
        """Distributed grouped/scalar aggregation, merged client-side.

        The head plans one partial-aggregate endpoint per shard; each shard
        folds its slice into a per-group state batch (``sum``+``count``
        pairs for ``mean``, running extrema — only group-sized state crosses
        the wire) and this client merges the partials.  Returns
        ``(result, TransferStats)`` where result is a per-group
        ``RecordBatch`` for ``plan.group_by`` plans or the scalar dict for
        ungrouped ones — element-equal to running ``query.engine.aggregate``
        over the whole dataset on one node.  Replica failover and hedging
        come from the scheduler exactly as for row reads."""
        from ...query.engine import merge_partials
        from .scheduler import _empty_batch

        info = self.query_info(plan)
        table, stats = self.scheduler(**sched_overrides).fetch(info)
        partials = list(table.batches) or [_empty_batch(info.schema)]
        return merge_partials(plan, partials), stats

    # -- shuffle / join ----------------------------------------------------- #
    def shuffle(self, name: str, key, into: str,
                num_partitions: int | None = None) -> dict:
        """Server-side hash shuffle of ``name`` by ``key`` into ``into``
        (see ``FlightClusterServer.shuffle_dataset``)."""
        body = {"dataset": name, "key": key, "into": into}
        if num_partitions:
            body["num_partitions"] = num_partitions
        return json.loads(self.head.do_action(
            Action("shuffle", json.dumps(body).encode()))[0].body)

    def join(self, left: str, right: str, on, into: str | None = None,
             **sched_overrides) -> tuple[Table, TransferStats]:
        """Distributed equi-join: shuffle both sides by the join key, join
        shard-locally, then fan the sharded result in.  Returns the joined
        table plus the read stats (the join itself runs server-side)."""
        into = into or f"{left}.join.{right}"
        self.head.do_action(Action("join", json.dumps(
            {"left": left, "right": right, "on": on, "into": into}).encode()))
        return self.read(into, **sched_overrides)

    # -- streaming exchange fan-out ---------------------------------------- #
    def exchange(
        self,
        command,
        batches: list[RecordBatch],
        **sched_overrides,
    ) -> tuple[Table, TransferStats]:
        """Fan one transform exchange across the cluster's shard endpoints.

        ``command`` names a registered ``ExchangeService`` (a service name
        string, an ``ExchangeCommand``, or a full descriptor).  The batches
        are split round-robin across the shards and each slice streams
        through its shard's exchange concurrently (one pipelined stream per
        endpoint — the paper's Fig 11 "throughput vs parallel streams"
        topology applied to the microservice verb).  Returns the gathered
        transformed table plus bidirectional transfer stats."""
        if not batches:
            raise FlightInvalidArgument(
                "cluster exchange needs at least one input batch "
                "(the input schema rides the first batch)")
        descriptor = as_exchange_descriptor(command)
        layout = json.loads(self.head.do_action(Action("shard-locations"))[0].body)
        parts = RoundRobinPlacement().assign(batches, layout["num_shards"])
        assignments = [
            (self._pick_location(entry["locations"]), part)
            for entry, part in zip(layout["shards"], parts) if part
        ]
        out_schema, outs, stats = self.scheduler(**sched_overrides).exchange(
            descriptor, batches[0].schema, assignments)
        if not outs:
            from .scheduler import _empty_batch

            outs = [_empty_batch(out_schema or batches[0].schema)]
        return Table(outs), stats

    def write(
        self,
        name: str,
        batches: list[RecordBatch],
        placement: Placement | None = None,
        transactional: bool = False,
        txn_id: str | None = None,
    ) -> TransferStats:
        """Partition client-side and DoPut each shard's slice in parallel.

        Plain mode: DoPut *appends* (matching ``InMemoryFlightServer``), and
        the N shard streams commit independently.  Transient per-stream
        failures are retried, and the shards' content-hash dedup guard drops
        a re-sent payload they already committed, so a failed ``write``
        re-issued within the dedup window does not duplicate rows.  Note the
        flip side: intentionally appending a byte-identical payload twice in
        quick succession is also deduplicated — use ``dedup_puts=False``
        shards (or distinct payloads) for that.

        ``transactional=True``: the two-phase protocol.  Each shard's slice
        streams as a *staged* payload under one txn id (same parallel
        fan-out, same wire speed — the stage leg is just a DoPut whose
        descriptor carries ``StagedPutCommand``), then a single
        ``txn-commit`` at the head drives prepare→commit across the staged
        shards.  The *outcome* is all-or-none: every slice ends up visible,
        or — on any stage or vote failure — none does (the txn is aborted
        everywhere and this call raises).  Each shard's flip is atomic
        under its store lock, so no reader ever sees part of a shard's
        slice; a read overlapping the brief commit fan-out can still catch
        some shards flipped before others (cross-shard read snapshots are a
        roadmap item).  Stage-leg retries stay safe against the default
        dedup-guarded shards: they dedup re-staged streams by content hash
        within the txn."""
        layout = json.loads(self.head.do_action(Action("shard-locations"))[0].body)
        if layout.get("replicas", 1) > 1:
            return self._write_replicated(name, batches, transactional, txn_id)
        if placement is None:
            placement = make_placement(layout["scheme"], layout.get("key"))
        parts = placement.assign(batches, layout["num_shards"])
        schema = batches[0].schema
        assignments, shard_ids = [], []
        for entry, part in zip(layout["shards"], parts):
            if not part:
                continue
            loc = self._pick_location(entry["locations"])
            assignments.append((loc, part))
            shard_ids.append(entry["shard"])
        if not transactional:
            stats = self.scheduler().put(
                FlightDescriptor.for_path(name), schema, assignments)
            self.head.do_action(
                Action("register-dataset",
                       json.dumps({"name": name, "schema": schema.to_json()}).encode())
            )
            return stats
        if not assignments:
            return TransferStats(streams=0)
        txn_id = txn_id or uuid.uuid4().hex
        commit_body = json.dumps(
            {"txn_id": txn_id, "dataset": name, "expect_shards": shard_ids}
        ).encode()
        return run_staged_put(self.scheduler(), self.head.do_action,
                              name, schema, assignments, txn_id, commit_body)

    def _write_replicated(
        self,
        name: str,
        batches: list[RecordBatch],
        transactional: bool,
        txn_id: str | None,
    ) -> TransferStats:
        """Client-side parallel write against a replicated cluster.

        ``write-plan`` at the head pins the slice → holders layout; each
        slice's payload then DoPuts straight to *every* holder under the
        slice's own storage key (the 3-tuple ``scheduler.put`` form — one
        descriptor per stream).  Transactionally, each slice stages under a
        per-slice sub-txn and the commit body names the whole (holder,
        sub-txn) fan-out, so the head's coordinator commits all replicas of
        all slices as one all-or-none round."""
        plan = json.loads(self.head.do_action(
            Action("write-plan", json.dumps({"name": name}).encode()))[0].body)
        placement = make_placement(plan["scheme"], plan.get("key"))
        parts = placement.assign(batches, len(plan["slices"]))
        schema = batches[0].schema
        locs = {int(h): uris for h, uris in plan["locations"].items()}
        if not transactional:
            assignments = [
                (self._pick_location(locs[h]), part,
                 FlightDescriptor.for_path(sl["key"]))
                for sl, part in zip(plan["slices"], parts) if part
                for h in sl["holders"]
            ]
            stats = self.scheduler().put(None, schema, assignments)
            self.head.do_action(
                Action("register-dataset",
                       json.dumps({"name": name, "schema": schema.to_json()}).encode())
            )
            return stats
        txn_id = txn_id or uuid.uuid4().hex
        assignments, subs = [], []
        for sl, part in zip(plan["slices"], parts):
            if not part:
                continue
            stxn = subtxn_id(txn_id, sl["index"])
            d = FlightDescriptor.for_command(StagedPutCommand(sl["key"], stxn, "stage"))
            for h in sl["holders"]:
                assignments.append((self._pick_location(locs[h]), part, d))
                subs.append([h, stxn])
        if not assignments:
            return TransferStats(streams=0)
        commit_body = json.dumps(
            {"txn_id": txn_id, "dataset": name, "subtxns": subs}).encode()
        return run_staged_put(self.scheduler(), self.head.do_action,
                              name, schema, assignments, txn_id, commit_body)

    def _pick_location(self, uris: list[str]) -> Location:
        """Prefer in-proc when we hold the server objects, else TCP."""
        if self._inproc_factory is not None:
            for u in uris:
                if u.startswith("inproc://"):
                    return Location(u)
        for u in uris:
            if u.startswith("tcp://"):
                return Location(u)
        if not uris:
            raise FlightError("shard exposes no locations")
        return Location(uris[0])

"""Columnar arrays: the Arrow Buffers layout (validity / offsets / values).

Each ``Array`` owns 0-3 buffers depending on type (Table 2 of the paper):
  primitive        -> [validity?, values]
  utf8 / binary    -> [validity?, offsets(int32), values(uint8)]
  list<T>          -> [validity?, offsets(int32)] + child Array
  fixed_size_list  -> [validity?] + child Array

Arrays are immutable; ``slice`` is zero-copy for values/offsets (offsets are
re-based lazily via an ``offset`` field, like Arrow).
"""
from __future__ import annotations

from typing import Any, Sequence

import numpy as np

from .buffer import Bitmap, Buffer, pad_to
from .schema import (
    BinaryType,
    DataType,
    FixedSizeListType,
    ListType,
    PrimitiveType,
    Utf8Type,
    type_from_numpy,
)


class Array:
    """An immutable columnar array of ``length`` values of ``type``."""

    def __init__(
        self,
        type: DataType,
        length: int,
        validity: Bitmap | None,
        buffers: list[Buffer],
        children: list["Array"] | None = None,
        offset: int = 0,
    ):
        self.type = type
        self.length = length
        self.validity = validity
        self.buffers = buffers
        self.children = children or []
        self.offset = offset  # logical start into buffers (zero-copy slicing)

    # ------------------------------------------------------------------ #
    # constructors
    # ------------------------------------------------------------------ #
    @staticmethod
    def from_numpy(values: np.ndarray, mask: np.ndarray | None = None) -> "Array":
        """Zero-copy from a 1-D numpy array (2-D becomes fixed_size_list)."""
        if values.ndim == 2:
            child = Array.from_numpy(np.ascontiguousarray(values).reshape(-1))
            typ = FixedSizeListType(child.type, values.shape[1])
            validity = Bitmap.from_bools(mask) if mask is not None else None
            return Array(typ, values.shape[0], validity, [], [child])
        if values.ndim != 1:
            raise ValueError("from_numpy wants 1-D or 2-D")
        typ = type_from_numpy(values.dtype)
        validity = Bitmap.from_bools(mask) if mask is not None else None
        return Array(typ, len(values), validity, [Buffer.from_array(values)])

    @staticmethod
    def from_pylist(values: Sequence[Any], type: DataType | None = None) -> "Array":
        """Build from a python list; ``None`` entries become nulls."""
        mask = np.array([v is not None for v in values], dtype=bool)
        has_nulls = not mask.all()
        validity = Bitmap.from_bools(mask) if has_nulls else None

        if type is None:
            type = _infer_type(values)

        if isinstance(type, PrimitiveType):
            np_vals = np.array(
                [v if v is not None else 0 for v in values], dtype=type.np_dtype
            )
            return Array(type, len(values), validity, [Buffer.from_array(np_vals)])

        if isinstance(type, (Utf8Type, BinaryType)):
            encoded = [
                (v.encode() if isinstance(v, str) else (v or b"")) for v in values
            ]
            offsets = np.zeros(len(values) + 1, dtype=np.int32)
            np.cumsum([len(e) for e in encoded], out=offsets[1:])
            data = b"".join(encoded)
            return Array(
                type,
                len(values),
                validity,
                [Buffer.from_array(offsets), Buffer.from_bytes(data)],
            )

        if isinstance(type, ListType):
            offsets = np.zeros(len(values) + 1, dtype=np.int32)
            np.cumsum([len(v) if v is not None else 0 for v in values], out=offsets[1:])
            flat: list[Any] = []
            for v in values:
                if v is not None:
                    flat.extend(v)
            child = Array.from_pylist(flat, type.value_type)
            return Array(type, len(values), validity, [Buffer.from_array(offsets)], [child])

        if isinstance(type, FixedSizeListType):
            flat = []
            for v in values:
                if v is None:
                    flat.extend([0] * type.list_size)
                else:
                    if len(v) != type.list_size:
                        raise ValueError("fixed_size_list length mismatch")
                    flat.extend(v)
            child = Array.from_pylist(flat, type.value_type)
            return Array(type, len(values), validity, [], [child])

        raise TypeError(f"cannot build {type!r} from pylist")

    # ------------------------------------------------------------------ #
    # accessors
    # ------------------------------------------------------------------ #
    @property
    def null_count(self) -> int:
        if self.validity is None:
            return 0
        return self.validity.slice(self.offset, self.length).null_count() if (
            self.offset or self.validity.length != self.length
        ) else self.validity.null_count()

    def is_valid(self, i: int) -> bool:
        if self.validity is None:
            return True
        return self.validity.is_valid(self.offset + i)

    def _values(self) -> np.ndarray:
        """The raw values region (primitive types), honoring offset/length."""
        assert isinstance(self.type, PrimitiveType)
        v = self.buffers[0].view(self.type.np_dtype)
        return v[self.offset : self.offset + self.length]

    def _offsets(self) -> np.ndarray:
        v = self.buffers[0].view(np.int32)
        return v[self.offset : self.offset + self.length + 1]

    def to_numpy(self, zero_copy: bool = True) -> np.ndarray:
        """Values as numpy.  Primitive: zero-copy view.  fixed_size_list: 2-D view."""
        if isinstance(self.type, PrimitiveType):
            return self._values()
        if isinstance(self.type, FixedSizeListType):
            child = self.children[0]
            sz = self.type.list_size
            flat = child.to_numpy()[self.offset * sz : (self.offset + self.length) * sz]
            return flat.reshape(self.length, sz)
        raise TypeError(f"to_numpy unsupported for {self.type!r} (use to_pylist)")

    def value(self, i: int):
        if not self.is_valid(i):
            return None
        t = self.type
        if isinstance(t, PrimitiveType):
            return self._values()[i].item()
        if isinstance(t, (Utf8Type, BinaryType)):
            off = self._offsets()
            raw = self.buffers[1].view(np.uint8)[off[i] : off[i + 1]].tobytes()
            return raw.decode() if isinstance(t, Utf8Type) else raw
        if isinstance(t, ListType):
            off = self._offsets()
            child = self.children[0]
            return [child.value(j) for j in range(off[i], off[i + 1])]
        if isinstance(t, FixedSizeListType):
            sz, child = t.list_size, self.children[0]
            s = (self.offset + i) * sz
            return [child.value(j) for j in range(s, s + sz)]
        raise TypeError(t)

    def to_pylist(self) -> list:
        return [self.value(i) for i in range(self.length)]

    def slice(self, offset: int, length: int | None = None) -> "Array":
        """Zero-copy logical slice."""
        if length is None:
            length = self.length - offset
        if offset < 0 or offset + length > self.length:
            raise IndexError(f"slice [{offset}, {offset + length}) of {self.length}")
        return Array(
            self.type, length, self.validity, self.buffers, self.children, self.offset + offset
        )

    def take(self, indices: np.ndarray) -> "Array":
        """Gather rows (copies — it must)."""
        indices = np.asarray(indices)
        t = self.type
        if isinstance(t, PrimitiveType):
            vals = self._values()[indices]
            mask = None
            if self.validity is not None:
                mask = self.validity.to_bools()[self.offset : self.offset + self.length][indices]
            return Array.from_numpy(vals, mask)
        # general path through python values (fine for tests/small data)
        return Array.from_pylist([self.value(int(i)) for i in indices], t)

    def nbytes(self) -> int:
        n = sum(b.nbytes for b in self.buffers)
        if self.validity is not None:
            n += self.validity.buffer.nbytes
        return n + sum(c.nbytes() for c in self.children)

    def __len__(self) -> int:
        return self.length

    def __eq__(self, other) -> bool:
        if not isinstance(other, Array):
            return NotImplemented
        return (
            self.type == other.type
            and self.length == other.length
            and self.to_pylist() == other.to_pylist()
        )

    def __repr__(self) -> str:
        head = self.to_pylist()[:6]
        more = ", ..." if self.length > 6 else ""
        return f"Array<{self.type!r}>[{self.length}]{head}{more}"


def _infer_type(values: Sequence[Any]) -> DataType:
    from .schema import binary, bool_, float64, int64, list_, utf8

    for v in values:
        if v is None:
            continue
        if isinstance(v, bool):
            return bool_
        if isinstance(v, int):
            return int64
        if isinstance(v, float):
            return float64
        if isinstance(v, str):
            return utf8
        if isinstance(v, bytes):
            return binary
        if isinstance(v, (list, tuple)):
            return list_(_infer_type(v))
        if isinstance(v, np.generic):
            return type_from_numpy(v.dtype)
        raise TypeError(f"cannot infer arrow type of {type(v)}")
    return int64  # all-null column


def concat_arrays(arrays: list[Array]) -> Array:
    """Concatenate arrays of the same type (copies)."""
    if not arrays:
        raise ValueError("empty concat")
    t = arrays[0].type
    if any(a.type != t for a in arrays):
        raise TypeError("concat type mismatch")
    if isinstance(t, PrimitiveType) and all(a.validity is None for a in arrays):
        return Array.from_numpy(np.concatenate([a._values() for a in arrays]))
    out: list = []
    for a in arrays:
        out.extend(a.to_pylist())
    return Array.from_pylist(out, t)

"""Schema / DataType layer — names + types + nullability for RecordBatches.

Mirrors the Arrow type system closely enough for the paper's use cases:
fixed-width primitives, variable-width binary/utf8, lists, and fixed-size
lists (the tensor-friendly type the data plane uses for embeddings).
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field as dc_field
from typing import Any

import numpy as np

# --------------------------------------------------------------------------
# Data types
# --------------------------------------------------------------------------


class DataType:
    """Base type. ``id`` is the wire tag; fixed-width types carry numpy dtype."""

    id: str = "?"

    @property
    def is_primitive(self) -> bool:
        return isinstance(self, PrimitiveType)

    @property
    def is_varlen(self) -> bool:
        return isinstance(self, (Utf8Type, BinaryType, ListType))

    def to_json(self) -> dict:
        return {"id": self.id}

    def __eq__(self, other):
        return type(self) is type(other) and self.to_json() == other.to_json()

    def __hash__(self):
        return hash(json.dumps(self.to_json(), sort_keys=True))

    def __repr__(self):
        return self.id


@dataclass(frozen=True, eq=False, repr=False)
class PrimitiveType(DataType):
    """Fixed-width type backed by a numpy dtype (int/uint/float/bool)."""

    np_dtype: np.dtype

    @property
    def id(self) -> str:  # type: ignore[override]
        return self.np_dtype.name

    @property
    def itemsize(self) -> int:
        return self.np_dtype.itemsize

    def to_json(self) -> dict:
        return {"id": "primitive", "dtype": self.np_dtype.str}


class Utf8Type(DataType):
    id = "utf8"


class BinaryType(DataType):
    id = "binary"


@dataclass(frozen=True, eq=False, repr=False)
class ListType(DataType):
    """Variable-length list of a child type (offsets + child array)."""

    value_type: DataType

    @property
    def id(self) -> str:  # type: ignore[override]
        return f"list<{self.value_type.id}>"

    def to_json(self) -> dict:
        return {"id": "list", "value": self.value_type.to_json()}


@dataclass(frozen=True, eq=False, repr=False)
class FixedSizeListType(DataType):
    """Fixed-size list — the embedding/tensor column type (no offsets buffer)."""

    value_type: DataType
    list_size: int

    @property
    def id(self) -> str:  # type: ignore[override]
        return f"fixed_size_list<{self.value_type.id}>[{self.list_size}]"

    def to_json(self) -> dict:
        return {"id": "fixed_size_list", "value": self.value_type.to_json(), "size": self.list_size}


# Convenience singletons (Arrow-style constructors)
def _prim(np_dt) -> PrimitiveType:
    return PrimitiveType(np.dtype(np_dt))


int8, int16, int32, int64 = _prim("int8"), _prim("int16"), _prim("int32"), _prim("int64")
uint8, uint16, uint32, uint64 = _prim("uint8"), _prim("uint16"), _prim("uint32"), _prim("uint64")
float16, float32, float64 = _prim("float16"), _prim("float32"), _prim("float64")
bool_ = _prim("bool")
utf8 = Utf8Type()
binary = BinaryType()


def list_(value_type: DataType) -> ListType:
    return ListType(value_type)


def fixed_size_list(value_type: DataType, size: int) -> FixedSizeListType:
    return FixedSizeListType(value_type, size)


def type_from_json(obj: dict) -> DataType:
    tid = obj["id"]
    if tid == "primitive":
        return PrimitiveType(np.dtype(obj["dtype"]))
    if tid == "utf8":
        return utf8
    if tid == "binary":
        return binary
    if tid == "list":
        return ListType(type_from_json(obj["value"]))
    if tid == "fixed_size_list":
        return FixedSizeListType(type_from_json(obj["value"]), obj["size"])
    raise ValueError(f"unknown type id {tid!r}")


def type_from_numpy(dt) -> PrimitiveType:
    return PrimitiveType(np.dtype(dt))


# --------------------------------------------------------------------------
# Field / Schema
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class Field:
    name: str
    type: DataType
    nullable: bool = True
    metadata: dict = dc_field(default_factory=dict)

    def to_json(self) -> dict:
        return {
            "name": self.name,
            "type": self.type.to_json(),
            "nullable": self.nullable,
            "metadata": self.metadata,
        }

    @classmethod
    def from_json(cls, obj: dict) -> "Field":
        return cls(obj["name"], type_from_json(obj["type"]), obj["nullable"], obj.get("metadata", {}))


@dataclass(frozen=True)
class Schema:
    fields: tuple[Field, ...]
    metadata: dict = dc_field(default_factory=dict)

    def __post_init__(self):
        object.__setattr__(self, "fields", tuple(self.fields))
        names = [f.name for f in self.fields]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate field names: {names}")

    @property
    def names(self) -> list[str]:
        return [f.name for f in self.fields]

    def field(self, name: str) -> Field:
        for f in self.fields:
            if f.name == name:
                return f
        raise KeyError(name)

    def index(self, name: str) -> int:
        for i, f in enumerate(self.fields):
            if f.name == name:
                return i
        raise KeyError(name)

    def select(self, names: list[str]) -> "Schema":
        return Schema(tuple(self.field(n) for n in names), dict(self.metadata))

    def to_json(self) -> dict:
        return {"fields": [f.to_json() for f in self.fields], "metadata": self.metadata}

    def serialize(self) -> bytes:
        return json.dumps(self.to_json(), sort_keys=True).encode()

    @classmethod
    def from_json(cls, obj: dict) -> "Schema":
        return cls(tuple(Field.from_json(f) for f in obj["fields"]), obj.get("metadata", {}))

    @classmethod
    def deserialize(cls, data: bytes) -> "Schema":
        return cls.from_json(json.loads(data.decode()))

    def __len__(self) -> int:
        return len(self.fields)

    def __repr__(self) -> str:
        inner = ", ".join(f"{f.name}: {f.type!r}{'' if f.nullable else ' not null'}" for f in self.fields)
        return f"Schema<{inner}>"


def schema(pairs: list[tuple[str, DataType]] | dict[str, DataType], metadata: dict | None = None) -> Schema:
    if isinstance(pairs, dict):
        pairs = list(pairs.items())
    return Schema(tuple(Field(n, t) for n, t in pairs), metadata or {})

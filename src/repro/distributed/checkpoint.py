"""Fault-tolerant sharded checkpointing with resharding restore.

Design (1000+-node posture, DESIGN.md §4):

* **Sharded save**: each host writes only the shards it owns (here: each
  *device*'s addressable shards, one .npy per leaf-shard) — no host ever
  materializes a 398 B-param global array.
* **Atomic commit**: writes land in ``step_N.tmp/``; a manifest (pytree
  structure, shapes, dtypes, shard index) is written last and the directory
  is atomically renamed — a crash mid-save can never corrupt the latest
  checkpoint (restore scans for the newest *committed* step).
* **Async**: ``save_async`` snapshots to host RAM (device_get) then writes
  on a background thread — training continues during I/O.
* **Resharding restore**: restore takes the *target* sharding tree; shards
  are reassembled per-leaf via ``jax.make_array_from_callback``, so a
  checkpoint taken on (16,16) restores onto (2,16,16) or a degraded
  (15-node) mesh unchanged — this is the elastic-scaling path.
* **Data-plane state**: the loader's Flight ticket (dataset, offset) is
  checkpointed too, giving deterministic resume of the input pipeline.
"""
from __future__ import annotations

import json
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Callable

import jax
import ml_dtypes
import numpy as np

_EXOTIC = {"bfloat16": (np.uint16, ml_dtypes.bfloat16),
           "float8_e4m3fn": (np.uint8, ml_dtypes.float8_e4m3fn)}


def _flatten_with_paths(tree, is_leaf=None) -> list[tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree, is_leaf=is_leaf)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((key, leaf))
    return out


def _is_shard_dict(x) -> bool:
    return isinstance(x, dict) and "__shards__" in x


class CheckpointManager:
    def __init__(self, directory: str | Path, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: threading.Thread | None = None
        self._error: Exception | None = None

    # ------------------------------------------------------------- save --
    def save(self, step: int, state, extra: dict | None = None) -> Path:
        """Synchronous sharded save with atomic commit."""
        host_state = jax.tree.map(self._to_host_shards, state,
                                  is_leaf=lambda x: hasattr(x, "addressable_shards") or
                                  isinstance(x, (np.ndarray, jax.Array)))
        return self._write(step, host_state, extra or {})

    def save_async(self, step: int, state, extra: dict | None = None) -> None:
        """Snapshot to host, write in background; join previous write first."""
        self.wait()
        host_state = jax.tree.map(self._to_host_shards, state,
                                  is_leaf=lambda x: hasattr(x, "addressable_shards") or
                                  isinstance(x, (np.ndarray, jax.Array)))
        self._thread = threading.Thread(
            target=self._write_guarded, args=(step, host_state, extra or {}), daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    @staticmethod
    def _to_host_shards(x):
        """jax.Array -> list of (index_slices, np.ndarray) addressable shards."""
        if isinstance(x, jax.Array) and hasattr(x, "addressable_shards"):
            shards = []
            seen = set()
            for s in x.addressable_shards:
                idx = s.index if isinstance(s.index, tuple) else (s.index,)
                key = tuple(
                    (sl.start if sl.start is not None else 0,
                     sl.stop if sl.stop is not None else dim)
                    for sl, dim in zip(idx, x.shape))
                if key in seen:
                    continue  # replicated shards: write once
                seen.add(key)
                shards.append({"index": key, "data": np.asarray(s.data)})
            return {"__shards__": shards, "shape": list(x.shape), "dtype": str(x.dtype)}
        arr = np.asarray(x)
        return {"__shards__": [{"index": tuple((0, d) for d in arr.shape), "data": arr}],
                "shape": list(arr.shape), "dtype": str(arr.dtype)}

    def _write_guarded(self, step, host_state, extra):
        try:
            self._write(step, host_state, extra)
        except Exception as e:  # surfaced on next wait()
            self._error = e

    def _write(self, step: int, host_state, extra: dict) -> Path:
        tmp = self.dir / f"step_{step:09d}.tmp"
        final = self.dir / f"step_{step:09d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        manifest = {"step": step, "extra": extra, "leaves": {}, "time": time.time()}
        for key, leaf in _flatten_with_paths(host_state, is_leaf=_is_shard_dict):
            if not _is_shard_dict(leaf):
                continue
            safe = key.replace("/", "__")
            entries = []
            for i, sh in enumerate(leaf["__shards__"]):
                fname = f"{safe}.shard{i}.npy"
                data = sh["data"]
                if str(data.dtype) in _EXOTIC:  # np.save can't roundtrip these
                    data = data.view(_EXOTIC[str(data.dtype)][0])
                np.save(tmp / fname, data, allow_pickle=False)
                entries.append({"file": fname, "index": [list(p) for p in sh["index"]]})
            manifest["leaves"][key] = {
                "shape": leaf["shape"], "dtype": leaf["dtype"], "shards": entries}
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        tmp.rename(final)  # atomic commit
        self._gc()
        return final

    def _gc(self) -> None:
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{s:09d}", ignore_errors=True)

    # ---------------------------------------------------------- restore --
    def all_steps(self) -> list[int]:
        out = []
        for p in self.dir.glob("step_*"):
            if p.suffix == ".tmp" or not (p / "manifest.json").exists():
                continue  # uncommitted
            out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, target_state, shardings=None):
        """Rebuild ``target_state``-structured arrays, resharding to
        ``shardings`` (tree of NamedSharding or None=host numpy)."""
        src = self.dir / f"step_{step:09d}"
        manifest = json.loads((src / "manifest.json").read_text())

        leaf_specs = manifest["leaves"]
        flat_target = _flatten_with_paths(target_state)
        flat_shard = (_flatten_with_paths(shardings) if shardings is not None
                      else [(k, None) for k, _ in flat_target])
        shard_by_key = dict(flat_shard)

        def load_leaf(key: str, like):
            spec = leaf_specs.get(key)
            if spec is None:
                raise KeyError(f"checkpoint missing leaf {key!r}")
            shape = tuple(spec["shape"])
            dtype = (_EXOTIC[spec["dtype"]][1] if spec["dtype"] in _EXOTIC
                     else np.dtype(spec["dtype"]))

            def read_region(index) -> np.ndarray:
                """Assemble an arbitrary region from saved shards."""
                np_dtype = (_EXOTIC[spec["dtype"]][1] if spec["dtype"] in _EXOTIC
                            else np.dtype(spec["dtype"]))
                region = np.zeros([sl.stop - sl.start for sl in index], dtype=np_dtype)
                for sh in spec["shards"]:
                    bounds = [tuple(b) for b in sh["index"]]
                    inter = []
                    ok = True
                    for (lo, hi), sl in zip(bounds, index):
                        s, e = max(lo, sl.start), min(hi, sl.stop)
                        if s >= e:
                            ok = False
                            break
                        inter.append((s, e, lo, sl.start))
                    if not ok:
                        continue
                    data = np.load(self.dir / f"step_{step:09d}" / sh["file"])
                    if spec["dtype"] in _EXOTIC:
                        data = data.view(_EXOTIC[spec["dtype"]][1])
                    src_sel = tuple(slice(s - lo, e - lo) for (s, e, lo, _) in inter)
                    dst_sel = tuple(slice(s - st, e - st) for (s, e, _, st) in inter)
                    region[dst_sel] = data[src_sel].astype(region.dtype)
                return region

            sharding = shard_by_key.get(key)
            if sharding is None:
                return read_region(tuple(slice(0, d) for d in shape))
            return jax.make_array_from_callback(
                shape, sharding,
                lambda idx: read_region(tuple(
                    slice(s.start or 0, s.stop if s.stop is not None else dim)
                    for s, dim in zip(idx, shape))).astype(dtype))

        out = [load_leaf(key, like) for key, like in flat_target]
        tree = jax.tree.structure(target_state)
        return jax.tree.unflatten(tree, out)

"""Distributed runtime: sharding, collectives, checkpoint, fault, elastic."""
from .sharding import (  # noqa: F401
    DEFAULT_RULES,
    ShardingCtx,
    resolve_spec,
    sharding_for,
    single_device_ctx,
    tree_shardings,
)

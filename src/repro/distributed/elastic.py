"""Elastic scaling: rebuild the mesh when the world size changes.

Synchronous SPMD cannot lose a participant mid-step, so elasticity happens
at checkpoint boundaries: on membership change the controller (1) picks the
largest supported mesh ≤ alive hosts, (2) restores the last committed
checkpoint **resharded** onto the new mesh (checkpoint.py does arbitrary
region reassembly), (3) rescales the data plane (Flight endpoints are range
tickets — re-partitioning the shard->host map is a metadata operation), and
(4) resumes.  Batch-size semantics under shrink are configurable: keep the
global batch (more grad accumulation) or scale it with the world.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import numpy as np
from jax.sharding import Mesh


# meshes we can reform to, largest first: (pod, data, model) — model axis is
# kept at 16 (TP within a rack is fixed by the wiring), pods×data flex.
_SUPPORTED: list[tuple[int, int, int]] = [
    (2, 16, 16), (1, 16, 16), (1, 8, 16), (1, 4, 16), (1, 2, 16), (1, 1, 16),
    (1, 1, 8), (1, 1, 4), (1, 1, 2), (1, 1, 1),
]


@dataclass(frozen=True)
class WorldChange:
    old_devices: int
    new_devices: int
    mesh_shape: tuple[int, int, int]
    microbatch_scale: int  # grad-accum factor to keep global batch constant


def best_mesh_shape(n_devices: int) -> tuple[int, int, int]:
    for shape in _SUPPORTED:
        if int(np.prod(shape)) <= n_devices:
            return shape
    raise ValueError(f"no supported mesh for {n_devices} devices")


def plan_reshape(old_devices: int, new_devices: int,
                 keep_global_batch: bool = True) -> WorldChange:
    shape = best_mesh_shape(new_devices)
    used = int(np.prod(shape))
    scale = max(1, old_devices // used) if keep_global_batch else 1
    return WorldChange(old_devices, new_devices, shape, scale)


def make_elastic_mesh(change: WorldChange, devices=None) -> Mesh:
    devices = devices if devices is not None else jax.devices()
    p, d, m = change.mesh_shape
    n = p * d * m
    arr = np.array(devices[:n])
    if p > 1:
        return Mesh(arr.reshape(p, d, m), ("pod", "data", "model"))
    return Mesh(arr.reshape(d, m), ("data", "model"))


def repartition_tickets(n_shards: int, workers: list[str]) -> dict[str, list[int]]:
    """Data-plane rescale: reassign dataset shard ranges to surviving
    workers (round robin; tickets are idempotent ranges so no data moves)."""
    assign: dict[str, list[int]] = {w: [] for w in workers}
    for s in range(n_shards):
        assign[workers[s % len(workers)]].append(s)
    return assign

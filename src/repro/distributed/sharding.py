"""Logical-axis sharding: one vocabulary, per-arch rules, GSPMD + shard_map.

Every parameter/activation is annotated with *logical* axis names; a rule
table maps them onto mesh axes.  This is the MaxText/GSPMD idiom and is what
lets one model definition run on the (16,16) single-pod and (2,16,16)
multi-pod meshes unchanged.

Conventions (see DESIGN.md §4):
  batch    -> ("pod", "data")      data parallel over pods × data axis
  embed    -> "data"               FSDP: parameters sharded on the d_model dim
  heads    -> "model"              Megatron TP on (padded) q heads
  kv_heads -> "model"              kv heads replicated up to 16 then TP
  ff       -> "model"              TP on FFN hidden
  experts  -> "model"              expert parallelism
  vocab    -> "model"              embedding/logits vocab dim
  inner    -> "model"              mamba/xlstm inner dim
  kv_seq   -> "data"               decode KV streamed seq-sharded (flash decode)
  layers/seq/stack -> replicated
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

DEFAULT_RULES: dict[str, Any] = {
    "batch": ("pod", "data"),
    "embed": "data",
    "embed_nosplit": None,
    "heads": "model",
    "kv_heads": "model",
    "ff": "model",
    "experts": "model",
    "experts_rep": None,
    "vocab": "model",
    "inner": "model",
    "kv_seq": ("pod", "data"),
    "kv_heads_rep": None,
    "q_per_kv": None,
    "ff_nosplit": None,
    "inner_nosplit": None,
    "heads_nosplit": None,
    "layers": None,
    "stack": None,
    "seq": None,
    "head_dim": None,
    "conv": None,
    "state": None,
    "dt": None,
    "patch": None,
    None: None,
}


def mesh_axes(mesh: Mesh) -> set[str]:
    return set(mesh.axis_names)


def resolve_spec(logical: tuple, mesh: Mesh, rules: dict | None = None) -> P:
    """Map a tuple of logical axis names to a PartitionSpec for ``mesh``.

    Rules naming mesh axes absent from ``mesh`` degrade to replication (this
    is what makes the same model run single-pod without a "pod" axis).
    """
    rules = {**DEFAULT_RULES, **(rules or {})}
    axes = mesh_axes(mesh)
    out, used = [], set()

    def pick(name):
        if name is None:
            return None
        r = rules.get(name, None)
        if r is None:
            return None
        cands = r if isinstance(r, tuple) else (r,)
        chosen = tuple(c for c in cands if c in axes and c not in used)
        used.update(chosen)
        if not chosen:
            return None
        return chosen if len(chosen) > 1 else chosen[0]

    for name in logical:
        out.append(pick(name))
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def sharding_for(logical: tuple, mesh: Mesh, rules: dict | None = None) -> NamedSharding:
    return NamedSharding(mesh, resolve_spec(logical, mesh, rules))


def tree_shardings(logical_tree, mesh: Mesh, rules: dict | None = None):
    """Map a pytree of logical-axis tuples to NamedShardings."""
    return jax.tree.map(
        lambda ax: sharding_for(ax, mesh, rules),
        logical_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(a, (str, type(None))) for a in x),
    )


class ShardingCtx:
    """Mesh + rule table threaded through model construction.

    ``ctx.constrain(x, ("batch", "seq", "embed_nosplit"))`` is the only way
    models talk about distribution — physical axes never appear in model code.
    """

    def __init__(self, mesh: Mesh, rules: dict | None = None):
        self.mesh = mesh
        self.rules = {**DEFAULT_RULES, **(rules or {})}

    def spec(self, logical: tuple) -> P:
        return resolve_spec(logical, self.mesh, self.rules)

    def sharding(self, logical: tuple) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(logical))

    def constrain(self, x, logical: tuple):
        return jax.lax.with_sharding_constraint(x, self.sharding(logical))

    def axis_size(self, mesh_axis: str) -> int:
        return self.mesh.shape[mesh_axis] if mesh_axis in self.mesh.axis_names else 1

    @property
    def model_parallelism(self) -> int:
        return self.axis_size("model")

    @property
    def data_parallelism(self) -> int:
        return self.axis_size("data") * self.axis_size("pod")


def single_device_ctx(rules: dict | None = None) -> ShardingCtx:
    """A 1×1 ("data","model") mesh for CPU smoke tests — constraints no-op."""
    dev = np.array(jax.devices()[:1]).reshape(1, 1)
    return ShardingCtx(Mesh(dev, ("data", "model")), rules)


def pad_to_multiple(n: int, m: int) -> int:
    return (n + m - 1) // m * m


def batch_shard_count(mesh: Mesh) -> int:
    n = 1
    for ax in ("pod", "data"):
        if ax in mesh.axis_names:
            n *= mesh.shape[ax]
    return n


def divisible_batch(global_batch: int, mesh: Mesh) -> bool:
    return global_batch % batch_shard_count(mesh) == 0

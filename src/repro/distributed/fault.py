"""Fault tolerance: heartbeats, failure detection, restart policy, stragglers.

The coordinator view of a 1000+-node job.  Mechanisms:

* **Heartbeat registry** — workers POST heartbeats (here: Flight DoAction
  "heartbeat"); the detector marks a worker dead after ``timeout_s`` without
  one, and the job controller reacts per ``RestartPolicy``.
* **Straggler detection** — per-step duration reports; a worker slower than
  ``straggler_factor`` × median for ``patience`` consecutive steps is flagged.
  Mitigation on the data plane is *hedged DoGet* (client.py) — tickets are
  idempotent range reads, so re-issuing against a replica endpoint is safe —
  and on the compute plane, flagged hosts are queued for replacement at the
  next checkpoint boundary (synchronous SPMD can't drop a participant
  mid-step; see elastic.py for the reshape).
* **TrainSupervisor** — wraps the train loop: run → on failure restore last
  committed checkpoint → reshape mesh if the world changed → resume.
"""
from __future__ import annotations

import json
import statistics
import threading
import time
from dataclasses import dataclass, field
from enum import Enum
from typing import Callable


class WorkerState(str, Enum):
    HEALTHY = "healthy"
    SUSPECT = "suspect"
    DEAD = "dead"
    STRAGGLER = "straggler"


@dataclass
class WorkerInfo:
    worker_id: str
    last_heartbeat: float = field(default_factory=time.time)
    state: WorkerState = WorkerState.HEALTHY
    step_times: list[float] = field(default_factory=list)
    slow_streak: int = 0


class FailureDetector:
    """Phi-accrual-lite: timeout-based with a suspect grace period."""

    def __init__(self, timeout_s: float = 30.0, suspect_s: float = 10.0):
        self.timeout_s = timeout_s
        self.suspect_s = suspect_s
        self.workers: dict[str, WorkerInfo] = {}
        self._lock = threading.Lock()

    def register(self, worker_id: str) -> None:
        with self._lock:
            self.workers[worker_id] = WorkerInfo(worker_id)

    def heartbeat(self, worker_id: str) -> None:
        with self._lock:
            w = self.workers.setdefault(worker_id, WorkerInfo(worker_id))
            w.last_heartbeat = time.time()
            if w.state in (WorkerState.SUSPECT, WorkerState.DEAD):
                w.state = WorkerState.HEALTHY

    def sweep(self, now: float | None = None) -> list[str]:
        """Advance states; returns newly-dead worker ids."""
        now = now or time.time()
        newly_dead = []
        with self._lock:
            for w in self.workers.values():
                dt = now - w.last_heartbeat
                if dt > self.timeout_s and w.state != WorkerState.DEAD:
                    w.state = WorkerState.DEAD
                    newly_dead.append(w.worker_id)
                elif dt > self.suspect_s and w.state == WorkerState.HEALTHY:
                    w.state = WorkerState.SUSPECT
        return newly_dead

    def alive(self) -> list[str]:
        with self._lock:
            return [w.worker_id for w in self.workers.values()
                    if w.state != WorkerState.DEAD]


class StragglerDetector:
    def __init__(self, factor: float = 1.5, patience: int = 3):
        self.factor = factor
        self.patience = patience
        self.detector_times: dict[str, list[float]] = {}
        self.slow_streaks: dict[str, int] = {}

    def report(self, worker_id: str, step_s: float) -> None:
        self.detector_times.setdefault(worker_id, []).append(step_s)
        self.detector_times[worker_id] = self.detector_times[worker_id][-20:]

    def flagged(self) -> list[str]:
        latest = {w: t[-1] for w, t in self.detector_times.items() if t}
        if len(latest) < 2:
            return []
        med = statistics.median(latest.values())
        out = []
        for w, t in latest.items():
            if t > self.factor * med:
                self.slow_streaks[w] = self.slow_streaks.get(w, 0) + 1
            else:
                self.slow_streaks[w] = 0
            if self.slow_streaks.get(w, 0) >= self.patience:
                out.append(w)
        return out


@dataclass
class RestartPolicy:
    max_restarts: int = 10
    backoff_s: float = 5.0
    elastic: bool = True          # allow resuming with fewer/more hosts
    min_workers: int = 1


class TrainSupervisor:
    """run_fn(start_step, world) -> final_step; restarts on failure from the
    last committed checkpoint (checkpoint manager passed by caller)."""

    def __init__(self, policy: RestartPolicy, ckpt_mgr, logger: Callable[[str], None] = print):
        self.policy = policy
        self.ckpt = ckpt_mgr
        self.log = logger
        self.restarts = 0

    def run(self, run_fn: Callable[[int], int]) -> int:
        while True:
            start = (self.ckpt.latest_step() or 0)
            try:
                return run_fn(start)
            except Exception as e:  # worker failure surfaces here
                self.restarts += 1
                if self.restarts > self.policy.max_restarts:
                    self.log(f"[supervisor] giving up after {self.restarts - 1} restarts: {e}")
                    raise
                self.log(f"[supervisor] failure at step>={start}: {e!r}; "
                         f"restart {self.restarts}/{self.policy.max_restarts} "
                         f"from step {self.ckpt.latest_step() or 0} in {self.policy.backoff_s}s")
                time.sleep(self.policy.backoff_s)

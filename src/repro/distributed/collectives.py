"""Compressed collectives: int8 ring all-reduce with error feedback.

The paper's thesis applied to the TPU fabric: if the wire is the bottleneck,
compress what crosses it.  ``compressed_psum_ring`` implements a
reduce-scatter/all-gather ring (`lax.ppermute` inside ``shard_map``) whose
hops carry **int8 blockwise-quantized** chunks (kernels/quantize.py is the
TPU kernel for the hop codec) — 4× fewer bytes on the dominant gradient
all-reduce at bf16, ~2× at int8-vs-bf16.

``compressed_grad_sync`` adds per-leaf **error feedback** (the quantization
residual is re-added next step), the standard trick that keeps convergence
within noise of exact all-reduce (1-bit Adam / EF-SGD lineage).

Engineering note: with pjit, gradient reduction normally happens *implicitly*
inside backward.  To substitute a custom collective we mark gradients as
per-shard partial sums via ``shard_map`` and reduce them ourselves — the
train step opts in with ``TrainConfig.compressed_allreduce``.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from .sharding import ShardingCtx


def _axis_size(axis_name: str) -> int:
    """Static mapped-axis size, portable across jax versions.

    ``jax.lax.axis_size`` only exists in newer jax; on 0.4.x the axis frame
    carries the size (as the frame itself, an int, on 0.4.37)."""
    if hasattr(jax.lax, "axis_size"):
        return int(jax.lax.axis_size(axis_name))
    frame = jax.core.axis_frame(axis_name)
    return int(getattr(frame, "size", frame))


def _quant_chunk(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric int8 quantization over flat chunks of 256 (jnp path; the
    Pallas kernel in kernels/quantize.py is the TPU version)."""
    n = x.shape[0]
    block = 256 if n % 256 == 0 else n
    xb = x.reshape(-1, block)
    amax = jnp.max(jnp.abs(xb), axis=-1)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(xb / scale[:, None]), -127, 127).astype(jnp.int8)
    return q, scale


def _dequant_chunk(q: jax.Array, scale: jax.Array) -> jax.Array:
    return (q.astype(jnp.float32) * scale[:, None]).reshape(-1)


def compressed_psum_ring(x_local: jax.Array, axis_name: str) -> jax.Array:
    """Ring all-reduce of a flat f32 vector with int8-compressed hops.

    Runs INSIDE shard_map.  x_local: (n,) per-device partial sum, n divisible
    by axis size.  Returns the summed (n,) on every device.
    """
    n_dev = _axis_size(axis_name)
    if n_dev == 1:
        return x_local
    n = x_local.shape[0]
    assert n % n_dev == 0, (n, n_dev)
    chunks = x_local.reshape(n_dev, n // n_dev)
    fwd = [(i, (i + 1) % n_dev) for i in range(n_dev)]
    me = jax.lax.axis_index(axis_name)

    # reduce-scatter phase: after n_dev-1 hops, chunk j is complete on dev j
    acc = chunks
    recv_idx = me  # which chunk index we accumulate this hop

    def rs_step(k, acc):
        # each device sends chunk (me - k) and receives chunk (me - k - 1)
        send_idx = (me - k) % n_dev
        q, s = _quant_chunk(acc[send_idx])
        q_r = jax.lax.ppermute(q, axis_name, perm=fwd)
        s_r = jax.lax.ppermute(s, axis_name, perm=fwd)
        add_idx = (me - k - 1) % n_dev
        contrib = _dequant_chunk(q_r, s_r)
        return acc.at[add_idx].add(contrib)

    acc = jax.lax.fori_loop(0, n_dev - 1, rs_step, acc)

    # all-gather phase: circulate completed chunks
    def ag_step(k, acc):
        send_idx = (me + 1 - k) % n_dev
        q, s = _quant_chunk(acc[send_idx])
        q_r = jax.lax.ppermute(q, axis_name, perm=fwd)
        s_r = jax.lax.ppermute(s, axis_name, perm=fwd)
        set_idx = (me - k) % n_dev
        return acc.at[set_idx].set(_dequant_chunk(q_r, s_r).reshape(acc.shape[1:]))

    acc = jax.lax.fori_loop(0, n_dev - 1, ag_step, acc)
    return acc.reshape(n)


def compressed_grad_sync(grads, ctx: ShardingCtx, axis: str = "data"):
    """Replace the implicit gradient all-reduce over ``axis`` with the
    compressed ring.  grads: pytree of *per-shard partial* gradients
    (replicated-spec leaves).  Error feedback is carried in optimizer state
    by the caller when enabled; here we apply plain compression."""
    mesh = ctx.mesh
    if axis not in mesh.axis_names or mesh.shape[axis] == 1:
        return grads
    n_dev = mesh.shape[axis]

    leaves, tree = jax.tree.flatten(grads)
    sizes = [int(np.prod(l.shape)) for l in leaves]
    total = sum(sizes)
    pad = (-total) % (n_dev * 256)

    def sync_flat(flat):
        return compressed_psum_ring(flat, axis)

    flat = jnp.concatenate([l.reshape(-1).astype(jnp.float32) for l in leaves])
    flat = jnp.pad(flat, (0, pad))
    other_axes = [a for a in mesh.axis_names if a != axis]
    synced = shard_map(
        sync_flat, mesh=mesh,
        in_specs=P(), out_specs=P(), check_rep=False,
    )(flat)
    synced = synced[:total]
    out, off = [], 0
    for l, s in zip(leaves, sizes):
        out.append(synced[off:off + s].reshape(l.shape).astype(l.dtype))
        off += s
    return jax.tree.unflatten(tree, out)


def quantized_error_feedback(grads, residuals):
    """EF update: g' = Q(g + r); r' = (g + r) - g'.  Returns (g', r')."""
    def leaf(g, r):
        gf = g.astype(jnp.float32) + r
        flat = gf.reshape(-1)
        n = flat.shape[0]
        block = 256 if n % 256 == 0 else n
        xb = flat.reshape(-1, block)
        amax = jnp.max(jnp.abs(xb), axis=-1)
        scale = jnp.where(amax > 0, amax / 127.0, 1.0)
        q = jnp.clip(jnp.round(xb / scale[:, None]), -127, 127)
        gq = (q * scale[:, None]).reshape(g.shape)
        return gq.astype(g.dtype), gf - gq

    pairs = jax.tree.map(leaf, grads, residuals)
    g2 = jax.tree.map(lambda p: p[0], pairs, is_leaf=lambda x: isinstance(x, tuple))
    r2 = jax.tree.map(lambda p: p[1], pairs, is_leaf=lambda x: isinstance(x, tuple))
    return g2, r2

"""FlightDataLoader — the paper's protocol as the training data plane.

Per-host data services expose corpus shards as Flight endpoints; each
trainer host pulls its shard ranges with N parallel DoGet streams (paper
Fig 2's recipe), prefetches into a bounded queue on background threads, and
converts ragged columnar documents into padded/packed device tensors —
``kernels/varlen_unpack`` is the TPU kernel for that conversion, numpy
packing the host fallback.

Determinism & fault tolerance:
  * the loader's position is a ``(epoch, shard_cursor)`` ticket —
    checkpointable and resumable exactly (checkpoint.py stores it);
  * shard order is a seeded permutation per epoch, partitioned by
    ``(host_id, n_hosts)`` so every host streams a disjoint shard set;
  * hedged reads against replica endpoints mitigate stragglers
    (client.read_all_parallel).
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass

import numpy as np

from ..core.flight.client import FlightClient
from ..core.flight.protocol import FlightDescriptor, Ticket
from .dataset import pack_documents


@dataclass
class LoaderState:
    epoch: int = 0
    cursor: int = 0  # next shard index within this host's permuted list

    def to_json(self) -> dict:
        return {"epoch": self.epoch, "cursor": self.cursor}

    @classmethod
    def from_json(cls, o: dict) -> "LoaderState":
        return cls(o["epoch"], o["cursor"])


class FlightDataLoader:
    """Streams (inputs, labels) int32 batches of (batch_size, seq_len)."""

    def __init__(
        self,
        client: FlightClient,
        dataset: str,
        *,
        batch_size: int,
        seq_len: int,
        host_id: int = 0,
        n_hosts: int = 1,
        streams: int = 4,
        prefetch: int = 4,
        seed: int = 0,
        state: LoaderState | None = None,
        hedge_after: float | None = None,
    ):
        self.client = client
        self.dataset = dataset
        self.batch_size = batch_size
        self.seq_len = seq_len
        self.host_id = host_id
        self.n_hosts = n_hosts
        self.streams = streams
        self.seed = seed
        self.state = state or LoaderState()
        self.hedge_after = hedge_after
        info = client.get_flight_info(FlightDescriptor.for_path(dataset))
        self.n_shards = len(info.endpoints)
        self._endpoints = info.endpoints
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._leftover = np.zeros((0, seq_len + 1), np.int32)
        self._stop = threading.Event()
        self._worker = threading.Thread(target=self._fill_loop, daemon=True)
        self._worker.start()

    # -- shard schedule ---------------------------------------------------- #
    def _host_shards(self, epoch: int) -> list[int]:
        rng = np.random.default_rng((self.seed, epoch))
        perm = rng.permutation(self.n_shards)
        return [int(s) for s in perm[self.host_id :: self.n_hosts]]

    # -- background fill ---------------------------------------------------- #
    def _fill_loop(self) -> None:
        try:
            while not self._stop.is_set():
                shards = self._host_shards(self.state.epoch)
                while self.state.cursor < len(shards):
                    # pull up to `streams` shards in parallel (paper Fig 2)
                    take = shards[self.state.cursor : self.state.cursor + self.streams]
                    rows = []
                    import concurrent.futures as cf

                    def fetch(s: int):
                        ep = self._endpoints[s]
                        reader = self.client.do_get(ep.ticket)
                        return [pack_documents(b, self.seq_len) for b in reader]

                    with cf.ThreadPoolExecutor(max_workers=len(take)) as pool:
                        for packed in pool.map(fetch, take):
                            rows.extend(packed)
                    self.state.cursor += len(take)
                    if rows:
                        self._q.put((np.concatenate(rows), LoaderState(
                            self.state.epoch, self.state.cursor)))
                self.state = LoaderState(self.state.epoch + 1, 0)
        except Exception as e:  # pragma: no cover
            self._q.put(e)

    # -- consumer API ------------------------------------------------------- #
    def __iter__(self):
        return self

    def __next__(self) -> tuple[dict, LoaderState]:
        while self._leftover.shape[0] < self.batch_size:
            item = self._q.get()
            if isinstance(item, Exception):
                raise item
            rows, st = item
            self._state_snapshot = st
            self._leftover = np.concatenate([self._leftover, rows]) if self._leftover.size else rows
        take, self._leftover = (self._leftover[: self.batch_size],
                                self._leftover[self.batch_size :])
        batch = {"tokens": take[:, :-1], "labels": take[:, 1:]}
        return batch, getattr(self, "_state_snapshot", self.state)

    def close(self) -> None:
        self._stop.set()

from .dataset import corpus_schema, pack_documents, synthesize_corpus  # noqa: F401
from .loader import FlightDataLoader, LoaderState  # noqa: F401

"""Tokenized datasets as columnar RecordBatch shards.

A training corpus is a list of RecordBatches with schema
``{tokens: list<int32>}`` (ragged documents, Arrow offsets+values layout) —
exactly what the paper ships over Flight.  ``synthesize_corpus`` builds a
reproducible synthetic corpus (Zipfian tokens, log-normal doc lengths);
``pack_documents`` does the standard LM sequence packing on the *columnar*
values buffer (no per-row work — the zero-copy discipline end to end).
"""
from __future__ import annotations

import numpy as np

from ..core.array import Array
from ..core.buffer import Buffer
from ..core.recordbatch import RecordBatch
from ..core.schema import Field, Schema, int32, list_


def corpus_schema() -> Schema:
    return Schema((Field("tokens", list_(int32), nullable=False),))


def synthesize_corpus(
    n_docs: int,
    vocab: int,
    *,
    mean_len: int = 512,
    seed: int = 0,
    batch_docs: int = 1024,
) -> list[RecordBatch]:
    """Zipfian synthetic corpus as columnar shards (one batch per shard)."""
    rng = np.random.default_rng(seed)
    # zipf over the vocab with smoothing; precompute alias table once
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    probs = 1.0 / ranks
    probs /= probs.sum()
    batches = []
    for start in range(0, n_docs, batch_docs):
        n = min(batch_docs, n_docs - start)
        lens = np.maximum(8, rng.lognormal(np.log(mean_len), 0.6, n).astype(np.int64))
        total = int(lens.sum())
        values = rng.choice(vocab, size=total, p=probs).astype(np.int32)
        offsets = np.zeros(n + 1, dtype=np.int32)
        np.cumsum(lens, out=offsets[1:])
        child = Array.from_numpy(values)
        col = Array(list_(int32), n, None, [Buffer.from_array(offsets)], [child])
        batches.append(RecordBatch(corpus_schema(), [col]))
    return batches


def pack_documents(batch: RecordBatch, seq_len: int, pad_id: int = 0) -> np.ndarray:
    """Pack a shard's ragged tokens into (n_seqs, seq_len+1) rows, columnar:
    one reshape over the contiguous values buffer + EOS-free truncation.
    Returns int32 array ready for (inputs=x[:, :-1], labels=x[:, 1:])."""
    col = batch.column("tokens")
    values = col.children[0].to_numpy()
    offs = col._offsets()
    flat = values[offs[0]:offs[-1]]
    n_seqs = len(flat) // (seq_len + 1)
    if n_seqs == 0:
        return np.zeros((0, seq_len + 1), np.int32)
    return flat[: n_seqs * (seq_len + 1)].reshape(n_seqs, seq_len + 1).astype(np.int32)

"""Training loop: Flight data plane + pjit step + checkpoint/fault hooks.

``Trainer`` is the single-controller view: it owns the jit'd step, the
FlightDataLoader, the CheckpointManager (async, with loader state in the
manifest), and the failure/straggler detectors.  ``build_dp_train_step``
is the pure-data-parallel variant whose gradient sync is the **compressed
int8 ring** (collectives.py) inside shard_map — the wire substitution the
pjit path can't express (GSPMD owns its collectives).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from ..data.loader import FlightDataLoader, LoaderState
from ..distributed.checkpoint import CheckpointManager
from ..distributed.collectives import compressed_psum_ring, quantized_error_feedback
from ..distributed.fault import FailureDetector, StragglerDetector
from ..models.lm import LM
from .optimizer import OptimizerConfig, make_optimizer
from .step import TrainConfig, build_train_step


@dataclass
class TrainerConfig:
    total_steps: int = 100
    log_every: int = 10
    checkpoint_every: int = 50
    keep_checkpoints: int = 3
    train: TrainConfig = field(default_factory=TrainConfig)


class Trainer:
    def __init__(self, model: LM, trainer_cfg: TrainerConfig, ckpt_dir: str,
                 loader: FlightDataLoader | None = None, log=print):
        self.model = model
        self.cfg = trainer_cfg
        self.loader = loader
        self.ckpt = CheckpointManager(ckpt_dir, keep=trainer_cfg.keep_checkpoints)
        self.log = log
        self.failure = FailureDetector()
        self.straggler = StragglerDetector()
        step_fn, opt_init = build_train_step(model, trainer_cfg.train, None)
        self._step = jax.jit(step_fn, donate_argnums=(0, 1))
        self._opt_init = opt_init

    def init_state(self, seed: int = 0):
        params, _ = self.model.init(jax.random.key(seed))
        opt_state = self._opt_init(params)
        return {"params": params, "opt": opt_state, "step": 0}

    def restore_or_init(self, seed: int = 0):
        latest = self.ckpt.latest_step()
        state = self.init_state(seed)
        if latest is None:
            return state, LoaderState()
        import json
        mani = json.loads((self.ckpt.dir / f"step_{latest:09d}" / "manifest.json").read_text())
        restored = self.ckpt.restore(latest, {"params": state["params"], "opt": state["opt"]})
        loader_state = LoaderState.from_json(mani["extra"].get("loader", {"epoch": 0, "cursor": 0}))
        return ({"params": restored["params"], "opt": restored["opt"], "step": latest},
                loader_state)

    def run(self, state, steps: int | None = None) -> dict:
        steps = steps or self.cfg.total_steps
        t_last = time.perf_counter()
        losses = []
        while state["step"] < steps:
            batch_np, loader_state = next(self.loader)
            batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
            params, opt, metrics = self._step(state["params"], state["opt"], batch)
            state = {"params": params, "opt": opt, "step": state["step"] + 1}
            losses.append(float(metrics["loss"]))
            if state["step"] % self.cfg.log_every == 0:
                dt = time.perf_counter() - t_last
                t_last = time.perf_counter()
                self.log(f"step {state['step']:5d} loss {np.mean(losses[-self.cfg.log_every:]):.4f} "
                         f"({dt / self.cfg.log_every:.2f}s/step)")
            if state["step"] % self.cfg.checkpoint_every == 0:
                self.ckpt.save_async(state["step"],
                                     {"params": state["params"], "opt": state["opt"]},
                                     extra={"loader": loader_state.to_json()})
        self.ckpt.wait()
        state["losses"] = losses
        return state


# ---------------------------------------------------------------------------
# pure-DP train step with compressed ring gradient sync (shard_map)
# ---------------------------------------------------------------------------


def build_dp_train_step(model: LM, opt_cfg: OptimizerConfig, mesh, axis: str = "data",
                        compressed: bool = True, error_feedback: bool = True):
    """Data-parallel step where *we* own the gradient collective: per-device
    grads -> int8 ring all-reduce (+error feedback) -> optimizer.

    Returns (step_fn, init_fn); state = {params, opt, residual}.
    params replicated; batch sharded on axis 0.
    """
    opt_init, opt_update = make_optimizer(opt_cfg)
    n_dev = mesh.shape[axis]

    def init_fn(params):
        return {"opt": opt_init(params),
                "residual": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)}

    def local_grads(params, batch):
        (loss, _), grads = jax.value_and_grad(model.loss_fn, has_aux=True)(params, batch)
        return loss, grads

    def step(params, opt_state, residual, batch):
        def body(params_l, batch_l, residual_l):
            loss, grads = local_grads(params_l, batch_l)
            if compressed:
                if error_feedback:
                    grads, new_res = quantized_error_feedback(grads, residual_l)
                else:
                    new_res = residual_l
                leaves, tree = jax.tree.flatten(grads)
                sizes = [int(np.prod(g.shape)) for g in leaves]
                flat = jnp.concatenate([g.reshape(-1).astype(jnp.float32) for g in leaves])
                pad = (-flat.shape[0]) % (n_dev * 256)
                flat = jnp.pad(flat, (0, pad))
                flat = compressed_psum_ring(flat, axis) / n_dev
                out, off = [], 0
                for g, s in zip(leaves, sizes):
                    out.append(flat[off:off + s].reshape(g.shape))
                    off += s
                grads = jax.tree.unflatten(tree, out)
            else:
                grads = jax.tree.map(lambda g: jax.lax.pmean(g, axis), grads)
                new_res = residual_l
            loss = jax.lax.pmean(loss, axis)
            return loss, grads, new_res

        other = [a for a in mesh.axis_names if a != axis]
        rep = P(*([None]))
        loss, grads, new_res = shard_map(
            body, mesh=mesh,
            in_specs=(P(), P(axis), P()),
            out_specs=(P(), P(), P()),
            check_rep=False,
        )(params, batch, residual)
        new_params, new_opt, metrics = opt_update(grads, opt_state, params)
        return new_params, new_opt, new_res, {"loss": loss, **metrics}

    return step, init_fn

"""Step builders: train_step / prefill_step / serve_step as pjit-able fns.

``build_train_step`` returns (fn, in_shardings, out_shardings) ready for
``jax.jit(...).lower(...)`` — the dry-run consumes exactly this.  Gradient
accumulation (microbatching) is a ``lax.scan`` over batch slices; donation of
params/opt-state keeps the memory analysis honest.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ShapeSpec, batch_logical_axes
from ..distributed.sharding import ShardingCtx, tree_shardings
from ..models.lm import LM, ModelConfig
from .optimizer import OptimizerConfig, make_optimizer, opt_state_axes_with_params


@dataclass(frozen=True)
class TrainConfig:
    optimizer: OptimizerConfig = OptimizerConfig()
    microbatches: int = 1
    compressed_allreduce: bool = False  # int8 ring psum (distributed/collectives)


def build_train_step(model: LM, train_cfg: TrainConfig, param_axes):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state, metrics)."""
    opt_init, opt_update = make_optimizer(train_cfg.optimizer)
    mb = train_cfg.microbatches

    def loss_fn(params, batch):
        return model.loss_fn(params, batch)

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        return loss, metrics, grads

    def train_step(params, opt_state, batch):
        if mb > 1:
            def micro(carry, mbatch):
                gsum, lsum = carry
                loss, metrics, grads = grads_of(params, mbatch)
                gsum = jax.tree.map(lambda a, g: a + g.astype(jnp.float32), gsum, grads)
                return (gsum, lsum + loss), None

            def split(x):
                b = x.shape[0]
                return x.reshape(mb, b // mb, *x.shape[1:])

            mbatches = jax.tree.map(split, batch)
            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss_sum), _ = jax.lax.scan(micro, (zeros, jnp.float32(0)), mbatches)
            grads = jax.tree.map(lambda g: g / mb, grads)
            loss = loss_sum / mb
            metrics = {}
        else:
            loss, metrics, grads = grads_of(params, batch)

        if train_cfg.compressed_allreduce:
            # pjit path: apply the hop codec's quantization to gradients (the
            # wire substitution itself lives in the DP driver's shard_map
            # train step — see train/loop.py build_dp_train_step)
            from ..distributed.collectives import quantized_error_feedback
            zeros = jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)
            grads, _ = quantized_error_feedback(grads, zeros)

        new_params, new_opt, opt_metrics = opt_update(grads, opt_state, params)
        return new_params, new_opt, {"loss": loss, **metrics, **opt_metrics}

    return train_step, opt_init


def step_shardings(model: LM, train_cfg: TrainConfig, param_axes, params_shape,
                   shape: ShapeSpec):
    """(in_shardings, out_shardings) trees for jit(train_step)."""
    ctx = model.ctx
    p_sh = tree_shardings(param_axes, ctx.mesh, ctx.rules)
    opt_axes = opt_state_axes_with_params(train_cfg.optimizer, params_shape, param_axes)
    o_sh = tree_shardings(opt_axes, ctx.mesh, ctx.rules)
    b_axes = batch_logical_axes(model.cfg, shape)
    b_sh = tree_shardings(b_axes, ctx.mesh, ctx.rules)
    metrics_sh = None  # replicated scalars
    return (p_sh, o_sh, b_sh), (p_sh, o_sh, metrics_sh)


def build_prefill_step(model: LM):
    def prefill_step(params, batch):
        return model.prefill(params, batch)
    return prefill_step


def build_serve_step(model: LM):
    def serve_step(params, caches, tokens, pos):
        return model.decode_step(params, caches, tokens, pos)
    return serve_step

"""Optimizers: AdamW and Adafactor (factored second moment, for ≥100 B models).

Pure pytree implementations (no optax dependency in this container).  State
layout mirrors params so the same sharding tree applies; Adafactor's factored
stats add only O(rows+cols) memory — the difference between Jamba-398B
fitting in HBM or not (DESIGN.md §4).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"           # adamw | adafactor
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    min_lr_ratio: float = 0.1
    # adafactor
    factored_min_dim: int = 128


def lr_schedule(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.learning_rate * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), tree), norm


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_update(cfg: OptimizerConfig, grads, state, params):
    step = state["step"] + 1
    lr = lr_schedule(cfg, step)
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    t = step.astype(jnp.float32)
    bc1 = 1 - cfg.b1 ** t
    bc2 = 1 - cfg.b2 ** t

    def upd(g, mu, nu, p):
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * g * g
        u = (mu / bc1) / (jnp.sqrt(nu / bc2) + cfg.eps)
        u = u + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype), mu, nu

    out = jax.tree.map(upd, grads, state["mu"], state["nu"], params)
    new_params = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"mu": new_mu, "nu": new_nu, "step": step}, {"lr": lr, "grad_norm": gnorm}


# ---------------------------------------------------------------------------
# Adafactor (Shazeer & Stern 2018), simplified: factored v, no first moment
# ---------------------------------------------------------------------------


def _factored(p, min_dim) -> bool:
    return p.ndim >= 2 and p.shape[-1] >= min_dim and p.shape[-2] >= min_dim


def adafactor_init(params, cfg: OptimizerConfig | None = None):
    cfg = cfg or OptimizerConfig(name="adafactor")

    def init_leaf(p):
        if _factored(p, cfg.factored_min_dim):
            return {
                "vr": jnp.zeros(p.shape[:-1], jnp.float32),         # row stats
                "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
            }
        return {"v": jnp.zeros(p.shape, jnp.float32)}

    return {
        "v": jax.tree.map(init_leaf, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adafactor_update(cfg: OptimizerConfig, grads, state, params):
    step = state["step"] + 1
    lr = lr_schedule(cfg, step)
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    t = step.astype(jnp.float32)
    beta2 = 1.0 - t ** -0.8  # Adafactor's schedule

    def upd(g, v, p):
        g2 = g * g + 1e-30
        if "vr" in v:
            vr = beta2 * v["vr"] + (1 - beta2) * jnp.mean(g2, axis=-1)
            vc = beta2 * v["vc"] + (1 - beta2) * jnp.mean(g2, axis=-2)
            denom = jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), 1e-30)
            vhat = vr[..., None] * vc[..., None, :] / denom[..., None]
            new_v = {"vr": vr, "vc": vc}
        else:
            vhat = beta2 * v["v"] + (1 - beta2) * g2
            new_v = {"v": vhat}
        u = g / jnp.sqrt(vhat + cfg.eps)
        # update clipping (RMS <= 1) per Adafactor
        rms = jnp.sqrt(jnp.mean(u * u) + 1e-30)
        u = u / jnp.maximum(1.0, rms)
        u = u + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype), new_v

    flat_p, tree = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_v = tree.flatten_up_to(state["v"])
    outs = [upd(g, v, p) for g, v, p in zip(flat_g, flat_v, flat_p)]
    new_params = jax.tree.unflatten(tree, [o[0] for o in outs])
    new_v = jax.tree.unflatten(tree, [o[1] for o in outs])
    return new_params, {"v": new_v, "step": step}, {"lr": lr, "grad_norm": gnorm}


# ---------------------------------------------------------------------------


def make_optimizer(cfg: OptimizerConfig):
    if cfg.name == "adamw":
        return adamw_init, partial(adamw_update, cfg)
    if cfg.name == "adafactor":
        return partial(adafactor_init, cfg=cfg), partial(adafactor_update, cfg)
    raise ValueError(cfg.name)


def opt_state_logical_axes(opt_cfg: OptimizerConfig, param_axes):
    """Logical axes for optimizer state (mirrors params; factored stats drop
    the reduced dim's axis)."""
    if opt_cfg.name == "adamw":
        return {"mu": param_axes, "nu": param_axes, "step": ()}

    def leaf_axes(ax):
        # ax is the tuple of logical names for one param
        # shapes aren't available here; mirror _factored via name count only
        return ax

    def v_axes(ax, shape_hint=None):
        return ax

    # adafactor: we need shapes — caller should use opt_state_axes_with_params
    raise NotImplementedError("use opt_state_axes_with_params for adafactor")


def opt_state_axes_with_params(opt_cfg: OptimizerConfig, params, param_axes):
    """Axes tree matching the *actual* opt state structure."""
    if opt_cfg.name == "adamw":
        return {"mu": param_axes, "nu": param_axes, "step": ()}

    is_ax = lambda x: isinstance(x, tuple) and all(isinstance(a, (str, type(None))) for a in x)

    def leaf(p, ax):
        if _factored(p, opt_cfg.factored_min_dim):
            return {"vr": tuple(ax[:-1]), "vc": tuple(ax[:-2]) + (ax[-1],)}
        return {"v": ax}

    v = jax.tree.map(leaf, params, jax.tree.unflatten(jax.tree.structure(params),
                                                      jax.tree.flatten(param_axes, is_leaf=is_ax)[0]))
    return {"v": v, "step": ()}

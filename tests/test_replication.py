"""Replicated cluster plane: membership, replication, rebalance, faults."""
import json
import time

import numpy as np
import pytest

from repro.core import RecordBatch
from repro.core.flight import (
    Action,
    ClusterMembership,
    FaultInjector,
    FlightClient,
    FlightClusterClient,
    FlightClusterServer,
    FlightUnavailable,
    MembershipProber,
    RemoteFlightProvider,
    ShardState,
    parse_slice_key,
    plan_layout,
    recover_layouts,
    slice_key,
)
from repro.core.flight.protocol import FlightInvalidArgument


def seq_batches(n=6, rows=100):
    """Batches whose rows are one global 0..n*rows-1 sequence — any
    duplicated or dropped row is detectable by sorting the k column."""
    return [
        RecordBatch.from_numpy({
            "k": np.arange(i * rows, (i + 1) * rows, dtype=np.int64),
            "v": np.arange(i * rows, (i + 1) * rows, dtype=np.float64) * 0.5,
        })
        for i in range(n)
    ]


def all_ks(table_or_batches):
    batches = getattr(table_or_batches, "batches", table_or_batches)
    return sorted(int(k) for b in batches for k in b.column("k").to_numpy())


# --------------------------------------------------------------------------
# membership
# --------------------------------------------------------------------------


class TestMembership:
    def test_state_ladder_and_epochs(self):
        m = ClusterMembership(suspect_after=1.0, dead_after=3.0)
        e0 = m.register(0)
        e1 = m.register(1)
        assert e1 == e0 + 1  # each join is a view change
        assert m.state(0) is ShardState.HEALTHY
        # re-announce of a live shard is not a view change
        assert m.register(0) == e1
        m.heartbeat(0, now=100.0)
        m.heartbeat(1, now=100.0)
        assert m.sweep(now=100.5) == []
        assert m.sweep(now=102.0) == []          # past suspect_after only
        assert m.state(0) is ShardState.SUSPECT
        assert m.is_routable(0)                   # suspect still serves
        epoch_before = m.epoch
        assert m.epoch == epoch_before            # SUSPECT is not a view change
        dead = m.sweep(now=104.0)
        assert sorted(dead) == [0, 1]
        assert m.epoch == epoch_before + 2        # one bump per death
        assert m.alive() == []

    def test_heartbeat_revives_dead_and_bumps_epoch(self):
        m = ClusterMembership(suspect_after=0.1, dead_after=0.2)
        m.register(0)
        m.heartbeat(0, now=0.0)
        m.sweep(now=1.0)
        assert m.state(0) is ShardState.DEAD
        e = m.epoch
        m.heartbeat(0)
        assert m.state(0) is ShardState.HEALTHY
        assert m.epoch == e + 1

    def test_removed_shards_ignore_heartbeats(self):
        m = ClusterMembership()
        m.register(0)
        m.deregister(0)
        e = m.epoch
        m.heartbeat(0)
        assert m.state(0) is ShardState.REMOVED
        assert m.epoch == e

    def test_prober_detects_and_reports_dead(self):
        m = ClusterMembership(suspect_after=0.01, dead_after=0.02)
        m.register(0)
        m.register(1)
        up = {0: True, 1: True}
        died = []
        p = MembershipProber(m, lambda sid: up[sid], on_dead=died.append)
        p.tick()
        assert m.state(0) is ShardState.HEALTHY
        up[1] = False
        time.sleep(0.03)
        p.tick()
        assert m.state(1) is ShardState.DEAD
        assert died == [[1]]
        assert m.state(0) is ShardState.HEALTHY   # its probes kept passing
        assert p.probe_failures >= 1


# --------------------------------------------------------------------------
# replication primitives
# --------------------------------------------------------------------------


class TestReplicationPrimitives:
    def test_slice_key_roundtrip(self):
        k = slice_key("users", 3, 1)
        assert k == "users@@g3s1"
        assert parse_slice_key(k) == ("users", 3, 1)
        assert parse_slice_key("users") is None
        with pytest.raises(FlightInvalidArgument):
            slice_key("a@@b", 1, 0)

    def test_chained_rotation_survives_any_single_loss(self):
        lay = plan_layout("d", 1, [0, 1, 2, 3], replicas=2)
        for dead in range(4):
            for sl in lay.slices:
                assert any(h != dead for h in sl.holders), (dead, sl)
        # each shard holds exactly R slices (balanced spread)
        loads = {h: 0 for h in range(4)}
        for sl in lay.slices:
            for h in sl.holders:
                loads[h] += 1
        assert set(loads.values()) == {2}

    def test_recover_layouts_picks_highest_complete_generation(self):
        listings = {
            0: ["users@@g1s0", "users@@g2s0", "plain"],
            1: ["users@@g1s1", "users@@g2s1", "users@@g3s1"],  # g3 has a hole
            2: ["users@@g2s0", "users@@g2s1"],
        }
        out = recover_layouts(listings)
        assert out["users"].gen == 2
        assert out["users"].slices[0].holders == (0, 2)
        assert out["users"].slices[1].holders == (1, 2)


# --------------------------------------------------------------------------
# replicated cluster: read/write/query
# --------------------------------------------------------------------------


class TestReplicatedCluster:
    def test_endpoints_list_all_replica_locations(self):
        cl = FlightClusterServer(num_shards=3, replicas=2)
        try:
            cl.add_dataset("d", seq_batches(6))
            info = cl._info_for("d")
            assert info.epoch == cl.membership.epoch
            assert len(info.endpoints) == 3
            for ep in info.endpoints:
                assert len(ep.locations) == 2   # one per replica holder
                assert len(ep.app_metadata["holders"]) == 2
            # every slice key is stored verbatim on both holders
            for sl in cl._layouts["d"].slices:
                holders = list(sl.holders)
                a = cl.shards[holders[0]].dataset(sl.key)
                b = cl.shards[holders[1]].dataset(sl.key)
                assert [x.to_rows() for x in a] == [y.to_rows() for y in b]
        finally:
            cl.shutdown()

    def test_read_survives_dead_shard(self):
        cl = FlightClusterServer(num_shards=3, replicas=2)
        try:
            cl.add_dataset("d", seq_batches(6))
            cli = FlightClusterClient(cl)
            cl.membership.mark_dead(0)
            table, _ = cli.read("d")
            assert all_ks(table) == list(range(600))
        finally:
            cl.shutdown()

    def test_replica_loss_beyond_r_minus_one_raises(self):
        cl = FlightClusterServer(num_shards=3, replicas=2)
        try:
            cl.add_dataset("d", seq_batches(6))
            cl.membership.mark_dead(0)
            cl.membership.mark_dead(1)
            with pytest.raises(FlightUnavailable):
                cl._info_for("d")
        finally:
            cl.shutdown()

    def test_client_write_plain_and_transactional(self):
        cl = FlightClusterServer(num_shards=3, replicas=2)
        try:
            cli = FlightClusterClient(cl)
            cli.write("plain", seq_batches(4))
            t, _ = cli.read("plain")
            assert all_ks(t) == list(range(400))
            cli.write("txn", seq_batches(4), transactional=True)
            t2, _ = cli.read("txn")
            assert all_ks(t2) == list(range(400))
            # both replicas of every slice committed
            for sl in cl._layouts["txn"].slices:
                for h in sl.holders:
                    assert cl.shards[h].storage.exists(sl.key)
        finally:
            cl.shutdown()

    def test_head_funneled_transactional_write(self):
        """A legacy writer staging through the head still gets the replica
        fan-out: the head remembers the sub-txn mapping and the bare
        txn-commit resolves it."""
        from repro.core.flight.protocol import FlightDescriptor, StagedPutCommand

        cl = FlightClusterServer(num_shards=3, replicas=2)
        try:
            c = FlightClient(cl)
            batches = seq_batches(4)
            w = c.do_put(FlightDescriptor.for_command(
                StagedPutCommand("hd", "t1", "stage")), batches[0].schema)
            w.write_batches(batches)
            ack = w.close()
            assert ack["staged"] and ack["replicas"] == 2
            assert ack["rows"] == 400          # logical rows, not copies
            # invisible until commit
            names = c.do_action(Action("list-names"))[0].body.decode()
            assert "hd" not in names
            out = json.loads(c.do_action(Action(
                "txn-commit", json.dumps({"txn_id": "t1"}).encode()))[0].body)
            assert out["committed"] and out["dataset"] == "hd"
            assert out["rows"] == 400
            t, _ = FlightClusterClient(cl).read("hd")
            assert all_ks(t) == list(range(400))
        finally:
            cl.shutdown()

    def test_transactional_abort_leaves_nothing_visible(self):
        from repro.core.flight.protocol import FlightDescriptor, StagedPutCommand

        cl = FlightClusterServer(num_shards=3, replicas=2)
        try:
            c = FlightClient(cl)
            batches = seq_batches(2)
            w = c.do_put(FlightDescriptor.for_command(
                StagedPutCommand("ab", "t2", "stage")), batches[0].schema)
            w.write_batches(batches)
            w.close()
            c.do_action(Action("txn-abort", json.dumps({"txn_id": "t2"}).encode()))
            names = c.do_action(Action("list-names"))[0].body.decode()
            assert "ab" not in names
            for s in cl.shards:
                assert not any(parse_slice_key(n) and parse_slice_key(n)[0] == "ab"
                               for n in s.storage.list())
        finally:
            cl.shutdown()

    def test_query_pushdown_on_replicated_layout_with_dead_shard(self):
        from repro.query.engine import QueryPlan
        from repro.query.expr import col

        cl = FlightClusterServer(num_shards=3, replicas=2)
        try:
            cl.add_dataset("d", seq_batches(6))
            cli = FlightClusterClient(cl)
            cl.membership.mark_dead(1)
            t, _ = cli.query(QueryPlan(dataset="d", predicate=col("k") < 150))
            assert all_ks(t) == list(range(150))
        finally:
            cl.shutdown()

    def test_epoch_bumps_on_view_change_not_on_load(self):
        cl = FlightClusterServer(num_shards=2, replicas=2)
        try:
            e0 = cl.membership.epoch
            cl.add_dataset("d", seq_batches(2))
            assert cl.membership.epoch == e0     # new dataset: no view change
            cl.membership.mark_dead(1)
            assert cl.membership.epoch == e0 + 1
        finally:
            cl.shutdown()

    def test_membership_and_heartbeat_actions(self):
        cl = FlightClusterServer(num_shards=2, replicas=2)
        try:
            c = FlightClient(cl)
            view = json.loads(c.do_action(Action("membership"))[0].body)
            assert [s["state"] for s in view["shards"]] == ["healthy", "healthy"]
            cl.membership.mark_dead(0)
            ack = json.loads(c.do_action(Action(
                "heartbeat", json.dumps({"shard": 0}).encode()))[0].body)
            assert ack["ok"]
            assert cl.membership.state(0) is ShardState.HEALTHY
            stats = json.loads(c.do_action(Action("stats"))[0].body)
            assert stats["replicas"] == 2
            assert "membership" in stats and "layouts" in stats
        finally:
            cl.shutdown()


# --------------------------------------------------------------------------
# elastic membership: rebalance, add/remove shard, recovery
# --------------------------------------------------------------------------


class TestRebalance:
    def test_add_shard_spreads_layout_and_preserves_rows(self):
        cl = FlightClusterServer(num_shards=2, replicas=2)
        try:
            cl.add_dataset("d", seq_batches(6))
            gen0 = cl._layouts["d"].gen
            e0 = cl.membership.epoch
            sid = cl.add_shard(wait=True)
            assert sid == 2 and cl.num_shards == 3
            lay = cl._layouts["d"]
            assert lay.gen > gen0
            assert cl.membership.epoch > e0      # join + cutover both bump
            assert {h for sl in lay.slices for h in sl.holders} == {0, 1, 2}
            t, _ = FlightClusterClient(cl).read("d")
            assert all_ks(t) == list(range(600))
            # the superseded generation's keys are gone
            for s in cl.shards:
                for n in s.storage.list():
                    assert parse_slice_key(n)[1] == lay.gen
            assert cl.rebalances == 1
        finally:
            cl.shutdown()

    def test_remove_shard_drains_then_tombstones(self):
        cl = FlightClusterServer(num_shards=3, replicas=2)
        try:
            cl.add_dataset("d", seq_batches(6))
            cl.remove_shard(1, wait=True)
            assert cl.membership.state(1) is ShardState.REMOVED
            lay = cl._layouts["d"]
            assert all(1 not in sl.holders for sl in lay.slices)
            assert cl.shards[1].storage.list() == []
            t, _ = FlightClusterClient(cl).read("d")
            assert all_ks(t) == list(range(600))
        finally:
            cl.shutdown()

    def test_rebalance_failure_is_all_or_none(self):
        """A fault mid-rebalance aborts the staged generation; the old
        layout keeps serving untouched."""
        cl = FlightClusterServer(num_shards=2, replicas=2)
        try:
            cl.add_dataset("d", seq_batches(6))
            lay0 = cl._layouts["d"]
            sid = len(cl.shards)
            s = cl._shard_factory(sid, f"{cl.location_name}-shard{sid}")
            s.shard_id = sid
            cl.shards.append(s)
            cl.membership.register(sid, [l.uri for l in s.locations()])
            FaultInjector(cl).kill(2)            # the new shard dies mid-move
            with pytest.raises(FlightUnavailable):
                cl._rebalance()
            assert cl._layouts["d"] is lay0      # cutover never happened
            t, _ = FlightClusterClient(cl).read("d")
            assert all_ks(t) == list(range(600))
            # no staged keys of the aborted generation linger on live shards
            for h in (0, 1):
                for n in cl.shards[h].storage.list():
                    assert parse_slice_key(n)[1] == lay0.gen
        finally:
            cl.shutdown()

    def test_background_rebalance_and_wait(self):
        cl = FlightClusterServer(num_shards=2, replicas=2)
        try:
            cl.add_dataset("d", seq_batches(4))
            cl.add_shard(wait=False)
            cl.wait_rebalanced(timeout=30.0)
            assert {h for sl in cl._layouts["d"].slices for h in sl.holders} == {0, 1, 2}
        finally:
            cl.shutdown()

    def test_add_shard_requires_replication(self):
        cl = FlightClusterServer(num_shards=2, replicas=1)
        try:
            with pytest.raises(FlightInvalidArgument):
                cl.add_shard()
            with pytest.raises(FlightInvalidArgument):
                cl.remove_shard(0)
        finally:
            cl.shutdown()

    def test_disk_cluster_restart_recovers_layouts(self, tmp_path):
        root = f"disk:{tmp_path}"
        cl = FlightClusterServer(num_shards=3, replicas=2, storage=root)
        cl.add_dataset("d", seq_batches(6))
        lay0 = cl._layouts["d"]
        cl.shutdown()
        cl2 = FlightClusterServer(num_shards=3, replicas=2, storage=root)
        try:
            lay = cl2._layouts["d"]
            assert lay.gen == lay0.gen
            # holder *sets* recover exactly (ordering is a routing
            # preference the listings don't encode)
            assert [set(sl.holders) for sl in lay.slices] == \
                   [set(sl.holders) for sl in lay0.slices]
            t, _ = FlightClusterClient(cl2).read("d")
            assert all_ks(t) == list(range(600))
        finally:
            cl2.shutdown()


# --------------------------------------------------------------------------
# fault injection + failover (the acceptance scenario)
# --------------------------------------------------------------------------


class TestFaultInjection:
    def test_kill_mid_doget_drains_from_replica_over_tcp(self):
        """The PR's acceptance bar: kill a shard while its DoGet streams are
        mid-flight; the client must drain the complete dataset from the
        surviving replicas — zero duplicate rows, zero missing rows."""
        cl = FlightClusterServer(num_shards=3, replicas=2).serve_tcp()
        try:
            cl.add_dataset("big", seq_batches(30, rows=200))
            cli = FlightClusterClient(
                f"tcp://127.0.0.1:{cl.port}", max_streams=3, window=2)
            inj = FaultInjector(cl)
            got, killed = [], False
            for i, b in enumerate(cli.stream("big")):
                got.append(b)
                if i == 2 and not killed:
                    inj.kill(0)                  # verbs fail + connections drop
                    killed = True
            assert killed
            assert all_ks(got) == list(range(6000))
            # subsequent reads keep working without a heal
            t, _ = cli.read("big")
            assert all_ks(t) == list(range(6000))
        finally:
            cl.shutdown()

    def test_kill_mid_read_traces_error_and_failover_spans_in_one_trace(self):
        """Trace propagation under faults: a kill mid-read must yield a span
        marked ``error=FlightUnavailable`` on the dead holder AND a
        successful sibling span on the failover holder, under one trace."""
        from repro.core.flight import Tracer, batch_to_spans, decode_telemetry_batch

        cl = FlightClusterServer(num_shards=3, replicas=2).serve_tcp()
        try:
            cl.add_dataset("big", seq_batches(30, rows=200))
            cli = FlightClusterClient(
                f"tcp://127.0.0.1:{cl.port}", max_streams=3, window=2)
            inj = FaultInjector(cl)
            tracer = Tracer()
            with tracer.trace("failover-read") as ctx:
                got, killed = [], False
                for i, b in enumerate(cli.stream("big")):
                    got.append(b)
                    if i == 2 and not killed:
                        inj.kill(0)              # verbs fail + connections drop
                        killed = True
                # a second read in the same trace, on a fresh client (fresh
                # dials — the old client's severed connections fail before
                # reaching any server): membership still lists the killed
                # shard, so its endpoints route there first — the dead
                # holder's DoGet dies typed on the server, the replica
                # serves the slice
                cli2 = FlightClusterClient(
                    f"tcp://127.0.0.1:{cl.port}", max_streams=3, window=2)
                t, _ = cli2.read("big")
            assert killed
            assert all_ks(got) == list(range(6000))
            assert all_ks(t) == list(range(6000))
            res = cli.head.do_action(Action("cluster-trace", b""))
            spans = [s for s in batch_to_spans(decode_telemetry_batch(res[0].body))
                     if s["trace_id"] == ctx.trace_id]
            dead = [s for s in spans
                    if s["name"] == "DoGet" and s["status"] == "unavailable"]
            assert dead and all(s["shard"] == 0 for s in dead)
            # the failover sibling: same trace, same parent hop, another shard
            ok = [s for s in spans
                  if s["name"] == "DoGet" and s["status"] == "ok"
                  and s["shard"] != 0]
            assert ok
            parents = {s["parent_id"] for s in dead}
            assert any(s["parent_id"] in parents for s in ok)
        finally:
            cl.shutdown()

    def test_prober_declares_killed_shard_dead_and_plans_avoid_it(self):
        cl = FlightClusterServer(num_shards=3, replicas=2,
                                 suspect_after=0.05, dead_after=0.1)
        try:
            cl.add_dataset("d", seq_batches(6))
            inj = FaultInjector(cl)
            inj.kill(1)
            deadline = time.time() + 5.0
            while cl.membership.state(1) is not ShardState.DEAD:
                cl.prober.tick()
                time.sleep(0.06)
                assert time.time() < deadline
            info = cl._info_for("d")
            for ep in info.endpoints:
                assert 1 not in ep.app_metadata["holders"]
            inj.revive(1)
            cl.prober.tick()
            assert cl.membership.state(1) is ShardState.HEALTHY
        finally:
            cl.shutdown()

    def test_hang_fails_actions_fast_but_stalls_data(self):
        cl = FlightClusterServer(num_shards=2, replicas=2)
        try:
            cl.add_dataset("d", seq_batches(2))
            inj = FaultInjector(cl)
            inj.hang(0, seconds=0.2)
            t0 = time.perf_counter()
            with pytest.raises(FlightUnavailable):
                cl.shards[0].do_action_impl(Action("health"))
            assert time.perf_counter() - t0 < 0.1   # probe path fails fast
            t0 = time.perf_counter()
            with pytest.raises(FlightUnavailable):
                cl.shards[0].get_flight_info_impl(None)
            assert time.perf_counter() - t0 >= 0.15  # data path stalled
            inj.revive(0)
            assert cl.shards[0].do_action_impl(Action("health"))[0].body == b"ok"
        finally:
            cl.shutdown()

    def test_hedged_read_escapes_slow_replica_and_counts_rows_once(self):
        cl = FlightClusterServer(num_shards=2, replicas=2).serve_tcp()
        try:
            cl.add_dataset("d", seq_batches(8))
            cli = FlightClusterClient(
                f"tcp://127.0.0.1:{cl.port}", hedge_after=0.05)
            FaultInjector(cl).slow(0, delay=0.5)
            t0 = time.perf_counter()
            t, stats = cli.read("d")
            dt = time.perf_counter() - t0
            assert stats.hedges >= 1
            assert dt < 2.0                      # 8 paced batches would be ~4s
            assert stats.rows == 800             # winner's rows counted once
            assert all_ks(t) == list(range(800))
            # the loser's connection is reclaimed: the next read still works
            # and pulls the full dataset through the same pooled clients
            t2, _ = cli.read("d")
            assert all_ks(t2) == list(range(800))
        finally:
            cl.shutdown()

    def test_drop_connections_severs_but_listener_survives(self):
        from repro.core.flight import InMemoryFlightServer

        srv = InMemoryFlightServer().serve_tcp()
        try:
            srv.add_dataset("d", seq_batches(1))
            c = FlightClient(f"tcp://127.0.0.1:{srv.port}")
            assert len(c.list_flights()) == 1
            inj = FaultInjector([srv])
            inj.drop_connections(0)
            time.sleep(0.05)
            # a fresh dial works: only connections died, not the listener
            c2 = FlightClient(f"tcp://127.0.0.1:{srv.port}")
            assert len(c2.list_flights()) == 1
        finally:
            srv.shutdown()


@pytest.mark.slow
class TestSelfHealing:
    def test_auto_rebalance_restores_replication_after_death(self):
        """With ``auto_rebalance``, a shard death triggers re-replication:
        the prober declares it DEAD, the rebalance re-plans every layout
        over the survivors, and every slice is back to R live holders —
        reads keep answering throughout."""
        cl = FlightClusterServer(
            num_shards=4, replicas=2, heartbeat_interval=0.03,
            suspect_after=0.05, dead_after=0.1, auto_rebalance=True).serve_tcp()
        try:
            cl.add_dataset("d", seq_batches(12, rows=200))
            cli = FlightClusterClient(f"tcp://127.0.0.1:{cl.port}")
            FaultInjector(cl).kill(2)
            deadline = time.time() + 15.0
            while time.time() < deadline:
                lay = cl._layouts["d"]
                if (cl.membership.state(2) is ShardState.DEAD
                        and all(2 not in sl.holders for sl in lay.slices)):
                    break
                t, _ = cli.read("d")   # reads never fail during the churn
                assert all_ks(t) == list(range(2400))
                time.sleep(0.05)
            else:
                raise AssertionError("auto-rebalance never healed the layout")
            cl.wait_rebalanced(timeout=15.0)
            lay = cl._layouts["d"]
            for sl in lay.slices:
                assert len(sl.holders) == 2
                assert all(cl.membership.is_routable(h) for h in sl.holders)
                for h in sl.holders:
                    assert cl.shards[h].storage.exists(sl.key)
            t, _ = cli.read("d")
            assert all_ks(t) == list(range(2400))
        finally:
            cl.shutdown()


# --------------------------------------------------------------------------
# satellites: listener stats, remote provider retries
# --------------------------------------------------------------------------


class TestListenerStats:
    def test_server_stats_surfaces_io_depth_fields(self):
        from repro.core.flight import InMemoryFlightServer

        srv = InMemoryFlightServer().serve_tcp()
        try:
            srv.add_dataset("d", seq_batches(1))
            c = FlightClient(f"tcp://127.0.0.1:{srv.port}")
            io = json.loads(c.do_action(Action("server-stats"))[0].body)["io"]
            assert io["io_mode"] == "eventloop"
            assert io["open_fds"] >= io["open_connections"] + 3
            assert io["worker_queue_depth"] >= 0
            assert io["inline_rpcs"] >= 0 and io["accepted"] >= 1
        finally:
            srv.shutdown()

    def test_threads_listener_has_stat_parity(self):
        from repro.core.flight import InMemoryFlightServer, ServerConfig

        srv = InMemoryFlightServer(
            config=ServerConfig(io_mode="threads")).serve_tcp()
        try:
            c = FlightClient(f"tcp://127.0.0.1:{srv.port}")
            io = json.loads(c.do_action(Action("server-stats"))[0].body)["io"]
            assert io["io_mode"] == "threads"
            assert "open_fds" in io and "worker_queue_depth" in io
        finally:
            srv.shutdown()


class TestRemoteProviderRetry:
    def test_dead_target_raises_typed_unavailable(self):
        p = RemoteFlightProvider("tcp://127.0.0.1:9", retry_backoff=0.001)
        with pytest.raises(FlightUnavailable):
            p.list()

    def test_bounded_retries_are_counted_and_exhausted(self):
        p = RemoteFlightProvider("tcp://127.0.0.1:9",
                                 retries=3, retry_backoff=0.001)
        with pytest.raises(FlightUnavailable):
            p.list()
        assert p.retried_calls == 3

    def test_retry_succeeds_after_transient_failure(self):
        calls = {"n": 0}

        class Flaky:
            def do_action(self, action):
                calls["n"] += 1
                if calls["n"] < 3:
                    raise ConnectionResetError("transient")
                class R:  # matches ActionResult shape
                    body = b"a,b"
                return [R()]

        from repro.core.flight.client import FlightClient as FC

        p = RemoteFlightProvider.__new__(RemoteFlightProvider)
        p.target = "flaky"
        p._client = Flaky()
        p._txn_datasets = {}
        p.retries = 5
        p.retry_backoff = 0.0
        p.retried_calls = 0
        p.proxied_reads = p.proxied_writes = 0
        assert p.list() == ["a", "b"]
        assert p.retried_calls == 2

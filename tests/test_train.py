"""Training: optimizers, schedules, microbatching, and the e2e loss-decreases
integration over the Flight data plane."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.flight import FlightClient, InMemoryFlightServer
from repro.data import FlightDataLoader, synthesize_corpus
from repro.distributed.sharding import single_device_ctx
from repro.models.lm import LM
from repro.train.loop import Trainer, TrainerConfig
from repro.train.optimizer import (
    OptimizerConfig,
    adafactor_init,
    adafactor_update,
    adamw_init,
    adamw_update,
    lr_schedule,
)
from repro.train.step import TrainConfig, build_train_step


class TestOptimizers:
    def _quadratic(self, opt_init, opt_update, steps=120):
        """Optimize f(w) = ||w - 3||^2; any sane optimizer converges."""
        params = {"w": jnp.zeros(4)}
        state = opt_init(params)

        @jax.jit
        def step(params, state):
            grads = jax.tree.map(lambda w: 2 * (w - 3.0), params)
            return opt_update(grads, state, params)

        for _ in range(steps):
            params, state, metrics = step(params, state)
        return params["w"], metrics

    def test_adamw_converges(self):
        cfg = OptimizerConfig(learning_rate=0.1, warmup_steps=5, total_steps=200,
                              weight_decay=0.0)
        w, _ = self._quadratic(adamw_init, lambda g, s, p: adamw_update(cfg, g, s, p))
        np.testing.assert_allclose(np.asarray(w), 3.0, atol=0.3)

    def test_adafactor_converges(self):
        cfg = OptimizerConfig(name="adafactor", learning_rate=0.3, warmup_steps=5,
                              total_steps=200, weight_decay=0.0)
        w, _ = self._quadratic(lambda p: adafactor_init(p, cfg),
                               lambda g, s, p: adafactor_update(cfg, g, s, p))
        np.testing.assert_allclose(np.asarray(w), 3.0, atol=0.5)

    def test_adafactor_memory_is_factored(self):
        params = {"big": jnp.zeros((256, 512))}
        state = adafactor_init(params, OptimizerConfig(name="adafactor"))
        sizes = [int(np.prod(x.shape)) for x in jax.tree.leaves(state["v"])]
        assert sum(sizes) == 256 + 512  # vr + vc, not 256*512

    def test_lr_schedule_shape(self):
        cfg = OptimizerConfig(learning_rate=1.0, warmup_steps=10, total_steps=100)
        lrs = [float(lr_schedule(cfg, jnp.int32(s))) for s in (0, 5, 10, 55, 100)]
        assert lrs[0] == 0.0 and lrs[1] == pytest.approx(0.5)
        assert lrs[2] == pytest.approx(1.0)
        assert lrs[2] > lrs[3] > lrs[4] >= cfg.min_lr_ratio * 0.99

    def test_grad_clip(self):
        from repro.train.optimizer import clip_by_global_norm
        clipped, norm = clip_by_global_norm({"g": jnp.full(4, 100.0)}, 1.0)
        assert float(norm) == pytest.approx(200.0)
        assert float(jnp.linalg.norm(clipped["g"])) == pytest.approx(1.0, rel=1e-4)


class TestTrainStep:
    def test_microbatching_matches_full_batch(self):
        cfg = dataclasses.replace(get_smoke_config("internlm2_1_8b"), remat=False)
        model = LM(cfg, single_device_ctx())
        params, _ = model.init(jax.random.key(0))
        rng = np.random.default_rng(0)
        batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (4, 16)), jnp.int32),
                 "labels": jnp.asarray(rng.integers(0, cfg.vocab, (4, 16)), jnp.int32)}
        ocfg = OptimizerConfig(learning_rate=1e-2, warmup_steps=0, total_steps=10)
        s1, i1 = build_train_step(model, TrainConfig(optimizer=ocfg, microbatches=1), None)
        s2, i2 = build_train_step(model, TrainConfig(optimizer=ocfg, microbatches=2), None)
        p1, o1, m1 = jax.jit(s1)(params, i1(params), batch)
        p2, o2, m2 = jax.jit(s2)(params, i2(params), batch)
        # the meaningful equalities: identical loss and gradient norm
        assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-5)
        assert float(m1["grad_norm"]) == pytest.approx(float(m2["grad_norm"]), rel=1e-4)
        # params: Adam's sign normalization amplifies fp noise exactly where
        # grads ~ 0, so the bound is loose there by construction
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32), atol=2e-2)


@pytest.mark.slow
class TestIntegration:
    def test_loss_decreases_over_flight_data_plane(self, tmp_path):
        """The e2e criterion: train a small LM for 60 steps on the Flight
        loader; mean loss of the last 10 steps < first 10 steps."""
        cfg = get_smoke_config("internlm2_1_8b")
        cfg = dataclasses.replace(cfg, d_model=64, n_layers=2, vocab=256)
        model = LM(cfg, single_device_ctx())
        srv = InMemoryFlightServer(batches_per_endpoint=1)
        srv.add_dataset("c", synthesize_corpus(3000, cfg.vocab, mean_len=100, seed=2))
        loader = FlightDataLoader(FlightClient(srv), "c", batch_size=8, seq_len=32)
        tcfg = TrainerConfig(total_steps=60, log_every=1000, checkpoint_every=50,
                             train=TrainConfig(optimizer=OptimizerConfig(
                                 learning_rate=3e-3, warmup_steps=5, total_steps=60)))
        trainer = Trainer(model, tcfg, str(tmp_path), loader, log=lambda m: None)
        state = trainer.init_state()
        final = trainer.run(state)
        losses = final["losses"]
        loader.close()
        assert np.mean(losses[-10:]) < np.mean(losses[:10]) - 0.2, losses

    def test_checkpoint_resume_continues(self, tmp_path):
        cfg = dataclasses.replace(get_smoke_config("internlm2_1_8b"),
                                  d_model=32, n_layers=2, vocab=128)
        model = LM(cfg, single_device_ctx())
        srv = InMemoryFlightServer(batches_per_endpoint=1)
        srv.add_dataset("c", synthesize_corpus(500, cfg.vocab, mean_len=80, seed=3))
        loader = FlightDataLoader(FlightClient(srv), "c", batch_size=4, seq_len=16)
        tcfg = TrainerConfig(total_steps=10, log_every=1000, checkpoint_every=5,
                             train=TrainConfig(optimizer=OptimizerConfig(
                                 warmup_steps=2, total_steps=10)))
        trainer = Trainer(model, tcfg, str(tmp_path), loader, log=lambda m: None)
        state = trainer.init_state()
        trainer.run(state, steps=10)
        assert trainer.ckpt.latest_step() == 10
        # resume: restore_or_init picks up step 10
        state2, loader_state = trainer.restore_or_init()
        assert state2["step"] == 10
        loader.close()

"""Minimal fallback for the slice of the `hypothesis` API this suite uses.

The real hypothesis (installed from requirements-dev.txt in CI) is always
preferred — conftest.py only wires this module in when the import fails, so
offline containers can still collect and run every test module.  Examples are
drawn from a `random.Random` seeded per-test (by qualname), so runs are
deterministic and failures reproducible, just without shrinking.
"""
from __future__ import annotations

import functools
import inspect
import random
import sys
import types

DEFAULT_MAX_EXAMPLES = 20


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng: random.Random):
        return self._draw(rng)


def none() -> _Strategy:
    return _Strategy(lambda rng: None)


def integers(min_value: int = -(2**63), max_value: int = 2**63 - 1) -> _Strategy:
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


def floats(min_value: float = 0.0, max_value: float = 1.0) -> _Strategy:
    return _Strategy(lambda rng: rng.uniform(min_value, max_value))


# A compact alphabet that still exercises multibyte UTF-8, whitespace and
# quoting edge cases in the string-column round-trips.
_ALPHABET = "abcXYZ 0189_'\"\\\n\téß中\U0001f600"


def text(alphabet: str = _ALPHABET, min_size: int = 0, max_size: int = 10) -> _Strategy:
    def draw(rng):
        n = rng.randint(min_size, max_size)
        return "".join(rng.choice(alphabet) for _ in range(n))

    return _Strategy(draw)


def one_of(*strategies: _Strategy) -> _Strategy:
    return _Strategy(lambda rng: rng.choice(strategies).example(rng))


def lists(elements: _Strategy, min_size: int = 0, max_size: int = 10) -> _Strategy:
    def draw(rng):
        n = rng.randint(min_size, max_size)
        return [elements.example(rng) for _ in range(n)]

    return _Strategy(draw)


def sampled_from(options) -> _Strategy:
    options = list(options)
    return _Strategy(lambda rng: rng.choice(options))


def booleans() -> _Strategy:
    return _Strategy(lambda rng: rng.random() < 0.5)


class DataObject:
    """Interactive draws inside the test body (``st.data()``)."""

    def __init__(self, rng: random.Random):
        self._rng = rng

    def draw(self, strategy: _Strategy, label: str | None = None):
        return strategy.example(self._rng)


def data() -> _Strategy:
    return _Strategy(DataObject)


class settings:
    """Decorator form only — records max_examples on the decorated callable."""

    def __init__(self, max_examples: int = DEFAULT_MAX_EXAMPLES, deadline=None, **_kw):
        self.max_examples = max_examples

    def __call__(self, fn):
        fn._stub_max_examples = self.max_examples
        return fn


def given(*arg_strategies: _Strategy, **kw_strategies: _Strategy):
    def decorate(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_stub_max_examples", None) or getattr(
                fn, "_stub_max_examples", DEFAULT_MAX_EXAMPLES
            )
            rng = random.Random(fn.__qualname__)
            for _ in range(n):
                drawn = [s.example(rng) for s in arg_strategies]
                kw = {k: s.example(rng) for k, s in kw_strategies.items()}
                fn(*args, *drawn, **kwargs, **kw)

        wrapper.is_hypothesis_test = True
        # Hide the strategy-filled parameters from pytest's fixture resolution
        # (hypothesis fills positional params from the right, kwargs by name).
        params = list(inspect.signature(fn).parameters.values())
        keep = params[: len(params) - len(arg_strategies)]
        keep = [p for p in keep if p.name not in kw_strategies]
        del wrapper.__wrapped__  # stop inspect following back to fn
        wrapper.__signature__ = inspect.Signature(keep)
        return wrapper

    return decorate


def install() -> None:
    """Register this module as ``hypothesis`` (+ ``hypothesis.strategies``)."""
    st = types.ModuleType("hypothesis.strategies")
    for name in ("none", "integers", "floats", "text", "one_of", "lists",
                 "sampled_from", "booleans", "data"):
        setattr(st, name, globals()[name])

    hyp = types.ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    hyp.strategies = st
    hyp.HealthCheck = types.SimpleNamespace(all=lambda: [])
    hyp.__stub__ = True
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st

"""Data plane (loader determinism/resume) + scoring microservice + batcher."""
import threading

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import RecordBatch
from repro.core.flight import FlightClient, FlightDescriptor, InMemoryFlightServer
from repro.data import FlightDataLoader, LoaderState, pack_documents, synthesize_corpus
from repro.distributed.sharding import single_device_ctx
from repro.models.lm import LM
from repro.serving import Batcher, BatcherConfig, LMScoringService


@pytest.fixture(scope="module")
def corpus_server():
    srv = InMemoryFlightServer(batches_per_endpoint=1)
    srv.add_dataset("corpus", synthesize_corpus(2000, 512, mean_len=150, seed=7,
                                                batch_docs=250))
    return srv


class TestDataset:
    def test_corpus_is_columnar_and_reproducible(self):
        a = synthesize_corpus(100, 64, seed=3)
        b = synthesize_corpus(100, 64, seed=3)
        assert a[0] == b[0]

    def test_pack_documents_shapes_and_continuity(self):
        shard = synthesize_corpus(50, 64, seed=1)[0]
        rows = pack_documents(shard, seq_len=32)
        assert rows.shape[1] == 33
        flat = shard.column("tokens").children[0].to_numpy()
        assert np.array_equal(rows.reshape(-1), flat[: rows.size])


class TestLoader:
    def test_shapes_and_label_shift(self, corpus_server):
        loader = FlightDataLoader(FlightClient(corpus_server), "corpus",
                                  batch_size=4, seq_len=64, streams=2)
        batch, state = next(loader)
        loader.close()
        assert batch["tokens"].shape == (4, 64)
        assert np.array_equal(batch["tokens"][:, 1:], batch["labels"][:, :-1])

    def test_determinism_across_instances(self, corpus_server):
        def first_batch():
            l = FlightDataLoader(FlightClient(corpus_server), "corpus",
                                 batch_size=4, seq_len=64, streams=2, seed=5)
            b, _ = next(l)
            l.close()
            return b["tokens"]
        assert np.array_equal(first_batch(), first_batch())

    def test_hosts_get_disjoint_shards(self, corpus_server):
        l0 = FlightDataLoader(FlightClient(corpus_server), "corpus", batch_size=2,
                              seq_len=32, host_id=0, n_hosts=2)
        l1 = FlightDataLoader(FlightClient(corpus_server), "corpus", batch_size=2,
                              seq_len=32, host_id=1, n_hosts=2)
        s0, s1 = set(l0._host_shards(0)), set(l1._host_shards(0))
        l0.close(); l1.close()
        assert not (s0 & s1) and len(s0 | s1) == l0.n_shards


class TestScoring:
    def test_exchange_scoring_roundtrip(self):
        cfg = get_smoke_config("internlm2_1_8b")
        model = LM(cfg, single_device_ctx())
        params, _ = model.init(jax.random.key(0))
        svc = LMScoringService(model, params, max_seq=32).serve_tcp()
        try:
            c = FlightClient(f"tcp://127.0.0.1:{svc.port}")
            req = RecordBatch.from_pydict({"tokens": [[1, 2, 3], [4, 5]]})
            ex = c.do_exchange_stream(FlightDescriptor.for_path("score"), req.schema)
            ex.feed([req])
            (out,) = list(ex)
            ex.close()
            assert out.schema.names == ["next_token", "logprob"]
            assert out.num_rows == 2
            assert all(0 <= t < cfg.vocab for t in out.column("next_token").to_pylist())
        finally:
            svc.shutdown()

    def test_batcher_coalesces(self):
        calls = []

        def model_fn(toks, lens):
            calls.append(toks.shape[0])
            return toks.sum(axis=1)

        b = Batcher(BatcherConfig(max_batch=4, max_wait_s=0.1, pad_to=8), model_fn)
        results = {}

        def worker(i):
            results[i] = b.score(np.full(i + 1, i, np.int32))

        threads = [threading.Thread(target=worker, args=(i,)) for i in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert len(calls) == 1 and calls[0] == 4  # one coalesced model call
        for i in range(4):
            assert results[i] == i * (i + 1)


class TestGeneration:
    def test_greedy_generation_shapes_and_determinism(self):
        from repro.serving.generate import generate
        cfg = get_smoke_config("internlm2_1_8b")
        model = LM(cfg, single_device_ctx())
        params, _ = model.init(jax.random.key(0))
        prompts = np.random.default_rng(0).integers(1, cfg.vocab, (2, 6)).astype(np.int32)
        import jax.numpy as jnp
        out1 = generate(model, params, jnp.asarray(prompts), max_new_tokens=8)
        out2 = generate(model, params, jnp.asarray(prompts), max_new_tokens=8)
        assert out1.shape == (2, 8)
        assert np.array_equal(np.asarray(out1), np.asarray(out2))
        assert (np.asarray(out1) >= 0).all() and (np.asarray(out1) < cfg.vocab).all()

    def test_generation_recurrent_arch(self):
        from repro.serving.generate import generate
        cfg = get_smoke_config("xlstm_350m")
        model = LM(cfg, single_device_ctx())
        params, _ = model.init(jax.random.key(1))
        import jax.numpy as jnp
        prompts = np.random.default_rng(1).integers(1, cfg.vocab, (1, 4)).astype(np.int32)
        out = generate(model, params, jnp.asarray(prompts), max_new_tokens=5)
        assert out.shape == (1, 5)


class TestLoaderResume:
    def test_resume_from_state_skips_consumed_shards(self, corpus_server):
        """Checkpoint/restore of the loader ticket: a loader resumed from a
        mid-epoch state must not re-serve the shards before its cursor."""
        l0 = FlightDataLoader(FlightClient(corpus_server), "corpus",
                              batch_size=4, seq_len=64, streams=1, seed=11)
        b0, st = next(l0)
        l0.close()
        assert st.cursor > 0
        l1 = FlightDataLoader(FlightClient(corpus_server), "corpus",
                              batch_size=4, seq_len=64, streams=1, seed=11,
                              state=LoaderState(st.epoch, st.cursor))
        b1, _ = next(l1)
        l1.close()
        # resumed batch must differ from the consumed one (disjoint shards)
        assert not np.array_equal(b0["tokens"], b1["tokens"])

"""Telemetry plane: tracing, histograms, Arrow export, cluster scrape.

The acceptance scenario lives in ``TestClusterTraceTCP``: one traced
replicated-cluster query must stitch client + head + shard spans under a
single trace id, each with non-zero stage timings.
"""
import json

import pytest

from repro.core import RecordBatch
from repro.core.flight import (
    Action,
    FlightClient,
    FlightClusterClient,
    FlightClusterServer,
    FlightNotFound,
    InMemoryFlightServer,
    LogHistogram,
    ServerConfig,
    Tracer,
    TraceContext,
    batch_to_rows,
    batch_to_spans,
    decode_telemetry_batch,
)
from repro.core.flight.protocol import FlightDescriptor, Ticket
from repro.core.flight.telemetry import (
    HDR_PARENT,
    HDR_SPAN,
    HDR_TRACE,
    MAX_BUCKETS,
    ServerTelemetry,
    Span,
    encode_telemetry_batch,
    merge_telemetry_batches,
    metrics_rows,
    metrics_to_batch,
    spans_to_batch,
)
from repro.query import QueryPlan, col


def seq_batches(n=6, rows=100):
    return [
        RecordBatch.from_pydict({
            "k": list(range(i * rows, (i + 1) * rows)),
            "v": [float(j) * 0.5 for j in range(i * rows, (i + 1) * rows)],
        })
        for i in range(n)
    ]


# --------------------------------------------------------------------------
# log2 histograms
# --------------------------------------------------------------------------


class TestLogHistogram:
    def test_bucketing_and_percentiles(self):
        h = LogHistogram()  # scale=1e6: seconds in by microsecond bit-length
        for _ in range(99):
            h.observe(100e-6)   # ~100 µs -> bucket 7 (upper 128 µs)
        h.observe(50e-3)        # one 50 ms outlier
        assert h.count == 100
        assert h.percentile(0.50) == pytest.approx(128e-6)
        assert h.percentile(0.99) == pytest.approx(128e-6)
        assert h.percentile(1.0) == pytest.approx(h.bucket_upper(16))
        snap = h.snapshot()
        assert snap["count"] == 100
        assert snap["sum"] == pytest.approx(99 * 100e-6 + 50e-3, rel=1e-3)
        assert sum(snap["buckets"].values()) == 100

    def test_overflow_clamps_to_last_bucket(self):
        h = LogHistogram()
        h.observe(1e7)  # ~116 days: beyond the 2**39 µs ceiling
        assert h.counts[MAX_BUCKETS - 1] == 1
        assert h.percentile(0.5) == h.bucket_upper(MAX_BUCKETS - 1)

    def test_count_scale_buckets_raw_values(self):
        h = LogHistogram(scale=1)  # queue depths: raw integer domain
        for d in (1, 2, 3, 900):
            h.observe(d)
        assert h.percentile(0.5) == 4.0   # depth 3 -> bucket 2, upper 4
        assert h.percentile(1.0) == 1024.0

    def test_merge_sums_counts(self):
        a, b = LogHistogram(), LogHistogram()
        a.observe(1e-3)
        b.observe(1e-3)
        b.observe(2.0)
        a.merge(b)
        assert a.count == 3
        assert a.total == pytest.approx(2e-3 + 2.0)

    def test_empty_percentile_is_zero(self):
        assert LogHistogram().percentile(0.99) == 0.0


# --------------------------------------------------------------------------
# trace context + spans
# --------------------------------------------------------------------------


class TestTraceContext:
    def test_header_round_trip(self):
        ctx = TraceContext.new().child()
        back = TraceContext.from_headers(ctx.to_headers())
        assert back == ctx
        assert back.parent_id is not None

    def test_child_links_parent(self):
        root = TraceContext.new()
        child = root.child()
        assert child.trace_id == root.trace_id
        assert child.parent_id == root.span_id
        assert child.span_id != root.span_id

    def test_absent_or_partial_headers_are_untraced(self):
        assert TraceContext.from_headers(None) is None
        assert TraceContext.from_headers({}) is None
        assert TraceContext.from_headers({HDR_TRACE: "t"}) is None
        assert TraceContext.from_headers({HDR_SPAN: "s"}) is None
        full = {HDR_TRACE: "t", HDR_SPAN: "s", HDR_PARENT: ""}
        assert TraceContext.from_headers(full) == TraceContext("t", "s", None)


class TestSpanExport:
    def test_span_batch_round_trip(self):
        spans = [
            Span("t1", "s1", None, "read", service="client",
                 duration_s=0.5, stages={"handler": 0.4}),
            Span("t1", "s2", "s1", "DoGet", service="srv", shard=2,
                 status="unavailable"),
        ]
        rows = batch_to_spans(decode_telemetry_batch(
            encode_telemetry_batch(spans_to_batch(spans))))
        assert [r["span_id"] for r in rows] == ["s1", "s2"]
        assert rows[0]["parent_id"] == ""
        assert rows[0]["stages"] == {"handler": 0.4}
        assert rows[1]["shard"] == 2
        assert rows[1]["status"] == "unavailable"

    def test_empty_span_batch_round_trip(self):
        batch = decode_telemetry_batch(
            encode_telemetry_batch(spans_to_batch([])))
        assert batch.num_rows == 0
        assert batch_to_spans(batch) == []

    def test_metrics_batch_round_trip(self):
        h = LogHistogram()
        h.observe(1e-3)
        rows = metrics_rows("verb", {"DoGet": h})
        batch = metrics_to_batch(rows, shard=3, epoch=7)
        back = batch_to_rows(batch)
        assert back[0]["scope"] == "verb"
        assert back[0]["name"] == "DoGet"
        assert back[0]["count"] == 1
        assert back[0]["shard"] == 3 and back[0]["epoch"] == 7
        assert json.loads(back[0]["buckets"])  # non-empty bucket map

    def test_merge_stamps_shard_and_epoch(self):
        h = LogHistogram()
        h.observe(1e-3)
        part = metrics_to_batch(metrics_rows("io", {"queue_wait": h}))
        merged = merge_telemetry_batches([(0, part), (1, part)], epoch=9)
        rows = batch_to_rows(merged)
        assert [r["shard"] for r in rows] == [0, 1]
        assert all(r["epoch"] == 9 for r in rows)


class TestServerTelemetry:
    def test_mode_validation(self):
        with pytest.raises(ValueError):
            ServerTelemetry("verbose")
        assert not ServerTelemetry("off").metrics_enabled
        assert ServerTelemetry("metrics").metrics_enabled
        assert not ServerTelemetry("metrics").trace_enabled
        assert ServerTelemetry("full").trace_enabled

    def test_explicit_span_requires_parent(self):
        tel = ServerTelemetry("full", service="s")
        with tel.span("orphan") as sp:   # no active trace: no-op
            assert sp is None
        assert len(tel.spans) == 0
        with tel.span("child", parent=TraceContext.new()) as sp:
            assert sp is not None
        assert len(tel.spans) == 1

    def test_span_error_status_is_wire_code(self):
        tel = ServerTelemetry("full", service="s")
        with pytest.raises(FlightNotFound):
            with tel.span("lookup", parent=TraceContext.new()):
                raise FlightNotFound("nope")
        [span] = tel.spans.snapshot()
        assert span.status == "not_found"


# --------------------------------------------------------------------------
# one server over TCP: middleware spans, histograms, error codes, export
# --------------------------------------------------------------------------


class TestServerTelemetryTCP:
    def _serve(self, telemetry="full"):
        srv = InMemoryFlightServer(config=ServerConfig(telemetry=telemetry))
        srv.add_dataset("t", seq_batches(2))
        srv.serve_tcp()
        return srv, FlightClient(f"tcp://127.0.0.1:{srv.port}")

    def test_traced_read_records_stitched_spans_with_stages(self):
        srv, c = self._serve()
        try:
            tracer = Tracer()
            with tracer.trace("read") as ctx:
                info = c.get_flight_info(FlightDescriptor.for_path("t"))
                rows = sum(b.num_rows
                           for ep in info.endpoints for b in c.do_get(ep.ticket))
            assert rows == 200
            [client_span] = tracer.spans.snapshot()
            assert client_span.trace_id == ctx.trace_id
            spans = srv.telemetry.spans.snapshot()
            assert {s.name for s in spans} >= {"GetFlightInfo", "DoGet"}
            for s in spans:
                assert s.trace_id == ctx.trace_id
                assert s.parent_id == ctx.span_id  # direct children of the root
                assert s.duration_s > 0
                assert s.stages.get("handler", 0) > 0
            doget = next(s for s in spans if s.name == "DoGet")
            assert doget.stages.get("flush", 0) > 0  # cache-warm send timed
        finally:
            srv.shutdown()

    def test_untraced_requests_record_no_spans(self):
        srv, c = self._serve()
        try:
            assert len(c.list_flights()) == 1
            assert len(srv.telemetry.spans) == 0
            assert srv.metrics.calls.get("ListFlights") == 1  # metrics still on
        finally:
            srv.shutdown()

    def test_telemetry_off_records_nothing(self):
        srv, c = self._serve(telemetry="off")
        try:
            tracer = Tracer()
            with tracer.trace("read"):
                assert len(c.list_flights()) == 1
            assert len(srv.telemetry.spans) == 0
            assert srv.metrics.latency == {}
        finally:
            srv.shutdown()

    def test_error_counters_break_out_by_flight_code(self):
        srv, c = self._serve()
        try:
            with pytest.raises(FlightNotFound):
                c.get_flight_info(FlightDescriptor.for_path("missing"))
            with pytest.raises(FlightNotFound):
                list(c.do_get(Ticket.for_range("missing", 0, 1)))
            snap = srv.metrics.snapshot()
            assert snap["error_codes"]["GetFlightInfo"] == {"not_found": 1}
            assert snap["error_codes"]["DoGet"] == {"not_found": 1}
            # and the Arrow export carries them as scope="errors" rows
            res = c.do_action(Action("server-metrics", b""))
            rows = batch_to_rows(decode_telemetry_batch(res[0].body))
            errs = {r["name"]: r["count"] for r in rows if r["scope"] == "errors"}
            assert errs["DoGet:not_found"] == 1
        finally:
            srv.shutdown()

    def test_latency_histograms_replace_scalar_sums(self):
        srv, c = self._serve()
        try:
            for _ in range(5):
                assert len(c.list_flights()) == 1
            snap = srv.metrics.snapshot()
            lat = snap["latency"]["ListFlights"]
            assert lat["count"] == 5
            assert lat["p99"] >= lat["p50"] > 0
            assert snap["seconds"]["ListFlights"] > 0  # legacy sum kept
        finally:
            srv.shutdown()

    def test_server_trace_action_exports_and_clears(self):
        srv, c = self._serve()
        try:
            tracer = Tracer()
            with tracer.trace("read"):
                assert len(c.list_flights()) == 1
            res = c.do_action(Action("server-trace", b'{"clear": true}'))
            rows = batch_to_spans(decode_telemetry_batch(res[0].body))
            assert [r["name"] for r in rows] == ["ListFlights"]
            assert len(srv.telemetry.spans) == 0  # clear=true drained it
        finally:
            srv.shutdown()

    def test_server_metrics_exports_io_histograms(self):
        srv, c = self._serve()
        try:
            assert len(c.list_flights()) == 1
            res = c.do_action(Action("server-metrics", b""))
            rows = batch_to_rows(decode_telemetry_batch(res[0].body))
            scopes = {r["scope"] for r in rows}
            assert "verb" in scopes and "io" in scopes
            names = {r["name"] for r in rows if r["scope"] == "io"}
            assert names >= {"queue_wait", "inline_rpc", "dispatch",
                             "worker_queue_depth", "backpressure_stall"}
        finally:
            srv.shutdown()


class TestEventLoopErrorRecords:
    def test_handler_crash_yields_structured_io_error(self):
        class Crashy(InMemoryFlightServer):
            def do_action_impl(self, action):
                if action.type == "boom":
                    raise RuntimeError("kaput")
                return super().do_action_impl(action)

        srv = Crashy()
        srv.serve_tcp()
        try:
            c = FlightClient(f"tcp://127.0.0.1:{srv.port}")
            tracer = Tracer()
            with tracer.trace("crash"), pytest.raises(Exception):
                c.do_action(Action("boom", b""))
            c2 = FlightClient(f"tcp://127.0.0.1:{srv.port}")
            stats = json.loads(
                c2.do_action(Action("server-stats", b""))[0].body)
            io = stats["io"]
            assert io["handler_errors"] == 1
            [rec] = io["recent_errors"]
            assert rec["verb"] == "DoAction"
            assert rec["fd"] > 0
            assert "RuntimeError" in rec["error"]
            assert rec["trace_id"]  # the traced request's id rode along
        finally:
            srv.shutdown()


# --------------------------------------------------------------------------
# cluster: end-to-end stitching + cluster-wide scrape (the acceptance test)
# --------------------------------------------------------------------------


class TestClusterTraceTCP:
    def test_replicated_query_stitches_client_head_shard_spans(self):
        """Acceptance: one traced replicated cluster query end-to-end over
        TCP yields >= 3 spans (client root, head planning, shard execution)
        under a single trace id, every server span with non-zero stages."""
        cl = FlightClusterServer(num_shards=3, replicas=2).serve_tcp()
        try:
            cl.add_dataset("d", seq_batches(6))
            cli = FlightClusterClient(f"tcp://127.0.0.1:{cl.port}")
            tracer = Tracer()
            plan = QueryPlan("d", predicate=col("k") >= 300)
            with tracer.trace("query") as ctx:
                t, _ = cli.query(plan)
            assert t.num_rows == 300
            res = cli.head.do_action(Action("cluster-trace", b""))
            spans = [s for s in batch_to_spans(decode_telemetry_batch(res[0].body))
                     if s["trace_id"] == ctx.trace_id]
            [client_span] = tracer.spans.snapshot()
            assert client_span.trace_id == ctx.trace_id
            head = [s for s in spans if s["name"] == "GetFlightInfo"]
            shard = [s for s in spans if s["name"] == "DoGet"]
            assert len(head) == 1 and head[0]["shard"] == -1
            assert len(shard) >= 2  # one per shard holding a slice
            assert {s["shard"] for s in shard} >= {0, 1}
            # stitched hierarchy: client root -> head planning -> shard
            # execution; 1 (client) + 1 (head) + >=2 (shards) >= 3 spans
            assert head[0]["parent_id"] == ctx.span_id
            for s in shard:
                assert s["parent_id"] == head[0]["span_id"]
            for s in head + shard:
                assert s["duration_s"] > 0
                assert s["stages"].get("handler", 0) > 0
                assert s["stages"].get("queue", 0) > 0
        finally:
            cl.shutdown()

    def test_cluster_metrics_scrape_is_epoch_and_shard_stamped(self):
        cl = FlightClusterServer(num_shards=2, replicas=2).serve_tcp()
        try:
            cl.add_dataset("d", seq_batches(4))
            cli = FlightClusterClient(f"tcp://127.0.0.1:{cl.port}")
            t, _ = cli.read("d")
            assert t.num_rows == 400
            res = cli.head.do_action(Action("cluster-metrics", b""))
            rows = batch_to_rows(decode_telemetry_batch(res[0].body))
            assert rows
            assert {r["shard"] for r in rows} >= {-1, 0, 1}  # head + shards
            assert {r["epoch"] for r in rows} == {cl.membership.epoch}
            verbs = {(r["shard"], r["name"]) for r in rows if r["scope"] == "verb"}
            assert (0, "DoGet") in verbs and (1, "DoGet") in verbs
        finally:
            cl.shutdown()

    def test_2pc_commit_records_shard_subtxn_spans(self):
        cl = FlightClusterServer(num_shards=2, replicas=2).serve_tcp()
        try:
            cli = FlightClusterClient(f"tcp://127.0.0.1:{cl.port}")
            tracer = Tracer()
            with tracer.trace("write") as ctx:
                cli.write("d", seq_batches(4), transactional=True)
            res = cli.head.do_action(Action("cluster-trace", b""))
            spans = [s for s in batch_to_spans(decode_telemetry_batch(res[0].body))
                     if s["trace_id"] == ctx.trace_id]
            txn = [s for s in spans if s["name"].startswith("txn:")]
            assert {s["name"] for s in txn} >= {"txn:txn-prepare",
                                                "txn:txn-commit"}
            # sub-txn spans live on the shards that voted, parented under
            # the head's coordinating span (not the client root)
            head_ids = {s["span_id"] for s in spans if s["shard"] == -1}
            assert all(s["parent_id"] in head_ids for s in txn)
            assert all(s["status"] == "ok" for s in txn)
        finally:
            cl.shutdown()

"""Flight protocol: RPC verbs, transports, parallel streams, auth, hedging."""
import threading
import time

import numpy as np
import pytest

from repro.core import RecordBatch
from repro.core.flight import (
    Action,
    FlightClient,
    FlightDescriptor,
    FlightError,
    InMemoryFlightServer,
    Ticket,
)


def make_batches(n=4, rows=1000, seed=0):
    rng = np.random.default_rng(seed)
    return [RecordBatch.from_numpy({
        "a": rng.integers(0, 100, rows).astype(np.int64),
        "b": rng.standard_normal(rows),
    }) for _ in range(n)]


@pytest.fixture()
def server():
    srv = InMemoryFlightServer(batches_per_endpoint=1).serve_tcp()
    srv.add_dataset("ds", make_batches())
    yield srv
    srv.shutdown()


@pytest.fixture(params=["inproc", "tcp"])
def client(request, server):
    if request.param == "inproc":
        return FlightClient(server)
    return FlightClient(f"tcp://127.0.0.1:{server.port}")


class TestVerbs:
    def test_get_flight_info(self, client):
        info = client.get_flight_info(FlightDescriptor.for_path("ds"))
        assert len(info.endpoints) == 4
        assert info.total_records == 4000

    def test_list_flights(self, client):
        infos = client.list_flights()
        assert [i.descriptor.key for i in infos] == ["path:ds"]

    def test_do_get_stream(self, client):
        info = client.get_flight_info(FlightDescriptor.for_path("ds"))
        batches = list(client.do_get(info.endpoints[0].ticket))
        assert len(batches) == 1 and batches[0].num_rows == 1000

    def test_do_get_roundtrip_data(self, client, server):
        info = client.get_flight_info(FlightDescriptor.for_path("ds"))
        got = client.do_get(info.endpoints[2].ticket).read_all().combine()
        assert got == server.dataset("ds")[2]

    def test_do_put(self, client, server):
        batches = make_batches(2, 50, seed=9)
        w = client.do_put(FlightDescriptor.for_path("up"), batches[0].schema)
        for b in batches:
            w.write_batch(b)
        stats = w.close()
        assert stats["rows"] == 100
        assert server.dataset("up")[0] == batches[0]

    def test_do_action(self, client):
        names = client.do_action("list-names")[0].body.decode()
        assert "ds" in names

    def test_unknown_flight_raises(self, client):
        with pytest.raises(FlightError):
            client.get_flight_info(FlightDescriptor.for_path("nope"))

    def test_do_exchange_echo(self, client):
        b = make_batches(1, 10)[0]
        ex = client.do_exchange_stream(FlightDescriptor.for_path("echo"), b.schema)
        ex.feed([b])
        assert list(ex) == [b]
        ex.close()

    def test_ticket_range_reads_are_idempotent(self, client):
        info = client.get_flight_info(FlightDescriptor.for_path("ds"))
        t = info.endpoints[1].ticket
        a = client.do_get(t).read_all().combine()
        b = client.do_get(t).read_all().combine()
        assert a == b


class TestParallelStreams:
    def test_read_all_parallel(self, client):
        info = client.get_flight_info(FlightDescriptor.for_path("ds"))
        table, stats = client.read_all_parallel(info, max_streams=4)
        assert table.num_rows == 4000
        assert stats.streams == 4

    def test_write_parallel(self, client, server):
        batches = make_batches(8, 100, seed=5)
        stats = client.write_parallel(FlightDescriptor.for_path("pp"), batches, max_streams=4)
        assert stats.rows == 800
        assert sum(b.num_rows for b in server.dataset("pp")) == 800

    def test_hedged_read_completes(self, client):
        info = client.get_flight_info(FlightDescriptor.for_path("ds"))
        table, _ = client.read_all_parallel(info, max_streams=2, hedge_after=0.5)
        assert table.num_rows == 4000


class TestAuth:
    def test_token_required(self):
        srv = InMemoryFlightServer(auth_token="s3cret").serve_tcp()
        srv.add_dataset("ds", make_batches(1))
        try:
            bad = FlightClient(f"tcp://127.0.0.1:{srv.port}")
            with pytest.raises(FlightError):
                bad.list_flights()
            good = FlightClient(f"tcp://127.0.0.1:{srv.port}", token="s3cret")
            assert len(good.list_flights()) == 1
        finally:
            srv.shutdown()


class TestStragglerMitigation:
    def test_hedge_beats_slow_primary(self, server):
        """A slow server answer loses to the hedged replica read."""
        slow_first = {"n": 0}
        orig = server.do_get_impl

        def sometimes_slow(ticket):
            if ticket.command().start == 0 and slow_first["n"] == 0:
                slow_first["n"] += 1
                time.sleep(1.5)
            return orig(ticket)

        server.do_get_impl = sometimes_slow
        client = FlightClient(f"tcp://127.0.0.1:{server.port}")
        info = client.get_flight_info(FlightDescriptor.for_path("ds"))
        t0 = time.perf_counter()
        table, _ = client.read_all_parallel(
            info, max_streams=4, hedge_after=0.15,
            client_factory=lambda loc: FlightClient(f"tcp://127.0.0.1:{server.port}"))
        dt = time.perf_counter() - t0
        assert table.num_rows == 4000
        assert dt < 1.4  # hedge fired instead of waiting out the straggler

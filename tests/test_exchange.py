"""Streaming DoExchange: pipelined bidirectional streams, service registry,
window semantics, typed mid-stream errors, cluster fan-out, pipelines."""
import json

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import RecordBatch
from repro.core.flight import (
    ExchangeCommand,
    ExchangeService,
    ExchangeServiceRegistry,
    FlightClient,
    FlightClusterClient,
    FlightClusterServer,
    FlightDescriptor,
    FlightError,
    FlightExchange,
    FlightInvalidArgument,
    FlightNotFound,
    FlightUnauthenticated,
    InMemoryFlightServer,
    MapBatchesService,
    Pipeline,
    ScoreService,
    Ticket,
    open_exchange,
    parse_command,
)
from repro.core.flight.transport import dial
from repro.core.ipc import encode_batch
from repro.query import col


def make_batches(n=8, rows=100, seed=0):
    rng = np.random.default_rng(seed)
    return [RecordBatch.from_numpy({
        "a": rng.integers(0, 100, rows).astype(np.int64),
        "b": rng.standard_normal(rows),
    }) for _ in range(n)]


def server_stats(client):
    return json.loads(client.do_action("server-stats")[0].body)


@pytest.fixture()
def server():
    srv = InMemoryFlightServer().serve_tcp()
    srv.add_dataset("ds", make_batches())
    yield srv
    srv.shutdown()


@pytest.fixture(params=["inproc", "tcp"])
def client(request, server):
    if request.param == "inproc":
        return FlightClient(server)
    return FlightClient(f"tcp://127.0.0.1:{server.port}")


# --------------------------------------------------------------------------
# ExchangeCommand serialization (0xC2 type 4)
# --------------------------------------------------------------------------


class TestExchangeCommand:
    def test_golden_bytes(self):
        """Pin the versioned binary layout: any change is a wire break."""
        cmd = ExchangeCommand("echo")
        assert cmd.to_bytes().hex() == (
            "c2"            # COMMAND_MAGIC
            "01"            # version 1
            "04"            # type: Exchange
            "0400" "6563686f"  # u16 len + "echo"
            "00000000"      # u32 params length = 0
        )
        assert parse_command(cmd.to_bytes()) == cmd

    def test_params_roundtrip(self):
        cmd = ExchangeCommand.for_service("filter", threshold=3, flag=True)
        back = parse_command(cmd.to_bytes())
        assert back == cmd
        assert back.params == {"threshold": 3, "flag": True}
        assert ExchangeCommand("echo").params == {}

    def test_truncated_params_rejected(self):
        raw = ExchangeCommand.for_service("f", x=1).to_bytes()
        with pytest.raises(FlightInvalidArgument):
            parse_command(raw[:-2])

    def test_malformed_params_rejected(self):
        with pytest.raises(FlightInvalidArgument):
            ExchangeCommand("f", b"not json").params

    def test_not_redeemable_via_do_get(self, client):
        with pytest.raises(FlightInvalidArgument):
            client.do_get(Ticket.for_command(ExchangeCommand("echo"))).read_all()


# --------------------------------------------------------------------------
# streaming exchange: services end to end
# --------------------------------------------------------------------------


class TestStreamingExchange:
    def test_echo_roundtrip(self, client):
        batches = make_batches()
        stream = open_exchange(client, "echo", batches[0].schema, batches)
        out = list(stream)
        assert out == batches
        assert stream.stats["batches_in"] == 8
        assert stream.stats["batches_out"] == 8

    def test_out_schema_arrives_before_any_batch(self, client):
        batches = make_batches(2)
        stream = client.do_exchange_stream(
            FlightDescriptor.for_command(
                ExchangeCommand.for_service("project", columns=["b"])),
            batches[0].schema)
        # schema is declared up front: readable before one batch is written
        assert stream.out_schema.names == ["b"]
        stream.feed(batches)
        assert [b.schema.names for b in stream] == [["b"], ["b"]]

    def test_filter_matches_query_engine(self, client, server):
        batches = make_batches()
        pred = (col("a") > 50).to_json()
        stream = open_exchange(
            client, ExchangeCommand.for_service("filter", predicate=pred),
            batches[0].schema, batches)
        got = sum(b.num_rows for b in stream)
        want = sum(int((b.column("a").to_numpy() > 50).sum()) for b in batches)
        assert got == want > 0

    def test_repartition_rechunks(self, client):
        batches = make_batches(8, rows=100)
        stream = open_exchange(
            client, ExchangeCommand.for_service("repartition", rows=333),
            batches[0].schema, batches)
        sizes = [b.num_rows for b in stream]
        assert sizes == [333, 333, 134]

    def test_registered_map_batches_service(self, server):
        server.services.register(MapBatchesService(
            "double_a",
            lambda b: RecordBatch.from_numpy(
                {"a": b.column("a").to_numpy() * 2}),
            out_schema_fn=lambda s: s.select(["a"]),
        ))
        c = FlightClient(f"tcp://127.0.0.1:{server.port}")
        batches = make_batches(3)
        out = list(open_exchange(c, "double_a", batches[0].schema, batches))
        np.testing.assert_array_equal(
            out[0].column("a").to_numpy(), batches[0].column("a").to_numpy() * 2)

    def test_score_service_shape(self, server):
        server.services.register(ScoreService(
            lambda b: RecordBatch.from_numpy(
                {"score": b.column("b").to_numpy().astype(np.float64) ** 2})))
        c = FlightClient(f"tcp://127.0.0.1:{server.port}")
        batches = make_batches(4)
        stream = open_exchange(c, "score", batches[0].schema, batches)
        out = list(stream)
        assert all(b.schema.names == ["score"] for b in out)
        assert stream.stats["service"] == "score"

    def test_legacy_path_descriptor_uses_do_exchange_impl(self, client):
        batches = make_batches(3)
        stream = open_exchange(client, FlightDescriptor.for_path("echo"),
                               batches[0].schema, batches)
        assert list(stream) == batches

    def test_zero_batch_exchange(self, client):
        batches = make_batches(1)
        stream = open_exchange(client, "echo", batches[0].schema, [])
        assert list(stream) == []
        assert stream.stats["batches_in"] == 0

    def test_stream_as_context_manager(self, client):
        batches = make_batches(3)
        with open_exchange(client, "echo", batches[0].schema, batches) as stream:
            assert len(list(stream)) == 3
        assert stream.stats["batches_out"] == 3
        # exception inside the block aborts instead of hanging
        with pytest.raises(RuntimeError, match="user bail"):
            with open_exchange(client, "echo", batches[0].schema, batches):
                raise RuntimeError("user bail")

    def test_read_all_and_close(self, client):
        batches = make_batches(4)
        stream = open_exchange(client, "echo", batches[0].schema, batches)
        table = stream.read_all()
        assert table.num_rows == 400
        assert stream.close()["batches_out"] == 4  # idempotent after drain

    def test_deprecated_shim_ping_pong(self, client):
        """FlightExchange survives as a lockstep window=1 shim — and warns."""
        batches = make_batches(3)
        with pytest.warns(DeprecationWarning, match="do_exchange_stream"):
            ex = client.do_exchange(FlightDescriptor.for_path("echo"), batches[0].schema)
        for b in batches:
            assert ex.exchange(b) == b
        ex.close()
        assert "deprecat" in (FlightExchange.__doc__ or "").lower()
        assert "docs/wire-format.md" in FlightExchange.__doc__


# --------------------------------------------------------------------------
# window semantics
# --------------------------------------------------------------------------


class SlowConsume(ExchangeService):
    """Consumes everything before emitting — worst case for windowing."""

    name = "slow_consume"

    def transform(self, in_schema, batches, params):
        held = list(batches)
        yield from held


class TestWindowSemantics:
    def test_window_1_degenerates_to_lockstep(self, server):
        from repro.core.flight import CallOptions

        batches = make_batches(6)
        c = FlightClient(f"tcp://127.0.0.1:{server.port}")
        stream = open_exchange(c, "echo", batches[0].schema, batches,
                               options=CallOptions(read_window=1))
        assert list(stream) == batches
        assert stream.max_in_flight <= 1  # never more than one unacked batch

    @settings(max_examples=10, deadline=None)
    @given(window=st.integers(1, 8), n=st.integers(0, 12))
    def test_windowed_roundtrip_any_interleaving(self, window, n):
        from repro.core.flight import CallOptions

        srv = InMemoryFlightServer().serve_tcp()
        try:
            batches = make_batches(max(n, 1), rows=16)[:n]
            schema = make_batches(1)[0].schema
            c = FlightClient(f"tcp://127.0.0.1:{srv.port}")
            stream = open_exchange(c, "echo", schema, batches,
                                   options=CallOptions(read_window=window))
            assert list(stream) == batches
            assert stream.max_in_flight <= window
        finally:
            srv.shutdown()

    def test_eos_safe_out_of_order(self, server):
        """EOS may be written before any output is read — and the reader may
        drain outputs long after the server finished."""
        batches = make_batches(5)
        c = FlightClient(f"tcp://127.0.0.1:{server.port}")
        stream = c.do_exchange_stream(
            FlightDescriptor.for_command(ExchangeCommand("echo")),
            batches[0].schema)
        for b in batches:
            stream.write_batch(b)
        stream.done_writing()  # input closed before one output batch read
        assert list(stream) == batches

    def test_window_smaller_than_buffering_service_no_deadlock(self, server):
        """A service that consumes all input before emitting must not
        deadlock a small window (acks are driven by consumption, not by
        output production)."""
        from repro.core.flight import CallOptions

        server.services.register(SlowConsume())
        batches = make_batches(10)
        c = FlightClient(f"tcp://127.0.0.1:{server.port}")
        stream = open_exchange(c, "slow_consume", batches[0].schema, batches,
                               options=CallOptions(read_window=2))
        assert list(stream) == batches

    def test_early_stopping_service_drains_input(self, server):
        """A service that stops reading early must not wedge the writer or
        poison the connection for the next RPC."""

        class Head2(ExchangeService):
            name = "head2"

            def transform(self, in_schema, batches, params):
                for i, b in enumerate(batches):
                    if i == 2:
                        return
                    yield b

        server.services.register(Head2())
        batches = make_batches(12)
        c = FlightClient(f"tcp://127.0.0.1:{server.port}")
        stream = open_exchange(c, "head2", batches[0].schema, batches)
        assert list(stream) == batches[:2]
        # connection was pooled clean: the next RPC on this client works
        assert len(list(open_exchange(c, "echo", batches[0].schema, batches))) == 12


# --------------------------------------------------------------------------
# errors: typed, mid-stream, channel hygiene
# --------------------------------------------------------------------------


class Boom(ExchangeService):
    name = "boom"

    def transform(self, in_schema, batches, params):
        for i, b in enumerate(batches):
            if i == 2:
                raise FlightInvalidArgument("boom at batch 2",
                                            detail={"batch": 2})
            yield b


class TestExchangeErrors:
    def test_unknown_service_typed_refusal_channel_clean(self, client):
        batches = make_batches(1)
        with pytest.raises(FlightNotFound):
            client.do_exchange_stream(
                FlightDescriptor.for_command(ExchangeCommand("nope")),
                batches[0].schema)
        # the refusal happened before the stream: same client keeps working
        assert len(list(open_exchange(client, "echo", batches[0].schema, batches))) == 1

    def test_malformed_params_refused_at_open_both_transports(self, client):
        """check_params runs before the stream opens on every transport —
        a filter with no predicate refuses typed with the channel clean."""
        batches = make_batches(1)
        with pytest.raises(FlightInvalidArgument):
            client.do_exchange_stream(
                FlightDescriptor.for_command(ExchangeCommand("filter")),
                batches[0].schema)
        assert len(list(open_exchange(client, "echo", batches[0].schema, batches))) == 1

    def test_aborted_inproc_stream_worker_exits(self, server):
        """abort() must not leak the in-proc worker thread blocked on input."""
        import time

        from repro.core.flight import CallOptions

        c = FlightClient(server)
        batches = make_batches(10)
        stream = c.do_exchange_stream(
            FlightDescriptor.for_command(ExchangeCommand("echo")),
            batches[0].schema, options=CallOptions(read_window=2))
        stream.write_batch(batches[0])  # worker alive, waiting for more input
        stream.abort()
        deadline = time.monotonic() + 2.0
        while stream._worker.is_alive() and time.monotonic() < deadline:
            time.sleep(0.02)
        assert not stream._worker.is_alive()

    def test_non_exchange_command_rejected(self, client):
        batches = make_batches(1)
        with pytest.raises(FlightInvalidArgument):
            client.do_exchange_stream(
                FlightDescriptor.for_command(
                    Ticket.for_range("ds", 0, 1).raw), batches[0].schema)

    def test_mid_stream_error_rehydrates_typed(self, server):
        server.services.register(Boom())
        batches = make_batches(8)
        c = FlightClient(f"tcp://127.0.0.1:{server.port}")
        stream = open_exchange(c, "boom", batches[0].schema, batches)
        with pytest.raises(FlightInvalidArgument) as ei:
            list(stream)
        assert ei.value.detail == {"batch": 2}
        # server survives; a fresh exchange on the same client succeeds
        assert len(list(open_exchange(c, "echo", batches[0].schema, batches))) == 8

    def test_mid_stream_error_inproc(self, server):
        server.services.register(Boom())
        c = FlightClient(server)
        batches = make_batches(8)
        stream = open_exchange(c, "boom", batches[0].schema, batches)
        with pytest.raises(FlightInvalidArgument):
            list(stream)

    def test_malformed_first_frame_typed_control_frame(self, server):
        """A batch where the schema should be gets a typed error frame, not
        a bare failure after the ok (the old behavior left the client
        mid-stream with an untyped 'internal' error)."""
        batches = make_batches(1)
        conn = dial("127.0.0.1", server.port)
        try:
            conn.send_ctrl({
                "method": "DoExchange",
                "descriptor": FlightDescriptor.for_command(
                    ExchangeCommand("echo")).to_json(),
                "token": None,
            })
            assert conn.recv_ctrl() == {"ok": True}
            # protocol violation: batch before schema
            conn.send_data(encode_batch(batches[0]))
            with pytest.raises(FlightInvalidArgument):
                while True:
                    conn.recv_ctrl()  # raises on the typed error frame
        finally:
            conn.close()

    def test_eos_as_first_frame_is_invalid(self, server):
        from repro.core.ipc import encode_eos

        conn = dial("127.0.0.1", server.port)
        try:
            conn.send_ctrl({
                "method": "DoExchange",
                "descriptor": FlightDescriptor.for_command(
                    ExchangeCommand("echo")).to_json(),
                "token": None,
            })
            assert conn.recv_ctrl() == {"ok": True}
            conn.send_data(encode_eos())
            with pytest.raises(FlightInvalidArgument):
                while True:
                    conn.recv_ctrl()
        finally:
            conn.close()

    def test_writer_schema_mismatch_raises(self, client):
        batches = make_batches(1)
        other = RecordBatch.from_numpy({"z": np.arange(4, dtype=np.int64)})
        stream = client.do_exchange_stream(
            FlightDescriptor.for_command(ExchangeCommand("echo")),
            batches[0].schema)
        with pytest.raises(FlightError):
            stream.write_batch(other)
        stream.abort()

    def test_non_flight_service_bug_surfaces_typed_over_tcp(self, server):
        """A service callable raising a plain exception must reach the TCP
        client as a typed error frame (like inproc), not kill the handler
        thread and surface as a generic connection loss."""

        class Buggy(ExchangeService):
            name = "buggy"

            def transform(self, in_schema, batches, params):
                for b in batches:
                    raise ValueError("user bug")
                    yield b

        server.services.register(Buggy())
        c = FlightClient(f"tcp://127.0.0.1:{server.port}")
        batches = make_batches(3)
        stream = open_exchange(c, "buggy", batches[0].schema, batches)
        with pytest.raises(FlightError, match="exchange failed.*user bug"):
            list(stream)
        # server healthy afterwards, and the failure was counted
        assert len(list(open_exchange(c, "echo", batches[0].schema, batches))) == 3
        assert server_stats(c)["verbs"]["exchanges"]["buggy"]["errors"] == 1

    def test_close_while_feeder_active(self, server):
        """close() during an active feed() must finish the call cleanly
        (drain + join the feeder) instead of racing it with its own EOS."""
        c = FlightClient(f"tcp://127.0.0.1:{server.port}")
        batches = make_batches(12)
        stream = open_exchange(c, "echo", batches[0].schema, batches)
        next(iter(stream))  # consume a little, then close mid-flight
        stats = stream.close()
        assert stats["batches_in"] == 12
        # connection was pooled clean: the next exchange works
        assert len(list(open_exchange(c, "echo", batches[0].schema, batches))) == 12

    def test_feeder_failure_aborts_reader(self, server):
        c = FlightClient(f"tcp://127.0.0.1:{server.port}")
        batches = make_batches(3)

        def bad_iter():
            yield batches[0]
            raise ValueError("source exploded")

        stream = open_exchange(c, "echo", batches[0].schema, bad_iter())
        with pytest.raises(FlightError):
            list(stream)


# --------------------------------------------------------------------------
# middleware: auth + per-exchange metrics
# --------------------------------------------------------------------------


class TestExchangeMiddleware:
    def test_auth_guards_exchange_tcp_and_inproc(self):
        srv = InMemoryFlightServer(auth_token="s3cret").serve_tcp()
        try:
            batches = make_batches(2)
            desc = FlightDescriptor.for_command(ExchangeCommand("echo"))
            bad = FlightClient(f"tcp://127.0.0.1:{srv.port}")
            with pytest.raises(FlightUnauthenticated):
                bad.do_exchange_stream(desc, batches[0].schema)
            bad_inproc = FlightClient(srv)
            with pytest.raises(FlightUnauthenticated):
                stream = bad_inproc.do_exchange_stream(desc, batches[0].schema)
                stream.feed(batches)
                list(stream)
            good = FlightClient(f"tcp://127.0.0.1:{srv.port}", token="s3cret")
            assert len(list(open_exchange(good, "echo", batches[0].schema, batches))) == 2
            verbs = server_stats(good)["verbs"]
            # the rejected calls were *counted* — middleware saw them
            assert verbs["exchanges"]["echo"]["calls"] >= 3
            assert verbs["exchanges"]["echo"]["errors"] >= 2
        finally:
            srv.shutdown()

    def test_per_exchange_metrics_in_server_stats(self, server):
        c = FlightClient(f"tcp://127.0.0.1:{server.port}")
        batches = make_batches(2)
        list(open_exchange(c, "echo", batches[0].schema, batches))
        list(open_exchange(c, ExchangeCommand.for_service("project", columns=["a"]),
                           batches[0].schema, batches))
        ex = server_stats(c)["verbs"]["exchanges"]
        assert ex["echo"]["calls"] == 1 and ex["echo"]["errors"] == 0
        assert ex["project"]["calls"] == 1
        assert ex["echo"]["seconds"] >= 0

    def test_error_metrics_counted(self, server):
        server.services.register(Boom())
        c = FlightClient(f"tcp://127.0.0.1:{server.port}")
        batches = make_batches(4)
        with pytest.raises(FlightInvalidArgument):
            list(open_exchange(c, "boom", batches[0].schema, batches))
        ex = server_stats(c)["verbs"]["exchanges"]
        assert ex["boom"]["errors"] == 1


# --------------------------------------------------------------------------
# cluster fan-out
# --------------------------------------------------------------------------


class TestClusterExchange:
    @pytest.mark.parametrize("transport", ["inproc", "tcp"])
    def test_fan_out_across_shards(self, transport):
        cluster = FlightClusterServer(num_shards=4)
        if transport == "tcp":
            cluster.serve_tcp()
            cc = FlightClusterClient(f"tcp://127.0.0.1:{cluster.port}")
        else:
            cc = FlightClusterClient(cluster)
        try:
            batches = make_batches(8)
            table, stats = cc.exchange(
                ExchangeCommand.for_service("project", columns=["a"]), batches)
            assert table.num_rows == 800
            assert table.schema.names == ["a"]
            assert stats.streams == 4
        finally:
            cluster.shutdown()

    def test_shared_registry_reaches_every_shard(self):
        """One register on the cluster makes the service reachable on every
        shard endpoint a fanned-out exchange lands on."""
        cluster = FlightClusterServer(num_shards=3)
        cluster.services.register(MapBatchesService(
            "negate", lambda b: RecordBatch.from_numpy(
                {"a": -b.column("a").to_numpy(),
                 "b": b.column("b").to_numpy()})))
        try:
            cc = FlightClusterClient(cluster)
            batches = make_batches(6)
            table, stats = cc.exchange("negate", batches)
            assert stats.streams == 3
            assert table.num_rows == 600
            got = np.sort(np.concatenate([b.column("a").to_numpy() for b in table]))
            want = np.sort(-np.concatenate([b.column("a").to_numpy() for b in batches]))
            np.testing.assert_array_equal(got, want)
        finally:
            cluster.shutdown()

    def test_empty_input_is_typed_error(self):
        cluster = FlightClusterServer(num_shards=2)
        try:
            with pytest.raises(FlightInvalidArgument):
                FlightClusterClient(cluster).exchange("echo", [])
        finally:
            cluster.shutdown()

    def test_cluster_exchange_auth(self):
        cluster = FlightClusterServer(num_shards=2, auth_token="tk").serve_tcp()
        try:
            batches = make_batches(4)
            bad = FlightClusterClient(f"tcp://127.0.0.1:{cluster.port}")
            with pytest.raises(FlightError):
                bad.exchange("echo", batches)
            good = FlightClusterClient(f"tcp://127.0.0.1:{cluster.port}", token="tk")
            table, _ = good.exchange("echo", batches)
            assert table.num_rows == 400
        finally:
            cluster.shutdown()


# --------------------------------------------------------------------------
# chained pipelines (Mallard-style)
# --------------------------------------------------------------------------


class TestPipeline:
    def test_two_server_chain_tcp(self):
        """A→filter→B: server A's output stream is server B's input, end to
        end over TCP, no client-side materialization."""
        a = InMemoryFlightServer("a").serve_tcp()
        b = InMemoryFlightServer("b").serve_tcp()
        try:
            batches = make_batches(8)
            pred = (col("a") > 50).to_json()
            pipe = Pipeline([
                (FlightClient(f"tcp://127.0.0.1:{a.port}"),
                 ExchangeCommand.for_service("filter", predicate=pred)),
                (FlightClient(f"tcp://127.0.0.1:{b.port}"),
                 ExchangeCommand.for_service("repartition", rows=64)),
            ])
            table = pipe.run_all(batches[0].schema, batches)
            want = sum(int((x.column("a").to_numpy() > 50).sum()) for x in batches)
            assert table.num_rows == want > 0
            assert all(x.num_rows == 64 for x in list(table)[:-1])
            stages = pipe.stats()
            assert stages[0]["service"] == "filter"
            assert stages[1]["service"] == "repartition"
            assert stages[1]["rows_in"] == want
        finally:
            a.shutdown()
            b.shutdown()

    def test_three_stage_chain_mixed_transports(self):
        a = InMemoryFlightServer("a").serve_tcp()
        b = InMemoryFlightServer("b")
        try:
            batches = make_batches(6)
            pipe = Pipeline([
                (FlightClient(f"tcp://127.0.0.1:{a.port}"),
                 ExchangeCommand.for_service("project", columns=["a"])),
                (FlightClient(b), "echo"),
                (FlightClient(f"tcp://127.0.0.1:{a.port}"),
                 ExchangeCommand.for_service("repartition", rows=150)),
            ])
            table = pipe.run_all(batches[0].schema, batches)
            assert table.num_rows == 600
            assert table.schema.names == ["a"]
        finally:
            a.shutdown()
            b.shutdown()

    def test_stage_error_propagates_to_final_reader(self):
        a = InMemoryFlightServer("a").serve_tcp()
        a.services.register(Boom())
        b = InMemoryFlightServer("b").serve_tcp()
        try:
            batches = make_batches(8)
            pipe = Pipeline([
                (FlightClient(f"tcp://127.0.0.1:{a.port}"), "boom"),
                (FlightClient(f"tcp://127.0.0.1:{b.port}"), "echo"),
            ])
            with pytest.raises(FlightError):
                pipe.run_all(batches[0].schema, batches)
        finally:
            a.shutdown()
            b.shutdown()

    def test_pipeline_streams_without_materializing(self):
        """Many more batches than any window: the chain must keep flowing
        (a materializing implementation would need the whole dataset in
        memory before stage 2 — this would deadlock bounded queues if any
        link waited for its input to complete)."""
        from repro.core.flight import CallOptions

        a = InMemoryFlightServer("a").serve_tcp()
        b = InMemoryFlightServer("b").serve_tcp()
        try:
            batches = make_batches(40, rows=50)
            pipe = Pipeline([
                (FlightClient(f"tcp://127.0.0.1:{a.port}"), "echo"),
                (FlightClient(f"tcp://127.0.0.1:{b.port}"), "echo"),
            ], options=CallOptions(read_window=2))
            table = pipe.run_all(batches[0].schema, batches)
            assert table.num_rows == 40 * 50
        finally:
            a.shutdown()
            b.shutdown()


class TestRegistry:
    def test_stock_services_present(self):
        reg = ExchangeServiceRegistry()
        assert {"echo", "filter", "project", "repartition"} <= set(reg.names())

    def test_unknown_service_typed(self):
        reg = ExchangeServiceRegistry()
        with pytest.raises(FlightNotFound):
            reg.get("nope")

    def test_unnamed_service_rejected(self):
        reg = ExchangeServiceRegistry()
        with pytest.raises(FlightInvalidArgument):
            reg.register(ExchangeService())

"""Model zoo: every arch trains a step; decode == prefill; chunked == exact."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke_config, make_smoke_batch
from repro.distributed.sharding import single_device_ctx
from repro.models.lm import LM
from repro.models import layers as L
from repro.models.attention import HeadLayout, flash_attention
from repro.models.mamba import MambaConfig, init_mamba, mamba_init_state, mamba_mix
from repro.models.xlstm import XLSTMConfig, init_mlstm, mlstm_init_state, mlstm_mix


def build(arch):
    cfg = get_smoke_config(arch)
    model = LM(cfg, single_device_ctx())
    params, axes = model.init(jax.random.key(0))
    return cfg, model, params


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_backward_finite(arch):
    cfg, model, params = build(arch)
    batch = make_smoke_batch(cfg, 2, 32)
    loss, metrics = jax.jit(model.loss_fn)(params, batch)
    assert jnp.isfinite(loss), (arch, loss)
    grads = jax.grad(lambda p: model.loss_fn(p, batch)[0])(params)
    for path, g in jax.tree_util.tree_flatten_with_path(grads)[0]:
        assert jnp.all(jnp.isfinite(g)), (arch, path)


@pytest.mark.parametrize("arch", ["internlm2_1_8b", "jamba_1_5_large_398b",
                                  "xlstm_350m", "moonshot_v1_16b_a3b"])
def test_decode_matches_prefill(arch):
    """Greedy next-token from token-by-token decode == prefill's."""
    cfg, model, params = build(arch)
    B, S = 2, 16
    rng = np.random.default_rng(3)
    toks = jnp.asarray(rng.integers(1, cfg.vocab, (B, S)), jnp.int32)

    lgts_prefill, _ = jax.jit(model.prefill)(params, {"tokens": toks})

    caches = model.init_caches(B, S + 4)
    step = jax.jit(lambda c, t, p: model.decode_step(params, c, t, p, return_logits=True))
    for i in range(S):
        nxt, caches, lgts = step(caches, toks[:, i:i + 1], jnp.int32(i))
    np.testing.assert_allclose(np.asarray(lgts, np.float32),
                               np.asarray(lgts_prefill, np.float32),
                               rtol=0.08, atol=0.08)


def test_flash_attention_matches_naive():
    B, S, Ke, Gq, hd = 2, 64, 2, 2, 16
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((B, S, Ke, Gq, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, Ke, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, Ke, hd)), jnp.float32)
    out = flash_attention(q, k, v, causal=True, q_chunk=16, kv_chunk=16)
    # naive reference
    s = jnp.einsum("bskgh,btkh->bkgst", q, k) / np.sqrt(hd)
    mask = jnp.tril(jnp.ones((S, S), bool))
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    ref = jnp.einsum("bkgst,btkh->bskgh", p, v)
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(ref), atol=2e-2)


def test_head_layout_padding_math():
    """deepseek-style: 56 q / 8 kv -> (16, 4) padded grid, 56 real heads."""
    lo = HeadLayout(56, 8, 128, 16)
    assert lo.repl == 2 and lo.eff_kv == 16 and lo.q_per_kv == 4
    assert lo.padded_heads == 64
    assert int(lo.head_mask().sum()) == 56
    lo2 = HeadLayout(24, 8, 96, 16)
    assert lo2.padded_heads == 32 and int(lo2.head_mask().sum()) == 24


def test_mamba_chunked_equals_whole():
    cfg = MambaConfig(d_model=32, d_state=4, d_conv=4, expand=2)
    pb = L.ParamBuilder(jax.random.key(0))
    init_mamba(pb, cfg)
    x = jnp.asarray(np.random.default_rng(0).standard_normal((2, 32, 32)), jnp.float32)
    ctx = single_device_ctx()
    y_chunked, st1 = mamba_mix(pb.params, x, ctx, chunk=8)
    y_whole, st2 = mamba_mix(pb.params, x, ctx, chunk=32)
    np.testing.assert_allclose(np.asarray(y_chunked, np.float32),
                               np.asarray(y_whole, np.float32), atol=3e-2)
    np.testing.assert_allclose(np.asarray(st1["h"]), np.asarray(st2["h"]), atol=3e-2)


def test_mamba_decode_continues_train_state():
    """Running seq then one decode step == running seq+1 at once."""
    cfg = MambaConfig(d_model=16, d_state=4, d_conv=4, expand=2)
    pb = L.ParamBuilder(jax.random.key(1))
    init_mamba(pb, cfg)
    ctx = single_device_ctx()
    x = jnp.asarray(np.random.default_rng(1).standard_normal((1, 9, 16)), jnp.float32)
    y_all, _ = mamba_mix(pb.params, x, ctx, chunk=9)
    y_pre, st = mamba_mix(pb.params, x[:, :8], ctx, chunk=8)
    y_last, _ = mamba_mix(pb.params, x[:, 8:9], ctx, state=st)
    np.testing.assert_allclose(np.asarray(y_last[:, 0]), np.asarray(y_all[:, 8]), atol=3e-2)


def test_mlstm_chunked_equals_sequential():
    cfg = XLSTMConfig(d_model=32, n_heads=2)
    pb = L.ParamBuilder(jax.random.key(2))
    init_mlstm(pb, cfg)
    ctx = single_device_ctx()
    x = jnp.asarray(np.random.default_rng(2).standard_normal((2, 16, 32)), jnp.float32)
    y_par, st_par = mlstm_mix(pb.params, x, ctx, chunk=8)
    # sequential: feed one token at a time
    st = mlstm_init_state(2, cfg)
    outs = []
    for i in range(16):
        y, st = mlstm_mix(pb.params, x[:, i:i + 1], ctx, state=st)
        outs.append(y)
    y_seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_par, np.float32),
                               np.asarray(y_seq, np.float32), atol=5e-2)


def test_param_count_matches_actual():
    for arch in ("internlm2_1_8b", "moonshot_v1_16b_a3b", "xlstm_350m"):
        cfg, model, params = build(arch)
        actual = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
        analytic = cfg.param_count()
        # analytic counts true (unpadded) heads and no norm weights: within 5%
        assert abs(actual - analytic) / actual < 0.05, (arch, actual, analytic)


def test_vlm_patches_change_output():
    cfg, model, params = build("phi_3_vision_4_2b")
    batch = make_smoke_batch(cfg, 2, 32)
    l1, _ = model.loss_fn(params, batch)
    batch2 = dict(batch)
    batch2["patches"] = batch["patches"] + 1.0
    l2, _ = model.loss_fn(params, batch2)
    assert not np.isclose(float(l1), float(l2))


def test_audio_mask_limits_loss_positions():
    cfg, model, params = build("hubert_xlarge")
    batch = make_smoke_batch(cfg, 2, 32)
    batch["mask"] = np.zeros_like(batch["mask"])
    l0, _ = model.loss_fn(params, batch)
    assert float(l0) == 0.0  # no masked positions -> zero loss

"""Query engine vs numpy oracle + Flight query service + protocol baselines."""
import json

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import RecordBatch
from repro.core.flight import Action, FlightClient, FlightDescriptor
from repro.query import FlightQueryService, QueryPlan, aggregate, col, execute
from repro.query.odbc_sim import FlightColumnarProtocol, OdbcProtocol, TurbodbcProtocol


def taxi(n=5000, seed=0):
    rng = np.random.default_rng(seed)
    return RecordBatch.from_pydict({
        "passenger_count": rng.integers(1, 7, n).astype(np.int32),
        "trip_distance": rng.gamma(2.0, 1.5, n).astype(np.float32),
        "fare_amount": rng.gamma(3.0, 5.0, n).astype(np.float64),
    })


class TestEngine:
    def test_predicate_matches_numpy(self):
        b = taxi()
        plan = QueryPlan("t", predicate=(col("trip_distance") > 3.0) &
                                        (col("passenger_count") == 2))
        out = list(execute(plan, [b]))[0]
        d = b.column("trip_distance").to_numpy()
        p = b.column("passenger_count").to_numpy()
        want = int(((d > 3.0) & (p == 2)).sum())
        assert out.num_rows == want

    def test_projection_pushdown_only_ships_columns(self):
        b = taxi()
        plan = QueryPlan("t", projection=["fare_amount"],
                         predicate=col("trip_distance") > 1.0)
        out = list(execute(plan, [b]))[0]
        assert out.schema.names == ["fare_amount"]

    def test_limit(self):
        plan = QueryPlan("t", limit=7)
        outs = list(execute(plan, [taxi(), taxi(seed=1)]))
        assert sum(o.num_rows for o in outs) == 7

    def test_aggregate_matches_numpy(self):
        b = taxi()
        plan = QueryPlan("t", predicate=col("trip_distance") > 2.0,
                         aggregations=[("mean", "fare_amount"), ("count", "fare_amount")])
        out = aggregate(plan, [b])
        mask = b.column("trip_distance").to_numpy() > 2.0
        np.testing.assert_allclose(out["mean(fare_amount)"],
                                   b.column("fare_amount").to_numpy()[mask].mean())
        assert out["count(fare_amount)"] == mask.sum()

    def test_plan_serialization_roundtrip(self):
        plan = QueryPlan("t", projection=["a"], predicate=col("x") > 1,
                         aggregations=[("sum", "a")], limit=5)
        plan2 = QueryPlan.deserialize(plan.serialize())
        assert plan2.dataset == "t" and plan2.projection == ["a"]
        assert plan2.limit == 5 and plan2.aggregations == [("sum", "a")]


@settings(max_examples=25, deadline=None)
@given(st.floats(0.1, 10.0), st.integers(1, 6))
def test_prop_filter_count_invariant(threshold, pc):
    b = taxi(800, seed=42)
    plan = QueryPlan("t", predicate=(col("trip_distance") > threshold) &
                                    (col("passenger_count") == pc))
    outs = list(execute(plan, [b]))
    got = sum(o.num_rows for o in outs)
    d = b.column("trip_distance").to_numpy()
    p = b.column("passenger_count").to_numpy()
    assert got == int(((d > threshold) & (p == pc)).sum())


class TestService:
    def test_query_over_flight(self):
        svc = FlightQueryService().serve_tcp()
        try:
            svc.add_dataset("taxi", [taxi(seed=s) for s in range(4)])
            c = FlightClient(f"tcp://127.0.0.1:{svc.port}")
            plan = QueryPlan("taxi", projection=["fare_amount"],
                             predicate=col("trip_distance") > 2.0)
            info = c.get_flight_info(FlightDescriptor.for_command(plan.serialize()))
            table, _ = c.read_all_parallel(info, max_streams=4)
            assert table.schema.names == ["fare_amount"]
            want = sum(int((t.column("trip_distance").to_numpy() > 2.0).sum())
                       for t in (taxi(seed=s) for s in range(4)))
            assert table.num_rows == want
        finally:
            svc.shutdown()

    def test_aggregate_action(self):
        svc = FlightQueryService()
        svc.add_dataset("taxi", [taxi()])
        c = FlightClient(svc)
        plan = QueryPlan("taxi", aggregations=[("max", "fare_amount")])
        out = json.loads(c.do_action(Action("aggregate", plan.serialize()))[0].body)
        assert out["max(fare_amount)"] == pytest.approx(
            float(taxi().column("fare_amount").to_numpy().max()))


class TestProtocolBaselines:
    def test_all_protocols_agree(self):
        b = [taxi(2000)]
        plan = QueryPlan("t", projection=["fare_amount", "trip_distance"],
                         predicate=col("passenger_count") >= 3)
        rows, _ = OdbcProtocol().transfer(plan, b)
        tb, _ = TurbodbcProtocol(500).transfer(plan, b)
        fb, _ = FlightColumnarProtocol().transfer(plan, b)
        n = len(rows)
        assert n == sum(x.num_rows for x in tb) == sum(x.num_rows for x in fb)
        fare_odbc = np.array([r[0] for r in rows])
        fare_flight = np.concatenate([x.column("fare_amount").to_numpy() for x in fb])
        np.testing.assert_allclose(np.sort(fare_odbc), np.sort(fare_flight))

    def test_flight_serialization_cheaper_than_odbc(self):
        b = [taxi(20000)]
        plan = QueryPlan("t")
        _, st_o = OdbcProtocol().transfer(plan, b)
        _, st_f = FlightColumnarProtocol().transfer(plan, b)
        assert st_f.total_s < st_o.total_s  # the paper's entire point

"""Event-loop serving under concurrency: many-client correctness, slow-reader
backpressure, disconnect cleanup, listener thread bounds, dial retry."""
import gc
import json
import socket
import struct
import threading
import time
import weakref

import numpy as np
import pytest

from repro.core import RecordBatch
from repro.core.flight import (
    FlightClient,
    FlightDescriptor,
    InMemoryFlightServer,
    open_exchange,
)
from repro.core.flight.eventloop import OUT_HIGH_WATER, EventLoopListener
from repro.core.flight.transport import (
    FRAME,
    FRAME_MAGIC,
    KIND_CTRL,
    SocketListener,
    dial,
)


def make_batches(n=8, rows=200, seed=0):
    rng = np.random.default_rng(seed)
    return [RecordBatch.from_numpy({
        "a": rng.integers(0, 100, rows).astype(np.int64),
        "b": rng.standard_normal(rows),
    }) for _ in range(n)]


@pytest.fixture()
def server():
    srv = InMemoryFlightServer().serve_tcp()
    srv.add_dataset("ds", make_batches())
    yield srv
    srv.shutdown()


def get_all(port, ticket, rows_expected, results, idx):
    try:
        client = FlightClient(f"tcp://127.0.0.1:{port}")
        table = client.do_get(ticket).read_all()
        results[idx] = table.num_rows == rows_expected
    except Exception as e:  # pragma: no cover - failure detail for the assert
        results[idx] = e


class TestManyClients:
    def test_64_clients_concurrent_doget(self, server):
        info = FlightClient(server).get_flight_info(FlightDescriptor.for_path("ds"))
        ticket = info.endpoints[0].ticket
        results = [None] * 64
        threads = [
            threading.Thread(target=get_all,
                             args=(server.port, ticket, 1600, results, i))
            for i in range(64)
        ]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=60)
        assert all(r is True for r in results), results
        # the whole point: serving 64 clients never grew the worker pool
        assert server._listener.stats()["workers"] <= 8

    def test_concurrent_exchange_clients(self, server):
        batches = make_batches(4)
        results = [None] * 8

        def run(i):
            try:
                client = FlightClient(f"tcp://127.0.0.1:{server.port}")
                out = open_exchange(client, "echo", batches[0].schema,
                                    batches).read_all()
                results[i] = out.num_rows == 800
            except Exception as e:  # pragma: no cover
                results[i] = e

        threads = [threading.Thread(target=run, args=(i,)) for i in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=60)
        assert all(r is True for r in results), results

    def test_server_thread_count_o_workers(self, server):
        before = threading.active_count()
        clients = [FlightClient(f"tcp://127.0.0.1:{server.port}")
                   for _ in range(32)]
        info = clients[0].get_flight_info(FlightDescriptor.for_path("ds"))
        for c in clients:
            assert c.do_get(info.endpoints[0].ticket).read_all().num_rows == 1600
        # 32 held-open connections must not have spawned 32 server threads
        assert threading.active_count() <= before + server._listener._workers + 1
        assert server._listener.open_connections() >= 32


class TestBackpressure:
    def test_slow_reader_does_not_block_others(self):
        srv = InMemoryFlightServer().serve_tcp()
        # dataset bigger than kernel socket buffers + OUT_HIGH_WATER, so a
        # never-reading client forces the server's outbox to its high-water
        # mark and parks that RPC's worker in _flush
        big = [RecordBatch.from_numpy(
            {"x": np.arange(1 << 17, dtype=np.int64) + i}) for i in range(12)]
        assert sum(b.nbytes() for b in big) > OUT_HIGH_WATER
        srv.add_dataset("big", big)
        srv.add_dataset("small", make_batches(2))
        try:
            info_client = FlightClient(srv)
            big_ticket = info_client.get_flight_info(
                FlightDescriptor.for_path("big")).endpoints[0].ticket
            small_ticket = info_client.get_flight_info(
                FlightDescriptor.for_path("small")).endpoints[0].ticket

            # raw socket: open the DoGet RPC, then never read a byte
            stalled = socket.create_connection(("127.0.0.1", srv.port))
            stalled.setsockopt(socket.SOL_SOCKET, socket.SO_RCVBUF, 4096)
            meta = json.dumps(
                {"method": "DoGet", "ticket": big_ticket.to_json()}).encode()
            stalled.sendall(FRAME.pack(FRAME_MAGIC, KIND_CTRL, len(meta), 0) + meta)
            time.sleep(0.5)  # let the server wedge on the stalled outbox

            # other clients stream freely on the remaining workers
            t0 = time.monotonic()
            for _ in range(3):
                client = FlightClient(f"tcp://127.0.0.1:{srv.port}")
                assert client.do_get(small_ticket).read_all().num_rows == 400
            assert time.monotonic() - t0 < 10.0
            stalled.close()
        finally:
            srv.shutdown()

    def test_midstream_disconnect_frees_fd_and_buffers(self, server):
        info = FlightClient(server).get_flight_info(FlightDescriptor.for_path("ds"))
        ticket = info.endpoints[0].ticket
        # connect, open a DoGet, read a little, vanish
        conn = dial("127.0.0.1", server.port)
        conn.send_ctrl({"method": "DoGet", "ticket": ticket.to_json()})
        conn.recv_ctrl()   # ok
        conn.recv_frame()  # schema frame: the stream is live server-side
        assert server._listener.open_connections() >= 1
        channels = list(server._listener._conns.values())
        refs = [weakref.ref(ch) for ch in channels]
        conn.sock.close()
        deadline = time.monotonic() + 10
        while server._listener.open_connections() > 0:
            assert time.monotonic() < deadline, "fd not reaped after disconnect"
            time.sleep(0.02)
        del channels
        for _ in range(60):
            gc.collect()
            if all(r() is None for r in refs):
                break
            time.sleep(0.05)
        # channel gone => its BufferPool and pooled body slabs are released
        assert all(r() is None for r in refs)

    def test_disconnect_on_partial_frame(self, server):
        # half a frame header, then hang up: the parser must just drop it
        raw = socket.create_connection(("127.0.0.1", server.port))
        raw.sendall(struct.pack("<I", FRAME_MAGIC))
        raw.close()
        deadline = time.monotonic() + 10
        while server._listener.open_connections() > 0:
            assert time.monotonic() < deadline
            time.sleep(0.02)
        # server still serves
        client = FlightClient(f"tcp://127.0.0.1:{server.port}")
        info = client.get_flight_info(FlightDescriptor.for_path("ds"))
        assert client.do_get(info.endpoints[0].ticket).read_all().num_rows == 1600


class TestListenerChurn:
    def test_threads_listener_bounded_under_churn(self):
        handled = []

        def handler(conn):
            try:
                conn.recv_frame()
            except ConnectionError:
                pass
            handled.append(1)
            conn.close()

        lst = SocketListener(handler).start()
        try:
            for _ in range(3 * SocketListener.MAX_TRACKED):
                s = socket.create_connection(("127.0.0.1", lst.port))
                s.close()
                assert len(lst._threads) <= SocketListener.MAX_TRACKED
        finally:
            lst.stop()

    def test_eventloop_accept_churn(self):
        srv = InMemoryFlightServer().serve_tcp()
        try:
            before = threading.active_count()
            for _ in range(100):
                s = socket.create_connection(("127.0.0.1", srv.port))
                s.close()
            deadline = time.monotonic() + 10
            while srv._listener.open_connections() > 0:
                assert time.monotonic() < deadline
                time.sleep(0.02)
            assert threading.active_count() <= before + srv._listener._workers
        finally:
            srv.shutdown()


class TestDialRetry:
    def test_dial_retries_refused_until_server_up(self):
        probe = socket.socket()
        probe.bind(("127.0.0.1", 0))
        port = probe.getsockname()[1]
        probe.close()  # port now refuses connections until the server binds
        holder = {}

        def late_start():
            time.sleep(0.08)
            holder["srv"] = InMemoryFlightServer().serve_tcp(port=port)

        t = threading.Thread(target=late_start)
        t.start()
        try:
            conn = dial("127.0.0.1", port, attempts=5, backoff=0.05)
            conn.close()
        finally:
            t.join()
            holder["srv"].shutdown()

    def test_dial_refused_raises_after_bounded_attempts(self):
        probe = socket.socket()
        probe.bind(("127.0.0.1", 0))
        port = probe.getsockname()[1]
        probe.close()
        t0 = time.monotonic()
        with pytest.raises(ConnectionRefusedError):
            dial("127.0.0.1", port, attempts=2, backoff=0.01)
        assert time.monotonic() - t0 < 5.0


class TestIoModes:
    def test_threads_mode_still_serves(self):
        srv = InMemoryFlightServer(io_mode="threads").serve_tcp()
        srv.add_dataset("ds", make_batches(2))
        try:
            assert isinstance(srv._listener, SocketListener)
            client = FlightClient(f"tcp://127.0.0.1:{srv.port}")
            info = client.get_flight_info(FlightDescriptor.for_path("ds"))
            assert client.do_get(info.endpoints[0].ticket).read_all().num_rows == 400
            assert srv._listener.stats()["io_mode"] == "threads"
        finally:
            srv.shutdown()

    def test_eventloop_is_default_and_reports_stats(self, server):
        assert isinstance(server._listener, EventLoopListener)
        import json
        client = FlightClient(f"tcp://127.0.0.1:{server.port}")
        stats = json.loads(client.do_action("server-stats")[0].body)
        assert stats["io"]["io_mode"] == "eventloop"
        assert stats["io"]["workers"] == server._listener._workers

    def test_bad_io_mode_rejected(self):
        from repro.core.flight.errors import FlightError
        with pytest.raises(FlightError):
            InMemoryFlightServer(io_mode="fibers").serve_tcp()
